#!/usr/bin/env bash
# Tier-1 verify + lint gates + perf smoke.
#
# 1. cargo build --release && cargo test -q   (the repo's tier-1 gate)
# 2. lint gates:
#      - cargo fmt --check   (formatting drift; skipped if not installed)
#      - cargo clippy --all-targets -- -D warnings (skipped if not
#        installed)
#      - sasp lint            (the crate's own codebase-contract lints —
#        hot-loop allocation, GEMM attribution labels, atomic-ordering
#        justifications, serve-path panic-freedom, bitwise-contract
#        drift, crate hygiene — ratcheted against the committed
#        rust/lint-baseline.json: any fresh finding or stale baseline
#        entry is a hard failure; see rust/src/analysis/)
# 3. a short-budget run of benches/hotpath.rs with JSON recording
#    (BENCH_hotpath.json at the repo root — the machine-tracked perf
#    trajectory EXPERIMENTS.md logs across PRs)
# 4. same-run relative perf guards, so regressions fail loudly without
#    depending on absolute machine speed:
#      - the zero-alloc compute_into path must not be slower than the
#        allocating compute wrapper
#      - the parallel sweep must not be slower than the serial sweep
#        (equal is fine on a single core)
#      - the native engine's masked INT8 forward pass at 50% ff tile
#        sparsity must be measurably faster than its dense INT8 pass
#        (the functional SASP saving)
#      - the batched weight-stationary engine must beat the per-utterance
#        loop at batch 4, on both the FP32 and INT8 paths, at GEMM and
#        whole-encoder scope (the serving-runtime reuse win)
#      - the autoregressive MT decoder's KV-cache stepping must beat the
#        full-prefix recompute loop over 32 generated tokens, on both
#        the FP32 and INT8 paths (the decode-side caching win)
#      - continuous iteration-level batched decoding of 8 concurrent
#        utterances (one [8, d] weight-stationary panel per step) must
#        finish in <= 0.7x the sequential per-utterance decode's wall
#        time, on both the FP32 and INT8 paths (the continuous-batching
#        panel-reuse win)
#      - dynamic-batch serving sharded over 4 worker threads must beat
#        the single-threaded fixed-batch serving path on the same 16
#        queued utterances (the ISSUE-5 runtime scaling levers)
#      - the degradation-ladder serving run under 2x overload (32
#        pre-queued utts, dynamic batch 4) must keep its internal
#        Ok-latency p99 <= 0.8x the no-ladder run's (the ISSUE-6
#        graceful-degradation win)
#      - telemetry overhead on the serving hot path: with the
#        instrumentation compiled in but no recording session, the
#        fixed-batch serve case must stay <= 1.02x the uninstrumented
#        baseline (each site is one relaxed atomic load); with a live
#        recording session it must stay <= 1.10x (spans, metrics, and
#        per-iteration trace drain included)
# 5. the tail-batch stats regression (native serving must cost a tail
#    flush of 1 exactly one utterance — no slack work) re-run by name so
#    a regression fails loudly even if the tier-1 filter changes
# 6. the seeded fault-injection smoke (fixed seed, pinned retry/shed/
#    degrade counts) and the worker-panic containment regression, re-run
#    by name for the same reason
# 7. the telemetry histogram shard-merge property test (merged
#    multi-thread recording == single-thread recording), re-run by name
#    for the same reason
# 8. the occupancy cross-check property test (analytic per-tile
#    occupancy accounting == wavefront-simulated active-PE census on
#    random masks) and the utilization-report functional==analytic
#    cross-check, re-run by name for the same reason
# 8b. the bitwise-identity property tests of the two batched execution
#    paths — continuous iteration-level decode == sequential greedy on
#    random join/leave schedules, and the work-stealing sharded batch
#    forward == the single-threaded run on ragged batches — re-run by
#    name for the same reason
# 9. a bench-regression gate against the committed BENCH_hotpath.json:
#    when a baseline is present before the bench run, every case's fresh
#    median must stay within BENCH_REGRESSION_TOLERANCE (default 1.5x —
#    short budgets are noisy) of the committed median; with no committed
#    baseline the gate skips gracefully and this run's report becomes
#    the first baseline to commit
#
# Usage: scripts/verify.sh [--no-bench]

set -euo pipefail
cd "$(dirname "$0")/.."
ROOT="$PWD"

echo "== tier-1: cargo build --release && cargo test -q =="
(cd rust && cargo build --release && cargo test -q)

echo
echo "== lint gates: cargo fmt --check, cargo clippy -D warnings =="
if (cd rust && cargo fmt --version) >/dev/null 2>&1; then
    (cd rust && cargo fmt --check)
else
    echo "rustfmt component not installed; fmt gate skipped"
fi
if (cd rust && cargo clippy --version) >/dev/null 2>&1; then
    (cd rust && cargo clippy --all-targets -- -D warnings)
else
    echo "clippy component not installed; clippy gate skipped"
fi

echo
echo "== lint gate: sasp lint (codebase contracts, ratchet baseline) =="
(cd rust && cargo run --release --bin sasp -- lint)

echo
echo "== static-analysis regressions: lint engine + serve panic-freedom =="
(cd rust && cargo test -q lint_)
(cd rust && cargo test -q panicfree_)

echo
echo "== serve regression: tail-batch stats parity =="
(cd rust && cargo test -q tail_batch_native_stats_equal_standalone_batch_of_one)

echo
echo "== overload regressions: seeded fault smoke + worker-panic containment =="
(cd rust && cargo test -q seeded_fault_injection_smoke_pinned_counts)
(cd rust && cargo test -q batcher_survives_worker_panic)
(cd rust && cargo test -q contained_worker_panic_fails_only_its_shard)

echo
echo "== telemetry regression: histogram shard-merge property =="
(cd rust && cargo test -q histogram_shard_merge_equals_single_thread)

echo
echo "== observability regressions: occupancy cross-checks =="
(cd rust && cargo test -q occupancy_matches_wavefront_on_random_masks)
(cd rust && cargo test -q util_report_cross_checks_and_renders)

echo
echo "== batching regressions: bitwise-identity properties =="
(cd rust && cargo test -q prop_continuous_decode_bitwise_equals_sequential_greedy)
(cd rust && cargo test -q prop_sharded_forward_batch_bitwise_equals_single_thread)

if [[ "${1:-}" == "--no-bench" ]]; then
    echo "verify OK (bench smoke skipped)"
    exit 0
fi

echo
echo "== perf smoke: benches/hotpath.rs (short budget) =="
export BENCH_MEASURE_MS="${BENCH_MEASURE_MS:-150}"
export BENCH_WARMUP_MS="${BENCH_WARMUP_MS:-30}"
export BENCH_HOTPATH_JSON="$ROOT/BENCH_hotpath.json"
# Snapshot the committed baseline (if any) before the fresh run
# overwrites it — the regression gate below compares against it.
BENCH_BASELINE=""
if [[ -s "$BENCH_HOTPATH_JSON" ]]; then
    BENCH_BASELINE="$(mktemp)"
    cp "$BENCH_HOTPATH_JSON" "$BENCH_BASELINE"
fi
rm -f "$BENCH_HOTPATH_JSON"
(cd rust && cargo bench --bench hotpath)

if [[ ! -s "$BENCH_HOTPATH_JSON" ]]; then
    echo "FAIL: $BENCH_HOTPATH_JSON was not written" >&2
    exit 1
fi

if command -v python3 >/dev/null 2>&1; then
    python3 - "$BENCH_HOTPATH_JSON" <<'EOF'
import json, sys

cases = {c["name"]: c for c in json.load(open(sys.argv[1]))}

def median(name):
    if name not in cases:
        sys.exit(f"FAIL: bench case missing from report: {name!r}")
    return cases[name]["median_ns"]

compute = median("systolic: per-cycle 8x8 tile, M=32")
into = median("systolic: per-cycle 8x8 tile, M=32, compute_into")
serial = median("explorer: 24-point espnet_asr sweep, serial")
parallel = median("explorer: 24-point espnet_asr sweep, parallel")
inf_dense = median("infer: tiny_asr forward, int8 dense")
inf_pruned = median("infer: tiny_asr forward, int8 50% pruned")
g32p = median("infer: ff gemm 4x96x64x256 fp32, per-utterance")
g32b = median("infer: ff gemm 4x96x64x256 fp32, batched ws")
g8p = median("infer: ff gemm 4x96x64x256 int8, per-utterance")
g8b = median("infer: ff gemm 4x96x64x256 int8, batched ws")
e32p = median("infer: tiny_asr encoder fp32 25% pruned, per-utterance x4")
e32b = median("infer: tiny_asr encoder fp32 25% pruned, batched ws x4")
e8p = median("infer: tiny_asr encoder int8 25% pruned, per-utterance x4")
e8b = median("infer: tiny_asr encoder int8 25% pruned, batched ws x4")
d32c = median("infer: mt decode 32 steps fp32, kv-cache")
d32r = median("infer: mt decode 32 steps fp32, full-prefix recompute")
d8c = median("infer: mt decode 32 steps int8, kv-cache")
d8r = median("infer: mt decode 32 steps int8, full-prefix recompute")
c32s = median("infer: mt decode 8 utts fp32, sequential")
c32c = median("infer: mt decode 8 utts fp32, continuous 8 slots")
c8s = median("infer: mt decode 8 utts int8, sequential")
c8c = median("infer: mt decode 8 utts int8, continuous 8 slots")
sv1 = median("serve: 16 utts int8 25% pruned, fixed batch 4, 1 thread")
sv4 = median("serve: 16 utts int8 25% pruned, dynamic batch<=16, 4 threads")
toff = median("serve: 16 utts int8 25% pruned, fixed batch 4, telemetry off")
ton = median("serve: 16 utts int8 25% pruned, fixed batch 4, telemetry on")
ov0 = median("serve: 32 utts pre-queued overload, no ladder, p99")
ovl = median("serve: 32 utts pre-queued overload, degradation ladder, p99")

failures = []
# Short budgets are noisy; guard with generous slack.
if into > compute * 1.25:
    failures.append(
        f"compute_into ({into:.0f} ns) slower than compute ({compute:.0f} ns)")
if parallel > serial * 1.25:
    failures.append(
        f"parallel sweep ({parallel/1e6:.2f} ms) slower than serial "
        f"({serial/1e6:.2f} ms)")
# 50% ff tile sparsity removes ~half the feed-forward MACs (~53% of the
# tiny model's total); require at least a 8% wall-clock win.
if inf_pruned > inf_dense * 0.92:
    failures.append(
        f"masked int8 forward ({inf_pruned/1e6:.2f} ms) not measurably "
        f"faster than dense ({inf_dense/1e6:.2f} ms) at 50% sparsity")
# Batched weight-stationary serving vs the per-utterance loop (batch 4):
# each live tile is packed/dequantized once per batch instead of being
# re-read (INT8: re-table-looked-up) per utterance per MAC. Required to
# beat per-utterance on both formats; the INT8 GEMM margin is largest.
for name, batched, per_utt, slack in [
    ("fp32 batched gemm", g32b, g32p, 0.95),
    ("int8 batched gemm", g8b, g8p, 0.92),
    ("fp32 batched encoder", e32b, e32p, 0.97),
    ("int8 batched encoder", e8b, e8p, 0.95),
]:
    if batched > per_utt * slack:
        failures.append(
            f"{name} ({batched/1e6:.2f} ms) not faster than per-utterance "
            f"({per_utt/1e6:.2f} ms) at batch 4 (required <= {slack}x)")
# KV-cache decode vs full-prefix recompute over 32 tokens: the cached
# step touches one row per GEMV while the recompute loop re-runs the
# whole growing prefix (~16x more row-passes); require a clear win.
for name, cached, recompute in [
    ("fp32 kv-cache decode", d32c, d32r),
    ("int8 kv-cache decode", d8c, d8r),
]:
    if cached > recompute * 0.6:
        failures.append(
            f"{name} ({cached/1e6:.2f} ms) not faster than full-prefix "
            f"recompute ({recompute/1e6:.2f} ms) over 32 steps "
            f"(required <= 0.6x)")
# Continuous iteration-level batching vs sequential per-utterance
# decode over the same 8 utterances (identical tokens, shared
# precomputed cross-K/V): each step packs 8 GEMV rows onto one
# weight-stationary tile pass, so each live tile is loaded (INT8:
# dequantized) once per step instead of 8 times.
for name, continuous, sequential in [
    ("fp32 continuous decode", c32c, c32s),
    ("int8 continuous decode", c8c, c8s),
]:
    if continuous > sequential * 0.7:
        failures.append(
            f"{name} ({continuous/1e6:.2f} ms) not faster than sequential "
            f"per-utterance decode ({sequential/1e6:.2f} ms) over 8 utts "
            f"(required <= 0.7x)")
# Dynamic-batch serving sharded over 4 worker threads vs the
# single-threaded fixed-batch path on the same 16 queued utterances:
# thread sharding parallelizes the forward work across cores, so on a
# multi-core host require a clear wall-clock win; on a single core the
# shards only add spawn/join overhead, so (like the parallel-sweep
# guard) only require it not be slower.
import os
serve_slack = 0.95 if (os.cpu_count() or 1) >= 2 else 1.25
if sv4 > sv1 * serve_slack:
    failures.append(
        f"dynamic 4-thread serving ({sv4/1e6:.2f} ms) vs fixed-batch "
        f"single-thread ({sv1/1e6:.2f} ms) over 16 utts "
        f"(required <= {serve_slack}x at {os.cpu_count() or 1} cores)")
# Telemetry overhead on the identical fixed-batch serve workload: with
# no recording session every instrumentation site costs one relaxed
# atomic load, so the run must stay within 2% of the uninstrumented
# baseline; a live recording session (spans + metrics + per-iteration
# trace drain) gets 10%.
if toff > sv1 * 1.02:
    failures.append(
        f"telemetry-off serving ({toff/1e6:.2f} ms) > 1.02x the "
        f"uninstrumented baseline ({sv1/1e6:.2f} ms)")
if ton > sv1 * 1.10:
    failures.append(
        f"telemetry-on serving ({ton/1e6:.2f} ms) > 1.10x the "
        f"uninstrumented baseline ({sv1/1e6:.2f} ms)")
# Graceful degradation under 2x overload: stepping the backend from 25%
# to 90% pruning after the first flush drains the 32-deep backlog much
# faster, so the queue-wait-dominated Ok-latency p99 must drop to at
# most 0.8x the fixed-operating-point run's.
if ovl > ov0 * 0.8:
    failures.append(
        f"degradation-ladder overload p99 ({ovl/1e6:.2f} ms) not <= 0.8x "
        f"the no-ladder run ({ov0/1e6:.2f} ms)")

print(f"systolic per-cycle 8x8 M=32:  {compute/1e3:.1f} us median")
print(f"  .. compute_into:            {into/1e3:.1f} us median")
print(f"24-point sweep serial:        {serial/1e6:.2f} ms median")
print(f"  .. parallel:                {parallel/1e6:.2f} ms median")
print(f"native int8 forward, dense:   {inf_dense/1e6:.2f} ms median")
print(f"  .. 50% ff tiles pruned:     {inf_pruned/1e6:.2f} ms median")
print(f"ff gemm fp32 per-utt x4:      {g32p/1e6:.2f} ms median")
print(f"  .. batched ws:              {g32b/1e6:.2f} ms median")
print(f"ff gemm int8 per-utt x4:      {g8p/1e6:.2f} ms median")
print(f"  .. batched ws:              {g8b/1e6:.2f} ms median")
print(f"encoder fp32 per-utt x4:      {e32p/1e6:.2f} ms median")
print(f"  .. batched ws:              {e32b/1e6:.2f} ms median")
print(f"encoder int8 per-utt x4:      {e8p/1e6:.2f} ms median")
print(f"  .. batched ws:              {e8b/1e6:.2f} ms median")
print(f"mt decode fp32 recompute:     {d32r/1e6:.2f} ms median")
print(f"  .. kv-cache:                {d32c/1e6:.2f} ms median")
print(f"mt decode int8 recompute:     {d8r/1e6:.2f} ms median")
print(f"  .. kv-cache:                {d8c/1e6:.2f} ms median")
print(f"mt decode 8 utts fp32 seq:    {c32s/1e6:.2f} ms median")
print(f"  .. continuous 8 slots:      {c32c/1e6:.2f} ms median")
print(f"mt decode 8 utts int8 seq:    {c8s/1e6:.2f} ms median")
print(f"  .. continuous 8 slots:      {c8c/1e6:.2f} ms median")
print(f"serve 16 utts fixed b4 1t:    {sv1/1e6:.2f} ms median")
print(f"  .. dynamic b<=16 4t:        {sv4/1e6:.2f} ms median")
print(f"  .. telemetry off:           {toff/1e6:.2f} ms median")
print(f"  .. telemetry on:            {ton/1e6:.2f} ms median")
print(f"overload 32 utts p99:         {ov0/1e6:.2f} ms no ladder")
print(f"  .. degradation ladder:      {ovl/1e6:.2f} ms")
for f in failures:
    print("FAIL:", f, file=sys.stderr)
if failures:
    sys.exit(1)
EOF
else
    echo "python3 not found; skipping relative perf guards"
fi

echo
echo "== bench-regression gate: fresh medians vs committed baseline =="
if [[ -z "$BENCH_BASELINE" ]]; then
    echo "no committed BENCH_hotpath.json baseline; gate skipped" \
         "(commit $BENCH_HOTPATH_JSON to arm it)"
elif command -v python3 >/dev/null 2>&1; then
    python3 - "$BENCH_BASELINE" "$BENCH_HOTPATH_JSON" \
        "${BENCH_REGRESSION_TOLERANCE:-1.5}" <<'EOF'
import json, sys

base = {c["name"]: c["median_ns"] for c in json.load(open(sys.argv[1]))}
cur = {c["name"]: c["median_ns"] for c in json.load(open(sys.argv[2]))}
tol = float(sys.argv[3])

failures = []
compared = 0
for name in sorted(base):
    if name not in cur:
        print(f"note: baseline case no longer benched: {name!r}")
        continue
    compared += 1
    if cur[name] > base[name] * tol:
        failures.append(
            f"{name}: {cur[name]/1e6:.2f} ms vs baseline "
            f"{base[name]/1e6:.2f} ms (> {tol}x)")
for name in sorted(set(cur) - set(base)):
    print(f"note: new bench case (no baseline): {name!r}")

print(f"{compared} cases within {tol}x of the committed baseline"
      if not failures else f"{len(failures)} of {compared} cases regressed:")
for f in failures:
    print("FAIL:", f, file=sys.stderr)
if failures:
    sys.exit(1)
EOF
else
    echo "python3 not found; bench-regression gate skipped"
fi
[[ -n "$BENCH_BASELINE" ]] && rm -f "$BENCH_BASELINE"

echo
echo "verify OK — perf report: $BENCH_HOTPATH_JSON"
