//! End-to-end driver: the full SASP pipeline on the ASR model.
//!
//! With compiled artifacts present (`make artifacts`), runs the trained
//! encoder through PJRT exactly as before. Without them — the fresh
//! checkout / tier-1 case — it runs **fully offline** on the native
//! engine ([`sasp::infer`]): a deterministic synthetic model is written
//! through the `tensorfile` weight format, the test set is labeled by
//! the dense FP32 forward pass itself (baseline WER 0 by construction),
//! and the pruning-rate sweep executes with true tile skipping through
//! the INT8 sign-magnitude kernels, cross-checked against the analytic
//! timing model on the paper's headline configuration.
//!
//! Run: `cargo run --release --example asr_pipeline [artifacts_dir]`.

use anyhow::Result;

use sasp::coordinator::Explorer;
use sasp::data::{load_bundle, save_bundle};
use sasp::infer::{synth_testset, synth_weights, EncoderWeights, ModelDims, NativeBackend};
use sasp::model::zoo;
use sasp::qos::{AsrEvaluator, EvalMeta};
use sasp::runtime::Engine;
use sasp::systolic::Quant;
use sasp::util::json::Json;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/asr_encoder_ref.hlo.txt")).exists() {
        pjrt_pipeline(&dir)
    } else {
        println!("no PJRT artifacts under '{dir}' — running the native engine offline\n");
        native_pipeline(&dir)
    }
}

/// The artifact-backed pipeline (unchanged behaviour).
fn pjrt_pipeline(dir: &str) -> Result<()> {
    // --- training provenance -------------------------------------------
    if let Ok(log) = std::fs::read_to_string(format!("{dir}/train_log_asr.json")) {
        let v = Json::parse(&log)?;
        let entries = v.as_arr().unwrap_or(&[]).to_vec();
        println!("training loss curve (from python build step):");
        for e in entries.iter().filter(|e| e.get("loss").as_f64().is_some()) {
            let step = e.get("step").as_i64().unwrap_or(-1);
            if step % 250 == 0 {
                println!("  step {:>5}  loss {:>8.3}", step, e.get("loss").as_f64().unwrap());
            }
        }
    }

    let mut engine = Engine::new(dir)?;
    let eval = AsrEvaluator::new(&mut engine, dir, "asr_encoder_ref")?;
    println!("\ntest set: {} utterances", eval.n_utts());
    let base = eval.evaluate(&mut engine, 32, 0.0, Quant::Fp32)?;
    println!("baseline WER (FP32, unpruned): {:.4}", base.qos);

    println!("\nSASP sweep @ 32x32 FP32_INT8 (the headline configuration):");
    println!(
        "{:>6} {:>10} {:>10} {:>12} {:>12} {:>12}",
        "rate", "WER", "ΔWER", "speedup*", "vs dense", "energy J*"
    );
    let ex = Explorer::new(zoo::espnet_asr());
    let dense_fp32 = ex.timing_point(32, Quant::Fp32, 0.0);
    for rate in [0.0, 0.05, 0.10, 0.15, 0.20, 0.25, 0.30, 0.40] {
        let q = eval.evaluate(&mut engine, 32, rate, Quant::Int8)?;
        let t = ex.timing_point(32, Quant::Int8, rate);
        println!(
            "{:>6.2} {:>10.4} {:>+10.4} {:>12.2} {:>11.1}% {:>12.4}",
            rate,
            q.qos,
            q.qos - base.qos,
            t.speedup_vs_cpu,
            (t.speedup_vs_dense - 1.0) * 100.0,
            t.energy_j
        );
    }

    let q20 = eval.evaluate(&mut engine, 32, 0.20, Quant::Int8)?;
    let t20 = ex.timing_point(32, Quant::Int8, 0.20);
    headline(
        dense_fp32.speedup_vs_cpu,
        t20.speedup_vs_cpu,
        t20.energy_j,
        dense_fp32.energy_j,
        q20.qos - base.qos,
    );
    println!("asr_pipeline OK");
    Ok(())
}

/// The offline pipeline: synthetic tiny model through the native engine.
fn native_pipeline(dir: &str) -> Result<()> {
    let dims = ModelDims::tiny_asr();

    // Weights flow through the real tensorfile format, exactly like the
    // trained bundles would.
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/native_params_asr.bin");
    save_bundle(&path, &synth_weights(&dims, 7).to_bundle())?;
    let params = load_bundle(&path)?;
    let weights = EncoderWeights::from_bundle(dims, &params)?;
    println!("synthetic tiny ASR model written + reloaded via {path}");

    let batch = 4usize;
    let testset = synth_testset(&weights, 16, 11)?;
    let meta = EvalMeta {
        n_blocks: dims.n_blocks,
        batch,
        vocab: dims.vocab,
        blank: dims.ctc_blank,
        tile_hint: dims.tile,
    };
    let eval = AsrEvaluator::from_parts("native", params, &testset, &meta)?;
    let mut backend = NativeBackend::new(weights, batch)?;
    println!("test set: {} utterances (teacher-labeled)", eval.n_utts());

    let base = eval.evaluate_with(&mut backend, 32, 0.0, Quant::Fp32)?;
    println!("baseline WER (FP32, unpruned): {:.4}", base.qos);

    println!("\nSASP sweep @ 32x32 FP32_INT8 (the headline configuration):");
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "rate", "WER", "ΔWER", "ff skip%", "speedup*", "energy J*"
    );
    // Timing from the Table-1 ESPnet workload on the simulated platform;
    // the functional engine cross-reports the tile skipping it executed.
    // The paper's headline pruning rate; the sweep must include it so
    // the headline row and cross-check below read from captured stats.
    const HEADLINE_RATE: f64 = 0.20;
    let ex = Explorer::new(zoo::espnet_asr());
    let dense_fp32 = ex.timing_point(32, Quant::Fp32, 0.0);
    let mut q20 = base.qos;
    let mut achieved20 = 0.0f64;
    let mut dense_macs = 0usize;
    let mut pruned_macs = 0usize;
    for rate in [0.0, 0.05, 0.10, 0.15, HEADLINE_RATE, 0.25, 0.30, 0.40] {
        backend.reset_stats();
        let q = eval.evaluate_with(&mut backend, 32, rate, Quant::Int8)?;
        let st = backend.stats();
        let t = ex.timing_point(32, Quant::Int8, rate);
        println!(
            "{:>6.2} {:>10.4} {:>+10.4} {:>9.1}% {:>12.2} {:>12.4}",
            rate,
            q.qos,
            q.qos - base.qos,
            st.ff.sparsity() * 100.0,
            t.speedup_vs_cpu,
            t.energy_j
        );
        if rate == 0.0 {
            dense_macs = st.ff.timing.macs;
        }
        if rate == HEADLINE_RATE {
            q20 = q.qos;
            achieved20 = q.achieved_rate;
            pruned_macs = st.ff.timing.macs;
        }
    }
    assert!(pruned_macs > 0, "sweep must include the headline rate");

    // Analytic x functional cross-check at the headline rate: the MAC
    // reduction the native engine actually executed must equal the rate
    // the pruning plan achieved (equal-cost tiles: skipping is exactly
    // proportional).
    let measured = 1.0 - pruned_macs as f64 / dense_macs as f64;
    println!(
        "\ncross-check: functional ff MAC reduction at the headline rate: \
         {:.2}% (pruning plan achieved: {:.2}%)",
        measured * 100.0,
        achieved20 * 100.0
    );
    assert!(
        (measured - achieved20).abs() < 1e-9,
        "functional/analytic mismatch: {measured} vs {achieved20}"
    );

    let t20 = ex.timing_point(32, Quant::Int8, 0.20);
    headline(
        dense_fp32.speedup_vs_cpu,
        t20.speedup_vs_cpu,
        t20.energy_j,
        dense_fp32.energy_j,
        q20 - base.qos,
    );
    println!("asr_pipeline OK (native engine, no PJRT)");
    Ok(())
}

fn headline(dense_speedup: f64, sasp_speedup: f64, sasp_j: f64, dense_j: f64, dwer: f64) {
    let runtime_gain = 1.0 - dense_speedup / sasp_speedup;
    let energy_gain = 1.0 - sasp_j / dense_j;
    println!("\nheadline (SASP 20% + INT8 vs non-pruned non-quantized, 32x32):");
    println!(
        "  runtime -{:.1}% (paper: up to 44%), energy -{:.1}% (paper: 42%), \
         ΔWER {:+.4} (paper: +1.4% absolute)",
        runtime_gain * 100.0,
        energy_gain * 100.0,
        dwer
    );
}
