//! End-to-end driver: the full SASP pipeline on the trained ASR model.
//!
//! Loads the trained encoder (Layer 2 artifact + weights), shows the
//! training loss curve, measures baseline WER through PJRT, then sweeps
//! pruning rates at the paper's headline configuration (32x32, INT8) and
//! prints the combined QoS / runtime / energy picture — the repository's
//! reproduction of the paper's headline claim (44% speedup, 42% energy,
//! +1.4% WER at 20% pruning).
//!
//! Run: `cargo run --release --example asr_pipeline` (after `make artifacts`).

use anyhow::Result;

use sasp::coordinator::Explorer;
use sasp::model::zoo;
use sasp::qos::AsrEvaluator;
use sasp::runtime::Engine;
use sasp::systolic::Quant;
use sasp::util::json::Json;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // --- training provenance -------------------------------------------
    if let Ok(log) = std::fs::read_to_string(format!("{dir}/train_log_asr.json")) {
        let v = Json::parse(&log)?;
        let entries = v.as_arr().unwrap_or(&[]).to_vec();
        println!("training loss curve (from python build step):");
        for e in entries.iter().filter(|e| e.get("loss").as_f64().is_some()) {
            let step = e.get("step").as_i64().unwrap_or(-1);
            if step % 250 == 0 {
                println!("  step {:>5}  loss {:>8.3}", step,
                         e.get("loss").as_f64().unwrap());
            }
        }
    }

    // --- QoS through PJRT ------------------------------------------------
    let mut engine = Engine::new(&dir)?;
    let eval = AsrEvaluator::new(&mut engine, &dir, "asr_encoder_ref")?;
    println!("\ntest set: {} utterances", eval.n_utts());
    let base = eval.evaluate(&mut engine, 32, 0.0, Quant::Fp32)?;
    println!("baseline WER (FP32, unpruned): {:.4}", base.qos);

    println!("\nSASP sweep @ 32x32 FP32_INT8 (the headline configuration):");
    println!(
        "{:>6} {:>10} {:>10} {:>12} {:>12} {:>12}",
        "rate", "WER", "ΔWER", "speedup*", "vs dense", "energy J*"
    );
    // Timing from the Table-1 ESPnet workload on the simulated platform.
    let ex = Explorer::new(zoo::espnet_asr());
    let dense_fp32 = ex.timing_point(32, Quant::Fp32, 0.0);
    for rate in [0.0, 0.05, 0.10, 0.15, 0.20, 0.25, 0.30, 0.40] {
        let q = eval.evaluate(&mut engine, 32, rate, Quant::Int8)?;
        let t = ex.timing_point(32, Quant::Int8, rate);
        println!(
            "{:>6.2} {:>10.4} {:>+10.4} {:>12.2} {:>11.1}% {:>12.4}",
            rate,
            q.qos,
            q.qos - base.qos,
            t.speedup_vs_cpu,
            (t.speedup_vs_dense - 1.0) * 100.0,
            t.energy_j
        );
    }

    // --- headline row -----------------------------------------------------
    let q20 = eval.evaluate(&mut engine, 32, 0.20, Quant::Int8)?;
    let t20 = ex.timing_point(32, Quant::Int8, 0.20);
    let runtime_gain = 1.0 - dense_fp32.speedup_vs_cpu / t20.speedup_vs_cpu;
    let energy_gain = 1.0 - t20.energy_j / dense_fp32.energy_j;
    println!("\nheadline (SASP 20% + INT8 vs non-pruned non-quantized, 32x32):");
    println!(
        "  runtime -{:.1}% (paper: up to 44%), energy -{:.1}% (paper: 42%), \
         ΔWER {:+.4} (paper: +1.4% absolute)",
        runtime_gain * 100.0,
        energy_gain * 100.0,
        q20.qos - base.qos
    );
    println!("asr_pipeline OK");
    Ok(())
}
