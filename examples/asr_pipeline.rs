//! End-to-end driver: the full SASP pipeline on the ASR model.
//!
//! Backend selection is [`Backend::auto`] — one entry point for every
//! serving surface. With compiled artifacts present (`make artifacts`),
//! the trained encoder runs through PJRT exactly as before. Without
//! them — the fresh checkout / tier-1 case — the batched
//! weight-stationary native engine runs **fully offline**: a
//! deterministic synthetic tiny model, a test set labeled by the dense
//! FP32 forward pass itself (baseline WER 0 by construction), and the
//! pruning-rate sweep executing with true tile skipping through the
//! INT8 sign-magnitude kernels, cross-checked against the analytic
//! timing model at the paper's headline configuration.
//!
//! Run: `cargo run --release --example asr_pipeline [artifacts_dir]`.

use anyhow::Result;

use sasp::coordinator::serve::Backend;
use sasp::coordinator::Explorer;
use sasp::model::zoo;
use sasp::systolic::Quant;
use sasp::util::json::Json;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let mut backend = Backend::auto(&dir)?;
    println!("execution backend: {}", backend.describe());

    // Training provenance (PJRT builds only — the python build step).
    if !backend.is_native() {
        if let Ok(log) = std::fs::read_to_string(format!("{dir}/train_log_asr.json")) {
            let v = Json::parse(&log)?;
            let entries = v.as_arr().unwrap_or(&[]).to_vec();
            println!("training loss curve (from python build step):");
            for e in entries.iter().filter(|e| e.get("loss").as_f64().is_some()) {
                let step = e.get("step").as_i64().unwrap_or(-1);
                if step % 250 == 0 {
                    println!(
                        "  step {:>5}  loss {:>8.3}",
                        step,
                        e.get("loss").as_f64().unwrap()
                    );
                }
            }
        }
    }

    let eval = backend.asr_evaluator(&dir, 16)?;
    println!("\ntest set: {} utterances", eval.n_utts());
    let base = eval.evaluate_with(&mut backend, 32, 0.0, Quant::Fp32)?;
    println!("baseline WER (FP32, unpruned): {:.4}", base.qos);

    println!("\nSASP sweep @ 32x32 FP32_INT8 (the headline configuration):");
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>12} {:>12}",
        "rate", "WER", "ΔWER", "ff skip%", "speedup*", "energy J*"
    );
    // Timing from the Table-1 ESPnet workload on the simulated platform;
    // the native engine additionally cross-reports the tile skipping it
    // actually executed. The sweep must include the paper's headline
    // pruning rate so the headline row and cross-check read from
    // captured stats.
    const HEADLINE_RATE: f64 = 0.20;
    let ex = Explorer::new(zoo::espnet_asr());
    let dense_fp32 = ex.timing_point(32, Quant::Fp32, 0.0);
    let mut q20 = base.qos;
    let mut achieved20 = 0.0f64;
    let mut dense_macs = 0usize;
    let mut pruned_macs = 0usize;
    for rate in [0.0, 0.05, 0.10, 0.15, HEADLINE_RATE, 0.25, 0.30, 0.40] {
        if let Some(nb) = backend.native_mut() {
            nb.reset_stats();
        }
        let q = eval.evaluate_with(&mut backend, 32, rate, Quant::Int8)?;
        let t = ex.timing_point(32, Quant::Int8, rate);
        let (skip_col, ff_macs) = match backend.native_mut() {
            Some(nb) => {
                let st = nb.stats();
                (format!("{:>9.1}%", st.ff.sparsity() * 100.0), st.ff.timing.macs)
            }
            None => (format!("{:>10}", "-"), 0),
        };
        println!(
            "{:>6.2} {:>10.4} {:>+10.4} {} {:>12.2} {:>12.4}",
            rate,
            q.qos,
            q.qos - base.qos,
            skip_col,
            t.speedup_vs_cpu,
            t.energy_j
        );
        if rate == 0.0 {
            dense_macs = ff_macs;
        }
        if rate == HEADLINE_RATE {
            q20 = q.qos;
            achieved20 = q.achieved_rate;
            pruned_macs = ff_macs;
        }
    }

    if backend.is_native() {
        // Analytic x functional cross-check at the headline rate: the
        // MAC reduction the batched engine actually executed must equal
        // the rate the pruning plan achieved (equal-cost tiles: skipping
        // is exactly proportional).
        assert!(pruned_macs > 0, "sweep must include the headline rate");
        let measured = 1.0 - pruned_macs as f64 / dense_macs as f64;
        println!(
            "\ncross-check: functional ff MAC reduction at the headline rate: \
             {:.2}% (pruning plan achieved: {:.2}%)",
            measured * 100.0,
            achieved20 * 100.0
        );
        assert!(
            (measured - achieved20).abs() < 1e-9,
            "functional/analytic mismatch: {measured} vs {achieved20}"
        );
    }

    let t20 = ex.timing_point(32, Quant::Int8, HEADLINE_RATE);
    headline(
        dense_fp32.speedup_vs_cpu,
        t20.speedup_vs_cpu,
        t20.energy_j,
        dense_fp32.energy_j,
        q20 - base.qos,
    );
    println!("asr_pipeline OK ({} backend)", backend.label());
    Ok(())
}

fn headline(dense_speedup: f64, sasp_speedup: f64, sasp_j: f64, dense_j: f64, dwer: f64) {
    let runtime_gain = 1.0 - dense_speedup / sasp_speedup;
    let energy_gain = 1.0 - sasp_j / dense_j;
    println!("\nheadline (SASP 20% + INT8 vs non-pruned non-quantized, 32x32):");
    println!(
        "  runtime -{:.1}% (paper: up to 44%), energy -{:.1}% (paper: 42%), \
         ΔWER {:+.4} (paper: +1.4% absolute)",
        runtime_gain * 100.0,
        energy_gain * 100.0,
        dwer
    );
}
