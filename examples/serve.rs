//! Batched inference serving: the pruned model deployed behind a request
//! queue — latency/throughput on the real PJRT execution path.
//!
//! A producer thread generates synthetic utterances at a Poisson-ish
//! arrival rate; the server core batches them (fixed batch, deadline
//! flush) and runs the compiled encoder. Reports p50/p95 latency,
//! throughput and batch fill.
//!
//! Run: `cargo run --release --example serve [artifacts] [n_requests]`.

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::Result;

use sasp::coordinator::serve::{Request, ServeConfig, Server};
use sasp::data::load_bundle;
use sasp::runtime::Engine;
use sasp::util::rng::Rng;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n_requests: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(128);

    let mut engine = Engine::new(&dir)?;
    let params = load_bundle(format!("{dir}/params_asr.bin"))?;
    let manifest = engine.load("asr_encoder_ref")?.manifest.clone();
    let (t, f) = (manifest.model.seq_len, 40usize);

    let mut server = Server::new(
        &mut engine,
        "asr_encoder_ref",
        params,
        ServeConfig { batch: manifest.model.batch, max_wait: Duration::from_millis(5) },
    )?;

    let (req_tx, req_rx) = mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = mpsc::channel();

    // Producer: synthetic utterances, ~2 ms mean inter-arrival.
    let producer = thread::spawn(move || {
        let mut rng = Rng::new(42);
        for id in 0..n_requests as u64 {
            let feat_len = rng.index(t - 20) + 20;
            let feats: Vec<f32> =
                (0..t * f).map(|_| rng.normal() as f32 * 0.5).collect();
            let _ = req_tx.send(Request { id, feats, feat_len });
            thread::sleep(Duration::from_micros(500 + rng.index(3000) as u64));
        }
        // Dropping req_tx closes the queue and drains the server.
    });

    let report = server.run(&mut engine, req_rx, resp_tx)?;
    producer.join().unwrap();

    let responses: Vec<_> = resp_rx.try_iter().collect();
    println!("served {} responses in {} batches", responses.len(), report.n_batches);
    println!(
        "latency p50 {:?}  p95 {:?}  | mean batch fill {:.1}/{} | throughput {:.1} req/s",
        report.p50,
        report.p95,
        report.mean_batch_fill,
        server.cfg.batch,
        report.throughput_rps
    );
    assert_eq!(report.n_requests, n_requests);
    println!("serve OK");
    Ok(())
}
