//! Batched inference serving: the pruned model deployed behind a request
//! queue — latency/throughput on a real execution path.
//!
//! A producer thread generates synthetic utterances at a Poisson-ish
//! arrival rate; the server core batches them under the backend's
//! natural flush policy and runs the encoder. Backend selection is
//! [`Backend::auto`] — the one selection path every serving surface
//! shares: the PJRT engine when compiled artifacts exist (fixed-batch
//! flushes, zeroed slack rows accounted in the report), otherwise the
//! batched weight-stationary native engine serving a 25%-pruned INT8
//! configuration fully offline with **dynamic batching** (each flush
//! executes exactly the queued utterances) sharded across worker
//! threads.
//!
//! The queue is overload-safe (ISSUE 6): admission is bounded with
//! deadline-aware shedding, every request carries a deadline, and on the
//! native path a graceful-degradation ladder steps the operating point
//! to a cheaper pruning rate under sustained queue pressure, recovering
//! hysteretically once the backlog drains.
//!
//! Run: `cargo run --release --example serve [artifacts] [n_requests] [threads]`.

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use sasp::coordinator::resilience::{
    LadderConfig, OperatingPoint, ResilienceConfig, ShedPolicy,
};
use sasp::coordinator::serve::{Backend, Request, ServeBackend, ServeConfig, Server};
use sasp::systolic::Quant;
use sasp::util::rng::Rng;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n_requests: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(128);
    let threads: usize = std::env::args()
        .nth(3)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });

    let mut backend = Backend::auto(&dir)?;
    if let Some(nb) = backend.native_mut() {
        // The deployed offline configuration: 25% SASP at the artifact
        // tile, INT8 sign-magnitude kernels.
        let tile = nb.dims().tile;
        let plan = nb.prepare(tile, 0.25, Quant::Int8)?;
        println!(
            "no PJRT artifacts under '{dir}' — {:.0}% of ff tiles pruned for native serving",
            plan.achieved_rate * 100.0
        );
    }
    println!("backend: {}", backend.describe());

    let (manifest, params, artifact) = backend.serve_parts(&dir)?;
    let batch = manifest.model.batch;
    let t = manifest.model.seq_len;
    let feats_idx = manifest
        .arg_index("feats")
        .context("serving manifest has no 'feats' argument")?;
    let f = *manifest.args[feats_idx]
        .shape
        .last()
        .context("feats argument has no shape")?;
    // The native engine takes any batch, so it serves dynamic flushes
    // (up to 4x the manifest batch) sharded across worker threads; the
    // fixed-shape PJRT artifact keeps fixed batches on one thread.
    let cfg = if backend.is_native() {
        ServeConfig::dynamic(4 * batch, threads)
    } else {
        ServeConfig::fixed(batch, Duration::from_millis(5))
    };
    println!(
        "flush policy: {:?}, max batch {}, {} worker thread(s)",
        cfg.flush, cfg.max_batch, cfg.threads
    );
    let mut server = Server::with_manifest(&manifest, &artifact, params, cfg)?;
    // Overload safety: bound the queue at 16x the flush size and shed
    // the least-viable request first; the native backend additionally
    // arms the degradation ladder (25% -> 50% -> 75% pruning, INT8) so
    // sustained pressure trades a little QoS for queue drain speed.
    let mut res =
        ResilienceConfig::bounded(16 * server.cfg.max_batch, ShedPolicy::DeadlineAware);
    if backend.is_native() {
        res = res.with_ladder(LadderConfig::new(vec![
            OperatingPoint::new(0.25, Quant::Int8),
            OperatingPoint::new(0.5, Quant::Int8),
            OperatingPoint::new(0.75, Quant::Int8),
        ]));
    }
    server.set_resilience(res);
    drive(&mut server, &mut backend, t, f, n_requests)?;

    if let Some(nb) = backend.native_mut() {
        let st = nb.stats();
        // Dynamic batching executes exactly the queued rows, so the
        // utterance count equals the request count — no slack work.
        println!(
            "native schedule: {} forward rows (exactly the requests served), \
             {} ff tiles skipped ({:.0}% of ff schedule)",
            st.utterances,
            st.ff.tiles_skipped,
            st.ff.sparsity() * 100.0
        );
        // Weight-stationary reuse: every live ff tile is programmed
        // once per flushed shard, not once per utterance row.
        println!(
            "ff weight programming: {} bus words (charged once per \
             flushed shard, not once per utterance)",
            st.ff.timing.prog_words
        );
    }
    Ok(())
}

/// Shared producer + serving loop over any backend.
fn drive(
    server: &mut Server,
    backend: &mut impl ServeBackend,
    t: usize,
    f: usize,
    n_requests: usize,
) -> Result<()> {
    let (req_tx, req_rx) = mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = mpsc::channel();

    // Producer: synthetic utterances, ~2 ms mean inter-arrival, each
    // with a generous 250 ms deadline (stamped at creation — the
    // admission queue sheds or expires whatever cannot make it).
    let producer = thread::spawn(move || {
        let mut rng = Rng::new(42);
        for id in 0..n_requests as u64 {
            let feat_len = rng.index(t - 20) + 20;
            let feats: Vec<f32> = (0..t * f).map(|_| rng.normal() as f32 * 0.5).collect();
            let _ = req_tx.send(Request::with_deadline(
                id,
                feats,
                feat_len,
                Duration::from_millis(250),
            ));
            thread::sleep(Duration::from_micros(500 + rng.index(3000) as u64));
        }
        // Dropping req_tx closes the queue and drains the server.
    });

    let report = server.run(backend, req_rx, resp_tx)?;
    producer.join().unwrap();

    let responses: Vec<_> = resp_rx.try_iter().collect();
    println!("served {} responses in {} batches", responses.len(), report.n_batches);
    println!(
        "latency p50 {:?}  p95 {:?}  | mean batch fill {:.1}/{} | throughput {:.1} req/s \
         | slack rows {}",
        report.p50,
        report.p95,
        report.mean_batch_fill,
        server.cfg.max_batch,
        report.throughput_rps,
        report.slack_rows
    );
    println!(
        "overload: {} on-time ({:.1} goodput req/s) | shed {} expired {} failed {} \
         | retries {} breaker trips {} | ladder down {} up {}",
        report.on_time,
        report.goodput_rps,
        report.shed,
        report.expired,
        report.failed,
        report.retries,
        report.breaker_trips,
        report.degrade_steps,
        report.recover_steps
    );
    for o in &report.outcomes {
        println!(
            "  outcome {:?}: {} requests, p50 {:?} p95 {:?} p99 {:?}",
            o.outcome, o.count, o.p50, o.p95, o.p99
        );
    }
    // Every request lands in exactly one outcome bucket; exactly one
    // response per request either way.
    assert_eq!(responses.len(), n_requests);
    assert_eq!(
        report.n_requests + report.shed + report.expired + report.invalid + report.failed,
        n_requests
    );
    println!("serve OK");
    Ok(())
}
