//! Batched inference serving: the pruned model deployed behind a request
//! queue — latency/throughput on a real execution path.
//!
//! A producer thread generates synthetic utterances at a Poisson-ish
//! arrival rate; the server core batches them (fixed batch, deadline
//! flush) and runs the encoder. With compiled artifacts present the
//! backend is the PJRT engine; otherwise the native engine serves a
//! 25%-pruned INT8 configuration fully offline — the multi-backend
//! serving path.
//!
//! Run: `cargo run --release --example serve [artifacts] [n_requests]`.

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::Result;

use sasp::coordinator::serve::{Request, ServeBackend, ServeConfig, Server};
use sasp::data::{load_bundle, Bundle};
use sasp::infer::{synth_weights, ModelDims, NativeBackend};
use sasp::runtime::Engine;
use sasp::systolic::Quant;
use sasp::util::rng::Rng;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n_requests: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(128);

    if std::path::Path::new(&format!("{dir}/asr_encoder_ref.hlo.txt")).exists() {
        let mut engine = Engine::new(&dir)?;
        let params = load_bundle(format!("{dir}/params_asr.bin"))?;
        let manifest = engine.load("asr_encoder_ref")?.manifest.clone();
        let batch = manifest.model.batch;
        let (t, f) = (manifest.model.seq_len, 40usize);
        let mut server = Server::new(
            &mut engine,
            "asr_encoder_ref",
            params,
            ServeConfig { batch, max_wait: Duration::from_millis(5) },
        )?;
        println!("backend: PJRT ({})", engine.platform());
        drive(&mut server, &mut engine, t, f, n_requests)
    } else {
        println!("no PJRT artifacts under '{dir}' — serving on the native engine");
        let dims = ModelDims::tiny_asr();
        let batch = 4usize;
        let mut backend = NativeBackend::new(synth_weights(&dims, 7), batch)?;
        // The deployed configuration: 25% SASP at the artifact tile,
        // INT8 sign-magnitude kernels.
        let plan = backend.prepare(dims.tile, 0.25, Quant::Int8)?;
        println!(
            "backend: native engine ({}x{} tile, INT8, {:.0}% ff tiles pruned)",
            dims.tile,
            dims.tile,
            plan.achieved_rate * 100.0
        );
        let manifest = backend.manifest().clone();
        let mut server = Server::with_manifest(
            &manifest,
            "native_asr_encoder",
            Bundle::default(),
            ServeConfig { batch, max_wait: Duration::from_millis(5) },
        )?;
        let (t, f) = (dims.seq_len, dims.input_dim);
        let report = drive(&mut server, &mut backend, t, f, n_requests);
        let st = backend.stats();
        // `utterances` counts every forward row, including the rows
        // partial batches pad with repeats — so it can exceed the
        // request count printed by `drive`.
        println!(
            "native schedule: {} forward rows (incl. batch padding), \
             {} ff tiles skipped ({:.0}% of ff schedule)",
            st.utterances,
            st.ff.tiles_skipped,
            st.ff.sparsity() * 100.0
        );
        report
    }
}

/// Shared producer + serving loop over any backend.
fn drive(
    server: &mut Server,
    backend: &mut impl ServeBackend,
    t: usize,
    f: usize,
    n_requests: usize,
) -> Result<()> {
    let (req_tx, req_rx) = mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = mpsc::channel();

    // Producer: synthetic utterances, ~2 ms mean inter-arrival.
    let producer = thread::spawn(move || {
        let mut rng = Rng::new(42);
        for id in 0..n_requests as u64 {
            let feat_len = rng.index(t - 20) + 20;
            let feats: Vec<f32> = (0..t * f).map(|_| rng.normal() as f32 * 0.5).collect();
            let _ = req_tx.send(Request { id, feats, feat_len });
            thread::sleep(Duration::from_micros(500 + rng.index(3000) as u64));
        }
        // Dropping req_tx closes the queue and drains the server.
    });

    let report = server.run(backend, req_rx, resp_tx)?;
    producer.join().unwrap();

    let responses: Vec<_> = resp_rx.try_iter().collect();
    println!("served {} responses in {} batches", responses.len(), report.n_batches);
    println!(
        "latency p50 {:?}  p95 {:?}  | mean batch fill {:.1}/{} | throughput {:.1} req/s",
        report.p50,
        report.p95,
        report.mean_batch_fill,
        server.cfg.batch,
        report.throughput_rps
    );
    assert_eq!(report.n_requests, n_requests);
    println!("serve OK");
    Ok(())
}
