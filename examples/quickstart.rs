//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load the Pallas SASP GEMM artifact (Layer 1, AOT-compiled from
//!    python) on the PJRT CPU client.
//! 2. Run it with a pruned tile mask and check against the golden output
//!    the python oracle produced.
//! 3. Simulate the same GEMM on the modeled edge platform to see what
//!    the tile-skipping buys in cycles and energy.
//!
//! Run: `cargo run --example quickstart` (after `make artifacts`).

use anyhow::Result;

use sasp::data::load_bundle;
use sasp::hwmodel::EnergyModel;
use sasp::model::{GemmKind, GemmShape};
use sasp::runtime::Engine;
use sasp::sysim::{engine::gemm_on_array, SimParams, TileMask};
use sasp::systolic::{ArrayConfig, Quant};

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let mut engine = Engine::new(&dir)?;
    println!("PJRT platform: {}", engine.platform());

    // --- 1. Load the Layer-1 kernel artifact and its golden data -------
    let golden = load_bundle(format!("{dir}/golden_gemm.bin"))?;
    let x = golden.require("x")?.clone();
    let w = golden.require("w")?.clone();
    let mask = golden.require("mask")?.clone();
    let want = golden.require("y")?.f32s();

    // --- 2. Execute through PJRT ---------------------------------------
    let got = engine
        .execute("sasp_gemm_t8", &[x, w, mask.clone()])?
        .f32s();
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "sasp_gemm_t8: {} outputs, max |err| vs oracle = {max_err:.2e}",
        got.len()
    );
    assert!(max_err < 1e-3, "kernel does not match oracle");

    // --- 3. What does the skip buy on the modeled hardware? ------------
    let mvals = mask.i32s();
    let tm = TileMask {
        kt: 8,
        nt: 8,
        live: mvals.iter().map(|v| *v != 0).collect(),
    };
    let g = GemmShape { m: 64, k: 64, n: 64, kind: GemmKind::FeedForward };
    let array = ArrayConfig::square(8, Quant::Int8);
    let p = SimParams::default();
    let dense = gemm_on_array(&g, &array, &p, None);
    let pruned = gemm_on_array(&g, &array, &p, Some(&tm));
    let em = EnergyModel::default();
    println!(
        "8x8 INT8 array, 64x64x64 GEMM, {:.0}% tiles pruned:",
        tm.sparsity() * 100.0
    );
    println!(
        "  cycles {:>10.0} -> {:>10.0}  ({:.1}% faster)",
        dense.cycles,
        pruned.cycles,
        (1.0 - pruned.cycles / dense.cycles) * 100.0
    );
    println!(
        "  energy {:>9.2e} -> {:>9.2e} J ({:.1}% saved)",
        em.energy_j(&array, &dense.counts),
        em.energy_j(&array, &pruned.counts),
        (1.0 - em.energy_j(&array, &pruned.counts)
            / em.energy_j(&array, &dense.counts))
            * 100.0
    );

    // Bonus: the quantized kernel artifact (hybrid-multiplier datapath).
    let got_q = engine.execute(
        "quant_gemm_t8",
        &[
            golden.require("x")?.clone(),
            golden.require("w_q")?.clone(),
            golden.require("scale")?.clone(),
            golden.require("mask")?.clone(),
        ],
    )?;
    let want_q = golden.require("y_q")?.f32s();
    let err_q = got_q
        .f32s()
        .iter()
        .zip(&want_q)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("quant_gemm_t8: max |err| vs oracle = {err_q:.2e}");
    assert!(err_q < 1e-3);
    println!("quickstart OK");
    Ok(())
}
