//! ASR → MT cascade (the paper's MuST-C case study, Table 1 row 3).
//!
//! Evaluates the MT model's BLEU under SASP pruning on the auto-selected
//! backend — PJRT over compiled artifacts when they exist, otherwise the
//! fully offline native path: token-input encoder + autoregressive
//! KV-cache decoder over the synthetic teacher-labeled test set (dense
//! FP32 baseline = BLEU 100 by construction). Simulates the cascade's
//! two encoders (ASR stage + MT stage) on the modeled platform and
//! reports the joint runtime/energy picture with the BLEU floor of
//! Table 1 (27 of 31 BLEU).
//!
//! Run: `cargo run --release --example translation_cascade`.

use anyhow::Result;

use sasp::coordinator::{Explorer, RateSearch};
use sasp::harness::QosCache;
use sasp::model::zoo;
use sasp::systolic::Quant;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let mut qos = QosCache::auto(&dir)?;
    println!("QoS backend: {}", qos.backend_label());

    let base = qos.bleu(8, 0.0, Quant::Fp32)?;
    let floor = base * 27.0 / 31.0; // Table 1 QoS target ratio
    println!("baseline BLEU {base:.2}, floor {floor:.2}");

    println!(
        "\n{:>6} {:>6} {:>10} {:>12} {:>12}",
        "size", "rate", "BLEU", "cascade spd%", "energy sav%"
    );
    // Cascade timing: ASR-stage encoder + MT-stage encoder in sequence.
    let asr_stage = Explorer::new(zoo::mustc_asr_encoder());
    let mt_stage = Explorer::new(zoo::mustc_mt_encoder());
    let search = RateSearch::default();
    for n in [4usize, 8, 16, 32] {
        let found = search.max_rate(
            |rate| qos.bleu(n, rate, Quant::Int8),
            |b| b >= floor,
        )?;
        let (rate, bleu_at) = found.unwrap_or((0.0, base));
        let a_dense = asr_stage.timing_point(n, Quant::Int8, 0.0);
        let a_sasp = asr_stage.timing_point(n, Quant::Int8, rate);
        let m_dense = mt_stage.timing_point(n, Quant::Int8, 0.0);
        let m_sasp = mt_stage.timing_point(n, Quant::Int8, rate);
        // Cascade runtime ∝ sum of stage runtimes (same CPU baseline).
        let dense_t = 1.0 / a_dense.speedup_vs_cpu + 1.0 / m_dense.speedup_vs_cpu;
        let sasp_t = 1.0 / a_sasp.speedup_vs_cpu + 1.0 / m_sasp.speedup_vs_cpu;
        let speedup_pct = (dense_t / sasp_t - 1.0) * 100.0;
        let energy_pct = (1.0
            - (a_sasp.energy_j + m_sasp.energy_j)
                / (a_dense.energy_j + m_dense.energy_j))
            * 100.0;
        println!(
            "{:>6} {:>6.2} {:>10.2} {:>11.1}% {:>11.1}%",
            n, rate, bleu_at, speedup_pct, energy_pct
        );
    }
    println!(
        "\npaper reference: up to 51% runtime / 34% energy reduction at \
         <=4 BLEU degradation (§1, §4.3)"
    );
    println!("translation_cascade OK");
    Ok(())
}
