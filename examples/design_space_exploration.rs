//! Full SASP design-space exploration (the Fig. 10 dataset).
//!
//! Sweeps array size × quantization × pruning rate, evaluating QoS via
//! PJRT on the trained model and timing/energy/area on the simulated
//! platform, and emits both a table and a JSON dump for plotting.
//!
//! Run: `cargo run --release --example design_space_exploration`.

use anyhow::Result;

use sasp::config::ExperimentConfig;
use sasp::coordinator::Explorer;
use sasp::harness::QosCache;
use sasp::model::zoo;
use sasp::qos::AsrEvaluator;
use sasp::runtime::Engine;
use sasp::util::json::Json;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let cfg = ExperimentConfig { artifacts_dir: dir.clone(), ..Default::default() };

    let mut engine = Engine::new(&dir)?;
    let asr = AsrEvaluator::new(&mut engine, &dir, "asr_encoder_ref")?;
    let mut qos = QosCache::new(asr, None);
    let ex = Explorer::new(zoo::espnet_asr());

    println!(
        "{:>6} {:>10} {:>6} {:>10} {:>10} {:>12} {:>12}",
        "size", "quant", "rate", "WER", "speedup", "energy J", "area*energy"
    );
    let mut points = Vec::new();
    for &n in &cfg.sizes {
        for &q in &cfg.quants {
            for &rate in &cfg.rates {
                let wer = qos.wer(&mut engine, n, rate, q)?;
                let p = ex.timing_point(n, q, rate);
                println!(
                    "{:>6} {:>10} {:>6.2} {:>10.4} {:>10.2} {:>12.4} {:>12.4}",
                    n, q.label(), rate, wer, p.speedup_vs_cpu, p.energy_j,
                    p.area_energy
                );
                points.push(Json::obj(vec![
                    ("size", Json::num(n as f64)),
                    ("quant", Json::str(q.label())),
                    ("rate", Json::num(rate)),
                    ("wer", Json::num(wer)),
                    ("speedup", Json::num(p.speedup_vs_cpu)),
                    ("energy_j", Json::num(p.energy_j)),
                    ("area_energy", Json::num(p.area_energy)),
                ]));
            }
        }
    }
    let out = format!("{dir}/design_space.json");
    std::fs::write(&out, Json::Arr(points).to_string())?;
    println!("\nwrote {} ({} QoS evaluations cached)", out, qos.cached_points());
    Ok(())
}
