//! Full SASP design-space exploration (the Fig. 10 dataset).
//!
//! Sweeps array size × quantization × pruning rate: the timing/energy
//! axis runs through `Explorer::sweep` (parallel over a scoped worker
//! pool), the QoS axis through the auto-selected backend (PJRT on the
//! trained model when artifacts exist, the batched native engine
//! otherwise), and the result is emitted both as a table and as a JSON
//! dump for plotting.
//!
//! Run: `cargo run --release --example design_space_exploration`.

use anyhow::Result;

use sasp::config::ExperimentConfig;
use sasp::coordinator::{Explorer, SweepPoint};
use sasp::harness::QosCache;
use sasp::model::zoo;
use sasp::util::json::Json;

fn main() -> Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let cfg = ExperimentConfig { artifacts_dir: dir.clone(), ..Default::default() };

    let mut qos = QosCache::auto(&dir)?;
    eprintln!("QoS backend: {}", qos.backend_label());
    let ex = Explorer::new(zoo::espnet_asr());

    // Timing/energy for the whole grid in one parallel sweep.
    let grid = SweepPoint::grid(&cfg.sizes, &cfg.quants, &cfg.rates);
    let t0 = std::time::Instant::now();
    let timing = ex.sweep(&grid);
    eprintln!(
        "timing sweep: {} points in {:?} ({} workers)",
        grid.len(),
        t0.elapsed(),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );

    println!(
        "{:>6} {:>10} {:>6} {:>10} {:>10} {:>12} {:>12}",
        "size", "quant", "rate", "WER", "speedup", "energy J", "area*energy"
    );
    let mut points = Vec::new();
    for (sp, p) in grid.iter().zip(&timing) {
        let wer = qos.wer(sp.tile, sp.rate, sp.quant)?;
        println!(
            "{:>6} {:>10} {:>6.2} {:>10.4} {:>10.2} {:>12.4} {:>12.4}",
            sp.tile,
            sp.quant.label(),
            sp.rate,
            wer,
            p.speedup_vs_cpu,
            p.energy_j,
            p.area_energy
        );
        points.push(Json::obj(vec![
            ("size", Json::num(sp.tile as f64)),
            ("quant", Json::str(sp.quant.label())),
            ("rate", Json::num(sp.rate)),
            ("wer", Json::num(wer)),
            ("speedup", Json::num(p.speedup_vs_cpu)),
            ("energy_j", Json::num(p.energy_j)),
            ("area_energy", Json::num(p.area_energy)),
        ]));
    }
    let out = format!("{dir}/design_space.json");
    std::fs::write(&out, Json::Arr(points).to_string())?;
    println!("\nwrote {} ({} QoS evaluations cached)", out, qos.cached_points());
    Ok(())
}
