//! Integration tests over the compiled artifacts: python-AOT → rust-PJRT
//! round trips, cross-language numerical equivalence, and the full
//! prune→quantize→infer→score pipeline.
//!
//! All tests skip cleanly when `make artifacts` has not run (so `cargo
//! test` stays green on a fresh checkout); CI runs them after the make.

use sasp::data::{load_bundle, Tensor};
use sasp::pruning::{global_prune, tile_l1_norms};
use sasp::qos::{AsrEvaluator, MtEvaluator};
use sasp::runtime::Engine;
use sasp::systolic::Quant;

const DIR: &str = "artifacts";

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/asr_encoder_ref.hlo.txt").exists()
        && std::path::Path::new("artifacts/golden_gemm.bin").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn sasp_gemm_artifact_matches_python_golden() {
    require_artifacts!();
    let mut engine = Engine::new(DIR).unwrap();
    let g = load_bundle(format!("{DIR}/golden_gemm.bin")).unwrap();
    let got = engine
        .execute(
            "sasp_gemm_t8",
            &[
                g.require("x").unwrap().clone(),
                g.require("w").unwrap().clone(),
                g.require("mask").unwrap().clone(),
            ],
        )
        .unwrap();
    let err = max_abs_diff(&got.f32s(), &g.require("y").unwrap().f32s());
    assert!(err < 1e-3, "max err {err}");
}

#[test]
fn quant_gemm_artifact_matches_python_golden() {
    require_artifacts!();
    let mut engine = Engine::new(DIR).unwrap();
    let g = load_bundle(format!("{DIR}/golden_gemm.bin")).unwrap();
    let got = engine
        .execute(
            "quant_gemm_t8",
            &[
                g.require("x").unwrap().clone(),
                g.require("w_q").unwrap().clone(),
                g.require("scale").unwrap().clone(),
                g.require("mask").unwrap().clone(),
            ],
        )
        .unwrap();
    let err = max_abs_diff(&got.f32s(), &g.require("y_q").unwrap().f32s());
    assert!(err < 1e-3, "max err {err}");
}

#[test]
fn kernel_mask_skip_equals_zeroed_weights() {
    // The SASP identity at the kernel level: skipping tiles via the mask
    // == multiplying by zeroed weights.
    require_artifacts!();
    let mut engine = Engine::new(DIR).unwrap();
    let g = load_bundle(format!("{DIR}/golden_gemm.bin")).unwrap();
    let x = g.require("x").unwrap().clone();
    let w = g.require("w").unwrap();
    let mask = g.require("mask").unwrap();

    // Masked execution.
    let masked = engine
        .execute("sasp_gemm_t8", &[x.clone(), w.clone(), mask.clone()])
        .unwrap();

    // Zeroed-weights execution with a full mask.
    let tile = 8;
    let mvals = mask.i32s();
    let mut wz = w.clone();
    let n = wz.shape[1];
    wz.map_f32_inplace(|idx, v| {
        let (kk, nn) = (idx / n, idx % n);
        if mvals[(kk / tile) * (n / tile) + nn / tile] != 0 {
            v
        } else {
            0.0
        }
    });
    let ones = Tensor::from_i32(&mask.shape, &vec![1; mvals.len()]);
    let zeroed = engine.execute("sasp_gemm_t8", &[x, wz, ones]).unwrap();

    let err = max_abs_diff(&masked.f32s(), &zeroed.f32s());
    assert!(err < 1e-4, "identity violated: {err}");
}

#[test]
fn pallas_and_ref_encoders_agree() {
    // The Layer-1-in-Layer-2 composition: the encoder artifact built on
    // the Pallas kernel must match the oracle-path artifact.
    require_artifacts!();
    let mut engine = Engine::new(DIR).unwrap();
    let eval = AsrEvaluator::new(&mut engine, DIR, "asr_encoder_ref").unwrap();
    let params = load_bundle(format!("{DIR}/params_asr.bin")).unwrap();
    let hyps_ref = eval.decode_all(&mut engine, &params).unwrap();

    let eval_sasp = AsrEvaluator::new(&mut engine, DIR, "asr_encoder_sasp").unwrap();
    let hyps_sasp = eval_sasp.decode_all(&mut engine, &params).unwrap();
    assert_eq!(hyps_ref, hyps_sasp, "pallas and oracle decodes differ");
}

#[test]
fn baseline_wer_is_sane() {
    require_artifacts!();
    let mut engine = Engine::new(DIR).unwrap();
    let eval = AsrEvaluator::new(&mut engine, DIR, "asr_encoder_ref").unwrap();
    let wer = eval.baseline(&mut engine).unwrap();
    assert!(wer < 0.15, "baseline WER {wer} too high — training regressed?");
}

#[test]
fn wer_degrades_monotonically_with_rate() {
    // Fig. 9's core shape (allowing small non-monotonic noise at low
    // rates on the 64-utterance test set).
    require_artifacts!();
    let mut engine = Engine::new(DIR).unwrap();
    let eval = AsrEvaluator::new(&mut engine, DIR, "asr_encoder_ref").unwrap();
    let w0 = eval.evaluate(&mut engine, 8, 0.0, Quant::Fp32).unwrap().qos;
    let w3 = eval.evaluate(&mut engine, 8, 0.3, Quant::Fp32).unwrap().qos;
    let w6 = eval.evaluate(&mut engine, 8, 0.6, Quant::Fp32).unwrap().qos;
    assert!(w3 >= w0 - 0.02, "w0={w0} w3={w3}");
    assert!(w6 > w3, "w3={w3} w6={w6}");
    assert!(w6 > w0 + 0.03, "60% pruning must visibly hurt: {w0} -> {w6}");
}

#[test]
fn larger_tiles_hurt_more_at_same_rate() {
    // Fig. 9 / §4.4: large-tile structured pruning is more brittle.
    require_artifacts!();
    let mut engine = Engine::new(DIR).unwrap();
    let eval = AsrEvaluator::new(&mut engine, DIR, "asr_encoder_ref").unwrap();
    let rate = 0.4;
    let w4 = eval.evaluate(&mut engine, 4, rate, Quant::Fp32).unwrap().qos;
    let w32 = eval.evaluate(&mut engine, 32, rate, Quant::Fp32).unwrap().qos;
    assert!(
        w32 >= w4 - 0.02,
        "32-tile WER {w32} should be >= 4-tile WER {w4} at rate {rate}"
    );
}

#[test]
fn quantization_wer_close_to_fp32() {
    // §4.4: INT8 and FP32 QoS curves are similar at low rates.
    require_artifacts!();
    let mut engine = Engine::new(DIR).unwrap();
    let eval = AsrEvaluator::new(&mut engine, DIR, "asr_encoder_ref").unwrap();
    let f = eval.evaluate(&mut engine, 8, 0.1, Quant::Fp32).unwrap().qos;
    let i = eval.evaluate(&mut engine, 8, 0.1, Quant::Int8).unwrap().qos;
    assert!((f - i).abs() < 0.05, "fp32 {f} vs int8 {i}");
}

#[test]
fn mt_bleu_baseline_and_degradation() {
    require_artifacts!();
    let mut engine = Engine::new(DIR).unwrap();
    let eval = MtEvaluator::new(&mut engine, DIR, "mt_encoder_ref").unwrap();
    let b0 = eval.evaluate(&mut engine, 8, 0.0, Quant::Fp32).unwrap().qos;
    assert!(b0 > 50.0, "baseline BLEU {b0} too low — training regressed?");
    let b6 = eval.evaluate(&mut engine, 8, 0.6, Quant::Fp32).unwrap().qos;
    assert!(b6 < b0, "pruning must reduce BLEU: {b0} -> {b6}");
}

#[test]
fn pruned_weights_actually_sparse() {
    // End-to-end pruning accounting: requested rate == achieved rate and
    // the zeroed tiles really are zero in the executed weights.
    require_artifacts!();
    let params = load_bundle(format!("{DIR}/params_asr.bin")).unwrap();
    let w1 = params.require("block0.ff.w1").unwrap();
    let norms = vec![tile_l1_norms(w1, 8)];
    let plan = global_prune(&norms, 0.25);
    assert!((plan.achieved_rate - 0.25).abs() < 0.01);
    let mut w = w1.clone();
    sasp::pruning::norms::apply_mask_to_weights(&mut w, &plan.masks[0], 8);
    let nrm = tile_l1_norms(&w, 8);
    let zeros = nrm.norms.iter().filter(|v| **v == 0.0).count();
    assert_eq!(zeros, plan.masks[0].n_tiles() - plan.masks[0].live_count());
}

#[test]
fn manifest_contract_complete() {
    require_artifacts!();
    let mut engine = Engine::new(DIR).unwrap();
    for name in ["asr_encoder_ref", "asr_encoder_sasp", "mt_encoder_ref"] {
        let m = &engine.load(name).unwrap().manifest;
        assert!(!m.args.is_empty(), "{name} has no args");
        assert!(m.model.n_blocks > 0);
        // Params bundle covers every non-data, non-mask argument.
        let params = load_bundle(format!(
            "{DIR}/params_{}.bin",
            if name.starts_with("asr") { "asr" } else { "mt" }
        ))
        .unwrap();
        for a in &m.args {
            if ["feats", "pad_mask", "src"].contains(&a.name.as_str())
                || a.name.starts_with("mask.")
            {
                continue;
            }
            let t = params.require(&a.name).unwrap();
            assert_eq!(t.shape, a.shape, "{name}/{}", a.name);
        }
    }
}
