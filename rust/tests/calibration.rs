//! Calibration tests: the system model's *no-SASP* speedups must land in
//! the neighbourhood of the paper's Table 3 (the model's only fitted
//! quantities — SASP results are then predictions). Tolerances are wide
//! (±35 %) because the paper's testbed is a full gem5 OS simulation; what
//! must hold tightly is the *shape*: monotone in size, sublinear, and
//! the FP32/INT8 crossover at 4x4 (§4.5).

use sasp::coordinator::Explorer;
use sasp::model::zoo;
use sasp::systolic::Quant;

/// Paper Table 3, "No SASP" speedup rows (vs CPU baseline).
const PAPER_FP32: [(usize, f64); 4] =
    [(4, 8.42), (8, 19.79), (16, 35.22), (32, 50.95)];
const PAPER_INT8: [(usize, f64); 4] =
    [(4, 8.03), (8, 20.18), (16, 36.53), (32, 61.33)];

fn speedup(ex: &Explorer, n: usize, q: Quant) -> f64 {
    ex.timing_point(n, q, 0.0).speedup_vs_cpu
}

#[test]
fn no_sasp_speedups_near_table3_fp32() {
    let ex = Explorer::new(zoo::espnet_asr());
    for (n, want) in PAPER_FP32 {
        let got = speedup(&ex, n, Quant::Fp32);
        let rel = (got - want).abs() / want;
        assert!(rel < 0.35, "FP32 {n}x{n}: got {got:.2}, paper {want} (rel {rel:.2})");
    }
}

#[test]
fn no_sasp_speedups_near_table3_int8() {
    let ex = Explorer::new(zoo::espnet_asr());
    for (n, want) in PAPER_INT8 {
        let got = speedup(&ex, n, Quant::Int8);
        let rel = (got - want).abs() / want;
        assert!(rel < 0.35, "INT8 {n}x{n}: got {got:.2}, paper {want} (rel {rel:.2})");
    }
}

#[test]
fn speedup_monotone_and_sublinear_in_size() {
    let ex = Explorer::new(zoo::espnet_asr());
    for q in [Quant::Fp32, Quant::Int8] {
        let s: Vec<f64> = [4, 8, 16, 32]
            .iter()
            .map(|n| speedup(&ex, *n, q))
            .collect();
        assert!(s.windows(2).all(|w| w[1] > w[0]), "{q:?} monotone: {s:?}");
        // Each doubling of the dimension quadruples PEs but must give
        // < 4x speedup (paper: 8->32 gives 3.04x for 16x the PEs).
        for w in s.windows(2) {
            assert!(w[1] / w[0] < 4.0, "{q:?} sublinear: {s:?}");
        }
        // Paper's 8->32 reference point: 3.04x (INT8) — allow 2..4.
        let r = s[3] / s[1];
        assert!(r > 1.8 && r < 4.2, "{q:?} 8->32 ratio {r:.2}");
    }
}

#[test]
fn int8_crossover_at_small_arrays() {
    // §4.5: FP32_INT8 outperforms FP32_FP32 for sizes > 4x4; at 4x4 the
    // software/system overhead makes INT8 not better.
    let ex = Explorer::new(zoo::espnet_asr());
    let f4 = speedup(&ex, 4, Quant::Fp32);
    let i4 = speedup(&ex, 4, Quant::Int8);
    assert!(i4 <= f4 * 1.02, "4x4: INT8 {i4:.2} must not beat FP32 {f4:.2}");
    for n in [8, 16, 32] {
        let f = speedup(&ex, n, Quant::Fp32);
        let i = speedup(&ex, n, Quant::Int8);
        assert!(i > f, "{n}x{n}: INT8 {i:.2} must beat FP32 {f:.2}");
    }
}

#[test]
fn fig7_workload_dependence_ordering() {
    // §4.3: max gains vary by workload — MuST-C (d_model 128) benefits
    // more from SASP than the LibriSpeech models (larger FF share).
    let rate = 0.25;
    let gain = |spec: sasp::model::EncoderSpec| {
        let ex = Explorer::new(spec);
        ex.timing_point(8, Quant::Int8, rate).speedup_vs_dense
    };
    let asr = gain(zoo::espnet_asr());
    let mustc = gain(zoo::mustc_asr_encoder());
    assert!(
        mustc > asr,
        "mustc gain {mustc:.3} should exceed librispeech gain {asr:.3}"
    );
}

#[test]
fn sasp_gains_in_paper_range() {
    // Fig. 7: max speedup improvements 22-51% across workloads at the
    // paper's QoS-selected rates; at a fixed 25% rate our model should
    // produce gains in the same band (10-60%).
    for spec in zoo::fig7_workloads() {
        let ex = Explorer::new(spec.clone());
        let g = ex.timing_point(8, Quant::Int8, 0.25).speedup_vs_dense;
        let pct = (g - 1.0) * 100.0;
        assert!(
            (5.0..65.0).contains(&pct),
            "{}: gain {pct:.1}% out of plausible band",
            spec.name
        );
    }
}

#[test]
fn table3_sasp_rows_improve_on_dense() {
    let ex = Explorer::new(zoo::espnet_asr());
    for (n, rate) in [(4usize, 0.25), (8, 0.20), (16, 0.20), (32, 0.20)] {
        for q in [Quant::Fp32, Quant::Int8] {
            let dense = ex.timing_point(n, q, 0.0);
            let sasp = ex.timing_point(n, q, rate);
            assert!(sasp.speedup_vs_cpu > dense.speedup_vs_cpu,
                    "{n} {q:?} speedup");
            assert!(sasp.energy_j < dense.energy_j, "{n} {q:?} energy");
        }
    }
}

#[test]
fn energy_magnitudes_plausible() {
    // Per-inference energies should be positive and ordered: bigger
    // arrays burn more energy per inference at fixed work (leakage +
    // quadratic power), matching Table 3's energy column ordering.
    let ex = Explorer::new(zoo::espnet_asr());
    let e8 = ex.timing_point(8, Quant::Int8, 0.0).energy_j;
    let e32 = ex.timing_point(32, Quant::Int8, 0.0).energy_j;
    assert!(e8 > 0.0);
    // Table 3: 32x32 INT8 (10.64 J) > 8x8 INT8 (2.67 J)? No — runtime
    // shrinks at 32x32. The paper still measures *higher* energy for the
    // larger array (3.98x from 8->32). Require the same direction:
    assert!(
        e32 > e8,
        "larger array should cost more energy: e8={e8:.3e} e32={e32:.3e}"
    );
}
