//! Bench + regeneration of Fig. 9: WER vs structured pruning rate across
//! array (tile) sizes and quantization — the QoS axis, evaluated through
//! the compiled PJRT artifact on the trained stand-in model.
//!
//! Requires `make artifacts`; exits cleanly with a notice otherwise.

use sasp::qos::AsrEvaluator;
use sasp::runtime::Engine;
use sasp::systolic::Quant;
use sasp::util::bench::Bench;

fn main() {
    if !std::path::Path::new("artifacts/asr_encoder_ref.hlo.txt").exists() {
        println!("fig9_qos: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    let mut engine = Engine::new("artifacts").expect("engine");
    let eval = AsrEvaluator::new(&mut engine, "artifacts", "asr_encoder_ref")
        .expect("evaluator");
    let b = Bench::quick();
    b.run("fig9 one QoS point (64 utts via PJRT)", || {
        eval.evaluate(&mut engine, 8, 0.2, Quant::Int8).unwrap().qos
    });
    println!();
    println!("{:>6} {:>6} {:>12} {:>12}", "size", "rate", "FP32_FP32", "FP32_INT8");
    for n in [4usize, 8, 16, 32] {
        for rate in [0.0, 0.1, 0.2, 0.3, 0.4, 0.5] {
            let f = eval.evaluate(&mut engine, n, rate, Quant::Fp32).unwrap().qos;
            let i = eval.evaluate(&mut engine, n, rate, Quant::Int8).unwrap().qos;
            println!("{:>6} {:>6.2} {:>12.4} {:>12.4}", n, rate, f, i);
        }
    }
}
