//! Bench + regeneration of Fig. 9: WER vs structured pruning rate across
//! array (tile) sizes and quantization — the QoS axis, evaluated on the
//! auto-selected backend: the compiled PJRT artifact + trained stand-in
//! model when `make artifacts` has run, otherwise the batched native
//! engine over the synthetic teacher-labeled test set (fully offline).

use sasp::coordinator::serve::Backend;
use sasp::systolic::Quant;
use sasp::util::bench::Bench;

fn main() {
    let mut backend = Backend::auto("artifacts").expect("backend");
    println!("fig9_qos backend: {}", backend.describe());
    let eval = backend.asr_evaluator("artifacts", 16).expect("evaluator");
    let b = Bench::quick();
    b.run("fig9 one QoS point (testset inference)", || {
        eval.evaluate_with(&mut backend, 8, 0.2, Quant::Int8).unwrap().qos
    });
    println!();
    println!("{:>6} {:>6} {:>12} {:>12}", "size", "rate", "FP32_FP32", "FP32_INT8");
    for n in [4usize, 8, 16, 32] {
        for rate in [0.0, 0.1, 0.2, 0.3, 0.4, 0.5] {
            let f = eval.evaluate_with(&mut backend, n, rate, Quant::Fp32).unwrap().qos;
            let i = eval.evaluate_with(&mut backend, n, rate, Quant::Int8).unwrap().qos;
            println!("{:>6} {:>6.2} {:>12.4} {:>12.4}", n, rate, f, i);
        }
    }
}
