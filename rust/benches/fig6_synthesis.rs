//! Bench + regeneration of Fig. 6: synthesis area/power across array
//! sizes and quantization choices. Times the hardware model evaluation
//! and prints the figure's series.

use sasp::harness;
use sasp::hwmodel;
use sasp::systolic::{ArrayConfig, Quant};
use sasp::util::bench::Bench;

fn main() {
    let b = Bench::default();
    b.run("hwmodel::area+power full grid", || {
        let mut acc = 0.0;
        for n in [4usize, 8, 16, 32] {
            for q in [Quant::Fp32, Quant::Int8] {
                let cfg = ArrayConfig::square(n, q);
                acc += hwmodel::area_mm2(&cfg) + hwmodel::power_mw(&cfg);
                let br = hwmodel::components::area_breakdown(&cfg);
                acc += br.multipliers;
            }
        }
        acc
    });
    print!("{}", harness::fig6().render());
}
