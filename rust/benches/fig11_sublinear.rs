//! Bench + regeneration of Fig. 11 (timing axis): speedup vs array size,
//! demonstrating the sublinear scaling the paper attributes to reduced
//! pruning opportunities + non-scaling overheads.

use sasp::coordinator::Explorer;
use sasp::model::zoo;
use sasp::systolic::Quant;
use sasp::util::bench::Bench;

fn main() {
    let ex = Explorer::new(zoo::espnet_asr());
    let b = Bench::default();
    b.run("fig11 speedup-vs-size grid", || {
        let mut acc = 0.0;
        for n in [4usize, 8, 16, 32] {
            for q in [Quant::Fp32, Quant::Int8] {
                acc += ex.timing_point(n, q, 0.20).speedup_vs_cpu;
            }
        }
        acc
    });
    println!();
    println!("{:>6} {:>12} {:>12} (20% SASP rate)", "size", "FP32", "INT8");
    for n in [4usize, 8, 16, 32] {
        let f = ex.timing_point(n, Quant::Fp32, 0.20).speedup_vs_cpu;
        let i = ex.timing_point(n, Quant::Int8, 0.20).speedup_vs_cpu;
        println!("{:>6} {:>12.2} {:>12.2}", n, f, i);
    }
    // Sublinearity check: 8->32 is 4x the PEs but < 4x the speedup.
    let s8 = ex.timing_point(8, Quant::Int8, 0.20).speedup_vs_cpu;
    let s32 = ex.timing_point(32, Quant::Int8, 0.20).speedup_vs_cpu;
    println!("\n8->32 speedup ratio: {:.2}x (PE ratio 16x; paper reports 3.04x)", s32 / s8);
}
