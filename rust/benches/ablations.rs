//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Quantized-path software overhead** — the per-tile cost knob that
//!    produces the paper's §4.5 FP32/INT8 crossover at 4x4. Sweeps the
//!    knob and prints where the crossover lands.
//! 2. **Loop order / data arrangement** (paper ref [1]) — j-outer vs
//!    k-outer tile order through the traced cache hierarchy.
//! 3. **Weight-stationary reuse** — tile re-programming cost vs reuse
//!    across input batches.

use sasp::model::zoo;
use sasp::model::{GemmKind, GemmShape};
use sasp::sysim::{engine::gemm_on_array, LoopOrder, SimParams, System, TraceSim};
use sasp::systolic::{ArrayConfig, Quant, TileTiming};
use sasp::util::bench::Bench;

fn main() {
    let b = Bench::default();

    // --- 1. quant overhead knob -> 4x4 crossover -----------------------
    println!("ablation 1: quant per-tile overhead vs 4x4 crossover");
    let spec = zoo::espnet_asr();
    for extra in [0.0, 50.0, 100.0, 200.0] {
        let mut sys = System::default();
        sys.params.quant_tile_extra_cycles = extra;
        let cpu = sys.run_encoder_cpu(&spec).cycles;
        let f4 = cpu / sys.run_encoder(&spec, &ArrayConfig::square(4, Quant::Fp32), None).cycles;
        let i4 = cpu / sys.run_encoder(&spec, &ArrayConfig::square(4, Quant::Int8), None).cycles;
        let f8 = cpu / sys.run_encoder(&spec, &ArrayConfig::square(8, Quant::Fp32), None).cycles;
        let i8_ = cpu / sys.run_encoder(&spec, &ArrayConfig::square(8, Quant::Int8), None).cycles;
        println!(
            "  extra={extra:>5} cycles/tile: 4x4 fp32 {f4:.2} vs int8 {i4:.2} \
             ({}), 8x8 fp32 {f8:.2} vs int8 {i8_:.2} ({})",
            if i4 < f4 { "fp32 wins — paper shape" } else { "int8 wins" },
            if i8_ > f8 { "int8 wins — paper shape" } else { "fp32 wins" },
        );
    }

    // --- 2. loop order through the traced caches -----------------------
    println!("\nablation 2: data arrangement (trace-driven)");
    // Asymmetric shape: input panel fits L1, output panel does not.
    let g = GemmShape { m: 64, k: 64, n: 2048, kind: GemmKind::FeedForward };
    let cfg = ArrayConfig::square(8, Quant::Fp32);
    for (label, order) in [("j-outer", LoopOrder::JOuter), ("k-outer", LoopOrder::KOuter)] {
        let mut sim = TraceSim::default();
        let c = sim.trace_gemm_order(&g, &cfg, None, order);
        println!(
            "  {label}: l1 misses {:>8}  l2 misses {:>8}",
            c.l1d_misses, c.l2_misses
        );
    }
    b.run("trace 64x64x2048 j-outer", || {
        TraceSim::default().trace_gemm_order(&g, &cfg, None, LoopOrder::JOuter)
    });

    // --- 3. weight-stationary reuse -------------------------------------
    println!("\nablation 3: weight reuse across batches (8x8 fp32, M=256)");
    let acfg = ArrayConfig::square(8, Quant::Fp32);
    let live = TileTiming::live(&acfg, 256);
    let reuse = TileTiming::reuse(&acfg, 256);
    println!(
        "  program-every-batch: {} words/tile; reuse: {} (saves {:.1}% of tile words)",
        live.total_words(),
        reuse.total_words(),
        100.0 * (live.total_words() - reuse.total_words()) as f64
            / live.total_words() as f64
    );
    b.run("sysim espnet 4x4 int8 dense (ablation driver)", || {
        let p = SimParams::default();
        gemm_on_array(&g, &ArrayConfig::square(4, Quant::Int8), &p, None).cycles
    });
}
