//! Bench + regeneration of Fig. 7 (timing axes): SASP speedup and energy
//! improvement across workloads and array sizes at a representative
//! QoS-constrained rate per size (QoS-selected rates come from the
//! `sasp report fig7` CLI path; benches stay artifact-free).

use sasp::coordinator::Explorer;
use sasp::model::zoo;
use sasp::systolic::Quant;
use sasp::util::bench::Bench;

fn main() {
    let b = Bench::default();
    // Paper-selected rates per size (Table 3 row: 25/20/20/20 %).
    let rates = [(4usize, 0.25), (8, 0.20), (16, 0.20), (32, 0.20)];
    for spec in zoo::fig7_workloads() {
        let ex = Explorer::new(spec.clone());
        b.run(&format!("fig7 sweep {}", spec.name), || {
            let mut acc = 0.0;
            for (n, rate) in rates {
                let p = ex.timing_point(n, Quant::Int8, rate);
                acc += p.speedup_vs_dense + p.energy_j;
            }
            acc
        });
    }
    println!();
    println!("{:<26} {:>5} {:>6} {:>10} {:>10}", "workload", "size", "rate", "speedup%", "energy%");
    for spec in zoo::fig7_workloads() {
        let ex = Explorer::new(spec.clone());
        for (n, rate) in rates {
            let p = ex.timing_point(n, Quant::Int8, rate);
            println!(
                "{:<26} {:>5} {:>6.2} {:>9.1}% {:>9.1}%",
                spec.name, n, rate,
                (p.speedup_vs_dense - 1.0) * 100.0,
                (1.0 - p.energy_j / p.dense_energy_j) * 100.0
            );
        }
    }
}
