//! Bench + regeneration of Table 3: area / speedup / energy, no-SASP vs
//! SASP at the WER inflection point, across sizes and quantization.
//! End-to-end on the auto-selected backend: QoS via PJRT when artifacts
//! exist, via the batched native engine otherwise, + timing via the
//! system simulator.

use sasp::config::ExperimentConfig;
use sasp::harness::{self, QosCache};
use sasp::util::bench::Bench;

fn main() {
    let cfg = ExperimentConfig::default();
    let mut qos = QosCache::auto("artifacts").expect("qos stack");
    println!("table3_e2e backend: {}", qos.backend_label());
    // First generation populates the QoS cache (the expensive part) …
    let report = harness::table3(&mut qos, &cfg).expect("table3");
    // … then bench the cached regeneration (the explorer + search math).
    let b = Bench::default();
    b.run("table3 regen (QoS cached)", || {
        harness::table3(&mut qos, &cfg).unwrap().lines.len()
    });
    println!();
    print!("{}", report.render());
}
