//! Bench + regeneration of Table 3: area / speedup / energy, no-SASP vs
//! SASP at the WER inflection point, across sizes and quantization.
//! End-to-end: QoS via PJRT + timing via the system simulator.

use sasp::config::ExperimentConfig;
use sasp::harness::{self, QosCache};
use sasp::qos::AsrEvaluator;
use sasp::runtime::Engine;
use sasp::util::bench::Bench;

fn main() {
    if !std::path::Path::new("artifacts/asr_encoder_ref.hlo.txt").exists() {
        println!("table3_e2e: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    let cfg = ExperimentConfig::default();
    let mut engine = Engine::new("artifacts").expect("engine");
    let asr = AsrEvaluator::new(&mut engine, "artifacts", "asr_encoder_ref")
        .expect("evaluator");
    let mut qos = QosCache::new(asr, None);
    // First generation populates the QoS cache (the expensive part) …
    let report = harness::table3(&mut engine, &mut qos, &cfg).expect("table3");
    // … then bench the cached regeneration (the explorer + search math).
    let b = Bench::default();
    b.run("table3 regen (QoS cached)", || {
        harness::table3(&mut engine, &mut qos, &cfg).unwrap().lines.len()
    });
    println!();
    print!("{}", report.render());
}
