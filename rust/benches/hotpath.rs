//! Hot-path microbenches across all three layers' rust-side costs:
//! system-sim GEMM accounting, pruning ranking, cache simulation,
//! per-cycle systolic simulation, the functional tile scheduler, the
//! parallel design-space sweep, tensor<->literal conversion, and (when
//! artifacts exist) PJRT dispatch. The §Perf iteration log in
//! EXPERIMENTS.md is driven by these numbers; set
//! `BENCH_HOTPATH_JSON=BENCH_hotpath.json` to record them.

use sasp::coordinator::{Explorer, SweepPoint};
use sasp::data::Tensor;
use sasp::infer::backend::ff_norms;
use sasp::infer::batch::{gemm_batched_f32, gemm_batched_int8};
use sasp::infer::gemm::{gemm_f32, gemm_int8};
use sasp::infer::{
    synth_decoder_weights, synth_weights, BatchForward, ContinuousDecoder, DecoderDims,
    DecoderForward, Forward, ModelDims, NativeBackend, PreparedDecoder, PreparedModel,
    QuantizedLinear,
};
use sasp::model::zoo;
use sasp::pruning::{global_prune, synthetic_ff_norms};
use sasp::runtime::Engine;
use sasp::sysim::{Cache, CacheConfig, TileMask};
use sasp::systolic::{ArrayConfig, Quant, SystolicArray, TileScheduler};
use sasp::util::bench::Bench;
use sasp::util::rng::Rng;

fn main() {
    let b = Bench::default();

    // L3: whole-encoder system simulation (the explorer inner loop).
    let ex = Explorer::new(zoo::espnet_asr());
    b.run("sysim: espnet_asr encoder, 8x8 int8, dense", || {
        ex.pruned_run(8, Quant::Int8, 0.0).cycles
    });
    b.run("sysim: espnet_asr encoder, 8x8 int8, 25% pruned", || {
        ex.pruned_run(8, Quant::Int8, 0.25).cycles
    });

    // L3: the design-space sweep, serial vs the scoped worker pool
    // (identical points; speedup ~= core count on the pruned runs).
    let grid = SweepPoint::grid(
        &[4, 8, 16, 32],
        &[Quant::Int8],
        &[0.05, 0.10, 0.15, 0.20, 0.25, 0.30],
    );
    assert_eq!(grid.len(), 24);
    b.run("explorer: 24-point espnet_asr sweep, serial", || {
        grid.iter()
            .map(|p| ex.timing_point(p.tile, p.quant, p.rate).speedup_vs_cpu)
            .sum::<f64>()
    });
    b.run("explorer: 24-point espnet_asr sweep, parallel", || {
        ex.sweep(&grid)
            .iter()
            .map(|p| p.speedup_vs_cpu)
            .sum::<f64>()
    });

    // L3: pruning global ranking over the full-size model (36 FF GEMMs).
    let spec = zoo::espnet_asr();
    let norms = synthetic_ff_norms(&spec, 8, 7);
    let n_tiles: usize = norms.iter().map(|n| n.norms.len()).sum();
    b.run(&format!("pruning: global rank {n_tiles} tiles"), || {
        global_prune(&norms, 0.25).achieved_rate
    });

    // Substrate: functional cache, 1M accesses.
    b.run("cache: 1M line-strided accesses (L1 geometry)", || {
        let mut c = Cache::new(CacheConfig::l1());
        let mut h = 0u64;
        for i in 0..1_000_000u64 {
            if c.access((i * 64) % (1 << 20)) {
                h += 1;
            }
        }
        h
    });

    // Substrate: per-cycle systolic simulation, 8x8 tile, M=32.
    let mut arr = SystolicArray::new(ArrayConfig::square(8, Quant::Int8));
    let mut rng = Rng::new(3);
    let w: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
    let x: Vec<f32> = (0..32 * 8).map(|_| rng.normal() as f32).collect();
    arr.program_weights(&w, 0.01);
    b.run("systolic: per-cycle 8x8 tile, M=32", || {
        arr.compute(&x, 32)[0]
    });
    let mut out = vec![0.0f32; 32 * 8];
    b.run("systolic: per-cycle 8x8 tile, M=32, compute_into", || {
        arr.compute_into(&x, 32, &mut out);
        out[0]
    });

    // Functional tile scheduler: a whole masked GEMM on one array (the
    // macro-bench of the per-cycle layer; 64 tiles, 1/4 pruned).
    let (m, k, n) = (32usize, 64usize, 64usize);
    let gx: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let gw: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let mask = TileMask {
        kt: 8,
        nt: 8,
        live: (0..64).map(|i| i % 4 != 0).collect(),
    };
    let mut sched = TileScheduler::new(ArrayConfig::square(8, Quant::Int8));
    let mut y = Vec::new();
    b.run("scheduler: masked GEMM 32x64x64, t=8, 25% pruned", || {
        sched
            .gemm_into(&gx, &gw, m, k, n, Some(&mask), 0.01, &mut y)
            .tiles_live
    });

    // Native inference engine: whole tiny-ASR forward passes (one
    // utterance each). The masked INT8 case at 50% ff tile sparsity must
    // be measurably faster than the dense INT8 case — the functional
    // SASP saving scripts/verify.sh guards on.
    let dims = ModelDims::tiny_asr();
    let mut nb = NativeBackend::new(synth_weights(&dims, 7), 1).expect("backend");
    let feats: Vec<f32> = (0..dims.seq_len * dims.input_dim)
        .map(|_| rng.normal() as f32 * 0.5)
        .collect();
    let pad = vec![1.0f32; dims.seq_len];
    nb.prepare(dims.tile, 0.0, Quant::Fp32).expect("prepare");
    b.run("infer: tiny_asr forward, fp32 dense", || {
        nb.forward_batch(&feats, &pad, 1)[0]
    });
    nb.prepare(dims.tile, 0.0, Quant::Int8).expect("prepare");
    b.run("infer: tiny_asr forward, int8 dense", || {
        nb.forward_batch(&feats, &pad, 1)[0]
    });
    nb.prepare(dims.tile, 0.5, Quant::Int8).expect("prepare");
    b.run("infer: tiny_asr forward, int8 50% pruned", || {
        nb.forward_batch(&feats, &pad, 1)[0]
    });

    // Batched weight-stationary engine vs the per-utterance reference
    // loop — identical weights/masks/inputs, batch 4 (the serving case
    // scripts/verify.sh guards: batched must beat per-utterance on both
    // weight formats, GEMM and encoder scope).
    let bs = 4usize;
    let weights = synth_weights(&dims, 7);
    let plan = global_prune(&ff_norms(&weights, dims.tile).expect("norms"), 0.25);
    let (d, df) = (dims.d_model, dims.d_ff);
    let seq = dims.seq_len;
    let w1 = &weights.blocks[0].w1;
    let w1_mask = &plan.masks[0];
    let gx: Vec<f32> = (0..bs * seq * d).map(|_| rng.normal() as f32).collect();
    let mut gy = Vec::new();
    let mut scratch = Vec::new();
    b.run("infer: ff gemm 4x96x64x256 fp32, per-utterance", || {
        let mut acc = 0.0f32;
        for u in 0..bs {
            gemm_f32(
                &gx[u * seq * d..(u + 1) * seq * d],
                w1,
                seq,
                d,
                df,
                Some(w1_mask),
                dims.tile,
                &mut gy,
            );
            acc += gy[0];
        }
        acc
    });
    b.run("infer: ff gemm 4x96x64x256 fp32, batched ws", || {
        gemm_batched_f32(
            &gx,
            w1,
            bs,
            seq,
            d,
            df,
            Some(w1_mask),
            dims.tile,
            &mut gy,
            &mut scratch,
        );
        gy[0]
    });
    let q1 = QuantizedLinear::from_f32(w1, d, df);
    b.run("infer: ff gemm 4x96x64x256 int8, per-utterance", || {
        let mut acc = 0.0f32;
        for u in 0..bs {
            gemm_int8(
                &gx[u * seq * d..(u + 1) * seq * d],
                &q1,
                seq,
                Some(w1_mask),
                dims.tile,
                &mut gy,
            );
            acc += gy[0];
        }
        acc
    });
    b.run("infer: ff gemm 4x96x64x256 int8, batched ws", || {
        gemm_batched_int8(
            &gx,
            &q1,
            bs,
            seq,
            Some(w1_mask),
            dims.tile,
            &mut gy,
            &mut scratch,
        );
        gy[0]
    });

    // Encoder scope: whole tiny-ASR forwards, per-utterance loop vs one
    // batched weight-stationary pass (bitwise-identical outputs).
    let bfeats: Vec<f32> = (0..bs * seq * dims.input_dim)
        .map(|_| rng.normal() as f32 * 0.5)
        .collect();
    let bpad = vec![1.0f32; bs * seq];
    for quant in [Quant::Fp32, Quant::Int8] {
        let label = match quant {
            Quant::Fp32 => "fp32",
            Quant::Int8 => "int8",
        };
        let model = PreparedModel::new(&weights, dims.tile, quant, Some(&plan.masks))
            .expect("staged model");
        let mut fwd = Forward::new();
        let mut bf = BatchForward::new();
        let mut outv = Vec::new();
        b.run(
            &format!("infer: tiny_asr encoder {label} 25% pruned, per-utterance x4"),
            || {
                let mut acc = 0.0f32;
                for u in 0..bs {
                    fwd.run_feats(
                        &model,
                        &bfeats[u * seq * dims.input_dim..(u + 1) * seq * dims.input_dim],
                        &bpad[..seq],
                        &mut outv,
                    );
                    acc += outv[0];
                }
                acc
            },
        );
        b.run(
            &format!("infer: tiny_asr encoder {label} 25% pruned, batched ws x4"),
            || {
                bf.run_feats(&model, bs, &bfeats, &bpad, &mut outv);
                outv[0]
            },
        );
    }

    // Decode scope: KV-cache greedy stepping vs full-prefix recompute
    // over 32 generated tokens (the serving shape of the autoregressive
    // MT path). Outputs are bitwise identical; the KV cache turns the
    // O(L^2) recompute loop into O(L) single-row steps, and
    // scripts/verify.sh guards that the cached step wins on both weight
    // formats at seq >= 32.
    let mt_dims = ModelDims::tiny_mt();
    let dec_dims = DecoderDims { max_len: 32, ..DecoderDims::tiny_mt() };
    let enc_w = synth_weights(&mt_dims, 7);
    let dec_w = synth_decoder_weights(&dec_dims, 7);
    let enc_model =
        PreparedModel::new(&enc_w, mt_dims.tile, Quant::Fp32, None).expect("enc model");
    let src_len = mt_dims.seq_len;
    let src: Vec<i32> = (0..src_len).map(|i| (i % mt_dims.vocab) as i32).collect();
    let mut efwd = Forward::new();
    let mut memory = Vec::new();
    efwd.memory_tokens(&enc_model, &src, src_len, &mut memory);
    let dec_tokens: Vec<i32> =
        (0..32).map(|i| (i * 5 % dec_dims.vocab) as i32).collect();
    for quant in [Quant::Fp32, Quant::Int8] {
        let label = match quant {
            Quant::Fp32 => "fp32",
            Quant::Int8 => "int8",
        };
        let dm = PreparedDecoder::new(&dec_w, dec_dims.tile, quant, None).expect("dec model");
        let mut dfwd = DecoderForward::new();
        let mut lg = Vec::new();
        b.run(&format!("infer: mt decode 32 steps {label}, kv-cache"), || {
            dfwd.start(&dm, &memory, src_len);
            for &t in &dec_tokens {
                dfwd.step(&dm, t, &mut lg);
            }
            lg[0]
        });
        b.run(
            &format!("infer: mt decode 32 steps {label}, full-prefix recompute"),
            || {
                let mut acc = 0.0f32;
                for p in 1..=dec_tokens.len() {
                    dfwd.full_prefix(&dm, &memory, src_len, &dec_tokens[..p], &mut lg);
                    acc += lg[(p - 1) * dec_dims.vocab];
                }
                acc
            },
        );
    }

    // Continuous iteration-level batching: 8 full greedy decodes, one
    // per-utterance sequential pass vs a ContinuousDecoder packing each
    // step's 8 GEMVs into one [8, d] weight-stationary panel. Both
    // paths run over the same precomputed cross-K/V (the encode cost is
    // shared and hoisted), and produce bitwise-identical tokens;
    // scripts/verify.sh guards that the lockstep panels win on both
    // weight formats.
    {
        let n_utts = 8usize;
        let mut memories: Vec<Vec<f32>> = Vec::with_capacity(n_utts);
        for u in 0..n_utts {
            let src: Vec<i32> = (0..src_len)
                .map(|i| ((i * 3 + u * 7 + 1) % mt_dims.vocab) as i32)
                .collect();
            let mut mem = Vec::new();
            efwd.memory_tokens(&enc_model, &src, src_len, &mut mem);
            memories.push(mem);
        }
        for quant in [Quant::Fp32, Quant::Int8] {
            let label = match quant {
                Quant::Fp32 => "fp32",
                Quant::Int8 => "int8",
            };
            let dm =
                PreparedDecoder::new(&dec_w, dec_dims.tile, quant, None).expect("dec model");
            // Per-utterance, per-block cross-attention K/V, computed
            // once outside the timed region.
            let kv: Vec<Vec<(Vec<f32>, Vec<f32>)>> = memories
                .iter()
                .map(|mem| {
                    dm.blocks
                        .iter()
                        .map(|blk| {
                            let (mut k, mut v) = (Vec::new(), Vec::new());
                            blk.xk.gemm(mem, src_len, None, dm.tile, &mut k);
                            blk.xv.gemm(mem, src_len, None, dm.tile, &mut v);
                            (k, v)
                        })
                        .collect()
                })
                .collect();
            let mut dfwd = DecoderForward::new();
            let mut hyp = Vec::new();
            b.run(
                &format!("infer: mt decode 8 utts {label}, sequential"),
                || {
                    let mut acc = 0usize;
                    for ukv in &kv {
                        dfwd.start_with(&dm, src_len, |i| {
                            (&ukv[i].0[..], &ukv[i].1[..])
                        });
                        dfwd.generate_started(&dm, &mut hyp);
                        acc += hyp.len();
                    }
                    acc
                },
            );
            let mut cd = ContinuousDecoder::new(n_utts);
            b.run(
                &format!("infer: mt decode 8 utts {label}, continuous 8 slots"),
                || {
                    for (u, ukv) in kv.iter().enumerate() {
                        cd.admit(&dm, u as u64, src_len, |i| {
                            (&ukv[i].0[..], &ukv[i].1[..])
                        });
                    }
                    let mut acc = 0usize;
                    while cd.live() > 0 {
                        for fin in cd.step(&dm) {
                            acc += fin.tokens.len();
                        }
                    }
                    acc
                },
            );
        }
    }

    // Serving runtime end-to-end: 16 queued utterances through the
    // batcher + native backend — single-threaded fixed batches of 4 vs
    // one dynamic flush sharded over 4 worker threads (the runtime's
    // two new scaling levers; scripts/verify.sh guards that the
    // dynamic+threaded path wins).
    {
        use sasp::coordinator::serve::{Request, ServeConfig, Server};
        use std::sync::mpsc;
        use std::time::Duration;

        let sdims = ModelDims::tiny_asr();
        let n_req = 16usize;
        let sfeats: Vec<f32> = (0..sdims.seq_len * sdims.input_dim)
            .map(|_| rng.normal() as f32 * 0.5)
            .collect();
        let serve_case = |label: &str, cfg: ServeConfig| {
            let mut nb =
                NativeBackend::new(synth_weights(&sdims, 7), cfg.max_batch).expect("backend");
            nb.prepare(sdims.tile, 0.25, Quant::Int8).expect("prepare");
            let manifest = nb.manifest().clone();
            let mut server = Server::with_manifest(
                &manifest,
                &manifest.name,
                sasp::data::Bundle::default(),
                cfg,
            )
            .expect("server");
            b.run(label, || {
                let (req_tx, req_rx) = mpsc::channel::<Request>();
                let (resp_tx, resp_rx) = mpsc::channel();
                for id in 0..n_req as u64 {
                    req_tx
                        .send(Request::new(id, sfeats.clone(), sdims.seq_len))
                        .unwrap();
                }
                drop(req_tx);
                let report = server.run(&mut nb, req_rx, resp_tx).unwrap();
                assert_eq!(resp_rx.try_iter().count(), n_req);
                report.n_batches
            });
        };
        serve_case(
            "serve: 16 utts int8 25% pruned, fixed batch 4, 1 thread",
            ServeConfig::fixed(4, Duration::from_millis(1)),
        );
        serve_case(
            "serve: 16 utts int8 25% pruned, dynamic batch<=16, 4 threads",
            ServeConfig::dynamic(16, 4),
        );
    }

    // Telemetry overhead on the serving hot path: the same fixed-batch
    // case as the baseline above, first with no recording session (every
    // instrumentation site costs its single-branch gate), then under a
    // live session that records spans/metrics and drains the trace each
    // iteration. scripts/verify.sh guards both against the baseline:
    // telemetry off <= 1.02x, telemetry on <= 1.10x.
    {
        use sasp::coordinator::serve::{Request, ServeConfig, Server};
        use sasp::telemetry::Telemetry;
        use std::sync::mpsc;
        use std::time::Duration;

        let sdims = ModelDims::tiny_asr();
        let n_req = 16usize;
        let sfeats: Vec<f32> = (0..sdims.seq_len * sdims.input_dim)
            .map(|_| rng.normal() as f32 * 0.5)
            .collect();
        let telemetry_case = |label: &str, record: bool| {
            let cfg = ServeConfig::fixed(4, Duration::from_millis(1));
            let mut nb =
                NativeBackend::new(synth_weights(&sdims, 7), cfg.max_batch).expect("backend");
            nb.prepare(sdims.tile, 0.25, Quant::Int8).expect("prepare");
            let manifest = nb.manifest().clone();
            let mut server = Server::with_manifest(
                &manifest,
                &manifest.name,
                sasp::data::Bundle::default(),
                cfg,
            )
            .expect("server");
            b.run(label, || {
                let session =
                    if record { Telemetry::start() } else { Telemetry::noop() };
                let (req_tx, req_rx) = mpsc::channel::<Request>();
                let (resp_tx, resp_rx) = mpsc::channel();
                for id in 0..n_req as u64 {
                    req_tx
                        .send(Request::new(id, sfeats.clone(), sdims.seq_len))
                        .unwrap();
                }
                drop(req_tx);
                let report = server.run(&mut nb, req_rx, resp_tx).unwrap();
                assert_eq!(resp_rx.try_iter().count(), n_req);
                let trace = session.finish();
                assert!(!record || !trace.events.is_empty());
                report.n_batches + trace.events.len()
            });
        };
        telemetry_case(
            "serve: 16 utts int8 25% pruned, fixed batch 4, telemetry off",
            false,
        );
        telemetry_case(
            "serve: 16 utts int8 25% pruned, fixed batch 4, telemetry on",
            true,
        );
    }

    // Overload resilience: 32 utterances pre-queued against dynamic
    // flushes of 4 — an 8-deep standing backlog (2x the steady-state
    // capacity of the 16-utt case above). The degradation-ladder run
    // steps the backend from 25% to 90% pruning once pressure exceeds
    // the watermark, draining the queue faster; scripts/verify.sh
    // guards that its internal Ok-latency p99 stays <= 0.8x the
    // no-ladder run's. Recorded via Bench::record because p99 is
    // measured inside the serving report, not by timing the closure.
    {
        use sasp::coordinator::resilience::{
            LadderConfig, OperatingPoint, ResilienceConfig, ShedPolicy,
        };
        use sasp::coordinator::serve::{Request, ServeConfig, Server};
        use std::sync::mpsc;

        let sdims = ModelDims::tiny_asr();
        let n_req = 32usize;
        let sfeats: Vec<f32> = (0..sdims.seq_len * sdims.input_dim)
            .map(|_| rng.normal() as f32 * 0.5)
            .collect();
        let overload_case = |label: &str, ladder: Option<LadderConfig>| {
            let cfg = ServeConfig::dynamic(4, 1);
            let mut nb =
                NativeBackend::new(synth_weights(&sdims, 7), cfg.max_batch).expect("backend");
            nb.prepare(sdims.tile, 0.25, Quant::Int8).expect("prepare");
            let manifest = nb.manifest().clone();
            let mut server = Server::with_manifest(
                &manifest,
                &manifest.name,
                sasp::data::Bundle::default(),
                cfg,
            )
            .expect("server");
            let mut res = ResilienceConfig::bounded(64, ShedPolicy::RejectNew);
            if let Some(l) = ladder {
                res = res.with_ladder(l);
            }
            server.set_resilience(res);
            let (req_tx, req_rx) = mpsc::channel::<Request>();
            let (resp_tx, resp_rx) = mpsc::channel();
            for id in 0..n_req as u64 {
                req_tx
                    .send(Request::new(id, sfeats.clone(), sdims.seq_len))
                    .unwrap();
            }
            drop(req_tx);
            let report = server.run(&mut nb, req_rx, resp_tx).unwrap();
            assert_eq!(resp_rx.try_iter().count(), n_req);
            assert_eq!(report.n_requests, n_req, "nothing shed at capacity 64");
            b.record(label, report.p99);
        };
        overload_case("serve: 32 utts pre-queued overload, no ladder, p99", None);
        overload_case(
            "serve: 32 utts pre-queued overload, degradation ladder, p99",
            Some(LadderConfig {
                points: vec![
                    OperatingPoint::new(0.25, Quant::Int8),
                    OperatingPoint::new(0.9, Quant::Int8),
                ],
                high_watermark: 2,
                low_watermark: 0,
                patience: 1,
                recover_after: 1_000,
            }),
        );
    }

    // Runtime: tensor -> literal conversion (the PJRT argument path).
    let big = Tensor::from_f32(&[16, 96, 40], &vec![0.5f32; 16 * 96 * 40]);
    b.run("runtime: tensor->literal 240KB f32", || {
        sasp::runtime::tensor_to_literal(&big).unwrap()
    });

    // PJRT dispatch (artifact-gated).
    if std::path::Path::new("artifacts/sasp_gemm_t8.hlo.txt").exists() {
        let mut engine = Engine::new("artifacts").expect("engine");
        let golden = sasp::data::load_bundle("artifacts/golden_gemm.bin").unwrap();
        let args = vec![
            golden.require("x").unwrap().clone(),
            golden.require("w").unwrap().clone(),
            golden.require("mask").unwrap().clone(),
        ];
        engine.load("sasp_gemm_t8").unwrap();
        b.run("pjrt: sasp_gemm_t8 execute (64x64x64)", || {
            engine.execute("sasp_gemm_t8", &args).unwrap()
        });
    } else {
        println!("pjrt bench skipped (no artifacts)");
    }
}
