//! Bench + regeneration of Fig. 8: per-layer normalized encoder runtime
//! under SASP at two global sparsity targets (8x8 FP32_INT8 array).

use sasp::coordinator::Explorer;
use sasp::harness;
use sasp::model::zoo;
use sasp::systolic::Quant;
use sasp::util::bench::Bench;

fn main() {
    let ex = Explorer::new(zoo::espnet_asr());
    let b = Bench::default();
    b.run("fig8 per-layer sim (18 blocks, 2 rates)", || {
        let a = ex.per_layer_normalized(8, Quant::Int8, 0.25);
        let c = ex.per_layer_normalized(8, Quant::Int8, 0.375);
        a[0] + c[17]
    });
    print!("{}", harness::fig8().render());
}
