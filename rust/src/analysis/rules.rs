//! The per-file rule engine: six codebase-specific rules over the
//! [`super::lexer`] token stream, plus allow-pragma handling.
//!
//! Every rule is scoped by *relative path under `src/`* (forward-slash
//! separators), runs only over non-`#[cfg(test)]` tokens, and reports
//! findings keyed by `(rule, file, trimmed line text)` — the key the
//! ratchet baseline matches on, so findings survive unrelated line
//! drift.
//!
//! Suppression: a `// lint:allow(serve-path-panic) -- index bounded above`
//! style comment allows the named rule on its own line and the line
//! directly below it. The reason is mandatory; a pragma without one (or
//! naming an unknown rule) is itself a `bad-pragma` finding, which
//! cannot be suppressed.

use std::collections::BTreeMap;

use super::lexer::{lex, Tok, TokKind};

/// Every rule id, in report order.
pub const RULES: &[&str] = &[
    "hot-loop-alloc",
    "unlabeled-gemm-site",
    "atomic-ordering-audit",
    "serve-path-panic",
    "bitwise-contract-drift",
    "lint-hygiene",
    "bad-pragma",
];

/// How many lines below a GEMM call a `layers::record(..)` attribution
/// call must appear (the codebase idiom places it 1–12 lines after the
/// call, often inside a `telemetry::active()` guard).
const GEMM_LABEL_WINDOW: u32 = 16;

/// Modules where the functional==analytic / bitwise-oracle contract
/// makes floating-point accumulation *order* part of the API.
const BITWISE_FILES: &[&str] = &[
    "infer/ops.rs",
    "infer/gemm.rs",
    "infer/encoder.rs",
    "infer/batch/gemm.rs",
    "infer/batch/encoder.rs",
    "infer/decoder/mod.rs",
    "infer/decoder/forward.rs",
    "infer/decoder/continuous.rs",
    "systolic/array.rs",
    "systolic/scheduler.rs",
];

/// Files whose non-test code must produce error `Response`s, never
/// panic (a panic kills the batcher thread and every queued request).
const SERVE_FILES: &[&str] = &["coordinator/serve.rs", "coordinator/resilience.rs"];

/// Keywords that can directly precede `[` without it being an index
/// expression (`&mut [f32]`, `return [a, b]`, ...).
const NONINDEX_KEYWORDS: &[&str] = &[
    "as", "box", "break", "const", "continue", "crate", "dyn", "else", "enum", "fn", "for",
    "if", "impl", "in", "let", "loop", "match", "move", "mut", "pub", "ref", "return",
    "static", "struct", "trait", "type", "union", "unsafe", "use", "where", "while",
];

/// One lint finding. `text` is the trimmed source line — the stable
/// part of the baseline key.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub text: String,
    pub msg: String,
}

struct FileCtx<'a> {
    path: &'a str,
    lines: Vec<&'a str>,
    toks: Vec<Tok>,
    /// Comment text per line (merged when a line has several).
    comments: BTreeMap<u32, String>,
    /// Token is inside a `#[cfg(test)]` item.
    in_test: Vec<bool>,
    /// Token is inside a `for`/`while`/`loop` body.
    in_loop: Vec<bool>,
}

/// Run every rule over one file. `path` is the path relative to the
/// source root, with `/` separators.
pub fn check_file(path: &str, src: &str) -> Vec<Finding> {
    let lexed = lex(src);
    let mut comments: BTreeMap<u32, String> = BTreeMap::new();
    for (line, text) in &lexed.comments {
        let e = comments.entry(*line).or_default();
        if !e.is_empty() {
            e.push(' ');
        }
        e.push_str(text);
    }
    let in_test = test_mask(&lexed.toks);
    let in_loop = loop_mask(&lexed.toks);
    let ctx = FileCtx {
        path,
        lines: src.lines().collect(),
        toks: lexed.toks,
        comments,
        in_test,
        in_loop,
    };

    let mut findings = Vec::new();
    rule_hot_loop_alloc(&ctx, &mut findings);
    rule_unlabeled_gemm_site(&ctx, &mut findings);
    rule_atomic_ordering_audit(&ctx, &mut findings);
    rule_serve_path_panic(&ctx, &mut findings);
    rule_bitwise_contract_drift(&ctx, &mut findings);
    rule_lint_hygiene(&ctx, &mut findings);

    // Pragmas: collect valid allows, report malformed ones.
    let mut allows: Vec<(String, u32)> = Vec::new();
    for (line, text) in &ctx.comments {
        let Some(at) = text.find("lint:allow(") else { continue };
        let rest = &text[at + "lint:allow(".len()..];
        let (rule, tail) = match rest.find(')') {
            Some(p) => (rest[..p].trim(), &rest[p + 1..]),
            None => ("", rest),
        };
        let reason_ok = tail
            .find("--")
            .map(|p| !tail[p + 2..].trim().is_empty())
            .unwrap_or(false);
        if !RULES.contains(&rule) {
            findings.push(ctx.finding(
                "bad-pragma",
                *line,
                format!("lint:allow names unknown rule '{rule}'"),
            ));
        } else if !reason_ok {
            findings.push(ctx.finding(
                "bad-pragma",
                *line,
                format!("lint:allow({rule}) needs a `-- <reason>` justification"),
            ));
        } else {
            allows.push((rule.to_string(), *line));
        }
    }
    findings.retain(|f| {
        f.rule == "bad-pragma"
            || !allows
                .iter()
                .any(|(rule, line)| rule == f.rule && (f.line == *line || f.line == *line + 1))
    });
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

impl<'a> FileCtx<'a> {
    fn finding(&self, rule: &'static str, line: u32, msg: String) -> Finding {
        let text = self
            .lines
            .get(line.saturating_sub(1) as usize)
            .map(|l| l.trim().to_string())
            .unwrap_or_default();
        Finding { rule, file: self.path.to_string(), line, text, msg }
    }

    /// Does any comment on `line` contain `marker`?
    fn comment_has(&self, line: u32, marker: &str) -> bool {
        self.comments.get(&line).is_some_and(|t| t.contains(marker))
    }

    fn ident_at(&self, i: usize, s: &str) -> bool {
        self.toks.get(i).is_some_and(|t| t.is_ident(s))
    }

    fn punct_at(&self, i: usize, c: char) -> bool {
        self.toks.get(i).is_some_and(|t| t.is_punct(c))
    }
}

/// Mark tokens inside `#[cfg(test)]` items (attribute through the end
/// of the annotated item — brace-matched, or up to `;` for brace-less
/// items).
fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0;
    while i + 6 < toks.len() {
        let is_cfg_test = toks[i].is_punct('#')
            && toks[i + 1].is_punct('[')
            && toks[i + 2].is_ident("cfg")
            && toks[i + 3].is_punct('(')
            && toks[i + 4].is_ident("test")
            && toks[i + 5].is_punct(')')
            && toks[i + 6].is_punct(']');
        if !is_cfg_test {
            i += 1;
            continue;
        }
        let mut j = i + 7;
        let mut end = toks.len().saturating_sub(1);
        while j < toks.len() {
            if toks[j].is_punct(';') {
                end = j;
                break;
            }
            if toks[j].is_punct('{') {
                end = match_brace(toks, j);
                break;
            }
            j += 1;
        }
        for m in mask.iter_mut().take(end + 1).skip(i) {
            *m = true;
        }
        i = end + 1;
    }
    mask
}

/// Index of the `}` matching the `{` at `open` (last token if the file
/// is unbalanced — the lexer guarantees nothing, the mask degrades
/// gracefully).
fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// Mark tokens inside `for`/`while`/`loop` bodies.
fn loop_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    for i in 0..toks.len() {
        let Some(kw) = toks[i].ident() else { continue };
        if kw != "for" && kw != "while" && kw != "loop" {
            continue;
        }
        // `for` in `impl Trait for Type` heads a type, not a loop; the
        // next `{` would be the impl body. Filter: a loop `for` is
        // never directly preceded by an identifier or `>`.
        if kw == "for"
            && i > 0
            && (toks[i - 1].ident().is_some() || toks[i - 1].is_punct('>'))
        {
            continue;
        }
        // Find the body `{`: first brace outside parens/brackets.
        let mut depth = 0i32;
        let mut open = None;
        for (j, t) in toks.iter().enumerate().skip(i + 1) {
            match &t.kind {
                TokKind::Punct('(') | TokKind::Punct('[') => depth += 1,
                TokKind::Punct(')') | TokKind::Punct(']') => depth -= 1,
                TokKind::Punct('{') if depth == 0 => {
                    open = Some(j);
                    break;
                }
                TokKind::Punct(';') if depth == 0 => break,
                _ => {}
            }
        }
        let Some(open) = open else { continue };
        let close = match_brace(toks, open);
        for m in mask.iter_mut().take(close).skip(open + 1) {
            *m = true;
        }
    }
    mask
}

/// Rule 1: no allocation/copy calls inside kernel-module loop bodies.
fn rule_hot_loop_alloc(ctx: &FileCtx, out: &mut Vec<Finding>) {
    let kernel = ctx.path == "infer/gemm.rs"
        || ctx.path.starts_with("infer/batch/")
        || ctx.path.starts_with("infer/decoder/")
        || ctx.path.starts_with("systolic/");
    if !kernel {
        return;
    }
    for i in 0..ctx.toks.len() {
        if ctx.in_test[i] || !ctx.in_loop[i] {
            continue;
        }
        let t = &ctx.toks[i];
        let hit = match t.ident() {
            Some(m @ ("push" | "clone" | "to_vec" | "collect"))
                if i > 0 && ctx.toks[i - 1].is_punct('.') =>
            {
                Some(format!("`.{m}(..)` in a kernel loop body"))
            }
            Some("Vec")
                if ctx.punct_at(i + 1, ':')
                    && ctx.punct_at(i + 2, ':')
                    && (ctx.ident_at(i + 3, "new") || ctx.ident_at(i + 3, "with_capacity")) =>
            {
                Some("`Vec` constructed in a kernel loop body".to_string())
            }
            Some("vec") if ctx.punct_at(i + 1, '!') => {
                Some("`vec![..]` in a kernel loop body".to_string())
            }
            _ => None,
        };
        if let Some(what) = hit {
            out.push(ctx.finding(
                "hot-loop-alloc",
                t.line,
                format!("{what}: allocate once outside the loop and reuse"),
            ));
        }
    }
}

/// Rule 2: every GEMM execution site in `infer/` must be followed by a
/// `layers::record(..)` attribution call within [`GEMM_LABEL_WINDOW`]
/// lines, so the per-layer accounting stays total.
fn rule_unlabeled_gemm_site(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !ctx.path.starts_with("infer/")
        || matches!(ctx.path, "infer/gemm.rs" | "infer/batch/gemm.rs" | "infer/layers.rs")
    {
        return;
    }
    // All lines holding a `layers::record(` (or `..::layers::record(`).
    let mut record_lines: Vec<u32> = Vec::new();
    for i in 0..ctx.toks.len() {
        if ctx.toks[i].is_ident("layers")
            && ctx.punct_at(i + 1, ':')
            && ctx.punct_at(i + 2, ':')
            && ctx.ident_at(i + 3, "record")
        {
            record_lines.push(ctx.toks[i].line);
        }
    }
    for i in 0..ctx.toks.len() {
        if ctx.in_test[i] {
            continue;
        }
        let Some(name) = ctx.toks[i].ident() else { continue };
        let method = matches!(name, "gemm" | "gemm_batched") && i > 0 && ctx.toks[i - 1].is_punct('.');
        let free = matches!(name, "gemm_f32" | "gemm_int8")
            && (i == 0 || !ctx.toks[i - 1].is_punct(':'));
        if !(method || free) || !ctx.punct_at(i + 1, '(') {
            continue;
        }
        if i > 0 && ctx.toks[i - 1].is_ident("fn") {
            continue; // a definition, not a call site
        }
        let line = ctx.toks[i].line;
        let labeled = record_lines
            .iter()
            .any(|&r| r >= line && r <= line + GEMM_LABEL_WINDOW);
        if !labeled {
            out.push(ctx.finding(
                "unlabeled-gemm-site",
                line,
                format!(
                    "`{name}(..)` has no `layers::record(..)` within {GEMM_LABEL_WINDOW} \
                     lines — per-layer attribution would go dark here"
                ),
            ));
        }
    }
}

/// Rule 3: every atomic `Ordering::` use needs an `// ordering:`
/// justification — on the same line, or anywhere in the contiguous
/// comment block directly above it; `SeqCst` is flagged unconditionally
/// (pragma-only, so the strongest ordering is always a deliberate,
/// reviewed choice).
fn rule_atomic_ordering_audit(ctx: &FileCtx, out: &mut Vec<Finding>) {
    const VARIANTS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];
    // Lines already justified — a use within two lines below one
    // inherits it, so one comment can cover a tight cluster.
    let mut justified: Vec<u32> = Vec::new();
    for i in 0..ctx.toks.len() {
        if ctx.in_test[i] || !ctx.toks[i].is_ident("Ordering") {
            continue;
        }
        if !(ctx.punct_at(i + 1, ':') && ctx.punct_at(i + 2, ':')) {
            continue;
        }
        let Some(variant) = ctx.toks.get(i + 3).and_then(|t| t.ident()) else { continue };
        if !VARIANTS.contains(&variant) {
            continue; // std::cmp::Ordering::{Less,Equal,Greater}
        }
        let line = ctx.toks[i].line;
        // Same-line marker, or the marker anywhere in the comment
        // lines stacked directly on top of this one.
        let mut commented = ctx.comment_has(line, "ordering:");
        let mut l = line.saturating_sub(1);
        while !commented && l >= 1 && ctx.comments.contains_key(&l) {
            commented = ctx.comment_has(l, "ordering:");
            l -= 1;
        }
        let chained = justified
            .iter()
            .any(|&j| j < line && line - j <= 2);
        if commented || chained {
            justified.push(line);
        }
        if variant == "SeqCst" {
            out.push(ctx.finding(
                "atomic-ordering-audit",
                line,
                "Ordering::SeqCst — justify why a weaker ordering is insufficient \
                 via `// lint:allow(atomic-ordering-audit) -- <reason>`"
                    .to_string(),
            ));
        } else if !(commented || chained) {
            out.push(ctx.finding(
                "atomic-ordering-audit",
                line,
                format!(
                    "Ordering::{variant} without an `// ordering:` justification on \
                     this line or in the comment block directly above it"
                ),
            ));
        }
    }
}

/// Rule 4: no panicking constructs in the serving request path.
fn rule_serve_path_panic(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !SERVE_FILES.contains(&ctx.path) {
        return;
    }
    for i in 0..ctx.toks.len() {
        if ctx.in_test[i] {
            continue;
        }
        let t = &ctx.toks[i];
        match &t.kind {
            TokKind::Ident(name) => {
                let method_panic = matches!(name.as_str(), "unwrap" | "expect")
                    && i > 0
                    && ctx.toks[i - 1].is_punct('.')
                    && ctx.punct_at(i + 1, '(');
                let macro_panic = matches!(
                    name.as_str(),
                    "panic" | "unreachable" | "todo" | "unimplemented" | "assert"
                        | "assert_eq" | "assert_ne"
                ) && ctx.punct_at(i + 1, '!');
                if method_panic {
                    out.push(ctx.finding(
                        "serve-path-panic",
                        t.line,
                        format!(
                            "`.{name}(..)` in the serving request path — a panic here \
                             kills the batcher; produce an error Response instead"
                        ),
                    ));
                } else if macro_panic {
                    out.push(ctx.finding(
                        "serve-path-panic",
                        t.line,
                        format!(
                            "`{name}!(..)` in the serving request path — return an \
                             error (`ensure!`/`bail!`) so the caller degrades gracefully"
                        ),
                    ));
                }
            }
            TokKind::Punct('[') if i > 0 => {
                let prev = &ctx.toks[i - 1];
                let indexes = match &prev.kind {
                    TokKind::Ident(p) => !NONINDEX_KEYWORDS.contains(&p.as_str()),
                    TokKind::Punct(']') | TokKind::Punct(')') => true,
                    _ => false,
                };
                if indexes {
                    out.push(ctx.finding(
                        "serve-path-panic",
                        t.line,
                        "slice/array indexing in the serving request path can panic — \
                         use `.get(..)` or restructure"
                            .to_string(),
                    ));
                }
            }
            _ => {}
        }
    }
}

/// Rule 5: in bitwise-contract modules, forbid rewrites that change
/// floating-point accumulation order or contract FMA.
fn rule_bitwise_contract_drift(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !BITWISE_FILES.contains(&ctx.path) {
        return;
    }
    const FAST_INTRINSICS: &[&str] =
        &["fadd_fast", "fsub_fast", "fmul_fast", "fdiv_fast", "frem_fast", "fadd_algebraic", "fmul_algebraic"];
    for i in 0..ctx.toks.len() {
        if ctx.in_test[i] {
            continue;
        }
        let Some(name) = ctx.toks[i].ident() else { continue };
        let line = ctx.toks[i].line;
        if name == "mul_add" {
            out.push(ctx.finding(
                "bitwise-contract-drift",
                line,
                "`mul_add` fuses rounding — bitwise-oracle outputs would diverge \
                 between code paths"
                    .to_string(),
            ));
        } else if FAST_INTRINSICS.contains(&name) {
            out.push(ctx.finding(
                "bitwise-contract-drift",
                line,
                format!("`{name}` licenses reassociation — forbidden in bitwise-contract modules"),
            ));
        } else if matches!(name, "sum" | "product" | "fold")
            && i > 0
            && ctx.toks[i - 1].is_punct('.')
        {
            out.push(ctx.finding(
                "bitwise-contract-drift",
                line,
                format!(
                    "`.{name}(..)` reduction in a bitwise-contract module — accumulation \
                     order is part of the contract; keep the explicit loop, or pragma \
                     with why the order is pinned (or exact)"
                ),
            ));
        }
    }
}

/// Rule 6: the crate root must carry `#![forbid(unsafe_code)]` and a
/// non-empty `#![deny(..)]` set.
fn rule_lint_hygiene(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if ctx.path != "lib.rs" {
        return;
    }
    let mut has_forbid_unsafe = false;
    let mut has_deny = false;
    for i in 0..ctx.toks.len() {
        if !(ctx.toks[i].is_punct('#') && ctx.punct_at(i + 1, '!') && ctx.punct_at(i + 2, '[')) {
            continue;
        }
        if ctx.ident_at(i + 3, "forbid")
            && ctx.punct_at(i + 4, '(')
            && ctx.ident_at(i + 5, "unsafe_code")
        {
            has_forbid_unsafe = true;
        }
        if ctx.ident_at(i + 3, "deny")
            && ctx.punct_at(i + 4, '(')
            && ctx.toks.get(i + 5).is_some_and(|t| t.ident().is_some())
        {
            has_deny = true;
        }
    }
    if !has_forbid_unsafe {
        out.push(ctx.finding(
            "lint-hygiene",
            1,
            "crate root is missing `#![forbid(unsafe_code)]`".to_string(),
        ));
    }
    if !has_deny {
        out.push(ctx.finding(
            "lint-hygiene",
            1,
            "crate root is missing a `#![deny(..)]` hygiene set".to_string(),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ---- hot-loop-alloc ------------------------------------------------

    #[test]
    fn lint_hot_loop_alloc_fires_in_kernel_loop() {
        let src = "fn k(out: &mut Vec<f32>) {\n    for i in 0..4 {\n        out.push(1.0);\n        let v = Vec::new();\n        let w = vec![0; 4];\n    }\n}\n";
        let f = check_file("systolic/array.rs", src);
        assert_eq!(rules_of(&f), vec!["hot-loop-alloc"; 3], "{f:?}");
        assert_eq!(f[0].line, 3);
        assert_eq!(f[0].text, "out.push(1.0);");
    }

    #[test]
    fn lint_hot_loop_alloc_ignores_non_kernel_and_non_loop() {
        let src = "fn k(out: &mut Vec<f32>) {\n    for i in 0..4 {\n        out.push(1.0);\n    }\n}\n";
        // Same code outside the kernel module set: clean.
        assert!(check_file("coordinator/serve.rs", src).iter().all(|f| f.rule != "hot-loop-alloc"));
        // Allocation outside any loop in a kernel module: clean.
        let src2 = "fn k() -> Vec<f32> {\n    let mut v = Vec::new();\n    v.push(1.0);\n    v\n}\n";
        assert!(check_file("infer/gemm.rs", src2).is_empty());
        // Test code in a kernel module: clean.
        let src3 = "#[cfg(test)]\nmod tests {\n    fn t() {\n        for i in 0..4 {\n            let mut v = Vec::new();\n            v.push(i);\n        }\n    }\n}\n";
        assert!(check_file("infer/gemm.rs", src3).is_empty());
    }

    // ---- unlabeled-gemm-site -------------------------------------------

    #[test]
    fn lint_unlabeled_gemm_site_fires_without_record() {
        let src = "fn f() {\n    let s = w.gemm(&x, t, None, tile, &mut out);\n}\n";
        let f = check_file("infer/encoder.rs", src);
        assert_eq!(rules_of(&f), vec!["unlabeled-gemm-site"]);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn lint_unlabeled_gemm_site_satisfied_by_nearby_record() {
        let src = "fn f() {\n    let s = w.gemm(&x, t, None, tile, &mut out);\n    layers::record(Layer::Qkv, &s, tile, quant);\n}\n";
        assert!(check_file("infer/encoder.rs", src).is_empty());
        // The kernel-definition modules are out of scope.
        let src2 = "fn f() {\n    let s = gemm_f32(&x, &w);\n}\n";
        assert!(check_file("infer/gemm.rs", src2).is_empty());
    }

    // ---- atomic-ordering-audit -----------------------------------------

    #[test]
    fn lint_atomic_ordering_audit_requires_justification() {
        let src = "fn f() {\n    A.store(1, Ordering::Relaxed);\n}\n";
        let f = check_file("telemetry/spans.rs", src);
        assert_eq!(rules_of(&f), vec!["atomic-ordering-audit"]);
        // cmp::Ordering variants never match.
        let src2 = "fn f() -> Ordering {\n    Ordering::Equal\n}\n";
        assert!(check_file("coordinator/explorer.rs", src2).is_empty());
    }

    #[test]
    fn lint_atomic_ordering_audit_accepts_comment_and_cluster() {
        let src = "fn f() {\n    // ordering: Relaxed — counter merged at scrape.\n    a.fetch_add(1, Ordering::Relaxed);\n    b.fetch_add(1, Ordering::Relaxed);\n    c.load(Ordering::Relaxed);\n}\n";
        assert!(check_file("telemetry/metrics.rs", src).is_empty());
        // Multi-line justification: the marker may sit anywhere in the
        // comment block stacked directly above the use.
        let src2 = "fn f() {\n    // ordering: Relaxed — a unique-id counter; only atomicity\n    // of the increment matters, never inter-thread ordering\n    // (ids are compared for equality, not for order).\n    a.fetch_add(1, Ordering::Relaxed);\n}\n";
        assert!(check_file("telemetry/spans.rs", src2).is_empty());
        // ... but a comment block separated by a code line does not count.
        let src3 = "fn f() {\n    // ordering: Relaxed — stale doc.\n    let x = 1;\n    a.fetch_add(x, Ordering::Relaxed);\n}\n";
        assert_eq!(rules_of(&check_file("telemetry/spans.rs", src3)), vec!["atomic-ordering-audit"]);
    }

    #[test]
    fn lint_atomic_ordering_audit_flags_seqcst_even_with_comment() {
        let src = "fn f() {\n    // ordering: belt and braces.\n    A.store(1, Ordering::SeqCst);\n}\n";
        let f = check_file("telemetry/spans.rs", src);
        assert_eq!(rules_of(&f), vec!["atomic-ordering-audit"]);
        // ... but a pragma (deliberate, reviewed) allows it.
        let src2 = "fn f() {\n    // lint:allow(atomic-ordering-audit) -- store must fence the epoch init\n    A.store(1, Ordering::SeqCst);\n}\n";
        assert!(check_file("telemetry/spans.rs", src2).is_empty());
    }

    // ---- serve-path-panic ----------------------------------------------

    #[test]
    fn lint_serve_path_panic_fires_on_each_construct() {
        let src = "fn f(v: &[u64], o: Option<u64>) -> u64 {\n    let a = o.unwrap();\n    let b = o.expect(\"set\");\n    if v.is_empty() { panic!(\"no\"); }\n    assert!(a > 0);\n    v[0] + a + b\n}\n";
        let f = check_file("coordinator/serve.rs", src);
        assert_eq!(
            rules_of(&f),
            vec!["serve-path-panic"; 5],
            "unwrap, expect, panic!, assert!, indexing: {f:?}"
        );
    }

    #[test]
    fn lint_serve_path_panic_ignores_tests_and_other_files() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let v = vec![1];\n        assert_eq!(v[0], 1);\n        v.get(9).unwrap();\n    }\n}\n";
        assert!(check_file("coordinator/serve.rs", src).is_empty());
        let src2 = "fn f(o: Option<u64>) -> u64 {\n    o.unwrap()\n}\n";
        assert!(check_file("infer/encoder.rs", src2).is_empty());
        // Slice *types* and attributes are not index expressions.
        let src3 = "fn f(x: &mut [f32]) -> [u8; 4] {\n    let [a, b] = [1u8, 2];\n    [a, b, a, b]\n}\n";
        assert!(check_file("coordinator/resilience.rs", src3).is_empty());
    }

    // ---- bitwise-contract-drift ----------------------------------------

    #[test]
    fn lint_bitwise_contract_drift_fires_on_mul_add_and_reductions() {
        let src = "fn f(xs: &[f32]) -> f32 {\n    let s: f32 = xs.iter().sum();\n    let m = xs.iter().fold(0.0f32, |a, b| a + b);\n    s.mul_add(2.0, m)\n}\n";
        let f = check_file("infer/ops.rs", src);
        assert_eq!(rules_of(&f), vec!["bitwise-contract-drift"; 3], "{f:?}");
    }

    #[test]
    fn lint_bitwise_contract_drift_scoped_to_contract_modules() {
        let src = "fn f(xs: &[usize]) -> usize {\n    xs.iter().sum()\n}\n";
        assert!(check_file("coordinator/serve.rs", src).is_empty());
        // A pragma with a reason allows an order-insensitive reduction.
        let src2 = "fn f(xs: &[f32]) -> f32 {\n    // lint:allow(bitwise-contract-drift) -- max-fold is order-independent\n    xs.iter().fold(0.0f32, |a, b| a.max(b))\n}\n";
        assert!(check_file("infer/ops.rs", src2).is_empty());
    }

    // ---- lint-hygiene --------------------------------------------------

    #[test]
    fn lint_hygiene_requires_forbid_and_deny() {
        let src = "pub mod a;\n";
        let f = check_file("lib.rs", src);
        assert_eq!(rules_of(&f), vec!["lint-hygiene"; 2]);
        let src2 = "#![forbid(unsafe_code)]\n#![deny(keyword_idents, non_ascii_idents)]\npub mod a;\n";
        assert!(check_file("lib.rs", src2).is_empty());
        // Other files carry no such obligation.
        assert!(check_file("main.rs", src).is_empty());
    }

    // ---- pragmas -------------------------------------------------------

    #[test]
    fn lint_pragma_suppresses_own_and_next_line_only() {
        let src = "fn f(v: &[u64]) -> u64 {\n    // lint:allow(serve-path-panic) -- index bounded by caller contract\n    v[0]\n}\n";
        assert!(check_file("coordinator/serve.rs", src).is_empty());
        // Same-line (trailing) pragma.
        let src2 = "fn f(v: &[u64]) -> u64 {\n    v[0] // lint:allow(serve-path-panic) -- bounded\n}\n";
        assert!(check_file("coordinator/serve.rs", src2).is_empty());
        // Two lines below: out of the pragma window.
        let src3 = "fn f(v: &[u64]) -> u64 {\n    // lint:allow(serve-path-panic) -- bounded\n    let x = 1;\n    v[0]\n}\n";
        assert_eq!(rules_of(&check_file("coordinator/serve.rs", src3)), vec!["serve-path-panic"]);
    }

    #[test]
    fn lint_bad_pragma_flags_missing_reason_and_unknown_rule() {
        let src = "fn f(v: &[u64]) -> u64 {\n    // lint:allow(serve-path-panic)\n    v[0]\n}\n";
        let f = check_file("coordinator/serve.rs", src);
        // The malformed pragma does not suppress, and is itself flagged.
        assert_eq!(rules_of(&f), vec!["bad-pragma", "serve-path-panic"], "{f:?}");
        let src2 = "// lint:allow(no-such-rule) -- whatever\nfn f() {}\n";
        assert_eq!(rules_of(&check_file("infer/mod.rs", src2)), vec!["bad-pragma"]);
    }

    // ---- masks ---------------------------------------------------------

    #[test]
    fn lint_loop_mask_sees_through_closure_parens() {
        // The `{` inside the iterator-chain closure must not be taken
        // for the loop body.
        let src = "fn k(xs: &[usize], out: &mut Vec<usize>) {\n    for x in xs.iter().map(|v| { v + 1 }) {\n        out.push(x);\n    }\n}\n";
        let f = check_file("systolic/pe.rs", src);
        assert_eq!(rules_of(&f), vec!["hot-loop-alloc"]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn lint_impl_trait_for_is_not_a_loop() {
        let src = "impl Clone for Thing {\n    fn clone(&self) -> Thing {\n        Thing\n    }\n}\n";
        assert!(check_file("systolic/pe.rs", src).is_empty());
    }
}
