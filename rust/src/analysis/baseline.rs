//! The ratchet baseline: known findings, grandfathered but frozen.
//!
//! The baseline file (`rust/lint-baseline.json`) is committed. Each
//! entry records one tolerated finding keyed by `(rule, file, trimmed
//! line text)` — deliberately *not* the line number, so findings
//! survive unrelated edits above them. Matching is multiset-budgeted:
//! three identical baseline entries tolerate at most three identical
//! findings.
//!
//! The ratchet has teeth in both directions:
//!
//! - a finding with no baseline budget is **fresh** → the lint fails;
//! - a baseline entry with no matching finding is **stale** → the lint
//!   fails too, so fixed findings must be deleted from the baseline
//!   (they can never quietly come back).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use crate::util::json::Json;
use crate::Result;

use super::rules::Finding;

/// One tolerated finding. `reason` documents *why* it is tolerated —
/// it is preserved across `--write-baseline` refreshes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BaselineEntry {
    pub rule: String,
    pub file: String,
    pub text: String,
    pub reason: String,
}

impl BaselineEntry {
    fn key(&self) -> (String, String, String) {
        (self.rule.clone(), self.file.clone(), self.text.clone())
    }
}

/// The findings a lint run tolerates.
#[derive(Clone, Debug, Default)]
pub struct Baseline {
    pub entries: Vec<BaselineEntry>,
}

/// A lint run split against a baseline.
#[derive(Debug, Default)]
pub struct Applied {
    /// Findings covered by a baseline entry (tolerated).
    pub grandfathered: Vec<Finding>,
    /// Findings with no baseline budget (fail the run).
    pub fresh: Vec<Finding>,
    /// Baseline entries no finding matched (fail the run — delete them).
    pub stale: Vec<BaselineEntry>,
}

impl Baseline {
    /// Load a baseline; a missing file is an empty baseline (the state
    /// of a fully clean tree).
    pub fn load(path: &Path) -> Result<Baseline> {
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Baseline::default())
            }
            Err(e) => return Err(anyhow::anyhow!("read {}: {e}", path.display())),
        };
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let mut entries = Vec::new();
        for (i, e) in doc.get("entries").as_arr().unwrap_or(&[]).iter().enumerate() {
            let field = |k: &str| -> Result<String> {
                e.get(k)
                    .as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("{}: entry {i} missing '{k}'", path.display()))
            };
            entries.push(BaselineEntry {
                rule: field("rule")?,
                file: field("file")?,
                text: field("text")?,
                reason: e.get("reason").as_str().unwrap_or("").to_string(),
            });
        }
        Ok(Baseline { entries })
    }

    /// Split `findings` into grandfathered / fresh / stale by multiset
    /// budget on `(rule, file, text)`.
    pub fn apply(&self, findings: Vec<Finding>) -> Applied {
        let mut budget: BTreeMap<(String, String, String), usize> = BTreeMap::new();
        for e in &self.entries {
            *budget.entry(e.key()).or_default() += 1;
        }
        let mut out = Applied::default();
        for f in findings {
            let key = (f.rule.to_string(), f.file.clone(), f.text.clone());
            match budget.get_mut(&key) {
                Some(n) if *n > 0 => {
                    *n -= 1;
                    out.grandfathered.push(f);
                }
                _ => out.fresh.push(f),
            }
        }
        // Reconstruct the unspent entries, preserving reasons: walk the
        // original list and claim leftover budget per key.
        for e in &self.entries {
            if let Some(n) = budget.get_mut(&e.key()) {
                if *n > 0 {
                    *n -= 1;
                    out.stale.push(e.clone());
                }
            }
        }
        out
    }

    /// Build a refreshed baseline from the current findings, keeping
    /// the reason of any entry whose key still matches.
    pub fn refreshed(&self, findings: &[Finding]) -> Baseline {
        let mut reasons: BTreeMap<(String, String, String), Vec<String>> = BTreeMap::new();
        for e in &self.entries {
            reasons.entry(e.key()).or_default().push(e.reason.clone());
        }
        let mut entries: Vec<BaselineEntry> = findings
            .iter()
            .map(|f| {
                let key = (f.rule.to_string(), f.file.clone(), f.text.clone());
                let reason = reasons
                    .get_mut(&key)
                    .and_then(|rs| (!rs.is_empty()).then(|| rs.remove(0)))
                    .unwrap_or_else(|| "TODO: justify or fix".to_string());
                BaselineEntry {
                    rule: f.rule.to_string(),
                    file: f.file.clone(),
                    text: f.text.clone(),
                    reason,
                }
            })
            .collect();
        entries.sort_by(|a, b| {
            (&a.file, a.rule.as_str(), &a.text).cmp(&(&b.file, b.rule.as_str(), &b.text))
        });
        Baseline { entries }
    }

    /// Serialize: pretty, one compact entry object per line, key order
    /// fixed (rule, file, text, reason), sorted by (file, rule, text) —
    /// deterministic so refreshes diff cleanly.
    pub fn render(&self) -> String {
        let mut sorted = self.entries.clone();
        sorted.sort_by(|a, b| {
            (&a.file, a.rule.as_str(), &a.text).cmp(&(&b.file, b.rule.as_str(), &b.text))
        });
        let mut out = String::from("{\n  \"version\": 1,\n  \"entries\": [");
        for (i, e) in sorted.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "\n    {{\"rule\":{},\"file\":{},\"text\":{},\"reason\":{}}}",
                Json::str(e.rule.as_str()),
                Json::str(e.file.as_str()),
                Json::str(e.text.as_str()),
                Json::str(e.reason.as_str()),
            );
        }
        if sorted.is_empty() {
            out.push_str("]\n}\n");
        } else {
            out.push_str("\n  ]\n}\n");
        }
        out
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        fs::write(path, self.render())
            .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, file: &str, line: u32, text: &str) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line,
            text: text.to_string(),
            msg: String::new(),
        }
    }

    fn entry(rule: &str, file: &str, text: &str, reason: &str) -> BaselineEntry {
        BaselineEntry {
            rule: rule.to_string(),
            file: file.to_string(),
            text: text.to_string(),
            reason: reason.to_string(),
        }
    }

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sasp-lint-{tag}-{}.json", std::process::id()))
    }

    #[test]
    fn lint_baseline_missing_file_is_empty() {
        let b = Baseline::load(Path::new("/nonexistent/sasp-baseline.json")).unwrap();
        assert!(b.entries.is_empty());
    }

    #[test]
    fn lint_baseline_apply_splits_grandfathered_fresh_stale() {
        let b = Baseline {
            entries: vec![
                entry("serve-path-panic", "coordinator/serve.rs", "v[0]", "bounded"),
                entry("serve-path-panic", "coordinator/serve.rs", "gone()", "fixed since"),
            ],
        };
        let findings = vec![
            finding("serve-path-panic", "coordinator/serve.rs", 10, "v[0]"),
            finding("hot-loop-alloc", "systolic/array.rs", 20, "x.push(1)"),
        ];
        let a = b.apply(findings);
        assert_eq!(a.grandfathered.len(), 1);
        assert_eq!(a.grandfathered[0].text, "v[0]");
        assert_eq!(a.fresh.len(), 1);
        assert_eq!(a.fresh[0].rule, "hot-loop-alloc");
        assert_eq!(a.stale.len(), 1);
        assert_eq!(a.stale[0].text, "gone()");
    }

    #[test]
    fn lint_baseline_matching_is_multiset_budgeted() {
        // One entry tolerates one occurrence; a second identical
        // finding (e.g. the same line duplicated) is fresh.
        let b = Baseline {
            entries: vec![entry("serve-path-panic", "f.rs", "v[0]", "r")],
        };
        let a = b.apply(vec![
            finding("serve-path-panic", "f.rs", 1, "v[0]"),
            finding("serve-path-panic", "f.rs", 9, "v[0]"),
        ]);
        assert_eq!(a.grandfathered.len(), 1);
        assert_eq!(a.fresh.len(), 1);
        assert!(a.stale.is_empty());
    }

    #[test]
    fn lint_baseline_roundtrips_through_disk() {
        let path = temp_path("roundtrip");
        let b = Baseline {
            entries: vec![
                entry("bitwise-contract-drift", "infer/ops.rs", "let s = x.sum();", "pinned"),
                entry("serve-path-panic", "coordinator/serve.rs", "q\"uote\\", "escapes"),
            ],
        };
        b.save(&path).unwrap();
        let loaded = Baseline::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        // render() sorts by (file, rule, text); compare as sets.
        assert_eq!(loaded.entries.len(), 2);
        assert!(b.entries.iter().all(|e| loaded.entries.contains(e)));
        // And the serialized form is itself stable.
        assert_eq!(loaded.render(), b.render());
    }

    #[test]
    fn lint_baseline_refresh_preserves_reasons_and_fills_todo() {
        let b = Baseline {
            entries: vec![entry("serve-path-panic", "f.rs", "v[0]", "bounded by contract")],
        };
        let findings = vec![
            finding("serve-path-panic", "f.rs", 3, "v[0]"),
            finding("serve-path-panic", "f.rs", 7, "w[1]"),
        ];
        let fresh = b.refreshed(&findings);
        assert_eq!(fresh.entries.len(), 2);
        let v0 = fresh.entries.iter().find(|e| e.text == "v[0]").unwrap();
        assert_eq!(v0.reason, "bounded by contract");
        let w1 = fresh.entries.iter().find(|e| e.text == "w[1]").unwrap();
        assert_eq!(w1.reason, "TODO: justify or fix");
    }

    #[test]
    fn lint_baseline_empty_renders_and_parses() {
        let b = Baseline::default();
        let text = b.render();
        assert!(Json::parse(&text).is_ok(), "{text}");
        let path = temp_path("empty");
        b.save(&path).unwrap();
        let loaded = Baseline::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(loaded.entries.is_empty());
    }
}
