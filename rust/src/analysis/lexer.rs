//! A lightweight line-aware Rust tokenizer for the lint rules.
//!
//! This is not a full lexer — it produces exactly what the rule engine
//! needs and nothing more: identifier tokens, single-character
//! punctuation tokens, and a per-line record of comment text. String
//! literals (including raw/byte/raw-byte forms), char literals,
//! lifetimes, and numeric literals are consumed and *dropped*, so a
//! rule can never be fooled by `"unwrap"` appearing inside a string or
//! a doc example. Multi-character operators arrive as their component
//! punctuation (`::` is two `':'` tokens), which keeps pattern matching
//! in the rules trivial.
//!
//! The lexer is deliberately forgiving: on malformed input it consumes
//! a byte and moves on rather than erroring, because the linter must
//! never be the thing that breaks the build on code rustc itself
//! accepts.

/// One token with the 1-based source line it starts on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tok {
    pub line: u32,
    pub kind: TokKind,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident(String),
    Punct(char),
}

impl Tok {
    /// The identifier text, if this token is an identifier.
    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Ident(s) => Some(s),
            TokKind::Punct(_) => None,
        }
    }

    pub fn is_ident(&self, s: &str) -> bool {
        self.ident() == Some(s)
    }

    pub fn is_punct(&self, c: char) -> bool {
        matches!(&self.kind, TokKind::Punct(p) if *p == c)
    }
}

/// Tokenized file: code tokens plus comment text by line. A line with
/// several comments (rare) gets one entry per comment, in order.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<(u32, String)>,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_char(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Tokenize `src`. Never fails; see the module docs for the contract.
pub fn lex(src: &str) -> Lexed {
    Lexer { b: src.as_bytes(), i: 0, line: 1, out: Lexed::default() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    out: Lexed,
}

impl<'a> Lexer<'a> {
    fn run(mut self) -> Lexed {
        while self.i < self.b.len() {
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                b' ' | b'\t' | b'\r' => self.i += 1,
                b'/' if self.peek(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek(1) == Some(b'*') => self.block_comment(),
                b'"' => self.string_body(),
                b'\'' => self.char_or_lifetime(),
                _ if c.is_ascii_digit() => self.number(),
                _ if is_ident_start(c) => {
                    if !self.try_prefixed_literal() {
                        self.ident();
                    }
                }
                _ if c.is_ascii() => {
                    self.out.toks.push(Tok { line: self.line, kind: TokKind::Punct(c as char) });
                    self.i += 1;
                }
                // Non-ASCII outside strings/comments: skip the byte
                // (denied by `non_ascii_idents` anyway).
                _ => self.i += 1,
            }
        }
        self.out
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.b.get(self.i + ahead).copied()
    }

    fn line_comment(&mut self) {
        let at = self.line;
        let start = self.i + 2;
        while self.i < self.b.len() && self.b[self.i] != b'\n' {
            self.i += 1;
        }
        let text = String::from_utf8_lossy(&self.b[start.min(self.i)..self.i]).into_owned();
        self.out.comments.push((at, text));
    }

    fn block_comment(&mut self) {
        let at = self.line;
        let start = self.i + 2;
        self.i += 2;
        let mut depth = 1usize;
        while self.i < self.b.len() && depth > 0 {
            match (self.b[self.i], self.peek(1)) {
                (b'/', Some(b'*')) => {
                    depth += 1;
                    self.i += 2;
                }
                (b'*', Some(b'/')) => {
                    depth -= 1;
                    self.i += 2;
                }
                (b'\n', _) => {
                    self.line += 1;
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
        let end = self.i.saturating_sub(2).max(start);
        let text = String::from_utf8_lossy(&self.b[start..end]).into_owned();
        self.out.comments.push((at, text));
    }

    /// Consume a `"..."` body (cursor on the opening quote), honoring
    /// backslash escapes and tracking newlines.
    fn string_body(&mut self) {
        self.i += 1;
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'"' => {
                    self.i += 1;
                    return;
                }
                b'\\' => self.i += 2,
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
    }

    /// Consume `r"..."` / `r#"..."#` (cursor on the first `#` or `"`
    /// after the prefix), tracking newlines.
    fn raw_string_body(&mut self) {
        let mut hashes = 0usize;
        while self.peek(0) == Some(b'#') {
            hashes += 1;
            self.i += 1;
        }
        if self.peek(0) != Some(b'"') {
            return; // not actually a raw string; caller lexed the prefix
        }
        self.i += 1;
        while self.i < self.b.len() {
            if self.b[self.i] == b'\n' {
                self.line += 1;
                self.i += 1;
                continue;
            }
            if self.b[self.i] == b'"' {
                let mut ok = true;
                for k in 0..hashes {
                    if self.peek(1 + k) != Some(b'#') {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    self.i += 1 + hashes;
                    return;
                }
            }
            self.i += 1;
        }
    }

    /// `r"`, `r#"`, `b"`, `b'`, `br"`, `br#"` literal prefixes. Returns
    /// true if a literal was consumed; false means "plain identifier".
    fn try_prefixed_literal(&mut self) -> bool {
        let c = self.b[self.i];
        let n1 = self.peek(1);
        if c == b'r' && matches!(n1, Some(b'"') | Some(b'#')) {
            // `r#ident` (raw identifier) is not a string: require that
            // the `#`s end in a quote.
            if n1 == Some(b'#') && !self.hashes_then_quote(1) {
                return false;
            }
            self.i += 1;
            self.raw_string_body();
            return true;
        }
        if c == b'b' {
            match n1 {
                Some(b'"') => {
                    self.i += 1;
                    self.string_body();
                    return true;
                }
                Some(b'\'') => {
                    self.i += 1;
                    self.char_or_lifetime();
                    return true;
                }
                Some(b'r') if matches!(self.peek(2), Some(b'"') | Some(b'#')) => {
                    if self.peek(2) == Some(b'#') && !self.hashes_then_quote(2) {
                        return false;
                    }
                    self.i += 2;
                    self.raw_string_body();
                    return true;
                }
                _ => {}
            }
        }
        false
    }

    /// Do the bytes at offset `at` form `#...#"`?
    fn hashes_then_quote(&self, mut at: usize) -> bool {
        while self.peek(at) == Some(b'#') {
            at += 1;
        }
        self.peek(at) == Some(b'"')
    }

    /// Cursor on a `'`: either a lifetime (consumed silently) or a char
    /// literal (consumed silently).
    fn char_or_lifetime(&mut self) {
        match self.peek(1) {
            Some(b'\\') => {
                // Escaped char literal: skip the escaped byte, then
                // scan to the closing quote (covers `'\''`, `'\u{..}'`).
                self.i += 3;
                while self.i < self.b.len() && self.b[self.i] != b'\'' {
                    self.i += 1;
                }
                self.i += 1;
            }
            Some(n) if is_ident_char(n) && self.peek(2) != Some(b'\'') => {
                // Lifetime or loop label: consume the identifier.
                self.i += 2;
                while self.i < self.b.len() && is_ident_char(self.b[self.i]) {
                    self.i += 1;
                }
            }
            _ => {
                // Plain char literal, possibly multi-byte UTF-8.
                self.i += 1;
                while self.i < self.b.len() && self.b[self.i] != b'\'' {
                    if self.b[self.i] == b'\n' {
                        self.line += 1;
                    }
                    self.i += 1;
                }
                self.i += 1;
            }
        }
    }

    /// Numeric literal: consumed, no token (rules never match numbers).
    fn number(&mut self) {
        while self.i < self.b.len() && is_ident_char(self.b[self.i]) {
            self.i += 1;
        }
        // A fraction only if `.` is followed by a digit — `0..n` must
        // leave the range dots as punctuation.
        if self.peek(0) == Some(b'.') && self.peek(1).is_some_and(|d| d.is_ascii_digit()) {
            self.i += 1;
            while self.i < self.b.len() && is_ident_char(self.b[self.i]) {
                self.i += 1;
            }
        }
    }

    fn ident(&mut self) {
        let start = self.i;
        let at = self.line;
        while self.i < self.b.len() && is_ident_char(self.b[self.i]) {
            self.i += 1;
        }
        let text = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
        self.out.toks.push(Tok { line: at, kind: TokKind::Ident(text) });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(l: &Lexed) -> Vec<&str> {
        l.toks.iter().filter_map(|t| t.ident()).collect()
    }

    #[test]
    fn lint_lexer_strings_chars_and_lifetimes_are_invisible() {
        let src = r##"
            fn f<'a>(x: &'a str) -> char {
                let s = "unwrap() \" inside";
                let r = r#"also "unwrap" here"#;
                let b = b"bytes";
                let c = 'x';
                let q = '\'';
                let nl = '\n';
                'outer: loop { break 'outer; }
            }
        "##;
        let l = lex(src);
        let ids = idents(&l);
        assert!(!ids.contains(&"unwrap"), "{ids:?}");
        assert!(!ids.contains(&"inside"));
        assert!(!ids.contains(&"also"));
        assert!(ids.contains(&"loop"));
        assert!(ids.contains(&"break"));
        // Lifetimes/labels are consumed, not identifiers.
        assert!(!ids.contains(&"outer"));
        assert!(!ids.contains(&"a") || src.contains("let a"), "lifetime 'a leaked");
    }

    #[test]
    fn lint_lexer_comments_are_captured_by_line() {
        let src = "let x = 1; // ordering: relaxed is fine\n/* block\nspans */ let y = 2;\n";
        let l = lex(src);
        assert_eq!(l.comments.len(), 2);
        assert_eq!(l.comments[0].0, 1);
        assert!(l.comments[0].1.contains("ordering:"));
        assert_eq!(l.comments[1].0, 2);
        assert!(l.comments[1].1.contains("spans"));
        // Tokens after the block comment land on the right line.
        let y = l.toks.iter().find(|t| t.is_ident("y")).unwrap();
        assert_eq!(y.line, 3);
    }

    #[test]
    fn lint_lexer_numbers_and_ranges() {
        let src = "for i in 0..n { let f = 1.5e3; let t = x.0; }";
        let l = lex(src);
        // The range dots survive as punctuation.
        let dots = l.toks.iter().filter(|t| t.is_punct('.')).count();
        assert!(dots >= 3, "range + field access dots, got {dots}");
        assert!(idents(&l).contains(&"n"));
    }

    #[test]
    fn lint_lexer_nested_block_comments() {
        let src = "/* a /* b */ c */ fn real() {}";
        let l = lex(src);
        assert_eq!(idents(&l), vec!["fn", "real"]);
    }

    #[test]
    fn lint_lexer_double_colon_is_two_puncts() {
        let l = lex("Ordering::SeqCst");
        let kinds: Vec<String> = l
            .toks
            .iter()
            .map(|t| match &t.kind {
                TokKind::Ident(s) => s.clone(),
                TokKind::Punct(c) => c.to_string(),
            })
            .collect();
        assert_eq!(kinds, vec!["Ordering", ":", ":", "SeqCst"]);
    }
}
