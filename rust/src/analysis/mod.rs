//! # Static analysis: codebase-specific lint rules with a ratchet
//!
//! `sasp lint` enforces the handful of invariants this codebase cares
//! about that `rustc`/`clippy` cannot see, because they are *project
//! contracts*, not language properties:
//!
//! | rule | contract |
//! |------|----------|
//! | `hot-loop-alloc` | kernel loop bodies (`infer/gemm.rs`, `infer/batch/`, `infer/decoder/`, `systolic/`) never allocate or copy |
//! | `unlabeled-gemm-site` | every GEMM execution site in `infer/` feeds the per-layer attribution ledger |
//! | `atomic-ordering-audit` | every atomic `Ordering::` choice carries a written justification; `SeqCst` needs a pragma |
//! | `serve-path-panic` | the serving request path (`coordinator/serve.rs`, `coordinator/resilience.rs`) returns errors, never panics |
//! | `bitwise-contract-drift` | bitwise-oracle modules keep accumulation order pinned (no `mul_add`, no `.sum()`) |
//! | `lint-hygiene` | the crate root keeps `#![forbid(unsafe_code)]` and the curated `#![deny(..)]` set |
//!
//! Like the rest of the crate ([`crate::util::json`] and friends), the
//! engine is zero-dependency: a [`lexer`] that is *not* a Rust parser —
//! just enough lexing to make strings, comments and `cfg(test)` regions
//! reliable — and a [`rules`] pass over the token stream.
//!
//! ## The ratchet
//!
//! Findings that predate the linter are recorded in a committed
//! baseline (`rust/lint-baseline.json`, see [`baseline`]). The gate
//! semantics:
//!
//! - **fresh** finding (not in the baseline) → fail: new code meets the
//!   bar from day one;
//! - **stale** entry (in the baseline, no longer found) → fail: fixes
//!   ratchet in by deleting their entry, and can't silently regress;
//! - **grandfathered** finding → reported, tolerated.
//!
//! Intentional, permanent exceptions use an inline pragma instead of
//! the baseline — `// lint:allow(bitwise-contract-drift) -- max is order-independent`
//! — which covers its own line and the next. The baseline is for debt;
//! pragmas are for decisions.

pub mod baseline;
pub mod lexer;
pub mod rules;

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use crate::Result;

pub use baseline::{Applied, Baseline, BaselineEntry};
pub use rules::{check_file, Finding, RULES};

/// One full lint run, split against the baseline.
#[derive(Debug)]
pub struct LintReport {
    pub files_scanned: usize,
    pub grandfathered: Vec<Finding>,
    pub fresh: Vec<Finding>,
    pub stale: Vec<BaselineEntry>,
}

impl LintReport {
    /// Does this run pass the gate?
    pub fn clean(&self) -> bool {
        self.fresh.is_empty() && self.stale.is_empty()
    }
}

/// Lint every `.rs` file under `src_root`, in deterministic (sorted
/// relative path) order. Returns the findings plus the file count.
pub fn scan_tree(src_root: &Path) -> Result<(Vec<Finding>, usize)> {
    let mut files = Vec::new();
    collect_rs_files(src_root, src_root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for rel in &files {
        let src = fs::read_to_string(src_root.join(rel))
            .map_err(|e| anyhow::anyhow!("read {rel}: {e}"))?;
        findings.extend(check_file(rel, &src));
    }
    Ok((findings, files.len()))
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let entries =
        fs::read_dir(dir).map_err(|e| anyhow::anyhow!("read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| anyhow::anyhow!("read dir {}: {e}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with('.') {
            continue;
        }
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if name.ends_with(".rs") {
            // Store '/'-separated relative paths so rule scoping and
            // baseline keys are platform-stable.
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Lint `src_root` and ratchet against the baseline at `baseline_path`
/// (missing file = empty baseline).
pub fn run(src_root: &Path, baseline_path: &Path) -> Result<LintReport> {
    let (findings, files_scanned) = scan_tree(src_root)?;
    let base = Baseline::load(baseline_path)?;
    let applied = base.apply(findings);
    Ok(LintReport {
        files_scanned,
        grandfathered: applied.grandfathered,
        fresh: applied.fresh,
        stale: applied.stale,
    })
}

/// Human-readable report: a table of violations (fresh + stale), then
/// the one-line verdict. Grandfathered findings are summarized only —
/// they are debt, not news.
pub fn render_human(r: &LintReport) -> String {
    let mut out = String::new();
    if !r.fresh.is_empty() {
        let _ = writeln!(out, "fresh findings (not in baseline):");
        for f in &r.fresh {
            let _ = writeln!(out, "  {:<24} {}:{}", f.rule, f.file, f.line);
            let _ = writeln!(out, "      {}", f.msg);
            let _ = writeln!(out, "      > {}", f.text);
        }
    }
    if !r.stale.is_empty() {
        let _ = writeln!(out, "stale baseline entries (fixed — delete them):");
        for e in &r.stale {
            let _ = writeln!(out, "  {:<24} {}", e.rule, e.file);
            let _ = writeln!(out, "      > {}", e.text);
        }
    }
    let _ = writeln!(
        out,
        "sasp lint: {} files, {} fresh, {} stale, {} grandfathered — {}",
        r.files_scanned,
        r.fresh.len(),
        r.stale.len(),
        r.grandfathered.len(),
        if r.clean() { "OK" } else { "FAIL" },
    );
    out
}

/// Machine-readable report (one JSON document).
pub fn render_json(r: &LintReport) -> String {
    use crate::util::json::JsonWriter;
    let mut w = JsonWriter::new(Vec::new());
    let emit = |w: &mut JsonWriter<Vec<u8>>, findings: &[Finding]| -> std::io::Result<()> {
        w.begin_arr()?;
        for f in findings {
            w.begin_obj()?;
            w.key("rule")?;
            w.str_val(f.rule)?;
            w.key("file")?;
            w.str_val(&f.file)?;
            w.key("line")?;
            w.u64_val(u64::from(f.line))?;
            w.key("text")?;
            w.str_val(&f.text)?;
            w.key("msg")?;
            w.str_val(&f.msg)?;
            w.end()?;
        }
        w.end()
    };
    // In-memory Vec<u8> writes cannot fail; a short report fits easily.
    let run = || -> std::io::Result<Vec<u8>> {
        w.begin_obj()?;
        w.key("files_scanned")?;
        w.u64_val(r.files_scanned as u64)?;
        w.key("clean")?;
        w.bool_val(r.clean())?;
        w.key("fresh")?;
        emit(&mut w, &r.fresh)?;
        w.key("stale")?;
        w.begin_arr()?;
        for e in &r.stale {
            w.begin_obj()?;
            w.key("rule")?;
            w.str_val(&e.rule)?;
            w.key("file")?;
            w.str_val(&e.file)?;
            w.key("text")?;
            w.str_val(&e.text)?;
            w.key("reason")?;
            w.str_val(&e.reason)?;
            w.end()?;
        }
        w.end()?;
        w.key("grandfathered")?;
        emit(&mut w, &r.grandfathered)?;
        w.end()?;
        w.finish()
    };
    match run() {
        Ok(bytes) => String::from_utf8_lossy(&bytes).into_owned(),
        Err(_) => String::from("{}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct TempTree {
        root: std::path::PathBuf,
    }

    impl TempTree {
        fn new(tag: &str) -> TempTree {
            let root = std::env::temp_dir()
                .join(format!("sasp-lint-tree-{tag}-{}", std::process::id()));
            let _ = fs::remove_dir_all(&root);
            fs::create_dir_all(root.join("src/coordinator")).unwrap();
            TempTree { root }
        }

        fn src(&self) -> std::path::PathBuf {
            self.root.join("src")
        }

        fn baseline(&self) -> std::path::PathBuf {
            self.root.join("lint-baseline.json")
        }

        fn write(&self, rel: &str, content: &str) {
            fs::write(self.src().join(rel), content).unwrap();
        }
    }

    impl Drop for TempTree {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.root);
        }
    }

    #[test]
    fn lint_engine_scan_tree_uses_sorted_relative_paths() {
        let t = TempTree::new("scan");
        t.write("coordinator/serve.rs", "fn f(o: Option<u64>) -> u64 {\n    o.unwrap()\n}\n");
        t.write("other.rs", "fn g() {}\n");
        let (findings, files) = scan_tree(&t.src()).unwrap();
        assert_eq!(files, 2);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].file, "coordinator/serve.rs");
        assert_eq!(findings[0].rule, "serve-path-panic");
    }

    #[test]
    fn lint_engine_ratchet_round_trip() {
        let t = TempTree::new("ratchet");
        t.write("coordinator/serve.rs", "fn f(o: Option<u64>) -> u64 {\n    o.unwrap()\n}\n");

        // 1. No baseline yet: the existing finding is fresh → FAIL.
        let r = run(&t.src(), &t.baseline()).unwrap();
        assert!(!r.clean());
        assert_eq!(r.fresh.len(), 1);

        // 2. Ratchet it: write the baseline, rerun → grandfathered, OK.
        Baseline::default().refreshed(&r.fresh).save(&t.baseline()).unwrap();
        let r = run(&t.src(), &t.baseline()).unwrap();
        assert!(r.clean(), "{:?}", r);
        assert_eq!(r.grandfathered.len(), 1);

        // 3. A new panic site is NOT covered — fresh again → FAIL, and
        //    the old one stays grandfathered.
        t.write(
            "coordinator/serve.rs",
            "fn f(o: Option<u64>) -> u64 {\n    o.unwrap()\n}\nfn g(o: Option<u64>) -> u64 {\n    o.expect(\"set\")\n}\n",
        );
        let r = run(&t.src(), &t.baseline()).unwrap();
        assert!(!r.clean());
        assert_eq!(r.fresh.len(), 1);
        assert_eq!(r.grandfathered.len(), 1);
        assert!(r.fresh[0].text.contains("expect"));

        // 4. Fix the original site: its baseline entry is now stale →
        //    FAIL until it is deleted (the ratchet only tightens).
        t.write("coordinator/serve.rs", "fn f(o: Option<u64>) -> u64 {\n    o.unwrap_or(0)\n}\n");
        let r = run(&t.src(), &t.baseline()).unwrap();
        assert!(!r.clean());
        assert!(r.fresh.is_empty());
        assert_eq!(r.stale.len(), 1);

        // 5. Refresh the baseline (now empty), rerun → clean tree.
        Baseline::default().refreshed(&r.fresh).save(&t.baseline()).unwrap();
        let r = run(&t.src(), &t.baseline()).unwrap();
        assert!(r.clean());
        assert_eq!(r.grandfathered.len(), 0);
    }

    #[test]
    fn lint_engine_renderers_cover_both_verdicts() {
        let t = TempTree::new("render");
        t.write("coordinator/serve.rs", "fn f(o: Option<u64>) -> u64 {\n    o.unwrap()\n}\n");
        let r = run(&t.src(), &t.baseline()).unwrap();
        let human = render_human(&r);
        assert!(human.contains("FAIL"), "{human}");
        assert!(human.contains("serve-path-panic"), "{human}");
        let json = crate::util::json::Json::parse(&render_json(&r)).unwrap();
        assert_eq!(json.get("clean").as_bool(), Some(false));
        assert_eq!(json.get("fresh").as_arr().unwrap().len(), 1);
        assert_eq!(
            json.get("fresh").as_arr().unwrap()[0].get("rule").as_str(),
            Some("serve-path-panic")
        );

        // Clean tree → OK verdict, clean JSON.
        Baseline::default().refreshed(&r.fresh).save(&t.baseline()).unwrap();
        let r = run(&t.src(), &t.baseline()).unwrap();
        assert!(render_human(&r).contains("OK"));
        let json = crate::util::json::Json::parse(&render_json(&r)).unwrap();
        assert_eq!(json.get("clean").as_bool(), Some(true));
    }
}
