//! Systolic Array Structured Pruning (§3.1).
//!
//! Weight matrices are partitioned into `tile x tile` blocks matching the
//! array dimensions; the fraction `rate` of tiles with the **lowest
//! L1-norm across the entire model** is zeroed. Global ranking prunes
//! GEMMs heterogeneously according to their sensitivity — in practice the
//! early feed-forward layers lose the most tiles (Fig. 8).
//!
//! Two weight sources feed the same pipeline:
//! - the **trained tiny model** (`artifacts/params_asr.bin`) for QoS
//!   experiments — masks produced here also drive the PJRT inference;
//! - a **synthetic norm model** for the Table 1 shape-only workloads
//!   (timing/energy experiments don't need real values, only a realistic
//!   per-layer distribution of tile norms).

pub mod norms;
pub mod synthetic;

pub use norms::{tile_l1_norms, TileNorms};
pub use synthetic::synthetic_ff_norms;

use crate::sysim::TileMask;

/// A pruning plan over a set of feed-forward GEMMs.
#[derive(Clone, Debug)]
pub struct PrunePlan {
    /// One mask per FF GEMM, in the order the norms were supplied.
    pub masks: Vec<TileMask>,
    /// Fraction of tiles pruned (== requested rate up to rounding).
    pub achieved_rate: f64,
    /// The global L1 threshold actually applied.
    pub threshold: f32,
}

impl PrunePlan {
    /// Mean sparsity of masks `lo..hi` (for per-layer reporting).
    pub fn sparsity_range(&self, lo: usize, hi: usize) -> f64 {
        let ms = &self.masks[lo..hi];
        ms.iter().map(TileMask::sparsity).sum::<f64>() / ms.len().max(1) as f64
    }
}

/// Prune `rate` of all tiles globally by lowest L1 norm.
///
/// Ties at the threshold are broken by (gemm index, tile index) order so
/// the result is deterministic and the achieved rate is exact.
///
/// Uses `select_nth_unstable` (expected O(n)) rather than a full sort —
/// the global ranking over the Table-1 models spans ~600k tiles and this
/// function sits in the explorer's inner loop (§Perf).
pub fn global_prune(norms: &[TileNorms], rate: f64) -> PrunePlan {
    assert!((0.0..=1.0).contains(&rate), "rate must be in [0,1]");
    let total: usize = norms.iter().map(|n| n.norms.len()).sum();
    let n_prune = (total as f64 * rate).round() as usize;

    let mut masks: Vec<TileMask> = norms
        .iter()
        .map(|n| TileMask::full(n.kt, n.nt))
        .collect();
    if n_prune == 0 {
        return PrunePlan { masks, achieved_rate: 0.0, threshold: 0.0 };
    }

    // Global (norm, gemm, tile) pool, partitioned around the n_prune-th
    // smallest element under the same total order the full sort used.
    let mut pool: Vec<(f32, u32, u32)> = Vec::with_capacity(total);
    for (gi, tn) in norms.iter().enumerate() {
        for (ti, v) in tn.norms.iter().enumerate() {
            pool.push((*v, gi as u32, ti as u32));
        }
    }
    let cmp = |a: &(f32, u32, u32), b: &(f32, u32, u32)| {
        a.0.partial_cmp(&b.0)
            .unwrap()
            .then(a.1.cmp(&b.1))
            .then(a.2.cmp(&b.2))
    };
    let n_prune = n_prune.min(total);
    let (low, pivot, _) = pool.select_nth_unstable_by(n_prune - 1, cmp);
    let threshold = pivot.0;
    for (_, gi, ti) in low.iter() {
        masks[*gi as usize].live[*ti as usize] = false;
    }
    masks[pivot.1 as usize].live[pivot.2 as usize] = false;
    PrunePlan {
        masks,
        achieved_rate: n_prune as f64 / total.max(1) as f64,
        threshold,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn norms_from(vals: Vec<Vec<f32>>, kt: usize, nt: usize) -> Vec<TileNorms> {
        vals.into_iter()
            .map(|v| {
                assert_eq!(v.len(), kt * nt);
                TileNorms { kt, nt, norms: v }
            })
            .collect()
    }

    #[test]
    fn zero_rate_prunes_nothing() {
        let n = norms_from(vec![vec![1.0, 2.0, 3.0, 4.0]], 2, 2);
        let plan = global_prune(&n, 0.0);
        assert_eq!(plan.masks[0].live_count(), 4);
        assert_eq!(plan.achieved_rate, 0.0);
    }

    #[test]
    fn full_rate_prunes_everything() {
        let n = norms_from(vec![vec![1.0, 2.0, 3.0, 4.0]], 2, 2);
        let plan = global_prune(&n, 1.0);
        assert_eq!(plan.masks[0].live_count(), 0);
    }

    #[test]
    fn lowest_norm_tiles_go_first() {
        let n = norms_from(vec![vec![5.0, 1.0, 3.0, 4.0]], 2, 2);
        let plan = global_prune(&n, 0.25);
        assert!(!plan.masks[0].live[1], "tile with norm 1.0 pruned");
        assert_eq!(plan.masks[0].live_count(), 3);
        assert_eq!(plan.threshold, 1.0);
    }

    #[test]
    fn global_ranking_is_heterogeneous() {
        // GEMM 0 has uniformly small norms; a global 50 % prune should
        // take (almost) all of it before touching GEMM 1.
        let n = norms_from(
            vec![vec![0.1, 0.2, 0.3, 0.4], vec![10.0, 11.0, 12.0, 13.0]],
            2,
            2,
        );
        let plan = global_prune(&n, 0.5);
        assert_eq!(plan.masks[0].live_count(), 0);
        assert_eq!(plan.masks[1].live_count(), 4);
    }

    #[test]
    fn prop_monotone_rates_nest() {
        // A higher rate prunes a superset of tiles (determinism + global
        // threshold semantics).
        check("prune nesting", 32, |rng: &mut Rng| {
            let kt = rng.index(4) + 1;
            let nt = rng.index(4) + 1;
            let g = rng.index(3) + 1;
            let norms: Vec<TileNorms> = (0..g)
                .map(|_| TileNorms {
                    kt,
                    nt,
                    norms: (0..kt * nt).map(|_| rng.f32() * 10.0).collect(),
                })
                .collect();
            let r1 = rng.f64() * 0.5;
            let r2 = r1 + rng.f64() * 0.5;
            let p1 = global_prune(&norms, r1);
            let p2 = global_prune(&norms, r2.min(1.0));
            for (m1, m2) in p1.masks.iter().zip(&p2.masks) {
                for (a, b) in m1.live.iter().zip(&m2.live) {
                    if !a && *b {
                        return (false, format!("r1={r1} r2={r2} not nested"));
                    }
                }
            }
            (true, String::new())
        });
    }

    #[test]
    fn prop_achieved_rate_exact() {
        check("achieved rate exact", 32, |rng: &mut Rng| {
            let n = 40;
            let norms = vec![TileNorms {
                kt: 5,
                nt: 8,
                norms: (0..n).map(|_| rng.f32()).collect(),
            }];
            let rate = rng.f64();
            let plan = global_prune(&norms, rate);
            let pruned = n - plan.masks[0].live_count();
            let want = (n as f64 * rate).round() as usize;
            (pruned == want, format!("rate={rate} pruned={pruned} want={want}"))
        });
    }
}
