//! Tile L1-norm computation over real weight matrices.

use crate::data::Tensor;

/// Per-tile L1 norms of one `K x N` weight matrix at a given tile size.
#[derive(Clone, Debug)]
pub struct TileNorms {
    pub kt: usize,
    pub nt: usize,
    /// Row-major `kt x nt` norms.
    pub norms: Vec<f32>,
}

/// Compute `tile x tile` L1 norms of a row-major `K x N` f32 tensor.
///
/// K and N must be tile-aligned (all paper and artifact shapes are).
pub fn tile_l1_norms(w: &Tensor, tile: usize) -> TileNorms {
    assert_eq!(w.shape.len(), 2, "weights must be 2-D");
    let (k, n) = (w.shape[0], w.shape[1]);
    assert!(k % tile == 0 && n % tile == 0,
            "{k}x{n} not aligned to tile {tile}");
    let vals = w.f32s();
    let (kt, nt) = (k / tile, n / tile);
    let mut norms = vec![0.0f32; kt * nt];
    for kk in 0..k {
        let tk = kk / tile;
        let row = &vals[kk * n..(kk + 1) * n];
        for (tn, chunk) in row.chunks_exact(tile).enumerate() {
            let s: f32 = chunk.iter().map(|v| v.abs()).sum();
            norms[tk * nt + tn] += s;
        }
    }
    TileNorms { kt, nt, norms }
}

/// Zero the weight values of pruned tiles in place (so the PJRT inference
/// sees exactly the weights the masks describe).
pub fn apply_mask_to_weights(w: &mut Tensor, mask: &crate::sysim::TileMask, tile: usize) {
    assert_eq!(w.shape.len(), 2);
    let (k, n) = (w.shape[0], w.shape[1]);
    assert_eq!((mask.kt, mask.nt), (k / tile, n / tile));
    w.map_f32_inplace(|idx, v| {
        let (kk, nn) = (idx / n, idx % n);
        if mask.is_live(kk / tile, nn / tile) {
            v
        } else {
            0.0
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sysim::TileMask;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn norms_of_known_matrix() {
        // 4x4 matrix, tile 2: four tiles with distinct sums.
        #[rustfmt::skip]
        let w = Tensor::from_f32(&[4, 4], &[
            1.0, 1.0,   2.0, 2.0,
            1.0, 1.0,   2.0, 2.0,
            -3.0, 3.0,  0.0, 0.0,
            3.0, -3.0,  0.0, 0.0,
        ]);
        let n = tile_l1_norms(&w, 2);
        assert_eq!((n.kt, n.nt), (2, 2));
        assert_eq!(n.norms, vec![4.0, 8.0, 12.0, 0.0]);
    }

    #[test]
    fn mask_zeroes_only_pruned_tiles() {
        let mut w = Tensor::from_f32(&[4, 4], &[1.0; 16]);
        let mask = TileMask { kt: 2, nt: 2, live: vec![true, false, false, true] };
        apply_mask_to_weights(&mut w, &mask, 2);
        let v = w.f32s();
        // Tile (0,0) and (1,1) live; (0,1) and (1,0) zeroed.
        assert_eq!(v[0], 1.0);
        assert_eq!(v[2], 0.0); // row 0, col 2 -> tile (0,1)
        assert_eq!(v[8], 0.0); // row 2, col 0 -> tile (1,0)
        assert_eq!(v[10], 1.0); // row 2, col 2 -> tile (1,1)
    }

    #[test]
    fn prop_norms_invariance() {
        // Sum of all tile norms == L1 norm of the whole matrix.
        check("tile norms sum to total L1", 24, |rng: &mut Rng| {
            let tile = [2usize, 4][rng.index(2)];
            let kt = rng.index(3) + 1;
            let nt = rng.index(3) + 1;
            let (k, n) = (kt * tile, nt * tile);
            let vals: Vec<f32> =
                (0..k * n).map(|_| rng.normal() as f32).collect();
            let w = Tensor::from_f32(&[k, n], &vals);
            let norms = tile_l1_norms(&w, tile);
            let total: f32 = norms.norms.iter().sum();
            let want: f32 = vals.iter().map(|v| v.abs()).sum();
            ((total - want).abs() < 1e-3 * want.max(1.0),
             format!("total={total} want={want}"))
        });
    }

    #[test]
    fn prop_mask_then_norms_zeroes_pruned() {
        check("masked tiles have zero norm", 16, |rng: &mut Rng| {
            let tile = 4;
            let (kt, nt) = (2, 3);
            let vals: Vec<f32> = (0..kt * nt * tile * tile)
                .map(|_| rng.normal() as f32 + 1.0)
                .collect();
            let mut w = Tensor::from_f32(&[kt * tile, nt * tile], &vals);
            let live: Vec<bool> = (0..kt * nt).map(|_| rng.chance(0.5)).collect();
            let mask = TileMask { kt, nt, live: live.clone() };
            apply_mask_to_weights(&mut w, &mask, tile);
            let norms = tile_l1_norms(&w, tile);
            for (i, l) in live.iter().enumerate() {
                if !l && norms.norms[i] != 0.0 {
                    return (false, format!("tile {i} not zeroed"));
                }
                if *l && norms.norms[i] == 0.0 {
                    return (false, format!("live tile {i} zeroed"));
                }
            }
            (true, String::new())
        });
    }
}
