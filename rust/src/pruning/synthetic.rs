//! Synthetic tile-norm model for the Table 1 workloads.
//!
//! The timing/energy experiments (Figs. 7, 8, 11; Table 3) simulate the
//! paper's full-size models, whose trained weights we do not have. What
//! those experiments need from the weights is only the *distribution of
//! tile L1-norms across layers*, which determines how a global pruning
//! threshold allocates sparsity per layer.
//!
//! Empirically (paper Fig. 8, and the trained tiny model here), early
//! feed-forward layers carry more low-norm tiles than later ones. We
//! model tile norms as log-normal with a location that rises with layer
//! depth; the tiny trained model's norm distributions validate the shape
//! (see `rust/tests/integration.rs`).

use crate::model::EncoderSpec;
use crate::util::rng::Rng;

use super::norms::TileNorms;

/// Depth-dependent log-normal location: later layers have larger-norm
/// (harder to prune) tiles. Spread within a layer stays constant.
const DEPTH_SLOPE: f64 = 0.9;
const SIGMA: f64 = 0.55;

/// Generate per-FF-GEMM tile norms for a Table 1 workload (2 FF GEMMs per
/// block, in execution order — same layout the system simulator expects).
pub fn synthetic_ff_norms(spec: &EncoderSpec, tile: usize, seed: u64) -> Vec<TileNorms> {
    let mut rng = Rng::new(seed ^ 0x5A57_0000);
    let mut out = Vec::with_capacity(2 * spec.n_blocks);
    for block in 0..spec.n_blocks {
        let depth = block as f64 / (spec.n_blocks.max(2) - 1) as f64; // 0..1
        let mu = DEPTH_SLOPE * depth; // log-location grows with depth
        for (k, n) in [
            (spec.d_model, spec.d_ff),
            (spec.d_ff, spec.d_model),
        ] {
            let (kt, nt) = (k.div_ceil(tile), n.div_ceil(tile));
            let norms: Vec<f32> = (0..kt * nt)
                .map(|_| {
                    let z = rng.normal();
                    ((mu + SIGMA * z).exp() * (tile * tile) as f64 * 0.02) as f32
                })
                .collect();
            out.push(TileNorms { kt, nt, norms });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::pruning::global_prune;

    #[test]
    fn layout_two_ff_per_block() {
        let spec = zoo::espnet_asr();
        let norms = synthetic_ff_norms(&spec, 8, 7);
        assert_eq!(norms.len(), 36);
        assert_eq!((norms[0].kt, norms[0].nt), (64, 256)); // 512x2048 / 8
        assert_eq!((norms[1].kt, norms[1].nt), (256, 64));
    }

    #[test]
    fn deterministic_in_seed() {
        let spec = zoo::espnet2_asr();
        let a = synthetic_ff_norms(&spec, 16, 42);
        let b = synthetic_ff_norms(&spec, 16, 42);
        assert_eq!(a[0].norms, b[0].norms);
        let c = synthetic_ff_norms(&spec, 16, 43);
        assert_ne!(a[0].norms, c[0].norms);
    }

    #[test]
    fn early_layers_prune_more_under_global_threshold() {
        // Reproduces the Fig. 8 allocation: a global prune concentrates
        // sparsity in early blocks.
        let spec = zoo::espnet_asr();
        let norms = synthetic_ff_norms(&spec, 8, 7);
        let plan = global_prune(&norms, 0.25);
        let first_block = plan.sparsity_range(0, 2);
        let last_block = plan.sparsity_range(34, 36);
        assert!(first_block > last_block + 0.1,
                "first {first_block} last {last_block}");
    }

    #[test]
    fn norms_positive() {
        let spec = zoo::mustc_mt_encoder();
        for tn in synthetic_ff_norms(&spec, 4, 1) {
            assert!(tn.norms.iter().all(|v| *v > 0.0));
        }
    }
}
