//! Layer-3 coordination: the SASP design-space explorer (the paper's
//! cross-stack co-design loop) and a batched inference serving loop that
//! exercises the compiled artifact as an edge deployment would.

pub mod explorer;
pub mod resilience;
pub mod serve;

pub use explorer::{DesignPoint, Explorer, RateSearch, SweepPoint};
pub use resilience::{
    AdmissionConfig, BreakerConfig, CircuitBreaker, FaultCounts, FaultInjector, FaultKind,
    FaultPlan, LadderConfig, OperatingPoint, ResilienceConfig, RetryPolicy, ShedPolicy,
};
pub use serve::{
    Backend, DecodeReport, DecodeServer, FlushPolicy, MtRequest, Outcome, OutcomeLatency,
    ServeBackend, ServeConfig, ServeReport, Server,
};
