//! Overload- and fault-tolerance primitives for the serving loop:
//! bounded admission with shedding policies, per-request deadlines,
//! deterministic fault injection, bounded retry with a circuit breaker,
//! and the graceful-degradation ladder over pruning/quant operating
//! points.
//!
//! The paper's co-design loop measures a QoS/throughput frontier; this
//! module is how the serving runtime *moves along it under stress*
//! instead of falling over: when the queue stays above a watermark or
//! the backend keeps failing, the native engine re-stages at a cheaper
//! prepared operating point (higher pruning rate and/or INT8) from a
//! preconfigured ladder, and recovers hysteretically once pressure
//! drops. Every degraded step is bitwise identical to a standalone run
//! at that operating point — re-staging always starts from the master
//! weights (see [`crate::infer::NativeBackend::prepare`]), so the
//! ladder adds no new numerics, only scheduling.
//!
//! Everything here is deterministic by construction: the
//! [`FaultInjector`] draws from the crate's seeded xoshiro256** RNG (or
//! replays an explicit script), and the admission/breaker/ladder state
//! machines are driven purely by queue contents and flush outcomes, so
//! a fixed seed + fault schedule reproduces shed/expired/retried/
//! degraded counts exactly.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::serve::ServeBackend;
use crate::data::Tensor;
use crate::systolic::Quant;
use crate::util::rng::Rng;

/// What a bounded admission queue does with the overflow request when
/// it is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Shed the incoming request; queued requests keep their slot.
    RejectNew,
    /// Shed the oldest queued request and admit the incoming one
    /// (tail-drop of stale work — the queue always holds the freshest
    /// requests).
    DropOldest,
    /// Shed the candidate (queued or incoming) with the **earliest**
    /// deadline — the one least likely to complete in time — breaking
    /// ties by admission order (oldest first). Requests without a
    /// deadline are infinitely patient and are only shed among
    /// themselves (oldest first), which degenerates to [`DropOldest`].
    DeadlineAware,
}

/// Bounded admission queue configuration.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Largest number of queued (admitted, not yet flushed) requests.
    /// Capacity 0 sheds every request — the hard-overload drain valve.
    pub capacity: usize,
    pub policy: ShedPolicy,
}

/// Bounded retry with exponential backoff for failed flushes.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Re-executions after the first failed attempt (0 = no retry).
    pub max_retries: usize,
    /// Base backoff slept before retry `k` as `backoff * 2^k`.
    /// [`Duration::ZERO`] (the default) never sleeps — what the
    /// deterministic scenario tests use.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, backoff: Duration::ZERO }
    }
}

/// Circuit-breaker configuration: trip after `trip_after` consecutive
/// flush failures (each counted after its retries are exhausted); while
/// open, `open_flushes` flushes fail fast without touching the backend,
/// then the breaker half-opens and the next flush probes it normally.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    pub trip_after: usize,
    pub open_flushes: usize,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig { trip_after: 3, open_flushes: 2 }
    }
}

/// Consecutive-failure circuit breaker over flush outcomes.
#[derive(Clone, Debug)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    consecutive: usize,
    open_remaining: usize,
    /// Cumulative trips since construction.
    pub trips: usize,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        CircuitBreaker { cfg, consecutive: 0, open_remaining: 0, trips: 0 }
    }

    pub fn is_open(&self) -> bool {
        self.open_remaining > 0
    }

    /// Consume one fail-fast flush of the open window.
    pub fn fail_fast(&mut self) {
        self.open_remaining = self.open_remaining.saturating_sub(1);
    }

    pub fn on_success(&mut self) {
        self.consecutive = 0;
    }

    /// Record one flush failure (after retries). Returns `true` when
    /// this failure trips the breaker open.
    pub fn on_failure(&mut self) -> bool {
        self.consecutive += 1;
        if self.consecutive >= self.cfg.trip_after {
            self.consecutive = 0;
            self.open_remaining = self.cfg.open_flushes;
            self.trips += 1;
            true
        } else {
            false
        }
    }

    /// Close immediately — used when a trip is absorbed by a
    /// degradation-ladder step instead of an open window.
    pub fn close(&mut self) {
        self.open_remaining = 0;
        self.consecutive = 0;
    }
}

/// One prepared operating point of the degradation ladder: the
/// (tile, pruning rate, weight format) configuration
/// [`crate::infer::NativeBackend::prepare`] re-stages from the master
/// weights.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperatingPoint {
    /// Systolic tile; `None` keeps the currently staged tile.
    pub tile: Option<usize>,
    /// Structured pruning rate handed to the global L1 ranking.
    pub rate: f64,
    pub quant: Quant,
}

impl OperatingPoint {
    pub fn new(rate: f64, quant: Quant) -> Self {
        OperatingPoint { tile: None, rate, quant }
    }

    /// Short human-readable label (`"rate=0.25 int8"`) — the state
    /// name used by [`StateTransition`] records and telemetry events.
    pub fn label(&self) -> String {
        let q = match self.quant {
            Quant::Fp32 => "fp32",
            Quant::Int8 => "int8",
        };
        match self.tile {
            Some(t) => format!("tile={t} rate={} {q}", self.rate),
            None => format!("rate={} {q}", self.rate),
        }
    }
}

/// One chronological breaker/ladder state change observed by the
/// serving loop — the per-run audit trail that the end-of-run counters
/// (`breaker_trips`, `degrade_steps`, ...) summarize away. Collected in
/// flush order on [`crate::coordinator::serve::ServeReport`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StateTransition {
    /// Time since the serving run started.
    pub at: Duration,
    /// State left (`"closed"`/`"open"` for the breaker, an
    /// [`OperatingPoint::label`] for the ladder).
    pub from: String,
    /// State entered.
    pub to: String,
    /// What forced the change (`"consecutive-failures"`, `"pressure"`,
    /// `"ladder-absorb"`, `"recovery"`, ...).
    pub trigger: String,
}

/// Graceful-degradation ladder: `points[0]` is the nominal operating
/// point, later entries are successively cheaper (higher rate / INT8).
/// The serving loop steps **down** (cheaper) after `patience`
/// consecutive flushes with queue pressure `>= high_watermark` or when
/// the circuit breaker trips, and steps **up** (recovers) after
/// `recover_after` consecutive successful flushes with pressure
/// `<= low_watermark` — the two watermarks plus the streak lengths are
/// the hysteresis that keeps it from oscillating.
#[derive(Clone, Debug)]
pub struct LadderConfig {
    pub points: Vec<OperatingPoint>,
    /// Queue depth (at flush time) at or above which pressure counts
    /// toward a step down.
    pub high_watermark: usize,
    /// Queue depth at or below which calm counts toward a step up.
    pub low_watermark: usize,
    /// Consecutive high-pressure flushes before stepping down.
    pub patience: usize,
    /// Consecutive calm successful flushes before stepping up.
    pub recover_after: usize,
}

impl LadderConfig {
    /// A ladder over `points` with conservative default hysteresis.
    pub fn new(points: Vec<OperatingPoint>) -> Self {
        LadderConfig {
            points,
            high_watermark: 8,
            low_watermark: 1,
            patience: 2,
            recover_after: 4,
        }
    }
}

/// The full resilience configuration the serving loop takes; absent
/// (`Server` default) the loop behaves exactly as before this module
/// existed.
#[derive(Clone, Debug)]
pub struct ResilienceConfig {
    pub admission: AdmissionConfig,
    pub retry: RetryPolicy,
    pub breaker: BreakerConfig,
    pub ladder: Option<LadderConfig>,
}

impl ResilienceConfig {
    /// Bounded admission at `capacity` under `policy`, default retry
    /// and breaker, no ladder.
    pub fn bounded(capacity: usize, policy: ShedPolicy) -> Self {
        ResilienceConfig {
            admission: AdmissionConfig { capacity, policy },
            retry: RetryPolicy::default(),
            breaker: BreakerConfig::default(),
            ladder: None,
        }
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    pub fn with_breaker(mut self, breaker: BreakerConfig) -> Self {
        self.breaker = breaker;
        self
    }

    pub fn with_ladder(mut self, ladder: LadderConfig) -> Self {
        self.ladder = Some(ladder);
        self
    }
}

/// One injected fault, drawn per backend call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// No fault: the call reaches the inner backend untouched.
    None,
    /// The call fails immediately with an error (a transient backend
    /// fault the retry policy is meant to absorb).
    Transient,
    /// The call sleeps [`FaultInjector::spike`] first, then proceeds —
    /// a latency spike, not a failure.
    Spike,
    /// The call sleeps [`FaultInjector::hang`] and then fails — a hang
    /// bounded by the caller's patience (modelled as a timeout error).
    Hang,
}

/// Where the injector's fault sequence comes from.
#[derive(Clone, Debug)]
pub enum FaultPlan {
    /// Draw per call from the crate's seeded xoshiro256**: one `f64`
    /// draw per call, faulting `Transient`/`Spike`/`Hang` with the
    /// given probabilities (cumulative thresholds, so the same seed
    /// always yields the same fault sequence regardless of which
    /// probabilities are zero).
    Seeded { seed: u64, p_transient: f64, p_spike: f64, p_hang: f64 },
    /// Replay an explicit per-call schedule; calls beyond the end are
    /// fault-free.
    Script(Vec<FaultKind>),
}

/// Cumulative injector accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounts {
    /// Backend calls gated (each consumed one draw/script slot).
    pub calls: usize,
    pub transient: usize,
    pub spikes: usize,
    pub hangs: usize,
}

/// Deterministic fault-injection wrapper over any [`ServeBackend`]:
/// every execute-path call first draws a [`FaultKind`] from the plan
/// and applies it; pass-through calls (`set_threads`,
/// `set_operating_point`, `any_batch`) are never faulted, so the
/// degradation ladder stays usable while the data path misbehaves.
pub struct FaultInjector<B: ServeBackend> {
    inner: B,
    plan: FaultPlan,
    rng: Rng,
    cursor: usize,
    /// Sleep applied by [`FaultKind::Spike`] (default zero — the
    /// deterministic tests keep wall-clock out of the loop).
    pub spike: Duration,
    /// Sleep applied by [`FaultKind::Hang`] before the timeout error.
    pub hang: Duration,
    counts: FaultCounts,
}

impl<B: ServeBackend> FaultInjector<B> {
    pub fn new(inner: B, plan: FaultPlan) -> Self {
        let seed = match &plan {
            FaultPlan::Seeded { seed, .. } => *seed,
            FaultPlan::Script(_) => 0,
        };
        FaultInjector {
            inner,
            plan,
            rng: Rng::new(seed),
            cursor: 0,
            spike: Duration::ZERO,
            hang: Duration::ZERO,
            counts: FaultCounts::default(),
        }
    }

    pub fn counts(&self) -> FaultCounts {
        self.counts
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut B {
        &mut self.inner
    }

    pub fn into_inner(self) -> B {
        self.inner
    }

    fn draw(&mut self) -> FaultKind {
        self.counts.calls += 1;
        let kind = match &self.plan {
            FaultPlan::Script(s) => {
                let k = s.get(self.cursor).copied().unwrap_or(FaultKind::None);
                self.cursor += 1;
                k
            }
            FaultPlan::Seeded { p_transient, p_spike, p_hang, .. } => {
                let u = self.rng.f64();
                if u < *p_transient {
                    FaultKind::Transient
                } else if u < p_transient + p_spike {
                    FaultKind::Spike
                } else if u < p_transient + p_spike + p_hang {
                    FaultKind::Hang
                } else {
                    FaultKind::None
                }
            }
        };
        match kind {
            FaultKind::None => {}
            FaultKind::Transient => self.counts.transient += 1,
            FaultKind::Spike => self.counts.spikes += 1,
            FaultKind::Hang => self.counts.hangs += 1,
        }
        kind
    }

    /// Draw and apply one fault; `Ok(())` means the call proceeds.
    fn gate(&mut self) -> Result<()> {
        match self.draw() {
            FaultKind::None => Ok(()),
            FaultKind::Spike => {
                if !self.spike.is_zero() {
                    std::thread::sleep(self.spike);
                }
                Ok(())
            }
            FaultKind::Transient => bail!("injected transient backend fault"),
            FaultKind::Hang => {
                if !self.hang.is_zero() {
                    std::thread::sleep(self.hang);
                }
                bail!("injected backend hang (request timed out)")
            }
        }
    }
}

impl<B: ServeBackend> ServeBackend for FaultInjector<B> {
    fn execute(&mut self, artifact: &str, args: &[Tensor]) -> Result<Tensor> {
        self.gate()?;
        self.inner.execute(artifact, args)
    }

    fn any_batch(&self) -> bool {
        self.inner.any_batch()
    }

    fn execute_rows(&mut self, artifact: &str, args: &[Tensor], rows: usize) -> Result<Tensor> {
        self.gate()?;
        self.inner.execute_rows(artifact, args, rows)
    }

    fn execute_rows_partial(
        &mut self,
        artifact: &str,
        args: &[Tensor],
        rows: usize,
    ) -> Result<(Tensor, Vec<usize>)> {
        self.gate()?;
        self.inner.execute_rows_partial(artifact, args, rows)
    }

    fn set_threads(&mut self, threads: usize) {
        self.inner.set_threads(threads);
    }

    fn set_operating_point(&mut self, point: &OperatingPoint) -> Result<bool> {
        self.inner.set_operating_point(point)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal inner backend: counts calls, returns a 1-element tensor.
    struct CountingStub {
        executed: usize,
    }

    impl ServeBackend for CountingStub {
        fn execute(&mut self, _artifact: &str, _args: &[Tensor]) -> Result<Tensor> {
            self.executed += 1;
            Ok(Tensor::from_f32(&[1], &[1.0]))
        }
    }

    fn call(inj: &mut FaultInjector<CountingStub>) -> Result<Tensor> {
        inj.execute("x", &[])
    }

    #[test]
    fn scripted_plan_replays_exactly() {
        let plan = FaultPlan::Script(vec![
            FaultKind::Transient,
            FaultKind::None,
            FaultKind::Hang,
            FaultKind::Spike,
        ]);
        let mut inj = FaultInjector::new(CountingStub { executed: 0 }, plan);
        assert!(call(&mut inj).is_err(), "scripted transient");
        assert!(call(&mut inj).is_ok());
        assert!(call(&mut inj).is_err(), "scripted hang");
        assert!(call(&mut inj).is_ok(), "spike proceeds after the sleep");
        // Beyond the script: fault-free.
        assert!(call(&mut inj).is_ok());
        assert_eq!(
            inj.counts(),
            FaultCounts { calls: 5, transient: 1, spikes: 1, hangs: 1 }
        );
        assert_eq!(inj.inner().executed, 3, "faulted calls never reach the inner backend");
    }

    #[test]
    fn seeded_plan_is_reproducible() {
        let plan = |seed| FaultPlan::Seeded {
            seed,
            p_transient: 0.3,
            p_spike: 0.1,
            p_hang: 0.1,
        };
        let run = |seed| {
            let mut inj = FaultInjector::new(CountingStub { executed: 0 }, plan(seed));
            let oks: Vec<bool> = (0..64).map(|_| call(&mut inj).is_ok()).collect();
            (oks, inj.counts())
        };
        let (a, ca) = run(99);
        let (b, cb) = run(99);
        assert_eq!(a, b, "same seed, same fault sequence");
        assert_eq!(ca, cb);
        assert!(ca.transient + ca.spikes + ca.hangs > 0, "p=0.5 over 64 calls must fault");
        let (c, _) = run(100);
        assert_ne!(a, c, "different seed, different sequence");
    }

    #[test]
    fn seeded_zero_probabilities_never_fault() {
        let mut inj = FaultInjector::new(
            CountingStub { executed: 0 },
            FaultPlan::Seeded { seed: 5, p_transient: 0.0, p_spike: 0.0, p_hang: 0.0 },
        );
        for _ in 0..32 {
            assert!(call(&mut inj).is_ok());
        }
        assert_eq!(inj.counts().transient, 0);
        assert_eq!(inj.inner().executed, 32);
    }

    #[test]
    fn breaker_trips_after_consecutive_failures_and_half_opens() {
        let mut br = CircuitBreaker::new(BreakerConfig { trip_after: 3, open_flushes: 2 });
        assert!(!br.on_failure());
        assert!(!br.on_failure());
        br.on_success(); // streak resets
        assert!(!br.on_failure());
        assert!(!br.on_failure());
        assert!(br.on_failure(), "third consecutive failure trips");
        assert_eq!(br.trips, 1);
        assert!(br.is_open());
        br.fail_fast();
        assert!(br.is_open());
        br.fail_fast();
        assert!(!br.is_open(), "open window exhausted: half-open");
        // A fresh trip needs a fresh streak.
        assert!(!br.on_failure());
    }

    #[test]
    fn breaker_close_absorbs_trip() {
        let mut br = CircuitBreaker::new(BreakerConfig { trip_after: 1, open_flushes: 5 });
        assert!(br.on_failure());
        assert!(br.is_open());
        br.close(); // the ladder stepped down instead
        assert!(!br.is_open());
        assert_eq!(br.trips, 1, "the trip still counts");
    }

    #[test]
    fn resilience_config_builders() {
        let r = ResilienceConfig::bounded(4, ShedPolicy::DeadlineAware)
            .with_retry(RetryPolicy { max_retries: 1, backoff: Duration::from_micros(10) })
            .with_breaker(BreakerConfig { trip_after: 2, open_flushes: 1 })
            .with_ladder(LadderConfig::new(vec![
                OperatingPoint::new(0.25, Quant::Int8),
                OperatingPoint::new(0.75, Quant::Int8),
            ]));
        assert_eq!(r.admission.capacity, 4);
        assert_eq!(r.admission.policy, ShedPolicy::DeadlineAware);
        assert_eq!(r.retry.max_retries, 1);
        assert_eq!(r.breaker.trip_after, 2);
        assert_eq!(r.ladder.as_ref().unwrap().points.len(), 2);
    }
}
