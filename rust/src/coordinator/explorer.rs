//! The SASP design-space explorer: sweeps (array size × quantization ×
//! pruning rate) across workloads, combining
//!
//! - timing/energy from the system simulator ([`crate::sysim`]) over the
//!   Table 1 workloads (synthetic tile-norm model),
//! - area/power from the calibrated hardware model ([`crate::hwmodel`]),
//! - QoS from the trained stand-in models via PJRT ([`crate::qos`]),
//!
//! into the design points plotted in Figs. 7–11 and Table 3.
//!
//! §Perf: the explorer is the sweep's inner loop, so everything that is
//! deterministic in the configuration is computed once and shared:
//!
//! - the synthetic tile norms per tile size and the CPU baseline (as in
//!   the seed),
//! - the **dense** `run_encoder` baseline per (tile, quant) — previously
//!   re-simulated by every `timing_point` call, i.e. once per *rate*,
//! - the encoder's GEMM-list expansion (reused across every run).
//!
//! All caches are `Mutex`-guarded so `Explorer` is `Sync`, which is what
//! lets [`Explorer::sweep`] fan design points out over a scoped worker
//! pool with plain `std::thread` — no external dependencies.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::hwmodel::{area_energy_product, area_mm2};
use crate::model::{EncoderSpec, LayerGemms};
use crate::pruning::{global_prune, synthetic_ff_norms, TileNorms};
use crate::sysim::{RunStats, System};
use crate::systolic::{ArrayConfig, Quant};

/// One fully-evaluated configuration.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub workload: &'static str,
    pub tile: usize,
    pub quant: Quant,
    pub rate: f64,
    /// Speedup of this configuration vs the software-only CPU baseline.
    pub speedup_vs_cpu: f64,
    /// Speedup vs the same array without pruning.
    pub speedup_vs_dense: f64,
    pub energy_j: f64,
    /// Energy of the same array without pruning.
    pub dense_energy_j: f64,
    pub area_mm2: f64,
    pub area_energy: f64,
    /// QoS of the configuration (WER for ASR, BLEU for MT); NaN when the
    /// point was evaluated timing-only.
    pub qos: f64,
}

impl PartialEq for DesignPoint {
    /// Float fields compare bitwise (`total_cmp`), so timing-only points
    /// (`qos` = NaN) produced by different evaluation paths — serial vs
    /// parallel sweep, cold vs warm caches — compare equal exactly when
    /// every computed quantity is identical.
    fn eq(&self, other: &Self) -> bool {
        let f = |a: f64, b: f64| a.total_cmp(&b) == std::cmp::Ordering::Equal;
        self.workload == other.workload
            && self.tile == other.tile
            && self.quant == other.quant
            && f(self.rate, other.rate)
            && f(self.speedup_vs_cpu, other.speedup_vs_cpu)
            && f(self.speedup_vs_dense, other.speedup_vs_dense)
            && f(self.energy_j, other.energy_j)
            && f(self.dense_energy_j, other.dense_energy_j)
            && f(self.area_mm2, other.area_mm2)
            && f(self.area_energy, other.area_energy)
            && f(self.qos, other.qos)
    }
}

/// One configuration to evaluate in a sweep.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SweepPoint {
    pub tile: usize,
    pub quant: Quant,
    pub rate: f64,
}

impl SweepPoint {
    /// The full (sizes × quants × rates) cross product, in the iteration
    /// order the serial sweep loops used (size-major, rate-minor).
    pub fn grid(sizes: &[usize], quants: &[Quant], rates: &[f64]) -> Vec<SweepPoint> {
        let mut out = Vec::with_capacity(sizes.len() * quants.len() * rates.len());
        for &tile in sizes {
            for &quant in quants {
                for &rate in rates {
                    out.push(SweepPoint { tile, quant, rate });
                }
            }
        }
        out
    }
}

/// Explorer over one workload spec.
///
/// `spec`/`system`/`seed` are immutable after construction: the
/// pre-expanded GEMM list and the norm/dense/CPU caches are all derived
/// from them, so exposing the fields mutably would let them silently
/// desync from the cached state.
pub struct Explorer {
    system: System,
    spec: EncoderSpec,
    /// Seed for the synthetic tile-norm model.
    seed: u64,
    /// Pre-expanded GEMM list (reused by every simulated run).
    layers: Vec<LayerGemms>,
    /// Synthetic norms are deterministic in (spec, seed, tile) — memoized,
    /// they dominate the sweep's inner loop (§Perf).
    norm_cache: Mutex<HashMap<usize, Arc<Vec<TileNorms>>>>,
    /// Dense (unpruned) accelerated baseline per (tile, quant) — shared
    /// by every rate evaluated at that configuration.
    dense_cache: Mutex<HashMap<(usize, Quant), Arc<RunStats>>>,
    /// Software-only baseline cycles (one per workload).
    cpu_cache: OnceLock<f64>,
}

impl Explorer {
    pub fn new(spec: EncoderSpec) -> Self {
        let layers = spec.layers();
        Explorer {
            system: System::default(),
            spec,
            seed: 7,
            layers,
            norm_cache: Mutex::new(HashMap::new()),
            dense_cache: Mutex::new(HashMap::new()),
            cpu_cache: OnceLock::new(),
        }
    }

    pub fn spec(&self) -> &EncoderSpec {
        &self.spec
    }

    pub fn system(&self) -> &System {
        &self.system
    }

    // Cache discipline (both caches): check under the lock, compute
    // OUTSIDE it, then insert with first-insert-wins. Two workers racing
    // on the same cold key may duplicate the (deterministic) computation,
    // but no worker ever blocks on another key's simulation — holding the
    // map-wide Mutex across run_encoder would serialize the cold-cache
    // sweep.

    fn norms_for(&self, tile: usize) -> Arc<Vec<TileNorms>> {
        if let Some(hit) = self.norm_cache.lock().unwrap().get(&tile) {
            return hit.clone();
        }
        let computed = Arc::new(synthetic_ff_norms(&self.spec, tile, self.seed));
        self.norm_cache
            .lock()
            .unwrap()
            .entry(tile)
            .or_insert(computed)
            .clone()
    }

    fn cpu_cycles(&self) -> f64 {
        *self
            .cpu_cache
            .get_or_init(|| self.system.run_encoder_cpu(&self.spec).cycles)
    }

    /// Dense accelerated baseline at (tile, quant), memoized.
    pub fn dense_run(&self, tile: usize, quant: Quant) -> Arc<RunStats> {
        if let Some(hit) = self.dense_cache.lock().unwrap().get(&(tile, quant)) {
            return hit.clone();
        }
        let array = ArrayConfig::square(tile, quant);
        let computed = Arc::new(self.system.run_encoder_layers(
            &self.spec,
            &self.layers,
            &array,
            None,
        ));
        self.dense_cache
            .lock()
            .unwrap()
            .entry((tile, quant))
            .or_insert(computed)
            .clone()
    }

    /// Simulate one (tile, quant, rate) configuration.
    pub fn timing_point(&self, tile: usize, quant: Quant, rate: f64) -> DesignPoint {
        let array = ArrayConfig::square(tile, quant);
        let cpu_cycles = self.cpu_cycles();
        let dense = self.dense_run(tile, quant);
        let pruned = self.pruned_run(tile, quant, rate);
        DesignPoint {
            workload: self.spec.name,
            tile,
            quant,
            rate,
            speedup_vs_cpu: cpu_cycles / pruned.cycles,
            speedup_vs_dense: dense.cycles / pruned.cycles,
            energy_j: pruned.energy_j,
            dense_energy_j: dense.energy_j,
            area_mm2: area_mm2(&array),
            area_energy: area_energy_product(&array, pruned.energy_j),
            qos: f64::NAN,
        }
    }

    /// Run the workload with a global prune at `rate`.
    pub fn pruned_run(&self, tile: usize, quant: Quant, rate: f64) -> RunStats {
        let array = ArrayConfig::square(tile, quant);
        if rate <= 0.0 {
            return (*self.dense_run(tile, quant)).clone();
        }
        let norms = self.norms_for(tile);
        let plan = global_prune(&norms, rate);
        self.system.run_encoder_layers(
            &self.spec,
            &self.layers,
            &array,
            Some(&plan.masks),
        )
    }

    /// Evaluate a batch of design points on a scoped worker pool
    /// (`std::thread::scope`, one worker per available core).
    ///
    /// The result is index-aligned with `points` and identical — field
    /// for field — to calling [`timing_point`](Self::timing_point)
    /// serially: each point's evaluation is deterministic, and the shared
    /// caches only change *when* a baseline is computed, never its value.
    pub fn sweep(&self, points: &[SweepPoint]) -> Vec<DesignPoint> {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(points.len().max(1));
        if workers <= 1 {
            return points
                .iter()
                .map(|p| self.timing_point(p.tile, p.quant, p.rate))
                .collect();
        }

        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<DesignPoint>>> =
            points.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    // ordering: Relaxed — the cursor only needs each
                    // index handed to exactly one worker (atomicity);
                    // results are published via the per-slot mutexes.
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= points.len() {
                        break;
                    }
                    let p = &points[i];
                    let dp = self.timing_point(p.tile, p.quant, p.rate);
                    *slots[i].lock().unwrap() = Some(dp);
                });
            }
        });
        slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap()
                    .expect("every sweep slot is filled before scope exit")
            })
            .collect()
    }

    /// Per-layer normalized runtime at a given global sparsity (Fig. 8):
    /// each layer's cycles divided by its unpruned cycles.
    pub fn per_layer_normalized(&self, tile: usize, quant: Quant, rate: f64) -> Vec<f64> {
        let dense = self.dense_run(tile, quant);
        let pruned = self.pruned_run(tile, quant, rate);
        dense
            .per_layer
            .iter()
            .zip(&pruned.per_layer)
            .map(|(d, p)| p.cycles / d.cycles)
            .collect()
    }
}

/// Search for the highest pruning rate meeting a QoS constraint on a
/// rate grid — the paper's "under the target QoS degradations defined in
/// Table 1" selection (Fig. 7, Table 3).
pub struct RateSearch {
    /// Candidate rates, ascending (e.g. 0.05 steps to 0.6).
    pub grid: Vec<f64>,
}

impl Default for RateSearch {
    fn default() -> Self {
        RateSearch { grid: (0..=12).map(|i| i as f64 * 0.05).collect() }
    }
}

impl RateSearch {
    /// Highest rate whose QoS passes `accept`. Assumes QoS degrades
    /// monotonically with rate (exponentially, per Fig. 9), so scans from
    /// the top of the grid down and returns on first acceptance.
    pub fn max_rate<E>(
        &self,
        mut qos_at: impl FnMut(f64) -> Result<f64, E>,
        mut accept: impl FnMut(f64) -> bool,
    ) -> Result<Option<(f64, f64)>, E> {
        for rate in self.grid.iter().rev() {
            let q = qos_at(*rate)?;
            if accept(q) {
                return Ok(Some((*rate, q)));
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn pruning_increases_speedup_and_cuts_energy() {
        let e = Explorer::new(zoo::espnet_asr());
        let p0 = e.timing_point(8, Quant::Int8, 0.0);
        let p25 = e.timing_point(8, Quant::Int8, 0.25);
        assert!(p25.speedup_vs_dense > 1.05, "{}", p25.speedup_vs_dense);
        assert!(p25.energy_j < p0.energy_j);
        assert!((p0.speedup_vs_dense - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sasp_gains_shrink_with_array_size() {
        // Fig. 7 trend: achievable improvements decrease for larger
        // arrays (fixed rate here; the QoS-constrained version amplifies
        // this).
        let e = Explorer::new(zoo::espnet_asr());
        let g8 = e.timing_point(8, Quant::Int8, 0.25).speedup_vs_dense;
        let g32 = e.timing_point(32, Quant::Int8, 0.25).speedup_vs_dense;
        assert!(g8 >= g32 * 0.98, "8x8 {g8} vs 32x32 {g32}");
    }

    #[test]
    fn per_layer_normalized_tracks_sparsity() {
        let e = Explorer::new(zoo::espnet_asr());
        let norm = e.per_layer_normalized(8, Quant::Int8, 0.25);
        assert_eq!(norm.len(), 18);
        // All layers at most 1.0 (pruning never slows a layer down).
        assert!(norm.iter().all(|v| *v <= 1.0 + 1e-9));
        // Early layers prune more than late ones (synthetic norm model).
        assert!(norm[0] < *norm.last().unwrap());
    }

    #[test]
    fn sweep_matches_serial_timing_points_exactly() {
        // The acceptance contract of the parallel sweep: identical
        // DesignPoints (bitwise-equal floats) in input order.
        let e = Explorer::new(zoo::espnet_asr());
        let points = SweepPoint::grid(
            &[4, 8, 16],
            &[Quant::Fp32, Quant::Int8],
            &[0.0, 0.15, 0.25, 0.4],
        );
        assert_eq!(points.len(), 24);
        let parallel = e.sweep(&points);
        let serial: Vec<DesignPoint> = points
            .iter()
            .map(|p| e.timing_point(p.tile, p.quant, p.rate))
            .collect();
        assert_eq!(parallel, serial);
    }

    #[test]
    fn sweep_on_fresh_explorer_matches_warm_caches() {
        // Cold caches in the parallel path must not change results.
        let points =
            SweepPoint::grid(&[8, 32], &[Quant::Int8], &[0.0, 0.2, 0.3]);
        let cold = Explorer::new(zoo::mustc_mt_encoder()).sweep(&points);
        let warm_ex = Explorer::new(zoo::mustc_mt_encoder());
        let warm: Vec<DesignPoint> = points
            .iter()
            .map(|p| warm_ex.timing_point(p.tile, p.quant, p.rate))
            .collect();
        assert_eq!(cold, warm);
    }

    #[test]
    fn sweep_handles_tiny_and_empty_batches() {
        let e = Explorer::new(zoo::espnet2_asr());
        assert!(e.sweep(&[]).is_empty());
        let one = e.sweep(&[SweepPoint { tile: 8, quant: Quant::Int8, rate: 0.1 }]);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].tile, 8);
    }

    #[test]
    fn dense_run_is_cached_and_consistent() {
        let e = Explorer::new(zoo::espnet_asr());
        let a = e.dense_run(8, Quant::Int8);
        let b = e.dense_run(8, Quant::Int8);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        // And matches an uncached simulation.
        let fresh = e.system().run_encoder(
            e.spec(),
            &ArrayConfig::square(8, Quant::Int8),
            None,
        );
        assert_eq!(a.cycles, fresh.cycles);
    }

    #[test]
    fn rate_search_returns_highest_accepted() {
        let rs = RateSearch { grid: vec![0.0, 0.1, 0.2, 0.3, 0.4] };
        // QoS = rate (degrades linearly); accept <= 0.25.
        let got = rs
            .max_rate::<()>(|r| Ok(r), |q| q <= 0.25)
            .unwrap()
            .unwrap();
        assert_eq!(got.0, 0.2);
    }

    #[test]
    fn rate_search_none_when_nothing_passes() {
        let rs = RateSearch { grid: vec![0.1, 0.2] };
        let got = rs.max_rate::<()>(|r| Ok(r), |_| false).unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn area_energy_monotone_in_size() {
        let e = Explorer::new(zoo::espnet2_asr());
        let a8 = e.timing_point(8, Quant::Fp32, 0.0);
        let a16 = e.timing_point(16, Quant::Fp32, 0.0);
        assert!(a16.area_mm2 > a8.area_mm2);
    }
}
