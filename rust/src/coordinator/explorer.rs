//! The SASP design-space explorer: sweeps (array size × quantization ×
//! pruning rate) across workloads, combining
//!
//! - timing/energy from the system simulator ([`crate::sysim`]) over the
//!   Table 1 workloads (synthetic tile-norm model),
//! - area/power from the calibrated hardware model ([`crate::hwmodel`]),
//! - QoS from the trained stand-in models via PJRT ([`crate::qos`]),
//!
//! into the design points plotted in Figs. 7–11 and Table 3.

use crate::hwmodel::{area_energy_product, area_mm2};
use crate::model::EncoderSpec;
use crate::pruning::{global_prune, synthetic_ff_norms};
use crate::sysim::{RunStats, System};
use crate::systolic::{ArrayConfig, Quant};

/// One fully-evaluated configuration.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub workload: &'static str,
    pub tile: usize,
    pub quant: Quant,
    pub rate: f64,
    /// Speedup of this configuration vs the software-only CPU baseline.
    pub speedup_vs_cpu: f64,
    /// Speedup vs the same array without pruning.
    pub speedup_vs_dense: f64,
    pub energy_j: f64,
    /// Energy of the same array without pruning.
    pub dense_energy_j: f64,
    pub area_mm2: f64,
    pub area_energy: f64,
    /// QoS of the configuration (WER for ASR, BLEU for MT); NaN when the
    /// point was evaluated timing-only.
    pub qos: f64,
}

/// Explorer over one workload spec.
pub struct Explorer {
    pub system: System,
    pub spec: EncoderSpec,
    /// Seed for the synthetic tile-norm model.
    pub seed: u64,
    /// Synthetic norms + baseline runs are deterministic in (spec, seed,
    /// tile) — memoized, they dominate the sweep's inner loop (§Perf).
    norm_cache: std::cell::RefCell<
        std::collections::HashMap<usize, std::rc::Rc<Vec<crate::pruning::TileNorms>>>,
    >,
    cpu_cache: std::cell::RefCell<Option<f64>>,
}

impl Explorer {
    pub fn new(spec: EncoderSpec) -> Self {
        Explorer {
            system: System::default(),
            spec,
            seed: 7,
            norm_cache: Default::default(),
            cpu_cache: Default::default(),
        }
    }

    fn norms_for(&self, tile: usize) -> std::rc::Rc<Vec<crate::pruning::TileNorms>> {
        self.norm_cache
            .borrow_mut()
            .entry(tile)
            .or_insert_with(|| {
                std::rc::Rc::new(synthetic_ff_norms(&self.spec, tile, self.seed))
            })
            .clone()
    }

    fn cpu_cycles(&self) -> f64 {
        if let Some(c) = *self.cpu_cache.borrow() {
            return c;
        }
        let c = self.system.run_encoder_cpu(&self.spec).cycles;
        *self.cpu_cache.borrow_mut() = Some(c);
        c
    }

    /// Simulate one (tile, quant, rate) configuration.
    pub fn timing_point(&self, tile: usize, quant: Quant, rate: f64) -> DesignPoint {
        let array = ArrayConfig::square(tile, quant);
        let cpu_cycles = self.cpu_cycles();
        let dense = self.system.run_encoder(&self.spec, &array, None);
        let pruned = self.pruned_run(tile, quant, rate);
        DesignPoint {
            workload: self.spec.name,
            tile,
            quant,
            rate,
            speedup_vs_cpu: cpu_cycles / pruned.cycles,
            speedup_vs_dense: dense.cycles / pruned.cycles,
            energy_j: pruned.energy_j,
            dense_energy_j: dense.energy_j,
            area_mm2: area_mm2(&array),
            area_energy: area_energy_product(&array, pruned.energy_j),
            qos: f64::NAN,
        }
    }

    /// Run the workload with a global prune at `rate`.
    pub fn pruned_run(&self, tile: usize, quant: Quant, rate: f64) -> RunStats {
        let array = ArrayConfig::square(tile, quant);
        if rate <= 0.0 {
            return self.system.run_encoder(&self.spec, &array, None);
        }
        let norms = self.norms_for(tile);
        let plan = global_prune(&norms, rate);
        self.system.run_encoder(&self.spec, &array, Some(&plan.masks))
    }

    /// Per-layer normalized runtime at a given global sparsity (Fig. 8):
    /// each layer's cycles divided by its unpruned cycles.
    pub fn per_layer_normalized(&self, tile: usize, quant: Quant, rate: f64) -> Vec<f64> {
        let array = ArrayConfig::square(tile, quant);
        let dense = self.system.run_encoder(&self.spec, &array, None);
        let pruned = self.pruned_run(tile, quant, rate);
        dense
            .per_layer
            .iter()
            .zip(&pruned.per_layer)
            .map(|(d, p)| p.cycles / d.cycles)
            .collect()
    }
}

/// Search for the highest pruning rate meeting a QoS constraint on a
/// rate grid — the paper's "under the target QoS degradations defined in
/// Table 1" selection (Fig. 7, Table 3).
pub struct RateSearch {
    /// Candidate rates, ascending (e.g. 0.05 steps to 0.6).
    pub grid: Vec<f64>,
}

impl Default for RateSearch {
    fn default() -> Self {
        RateSearch { grid: (0..=12).map(|i| i as f64 * 0.05).collect() }
    }
}

impl RateSearch {
    /// Highest rate whose QoS passes `accept`. Assumes QoS degrades
    /// monotonically with rate (exponentially, per Fig. 9), so scans from
    /// the top of the grid down and returns on first acceptance.
    pub fn max_rate<E>(
        &self,
        mut qos_at: impl FnMut(f64) -> Result<f64, E>,
        mut accept: impl FnMut(f64) -> bool,
    ) -> Result<Option<(f64, f64)>, E> {
        for rate in self.grid.iter().rev() {
            let q = qos_at(*rate)?;
            if accept(q) {
                return Ok(Some((*rate, q)));
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn pruning_increases_speedup_and_cuts_energy() {
        let e = Explorer::new(zoo::espnet_asr());
        let p0 = e.timing_point(8, Quant::Int8, 0.0);
        let p25 = e.timing_point(8, Quant::Int8, 0.25);
        assert!(p25.speedup_vs_dense > 1.05, "{}", p25.speedup_vs_dense);
        assert!(p25.energy_j < p0.energy_j);
        assert!((p0.speedup_vs_dense - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sasp_gains_shrink_with_array_size() {
        // Fig. 7 trend: achievable improvements decrease for larger
        // arrays (fixed rate here; the QoS-constrained version amplifies
        // this).
        let e = Explorer::new(zoo::espnet_asr());
        let g8 = e.timing_point(8, Quant::Int8, 0.25).speedup_vs_dense;
        let g32 = e.timing_point(32, Quant::Int8, 0.25).speedup_vs_dense;
        assert!(g8 >= g32 * 0.98, "8x8 {g8} vs 32x32 {g32}");
    }

    #[test]
    fn per_layer_normalized_tracks_sparsity() {
        let e = Explorer::new(zoo::espnet_asr());
        let norm = e.per_layer_normalized(8, Quant::Int8, 0.25);
        assert_eq!(norm.len(), 18);
        // All layers at most 1.0 (pruning never slows a layer down).
        assert!(norm.iter().all(|v| *v <= 1.0 + 1e-9));
        // Early layers prune more than late ones (synthetic norm model).
        assert!(norm[0] < *norm.last().unwrap());
    }

    #[test]
    fn rate_search_returns_highest_accepted() {
        let rs = RateSearch { grid: vec![0.0, 0.1, 0.2, 0.3, 0.4] };
        // QoS = rate (degrades linearly); accept <= 0.25.
        let got = rs
            .max_rate::<()>(|r| Ok(r), |q| q <= 0.25)
            .unwrap()
            .unwrap();
        assert_eq!(got.0, 0.2);
    }

    #[test]
    fn rate_search_none_when_nothing_passes() {
        let rs = RateSearch { grid: vec![0.1, 0.2] };
        let got = rs.max_rate::<()>(|r| Ok(r), |_| false).unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn area_energy_monotone_in_size() {
        let e = Explorer::new(zoo::espnet2_asr());
        let a8 = e.timing_point(8, Quant::Fp32, 0.0);
        let a16 = e.timing_point(16, Quant::Fp32, 0.0);
        assert!(a16.area_mm2 > a8.area_mm2);
    }
}
