//! Batched inference serving loop — the edge-deployment face of the
//! coordinator. Requests (utterances) arrive on a queue; the batcher
//! groups them under a [`FlushPolicy`]; the execution backend runs each
//! flush; the caller gets decoded hypotheses plus latency metrics.
//!
//! Two flush policies drive the runtime's scaling levers:
//!
//! - [`FlushPolicy::Fixed`] waits (up to `max_wait`, measured from the
//!   first queued request's arrival) for a full `max_batch` — the
//!   fixed-shape artifact contract. On a backend that cannot resize its
//!   batch (PJRT), partial flushes are padded with **zeroed slack rows**
//!   (zero features, zero pad mask) that are counted explicitly in
//!   [`ServeReport::slack_rows`] — never with repeated live requests,
//!   which would silently burn compute and pollute backend statistics.
//! - [`FlushPolicy::Dynamic`] is work-conserving: it flushes whatever is
//!   queued the moment the executor is free (up to `max_batch`). On an
//!   any-batch backend ([`ServeBackend::any_batch`], the native engine)
//!   each flush executes **exactly** the queued rows — no padding, no
//!   slack work — and the backend shards the flush's utterances across
//!   [`ServeConfig::threads`] worker threads, each utterance bitwise
//!   identical to the single-threaded run.
//!
//! An idle server blocks on the request channel — it never ticks
//! `max_wait` wake-ups while the queue is empty, and the batching window
//! starts at the first request's arrival, so late arrivals get their
//! full window.
//!
//! Autoregressive MT gets its own serving loop: [`DecodeServer`]
//! schedules at *iteration level* (the LLM-server technique — Orca-style
//! continuous batching) rather than request level. Up to `max_slots`
//! in-flight translations advance one token per step in lockstep on
//! shared weight-stationary panels ([`crate::infer::ContinuousDecoder`]);
//! finished slots retire between steps and are refilled from a bounded,
//! deadline-aware admission queue, so short utterances never wait for
//! long ones and the panels stay as full as the offered load allows.
//!
//! Implemented over std threads/channels (no tokio in the vendor set);
//! the PJRT client is kept on the worker thread, requests cross via mpsc.
//!
//! §Perf: everything static is hoisted into [`Server::new`] — the
//! artifact is loaded once, and the positional argument vector (weights,
//! masks, parameter tensors) is built once. The steady-state loop only
//! rewrites the `feats`/`pad_mask` bytes in place (fixed path) or the
//! reused dynamic argument tensors (any-batch path). The remaining
//! per-flush cost on the native path is the byte<->f32 conversion at
//! the [`ServeBackend`] tensor boundary (the contract PJRT needs);
//! bypassing it for in-process callers is a known follow-on.

use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use super::resilience::{
    AdmissionConfig, CircuitBreaker, OperatingPoint, ResilienceConfig, ShedPolicy,
    StateTransition,
};
use crate::data::{load_bundle, Bundle, DType, Tensor};
use crate::infer::{synth_testset, synth_weights, ContinuousDecoder, ModelDims, NativeBackend};
use crate::qos::decode::ctc_greedy;
use crate::qos::{AsrEvaluator, EvalMeta, PjrtState, QosBackend};
use crate::runtime::{Engine, Manifest};
use crate::systolic::Quant;
use crate::telemetry::{self, LazyCounter, LazyGauge, LazyHistogram};

// Serving metrics (see EXPERIMENTS.md §Observability for the full
// catalog). All updates are gated on `telemetry::active()` at the call
// site, so an idle registry costs one relaxed load per event.
static M_ADMITTED: LazyCounter = LazyCounter::new("serve_admitted_total");
static M_OK: LazyCounter = LazyCounter::new("serve_ok_total");
static M_SHED: LazyCounter = LazyCounter::new("serve_shed_total");
static M_EXPIRED: LazyCounter = LazyCounter::new("serve_expired_total");
static M_INVALID: LazyCounter = LazyCounter::new("serve_invalid_total");
static M_FAILED: LazyCounter = LazyCounter::new("serve_failed_total");
static M_RETRIES: LazyCounter = LazyCounter::new("serve_retries_total");
static M_FLUSHES: LazyCounter = LazyCounter::new("serve_flushes_total");
static M_BREAKER_TRIPS: LazyCounter = LazyCounter::new("serve_breaker_trips_total");
static M_DEGRADE: LazyCounter = LazyCounter::new("serve_ladder_degrade_total");
static M_RECOVER: LazyCounter = LazyCounter::new("serve_ladder_recover_total");
static M_QUEUE_DEPTH: LazyGauge = LazyGauge::new("serve_queue_depth");
static M_OK_LATENCY: LazyHistogram = LazyHistogram::new("serve_ok_latency_us");
static M_BATCH_FILL: LazyHistogram = LazyHistogram::new("serve_batch_fill");

/// The execution surface the server needs. Production uses the PJRT
/// [`Engine`] or the native engine ([`crate::infer::NativeBackend`],
/// which also publishes the [`Manifest`] it serves — the fully offline
/// path); tests drive the batching logic with a stub.
pub trait ServeBackend {
    fn execute(&mut self, artifact: &str, args: &[Tensor]) -> Result<Tensor>;

    /// Whether the backend executes a batch of any size in one call (the
    /// native engine). Fixed-shape backends (PJRT artifacts, the test
    /// stubs) take padded full-batch arguments instead.
    fn any_batch(&self) -> bool {
        false
    }

    /// Execute exactly `rows` utterances whose arguments are sized
    /// `[rows, ...]` — the dynamic-batch entry point. Only meaningful
    /// when [`Self::any_batch`] is true (the serving loop never calls
    /// it otherwise); the default delegates to [`Self::execute`], which
    /// is only correct if the backend's fixed batch equals `rows`.
    fn execute_rows(&mut self, artifact: &str, args: &[Tensor], rows: usize) -> Result<Tensor> {
        let _ = rows;
        self.execute(artifact, args)
    }

    /// [`Self::execute_rows`] with per-row fault containment: returns
    /// the output plus the indices of rows whose execution failed (a
    /// contained worker panic). Failed rows carry zeroed output —
    /// callers must map them to failed responses, never decode them.
    /// The default delegates to [`Self::execute_rows`] with no
    /// containment (any failure fails the whole call).
    fn execute_rows_partial(
        &mut self,
        artifact: &str,
        args: &[Tensor],
        rows: usize,
    ) -> Result<(Tensor, Vec<usize>)> {
        Ok((self.execute_rows(artifact, args, rows)?, Vec::new()))
    }

    /// Hint: shard batched execution across `threads` worker threads.
    /// Backends without a thread pool ignore it.
    fn set_threads(&mut self, _threads: usize) {}

    /// Switch to a prepared operating point of the degradation ladder.
    /// Returns `Ok(true)` when the backend re-staged itself at `point`
    /// (the native engine), `Ok(false)` when it cannot switch (PJRT
    /// artifacts are compiled for one configuration; stubs) — the
    /// serving loop then leaves the ladder inert rather than erroring.
    fn set_operating_point(&mut self, _point: &OperatingPoint) -> Result<bool> {
        Ok(false)
    }
}

impl ServeBackend for Engine {
    fn execute(&mut self, artifact: &str, args: &[Tensor]) -> Result<Tensor> {
        Engine::execute(self, artifact, args)
    }
}

/// The auto-selected execution backend — **one** selection path shared
/// by `serve`, `asr_pipeline`, and the QoS harness
/// ([`crate::harness::QosCache`]): PJRT over compiled artifacts when
/// they exist, the batched native engine otherwise. Implements both
/// [`ServeBackend`] and [`QosBackend`], so callers configure/execute
/// without knowing which engine is underneath.
pub enum Backend {
    /// The PJRT engine plus the per-configuration QoS state of the
    /// artifact it serves.
    Pjrt { engine: Engine, qos: PjrtState },
    /// The batched weight-stationary native engine (no artifacts).
    Native(Box<NativeBackend>),
}

impl Backend {
    /// The ASR encoder artifact every serving surface defaults to.
    pub const ASR_ARTIFACT: &'static str = "asr_encoder_ref";

    /// Pick the backend for `dir`: PJRT when the compiled ASR artifact
    /// is readable there, otherwise the batched native engine over the
    /// deterministic synthetic tiny-ASR model (the fully offline path),
    /// sharding batches across one worker thread per available core.
    pub fn auto(dir: &str) -> Result<Backend> {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::auto_with(dir, Self::ASR_ARTIFACT, ModelDims::tiny_asr(), 7, 4, threads)
    }

    /// [`Self::auto`] with explicit artifact name and native fallback
    /// parameters (synthetic model dims/seed, serving batch, worker
    /// threads for sharded batch execution).
    pub fn auto_with(
        dir: &str,
        artifact: &str,
        dims: ModelDims,
        seed: u64,
        batch: usize,
        threads: usize,
    ) -> Result<Backend> {
        // Probe via Path::join (a trailing-slash or otherwise odd `dir`
        // must not break selection) and require the artifact to actually
        // be readable: an existing-but-unreadable file would otherwise
        // only fail later, inside `Engine::new`/`Engine::load`, where
        // the offline native fallback is no longer reachable.
        let hlo = Path::new(dir).join(format!("{artifact}.hlo.txt"));
        if hlo.is_file() && std::fs::File::open(&hlo).is_ok() {
            Ok(Backend::Pjrt {
                engine: Engine::new(dir)?,
                qos: PjrtState::new(artifact),
            })
        } else {
            let mut native = NativeBackend::new(synth_weights(&dims, seed), batch)?;
            native.set_threads(threads);
            Ok(Backend::Native(Box::new(native)))
        }
    }

    pub fn is_native(&self) -> bool {
        matches!(self, Backend::Native(_))
    }

    pub fn label(&self) -> &'static str {
        match self {
            Backend::Pjrt { .. } => "pjrt",
            Backend::Native(_) => "native",
        }
    }

    /// Human-readable backend description for example/CLI banners.
    pub fn describe(&self) -> String {
        match self {
            Backend::Pjrt { engine, .. } => format!("PJRT ({})", engine.platform()),
            Backend::Native(nb) => {
                let m = nb.model();
                let quant = match m.quant {
                    Quant::Fp32 => "FP32",
                    Quant::Int8 => "INT8",
                };
                format!(
                    "native engine (batched weight-stationary, {}x{} tile, {quant})",
                    m.tile, m.tile
                )
            }
        }
    }

    /// The native engine, when that is what auto-selection picked.
    pub fn native_mut(&mut self) -> Option<&mut NativeBackend> {
        match self {
            Backend::Pjrt { .. } => None,
            Backend::Native(nb) => Some(nb),
        }
    }

    /// The PJRT engine, when artifacts were found.
    pub fn engine_mut(&mut self) -> Option<&mut Engine> {
        match self {
            Backend::Pjrt { engine, .. } => Some(engine),
            Backend::Native(_) => None,
        }
    }

    /// What [`Server::with_manifest`] needs for this backend: the
    /// serving manifest, the parameter bundle, and the artifact name.
    /// PJRT loads both from `dir`; the native engine publishes its own
    /// manifest and needs no parameter arguments.
    pub fn serve_parts(&mut self, dir: &str) -> Result<(Manifest, Bundle, String)> {
        match self {
            Backend::Pjrt { engine, qos } => {
                let artifact = qos.artifact().to_string();
                let manifest = engine.load(&artifact)?.manifest.clone();
                let params = load_bundle(format!("{dir}/params_asr.bin"))?;
                Ok((manifest, params, artifact))
            }
            Backend::Native(nb) => Ok((
                nb.manifest().clone(),
                Bundle::default(),
                nb.manifest().name.clone(),
            )),
        }
    }

    /// Build the matching ASR QoS evaluator: artifact bundles for PJRT,
    /// a teacher-labeled synthetic test set of `n_utts` utterances
    /// (deterministic, baseline WER 0) for the native engine.
    pub fn asr_evaluator(&mut self, dir: &str, n_utts: usize) -> Result<AsrEvaluator> {
        match self {
            Backend::Pjrt { engine, qos } => {
                let artifact = qos.artifact().to_string();
                AsrEvaluator::new(engine, dir, &artifact)
            }
            Backend::Native(nb) => {
                let dims = *nb.dims();
                let testset = synth_testset(nb.weights(), n_utts, 11)?;
                let meta = EvalMeta {
                    n_blocks: dims.n_blocks,
                    batch: nb.batch(),
                    vocab: dims.vocab,
                    blank: dims.ctc_blank,
                    tile_hint: dims.tile,
                };
                AsrEvaluator::from_parts("native", nb.weights().to_bundle(), &testset, &meta)
            }
        }
    }
}

impl ServeBackend for Backend {
    fn execute(&mut self, artifact: &str, args: &[Tensor]) -> Result<Tensor> {
        match self {
            Backend::Pjrt { engine, .. } => engine.execute(artifact, args),
            Backend::Native(nb) => nb.execute(artifact, args),
        }
    }

    fn any_batch(&self) -> bool {
        matches!(self, Backend::Native(_))
    }

    fn execute_rows(&mut self, artifact: &str, args: &[Tensor], rows: usize) -> Result<Tensor> {
        match self {
            // The PJRT artifact is compiled for one fixed batch; handing
            // it `[rows, ...]`-shaped literals would fail (or worse,
            // not) deep inside argument marshalling. Callers must use
            // the padded fixed-shape `execute` path instead.
            Backend::Pjrt { .. } => anyhow::bail!(
                "PJRT backend is fixed-batch; pad to the artifact batch and use execute()"
            ),
            Backend::Native(nb) => ServeBackend::execute_rows(nb.as_mut(), artifact, args, rows),
        }
    }

    fn execute_rows_partial(
        &mut self,
        artifact: &str,
        args: &[Tensor],
        rows: usize,
    ) -> Result<(Tensor, Vec<usize>)> {
        match self {
            Backend::Pjrt { .. } => anyhow::bail!(
                "PJRT backend is fixed-batch; pad to the artifact batch and use execute()"
            ),
            Backend::Native(nb) => {
                ServeBackend::execute_rows_partial(nb.as_mut(), artifact, args, rows)
            }
        }
    }

    fn set_threads(&mut self, threads: usize) {
        if let Backend::Native(nb) = self {
            nb.set_threads(threads);
        }
    }

    fn set_operating_point(&mut self, point: &OperatingPoint) -> Result<bool> {
        match self {
            // A PJRT artifact is compiled at one configuration — the
            // ladder has nothing to switch.
            Backend::Pjrt { .. } => Ok(false),
            Backend::Native(nb) => ServeBackend::set_operating_point(nb.as_mut(), point),
        }
    }
}

impl QosBackend for Backend {
    fn configure(&mut self, params: &Bundle, tile: usize, quant: Quant) -> Result<()> {
        match self {
            Backend::Pjrt { engine, qos } => qos.configure(engine, params),
            Backend::Native(nb) => nb.configure(params, tile, quant),
        }
    }

    fn run_asr(&mut self, feats: &[f32], pad: &[f32], batch: usize) -> Result<Vec<f32>> {
        match self {
            Backend::Pjrt { engine, qos } => qos.run_asr(engine, feats, pad, batch),
            Backend::Native(nb) => nb.run_asr(feats, pad, batch),
        }
    }

    fn run_mt(&mut self, src: &[i32], batch: usize) -> Result<Vec<f32>> {
        match self {
            Backend::Pjrt { engine, qos } => qos.run_mt(engine, src, batch),
            Backend::Native(nb) => nb.run_mt(src, batch),
        }
    }

    fn translate(&mut self, src: &[i32], src_len: &[usize], batch: usize) -> Result<Vec<Vec<i32>>> {
        match self {
            // The PJRT encoder artifacts have no autoregressive decoder.
            Backend::Pjrt { .. } => {
                anyhow::bail!("PJRT backend has no autoregressive MT decoder")
            }
            Backend::Native(nb) => QosBackend::translate(&mut **nb, src, src_len, batch),
        }
    }
}

/// When the batcher hands queued requests to the executor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushPolicy {
    /// Wait (up to `max_wait` from the first queued request's arrival)
    /// for a full `max_batch`, then flush — the fixed-shape artifact
    /// contract; partial flushes are padded with zeroed slack rows on
    /// fixed-shape backends.
    Fixed,
    /// Work-conserving: flush whatever is queued as soon as the
    /// executor is free (up to `max_batch`); any-batch backends execute
    /// exactly the queued rows. `max_wait` is unused — batches grow
    /// naturally while the previous flush executes.
    Dynamic,
}

/// Serving-loop configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Largest batch one flush executes. Under [`FlushPolicy::Fixed`]
    /// this must equal the artifact's compiled batch.
    pub max_batch: usize,
    /// The batching window of [`FlushPolicy::Fixed`], measured from the
    /// first queued request's arrival.
    pub max_wait: Duration,
    pub flush: FlushPolicy,
    /// Worker threads an any-batch backend shards each flush across.
    pub threads: usize,
}

impl ServeConfig {
    /// The fixed-batch policy at the artifact batch, single-threaded.
    pub fn fixed(batch: usize, max_wait: Duration) -> ServeConfig {
        ServeConfig {
            max_batch: batch,
            max_wait,
            flush: FlushPolicy::Fixed,
            threads: 1,
        }
    }

    /// The dynamic any-batch policy with a thread-sharded executor.
    /// There is no `max_wait` knob: the batching window does not apply —
    /// batches grow naturally while the previous flush executes.
    pub fn dynamic(max_batch: usize, threads: usize) -> ServeConfig {
        ServeConfig {
            max_batch,
            max_wait: Duration::ZERO,
            flush: FlushPolicy::Dynamic,
            threads,
        }
    }
}

/// One inference request: an utterance.
pub struct Request {
    pub id: u64,
    pub feats: Vec<f32>,
    pub feat_len: usize,
    /// When the request entered the system ([`Request::new`] stamps it;
    /// construct right before sending). Latency is measured from here,
    /// so time spent queued in the channel while a flush executes —
    /// the very mechanism of dynamic batching — counts.
    pub arrived: Instant,
    /// Completion deadline, stamped at creation
    /// ([`Request::with_deadline`]). A request past its deadline is
    /// expired before execution — it never reaches the backend — and
    /// an on-time completion is what goodput counts. `None` = the
    /// request is infinitely patient.
    pub deadline: Option<Instant>,
}

impl Request {
    /// Build a request stamped with the current instant, no deadline.
    pub fn new(id: u64, feats: Vec<f32>, feat_len: usize) -> Request {
        Request { id, feats, feat_len, arrived: Instant::now(), deadline: None }
    }

    /// [`Request::new`] with a completion deadline `ttl` from now.
    /// `Duration::ZERO` is born expired — what the deterministic
    /// expiry tests use.
    pub fn with_deadline(id: u64, feats: Vec<f32>, feat_len: usize, ttl: Duration) -> Request {
        let now = Instant::now();
        Request { id, feats, feat_len, arrived: now, deadline: Some(now + ttl) }
    }

    /// Whether the deadline has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// How a request left the system. Every request gets exactly one
/// response, whatever its fate — the overload contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Outcome {
    /// Served: `tokens` holds the decoded hypothesis.
    Ok,
    /// Shed by the bounded admission queue (never executed).
    Shed,
    /// Deadline passed before execution (never reached the backend).
    Expired,
    /// Rejected at admission: the request violates the manifest
    /// contract (`feat_len` beyond the model sequence length, or a
    /// `feats` buffer whose length disagrees with the manifest shape).
    Invalid,
    /// Execution failed after retries (or the circuit breaker was
    /// open, or the request's rows were lost to a contained worker
    /// panic).
    Failed,
}

impl Outcome {
    /// Stable lowercase label — used by telemetry attributes and the
    /// report tables.
    pub fn name(self) -> &'static str {
        match self {
            Outcome::Ok => "ok",
            Outcome::Shed => "shed",
            Outcome::Expired => "expired",
            Outcome::Invalid => "invalid",
            Outcome::Failed => "failed",
        }
    }
}

/// One response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency: Duration,
    pub outcome: Outcome,
}

/// Nearest-rank latency percentiles of one outcome class.
#[derive(Clone, Debug)]
pub struct OutcomeLatency {
    pub outcome: Outcome,
    pub count: usize,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub p999: Duration,
}

/// Latency/throughput summary of a serving run.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Requests served successfully ([`Outcome::Ok`]).
    pub n_requests: usize,
    /// Flushes that reached the backend (including failed attempts;
    /// fail-fast breaker flushes never do and are not counted).
    pub n_batches: usize,
    /// Nearest-rank latency percentiles over the served requests.
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    /// Tail of the tail: the 99.9th percentile (nearest rank). With
    /// fewer than 1000 served requests this collapses toward the max —
    /// the honest nearest-rank answer, not an interpolation.
    pub p999: Duration,
    pub mean_batch_fill: f64,
    pub throughput_rps: f64,
    /// Zeroed padding rows executed on fixed-shape backends (slack
    /// work the any-batch path avoids entirely).
    pub slack_rows: usize,
    /// Requests shed by the bounded admission queue.
    pub shed: usize,
    /// Requests expired before execution.
    pub expired: usize,
    /// Requests rejected at admission as contract-invalid.
    pub invalid: usize,
    /// Requests whose execution failed after retries.
    pub failed: usize,
    /// Flush re-executions performed by the retry policy.
    pub retries: usize,
    /// Circuit-breaker trips.
    pub breaker_trips: usize,
    /// Degradation-ladder steps taken toward cheaper operating points.
    pub degrade_steps: usize,
    /// Hysteretic recovery steps back toward the nominal point.
    pub recover_steps: usize,
    /// Served responses that completed before their deadline
    /// (deadline-free requests count as on time).
    pub on_time: usize,
    /// On-time completions per second — the overload figure of merit.
    pub goodput_rps: f64,
    /// Per-outcome latency percentiles (only outcomes that occurred).
    pub outcomes: Vec<OutcomeLatency>,
    /// Chronological breaker/ladder state transitions: each records
    /// when (offset from run start), from which state, to which state,
    /// and what triggered the move. Recorded unconditionally (no
    /// telemetry session required) — the overload reports and the
    /// hysteresis tests read it.
    pub transitions: Vec<StateTransition>,
}

/// Nearest-rank percentile over an ascending-sorted sample list: the
/// smallest element with at least `p`% of the samples at or below it
/// (rank `ceil(p·n/100)`, 1-based). Empty input reports zero.
fn percentile(sorted: &[Duration], p: usize) -> Duration {
    permille(sorted, p * 10)
}

/// [`percentile`] at per-mille resolution — p99.9 is `permille(l, 999)`
/// (rank `ceil(pm·n/1000)`, 1-based). Empty input reports zero.
fn permille(sorted: &[Duration], pm: usize) -> Duration {
    if sorted.is_empty() {
        return Duration::default();
    }
    let rank = (pm * sorted.len()).div_ceil(1000).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// One admitted request plus its admission sequence number — the
/// deterministic tie-breaker for deadline-aware shedding.
struct Queued {
    req: Request,
    seq: u64,
    /// Telemetry span covering the request's time in the admission
    /// queue. Detached (non-LIFO: queue spans end in drain order, not
    /// reverse admission order); ends when the `Queued` drops — at
    /// flush take, shed, or expiry. Inert when no session is recording.
    #[allow(dead_code)]
    span: telemetry::Span,
}

/// Whether `a` should be shed before `b` under
/// [`ShedPolicy::DeadlineAware`]: earliest deadline first, admission
/// order on ties; deadline-free requests are infinitely patient.
fn sheds_before(a: &Queued, b: &Queued) -> bool {
    edf_before(a.req.deadline, a.seq, b.req.deadline, b.seq)
}

/// The deadline/admission-order comparison behind [`sheds_before`],
/// shared by the encoder queue ([`Queued`]) and the continuous-decode
/// queue ([`QueuedMt`]).
fn edf_before(ad: Option<Instant>, aseq: u64, bd: Option<Instant>, bseq: u64) -> bool {
    match (ad, bd) {
        (Some(x), Some(y)) => (x, aseq) < (y, bseq),
        (Some(_), None) => true,
        (None, Some(_)) => false,
        (None, None) => aseq < bseq,
    }
}

/// Response accounting shared by every exit path: each request gets
/// exactly one response, its latency filed under its outcome.
struct Tally {
    tx: mpsc::Sender<Response>,
    /// Latency samples indexed by [`Tally::slot`].
    lats: [Vec<Duration>; 5],
    on_time: usize,
}

impl Tally {
    fn new(tx: mpsc::Sender<Response>) -> Tally {
        Tally {
            tx,
            lats: [Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new()],
            on_time: 0,
        }
    }

    fn slot(outcome: Outcome) -> usize {
        match outcome {
            Outcome::Ok => 0,
            Outcome::Shed => 1,
            Outcome::Expired => 2,
            Outcome::Invalid => 3,
            Outcome::Failed => 4,
        }
    }

    /// Build + account + send a response for a request that never
    /// produced tokens (shed/expired/invalid/failed paths).
    fn finish(&mut self, req: &Request, outcome: Outcome) {
        let resp = Response {
            id: req.id,
            tokens: Vec::new(),
            latency: req.arrived.elapsed(),
            outcome,
        };
        self.record(req, resp);
    }

    /// Account + send an already-built response.
    fn record(&mut self, req: &Request, resp: Response) {
        self.respond(req.deadline, resp);
    }

    /// [`Tally::finish`] for the continuous-decode MT queue.
    fn finish_mt(&mut self, req: &MtRequest, outcome: Outcome) {
        let resp = Response {
            id: req.id,
            tokens: Vec::new(),
            latency: req.arrived.elapsed(),
            outcome,
        };
        self.respond(req.deadline, resp);
    }

    /// The outcome-agnostic core: account + send, with on-time goodput
    /// judged against the request's `deadline` at response time.
    fn respond(&mut self, deadline: Option<Instant>, resp: Response) {
        if resp.outcome == Outcome::Ok && !deadline.is_some_and(|d| Instant::now() >= d) {
            self.on_time += 1;
        }
        if telemetry::active() {
            match resp.outcome {
                Outcome::Ok => {
                    M_OK.get().inc();
                    M_OK_LATENCY.get().observe(resp.latency.as_micros() as u64);
                }
                Outcome::Shed => M_SHED.get().inc(),
                Outcome::Expired => M_EXPIRED.get().inc(),
                Outcome::Invalid => M_INVALID.get().inc(),
                Outcome::Failed => M_FAILED.get().inc(),
            }
            telemetry::instant(
                "request.respond",
                vec![
                    ("req_id", resp.id.into()),
                    ("outcome", resp.outcome.name().into()),
                ],
            );
        }
        self.lats[Self::slot(resp.outcome)].push(resp.latency);
        let _ = self.tx.send(resp);
    }

    #[allow(clippy::too_many_arguments)]
    fn report(
        mut self,
        fills: &[usize],
        slack_rows: usize,
        retries: usize,
        breaker_trips: usize,
        degrade_steps: usize,
        recover_steps: usize,
        transitions: Vec<StateTransition>,
        total_secs: f64,
    ) -> ServeReport {
        for l in &mut self.lats {
            l.sort_unstable();
        }
        const ORDER: [Outcome; 5] = [
            Outcome::Ok,
            Outcome::Shed,
            Outcome::Expired,
            Outcome::Invalid,
            Outcome::Failed,
        ];
        let outcomes: Vec<OutcomeLatency> = ORDER
            .iter()
            .zip(&self.lats)
            .filter(|(_, l)| !l.is_empty())
            .map(|(&outcome, l)| OutcomeLatency {
                outcome,
                count: l.len(),
                p50: percentile(l, 50),
                p95: percentile(l, 95),
                p99: percentile(l, 99),
                p999: permille(l, 999),
            })
            .collect();
        let ok = &self.lats[0];
        let total = total_secs.max(1e-9);
        ServeReport {
            n_requests: ok.len(),
            n_batches: fills.len(),
            p50: percentile(ok, 50),
            p95: percentile(ok, 95),
            p99: percentile(ok, 99),
            p999: permille(ok, 999),
            mean_batch_fill: fills.iter().sum::<usize>() as f64 / fills.len().max(1) as f64,
            throughput_rps: ok.len() as f64 / total,
            slack_rows,
            shed: self.lats[1].len(),
            expired: self.lats[2].len(),
            invalid: self.lats[3].len(),
            failed: self.lats[4].len(),
            retries,
            breaker_trips,
            degrade_steps,
            recover_steps,
            on_time: self.on_time,
            goodput_rps: self.on_time as f64 / total,
            outcomes,
            transitions,
        }
    }
}

/// Single-threaded synchronous server core: batching logic + execution.
/// (The `serve` example wraps it with a producer thread; keeping the core
/// synchronous makes it deterministic and unit-testable.)
pub struct Server {
    pub cfg: ServeConfig,
    /// Overload/fault behavior; `None` keeps the pre-resilience
    /// contract (unbounded queue, no retry, backend errors abort).
    resilience: Option<ResilienceConfig>,
    artifact: String,
    /// Prebuilt fixed-shape positional arguments (the artifact batch);
    /// only the `feats`/`pad_mask` slots are rewritten (in place) per
    /// batch. Used for fixed-shape backends.
    args: Vec<Tensor>,
    /// Reused `[rows, ...]` argument tensors of the any-batch path
    /// (`feats` + `pad_mask`, resized per flush, no steady-state
    /// allocation beyond growth to the largest flush seen).
    dyn_args: Vec<Tensor>,
    /// The batch the artifact/manifest was built for (== the padded
    /// batch of fixed-shape execution).
    model_batch: usize,
    feats_idx: usize,
    pad_idx: usize,
    seq_len: usize,
    feat_dim: usize,
    vocab: usize,
    blank: i32,
}

impl Server {
    /// Load the artifact once and build the static argument vector.
    pub fn new(
        engine: &mut Engine,
        artifact: &str,
        params: Bundle,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let manifest = engine.load(artifact)?.manifest.clone();
        Server::with_manifest(&manifest, artifact, params, cfg)
    }

    /// Engine-free constructor over an already-loaded manifest — what the
    /// stub-backed tests use, and what [`Server::new`] delegates to.
    pub fn with_manifest(
        manifest: &Manifest,
        artifact: &str,
        params: Bundle,
        cfg: ServeConfig,
    ) -> Result<Server> {
        // Shared manifest contract (data args zeroed, masks all-ones,
        // params by name) — same assembly the QoS backends use.
        let args = manifest.assemble_args(&params)?;
        let feats_idx = manifest
            .arg_index("feats")
            .context("artifact has no 'feats' argument")?;
        let pad_idx = manifest
            .arg_index("pad_mask")
            .context("artifact has no 'pad_mask' argument")?;
        let feat_dim = *manifest.args[feats_idx]
            .shape
            .last()
            .context("feats argument has no shape")?;
        let seq_len = manifest.model.seq_len;
        let model_batch = manifest.model.batch;
        ensure!(cfg.max_batch > 0, "max_batch must be positive");
        ensure!(cfg.threads > 0, "threads must be positive");
        ensure!(
            manifest.args[feats_idx].shape == [model_batch, seq_len, feat_dim],
            "feats shape {:?} != manifest batch {} x seq {} x feat {}",
            manifest.args[feats_idx].shape,
            model_batch,
            seq_len,
            feat_dim
        );
        ensure!(
            manifest.args[pad_idx].shape == [model_batch, seq_len],
            "pad_mask shape {:?} != manifest batch {} x seq {}",
            manifest.args[pad_idx].shape,
            model_batch,
            seq_len
        );
        // Under the fixed policy the flush size must be the batch the
        // artifact was compiled for — the reusable argument tensors are
        // sized from the manifest, so a mismatch caught here would
        // otherwise surface as an out-of-bounds slice in the serving
        // loop. The dynamic policy sizes its own argument tensors per
        // flush, so any `max_batch` is legal there.
        if cfg.flush == FlushPolicy::Fixed {
            ensure!(
                cfg.max_batch == model_batch,
                "fixed flush: configured batch {} != artifact batch {}",
                cfg.max_batch,
                model_batch
            );
        }
        Ok(Server {
            cfg,
            resilience: None,
            artifact: artifact.to_string(),
            args,
            dyn_args: vec![
                Tensor::zeros(&[0, seq_len, feat_dim], DType::F32),
                Tensor::zeros(&[0, seq_len], DType::F32),
            ],
            model_batch,
            feats_idx,
            pad_idx,
            seq_len,
            feat_dim,
            vocab: manifest.model.vocab,
            blank: manifest.model.ctc_blank as i32,
        })
    }

    /// Enable overload/fault resilience: bounded admission with load
    /// shedding, bounded retry, a circuit breaker, and (optionally) the
    /// graceful-degradation ladder.
    pub fn set_resilience(&mut self, res: ResilienceConfig) {
        self.resilience = Some(res);
    }

    /// Validate + admit one incoming request, shedding per policy when
    /// the bounded queue is full. Invalid requests (feat_len beyond the
    /// manifest sequence length, or a feats payload whose length
    /// disagrees with the manifest shape) get an error response here
    /// instead of panicking inside the batch kernels.
    fn admit(
        &self,
        req: Request,
        pending: &mut VecDeque<Queued>,
        seq: &mut u64,
        tally: &mut Tally,
    ) {
        if req.feat_len > self.seq_len || req.feats.len() != self.seq_len * self.feat_dim {
            tally.finish(&req, Outcome::Invalid);
            return;
        }
        // The queue span starts at validation and ends when the Queued
        // drops — shed decisions below end it immediately, which is the
        // honest queue residency of a shed request.
        let mut span = telemetry::Span::detached("request.queue", telemetry::current_span());
        if span.is_live() {
            M_ADMITTED.get().inc();
            span.attr("req_id", req.id);
        }
        let q = Queued { req, seq: *seq, span };
        *seq += 1;
        let Some(adm) = self.resilience.as_ref().map(|r| r.admission) else {
            pending.push_back(q);
            return;
        };
        if pending.len() < adm.capacity {
            pending.push_back(q);
            return;
        }
        match adm.policy {
            ShedPolicy::RejectNew => tally.finish(&q.req, Outcome::Shed),
            ShedPolicy::DropOldest => {
                if let Some(old) = pending.pop_front() {
                    tally.finish(&old.req, Outcome::Shed);
                    pending.push_back(q);
                } else {
                    // Capacity 0: nothing queued to drop — shed the
                    // incoming request itself.
                    tally.finish(&q.req, Outcome::Shed);
                }
            }
            ShedPolicy::DeadlineAware => {
                // Shed the candidate least likely to finish on time:
                // earliest deadline first, admission order on ties;
                // deadline-free requests are infinitely patient. The
                // incoming request is a candidate too.
                let mut victim = pending.len(); // == len() means the incoming one
                for i in 0..pending.len() {
                    let cur = if victim == pending.len() {
                        &q
                    } else {
                        &pending[victim] // lint:allow(serve-path-panic) -- victim < pending.len() on this branch
                    };
                    // lint:allow(serve-path-panic) -- i < pending.len() by the loop bound
                    if sheds_before(&pending[i], cur) {
                        victim = i;
                    }
                }
                if victim == pending.len() {
                    tally.finish(&q.req, Outcome::Shed);
                } else if let Some(old) = pending.remove(victim) {
                    tally.finish(&old.req, Outcome::Shed);
                    pending.push_back(q);
                } else {
                    // Unreachable (victim < len() here), but a panic in
                    // the admission path would kill the batcher — shed
                    // the incoming request instead.
                    tally.finish(&q.req, Outcome::Shed);
                }
            }
        }
    }

    /// Drain a request channel until it closes, serving batches.
    pub fn run(
        &mut self,
        backend: &mut impl ServeBackend,
        rx: mpsc::Receiver<Request>,
        tx: mpsc::Sender<Response>,
    ) -> Result<ServeReport> {
        backend.set_threads(self.cfg.threads);
        let res = self.resilience.clone();
        // Ladder state. Always restart at the nominal point so a reused
        // server (benches re-run the same pre-queued load) reproduces
        // the same trajectory, and so "ladder on, never pressured" is
        // bitwise-identical to "ladder off".
        let mut ladder_step = 0usize;
        let mut ladder_live = false;
        let mut high_streak = 0usize;
        let mut low_streak = 0usize;
        if let Some(l) = res.as_ref().and_then(|r| r.ladder.as_ref()) {
            ensure!(
                !l.points.is_empty(),
                "degradation ladder needs at least one operating point"
            );
            ensure!(
                l.low_watermark <= l.high_watermark,
                "ladder watermarks inverted: low {} > high {}",
                l.low_watermark,
                l.high_watermark
            );
            // A backend that cannot switch operating points (fixed
            // PJRT artifact, plain stub) leaves the ladder inert.
            ladder_live = backend.set_operating_point(&l.points[0])?;
        }
        let mut breaker = res.as_ref().map(|r| CircuitBreaker::new(r.breaker));
        // One flush never exceeds what the backend can execute: a
        // fixed-shape backend is capped at the artifact batch even when
        // a dynamic `max_batch` asks for more (the surplus simply rides
        // into the next flush).
        let cap = if backend.any_batch() {
            self.cfg.max_batch
        } else {
            self.cfg.max_batch.min(self.model_batch)
        };
        let mut tally = Tally::new(tx);
        let mut fills: Vec<usize> = Vec::new();
        let t0 = Instant::now();
        let mut pending: VecDeque<Queued> = VecDeque::new();
        let mut seq = 0u64;
        let mut slack_rows = 0usize;
        let mut retries = 0usize;
        let mut degrade_steps = 0usize;
        let mut recover_steps = 0usize;
        let mut transitions: Vec<StateTransition> = Vec::new();
        // Root span of the run: every coordinator-thread span below
        // parents under it via the thread-local stack; inert (one
        // relaxed load) when no telemetry session is recording.
        let run_span = telemetry::Span::begin("serve.run");
        let mut open = true;
        while open || !pending.is_empty() {
            // Idle: block until the first request arrives — no
            // `max_wait` wake-ups while the queue is empty. Shedding
            // still happens here: with capacity 0 the request admitted
            // from the blocking recv is itself shed and the loop blocks
            // again.
            if open && pending.is_empty() {
                match rx.recv() {
                    Ok(r) => self.admit(r, &mut pending, &mut seq, &mut tally),
                    Err(_) => {
                        open = false;
                        continue;
                    }
                }
            }
            // The batching window: everything between "work exists" and
            // "the flush is cut". Queue spans opened by `admit` inside
            // the window parent under it.
            let mut wspan = telemetry::Span::begin("serve.batch_window");
            match self.cfg.flush {
                FlushPolicy::Fixed => {
                    // The batching window runs from the first queued
                    // request's arrival, so a request that lands after
                    // an idle stretch still gets its full window.
                    if let Some(first) = pending.front() {
                        let deadline = first.req.arrived + self.cfg.max_wait;
                        while open && pending.len() < cap {
                            let timeout =
                                deadline.saturating_duration_since(Instant::now());
                            match rx.recv_timeout(timeout) {
                                Ok(r) => self.admit(r, &mut pending, &mut seq, &mut tally),
                                Err(mpsc::RecvTimeoutError::Timeout) => break,
                                Err(mpsc::RecvTimeoutError::Disconnected) => {
                                    open = false;
                                }
                            }
                        }
                    }
                }
                FlushPolicy::Dynamic => {
                    // Work-conserving: take everything already queued
                    // (batches grow while the previous flush executes).
                    // With bounded admission the queue bounds itself, so
                    // the channel is drained fully and overflow is shed
                    // *now* rather than left invisible in the channel;
                    // without it the legacy drain stops at one flush.
                    while open && (res.is_some() || pending.len() < cap) {
                        match rx.try_recv() {
                            Ok(r) => self.admit(r, &mut pending, &mut seq, &mut tally),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                open = false;
                            }
                        }
                    }
                }
            }
            if wspan.is_live() {
                wspan.attr("queued", pending.len());
            }
            drop(wspan);
            // Pre-execution expiry: a request past its deadline never
            // reaches the backend. (`retain` keeps this index-free —
            // dropping each removed Queued ends its queue span.)
            let now = Instant::now();
            pending.retain(|q| {
                if q.req.expired(now) {
                    tally.finish(&q.req, Outcome::Expired);
                    false
                } else {
                    true
                }
            });
            if pending.is_empty() {
                continue;
            }
            // Queue pressure for the ladder: backlog depth at flush
            // time, before this flush's requests are taken.
            let backlog = pending.len();
            if telemetry::active() {
                M_QUEUE_DEPTH.get().set(backlog as i64);
            }
            let take = backlog.min(cap);
            // Dropping each Queued here ends its queue span.
            let batch: Vec<Request> = pending.drain(..take).map(|q| q.req).collect();

            // Fail fast while the breaker is open: the flush never
            // reaches the backend (and is not counted as a batch).
            let failing_fast = match breaker.as_mut() {
                Some(b) if b.is_open() => {
                    b.fail_fast();
                    true
                }
                _ => false,
            };
            if failing_fast {
                if telemetry::active() {
                    telemetry::instant(
                        "resilience.fail_fast",
                        vec![("rows", batch.len().into())],
                    );
                }
                for req in &batch {
                    tally.finish(req, Outcome::Failed);
                }
                continue;
            }

            // Execute, with bounded retry + exponential backoff. The
            // flush span covers every attempt; each re-execution emits
            // a `resilience.retry` instant.
            let mut fspan = telemetry::Span::begin("serve.flush");
            if fspan.is_live() {
                fspan.attr("rows", batch.len());
            }
            let mut flush_result = self.run_batch(backend, &batch);
            if let Some(r) = res.as_ref() {
                let mut attempt = 0usize;
                while flush_result.is_err() && attempt < r.retry.max_retries {
                    let delay = r.retry.backoff * (1u32 << attempt.min(16));
                    if !delay.is_zero() {
                        std::thread::sleep(delay);
                    }
                    attempt += 1;
                    retries += 1;
                    if telemetry::active() {
                        M_RETRIES.get().inc();
                        telemetry::instant(
                            "resilience.retry",
                            vec![("attempt", attempt.into())],
                        );
                    }
                    flush_result = self.run_batch(backend, &batch);
                }
            }
            if fspan.is_live() {
                fspan.attr("ok", u64::from(flush_result.is_ok()));
                M_FLUSHES.get().inc();
                M_BATCH_FILL.get().observe(batch.len() as u64);
            }
            drop(fspan);
            fills.push(batch.len());
            match flush_result {
                Ok((responses, slack)) => {
                    slack_rows += slack;
                    if let Some(b) = breaker.as_mut() {
                        b.on_success();
                    }
                    for (req, resp) in batch.iter().zip(responses) {
                        tally.record(req, resp);
                    }
                }
                Err(e) => {
                    // Legacy contract: without a resilience config (and
                    // therefore without a breaker — they are constructed
                    // together above) a backend error aborts the run.
                    let (Some(r), Some(b)) = (res.as_ref(), breaker.as_mut()) else {
                        return Err(e);
                    };
                    let tripped = b.on_failure();
                    if tripped {
                        transitions.push(StateTransition {
                            at: t0.elapsed(),
                            from: "closed".to_string(),
                            to: "open".to_string(),
                            trigger: "consecutive-failures".to_string(),
                        });
                        if telemetry::active() {
                            M_BREAKER_TRIPS.get().inc();
                            telemetry::instant(
                                "resilience.breaker",
                                vec![("state", "open".into())],
                            );
                        }
                        // A ladder step down absorbs the trip — the
                        // cheaper operating point *is* the remedy, so
                        // the breaker closes immediately. With no step
                        // left it stays open for its fail-fast window.
                        if let Some(l) = r.ladder.as_ref() {
                            if ladder_live && ladder_step + 1 < l.points.len() {
                                let from = l.points[ladder_step].label();
                                ladder_step += 1;
                                ladder_live =
                                    backend.set_operating_point(&l.points[ladder_step])?;
                                degrade_steps += 1;
                                high_streak = 0;
                                let to = l.points[ladder_step].label();
                                if telemetry::active() {
                                    M_DEGRADE.get().inc();
                                    telemetry::instant(
                                        "resilience.ladder",
                                        vec![("step", "degrade".into()), ("point", to.as_str().into())],
                                    );
                                }
                                transitions.push(StateTransition {
                                    at: t0.elapsed(),
                                    from,
                                    to,
                                    trigger: "breaker-trip".to_string(),
                                });
                                b.close();
                                transitions.push(StateTransition {
                                    at: t0.elapsed(),
                                    from: "open".to_string(),
                                    to: "closed".to_string(),
                                    trigger: "ladder-absorb".to_string(),
                                });
                            }
                        }
                    }
                    for req in &batch {
                        tally.finish(req, Outcome::Failed);
                    }
                }
            }
            // Hysteretic pressure ladder: sustained backlog above the
            // high watermark steps down to a cheaper operating point;
            // sustained calm below the low watermark steps back up.
            if let Some(l) = res.as_ref().and_then(|r| r.ladder.as_ref()) {
                if ladder_live {
                    if backlog >= l.high_watermark {
                        high_streak += 1;
                        low_streak = 0;
                    } else if backlog <= l.low_watermark {
                        low_streak += 1;
                        high_streak = 0;
                    } else {
                        high_streak = 0;
                        low_streak = 0;
                    }
                    if high_streak >= l.patience && ladder_step + 1 < l.points.len() {
                        let from = l.points[ladder_step].label();
                        ladder_step += 1;
                        ladder_live = backend.set_operating_point(&l.points[ladder_step])?;
                        degrade_steps += 1;
                        high_streak = 0;
                        let to = l.points[ladder_step].label();
                        if telemetry::active() {
                            M_DEGRADE.get().inc();
                            telemetry::instant(
                                "resilience.ladder",
                                vec![("step", "degrade".into()), ("point", to.as_str().into())],
                            );
                        }
                        transitions.push(StateTransition {
                            at: t0.elapsed(),
                            from,
                            to,
                            trigger: "pressure".to_string(),
                        });
                    } else if low_streak >= l.recover_after && ladder_step > 0 {
                        let from = l.points[ladder_step].label();
                        ladder_step -= 1;
                        ladder_live = backend.set_operating_point(&l.points[ladder_step])?;
                        recover_steps += 1;
                        low_streak = 0;
                        let to = l.points[ladder_step].label();
                        if telemetry::active() {
                            M_RECOVER.get().inc();
                            telemetry::instant(
                                "resilience.ladder",
                                vec![("step", "recover".into()), ("point", to.as_str().into())],
                            );
                        }
                        transitions.push(StateTransition {
                            at: t0.elapsed(),
                            from,
                            to,
                            trigger: "recovery".to_string(),
                        });
                    }
                }
            }
        }
        drop(run_span);
        let total = t0.elapsed().as_secs_f64();
        let breaker_trips = breaker.map_or(0, |b| b.trips);
        Ok(tally.report(
            &fills,
            slack_rows,
            retries,
            breaker_trips,
            degrade_steps,
            recover_steps,
            transitions,
            total,
        ))
    }

    /// Execute one batch and return the responses plus the number of
    /// slack rows executed. On an any-batch backend exactly
    /// `batch.len()` rows run — no padding, no slack work, so backend
    /// statistics stay per-request-exact. On fixed-shape backends the
    /// tail is padded with zeroed rows (zero features **and** zero pad
    /// mask — never repeats of live requests, which would silently burn
    /// compute and pollute backend accounting) and the slack is counted
    /// explicitly. Steady state writes only the `feats`/`pad_mask`
    /// bytes — no loads, clones, or allocations of the parameter
    /// arguments.
    fn run_batch(
        &mut self,
        backend: &mut impl ServeBackend,
        batch: &[Request],
    ) -> Result<(Vec<Response>, usize)> {
        let n = batch.len();
        // A malformed flush is a server bug, but it must surface as a
        // backend error (retry/breaker path), never a batcher panic.
        ensure!(
            n > 0 && n <= self.cfg.max_batch,
            "flush of {n} rows outside 1..={}",
            self.cfg.max_batch
        );
        let (t, f) = (self.seq_len, self.feat_dim);
        for req in batch {
            // Guaranteed by admission validation (which turns a
            // violation into an `Invalid` response); a failure here
            // means the admission check regressed.
            debug_assert_eq!(
                req.feats.len(),
                t * f,
                "request {} feats length != seq_len x feat_dim",
                req.id
            );
        }

        // Covers argument assembly + backend execution (the gemm/shard
        // spans emitted inside the native backend parent under it).
        let mut espan = telemetry::Span::begin("serve.execute");
        if espan.is_live() {
            espan.attr("rows", n);
        }
        let (out, slack, failed_rows) = if backend.any_batch() {
            {
                let feats = &mut self.dyn_args[0];
                feats.shape = vec![n, t, f];
                feats.data.resize(n * t * f * 4, 0);
                write_feats_rows(feats, batch, t, f);
            }
            {
                let pad = &mut self.dyn_args[1];
                pad.shape = vec![n, t];
                pad.data.clear();
                pad.data.resize(n * t * 4, 0);
                write_pad_rows(pad, batch, t);
            }
            let (out, failed) =
                backend.execute_rows_partial(&self.artifact, &self.dyn_args, n)?;
            (out, 0, failed)
        } else {
            let b = self.model_batch;
            ensure!(
                n <= b,
                "flush of {n} exceeds the fixed artifact batch {b}"
            );
            {
                let feats = &mut self.args[self.feats_idx];
                debug_assert_eq!(feats.data.len(), b * t * f * 4);
                write_feats_rows(feats, batch, t, f);
                // Zero the slack rows: the tensor is reused across
                // batches, so stale frames must not leak into them.
                feats.data[n * t * f * 4..].fill(0);
            }
            {
                let pad = &mut self.args[self.pad_idx];
                // Slack rows keep an all-zero pad mask: executed by the
                // fixed-shape artifact but masked out of attention.
                pad.data.fill(0);
                write_pad_rows(pad, batch, t);
            }
            (backend.execute(&self.artifact, &self.args)?, b - n, Vec::new())
        };
        if espan.is_live() {
            espan.attr("slack_rows", slack);
        }
        drop(espan);

        let lp = out.f32s();
        let mut responses = Vec::with_capacity(n);
        for (i, req) in batch.iter().enumerate() {
            if failed_rows.contains(&i) {
                // Contained worker fault: this row's output is
                // zero-fill for alignment — never decode it.
                responses.push(Response {
                    id: req.id,
                    tokens: Vec::new(),
                    latency: req.arrived.elapsed(),
                    outcome: Outcome::Failed,
                });
                continue;
            }
            let mut dspan = telemetry::Span::begin("request.decode");
            if dspan.is_live() {
                dspan.attr("req_id", req.id);
            }
            let tokens = ctc_greedy(
                &lp[i * t * self.vocab..(i + 1) * t * self.vocab],
                req.feat_len.min(t),
                self.vocab,
                self.blank,
            );
            drop(dspan);
            responses.push(Response {
                id: req.id,
                tokens,
                latency: req.arrived.elapsed(),
                outcome: Outcome::Ok,
            });
        }
        Ok((responses, slack))
    }
}

/// Write each request's features into its row of `feats` (row `i` =
/// request `i`). Shared by the dynamic and fixed execution paths so the
/// row layout lives in one place.
fn write_feats_rows(feats: &mut Tensor, batch: &[Request], t: usize, f: usize) {
    for (i, req) in batch.iter().enumerate() {
        write_f32s(feats, i * t * f, &req.feats);
    }
}

/// Set the `1.0` validity prefix of each request's pad-mask row (the
/// buffer must already be zeroed — slack rows and pad tails stay 0).
fn write_pad_rows(pad: &mut Tensor, batch: &[Request], t: usize) {
    let one = 1.0f32.to_le_bytes();
    for (i, req) in batch.iter().enumerate() {
        for tt in 0..req.feat_len.min(t) {
            let at = (i * t + tt) * 4;
            pad.data[at..at + 4].copy_from_slice(&one);
        }
    }
}

/// Overwrite `count(vals)` f32 elements of `t` starting at element
/// `offset`, in place (no tensor reconstruction).
fn write_f32s(t: &mut Tensor, offset: usize, vals: &[f32]) {
    debug_assert_eq!(t.dtype, DType::F32);
    let start = offset * 4;
    let dst = &mut t.data[start..start + vals.len() * 4];
    for (chunk, v) in dst.chunks_exact_mut(4).zip(vals) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
}

/// One MT translation request: a token-id source utterance, padded to
/// the model sequence length. The decode-side twin of [`Request`] —
/// same arrival stamping, same optional completion deadline.
pub struct MtRequest {
    pub id: u64,
    /// Source token ids, exactly `seq_len` of them (the valid prefix is
    /// `src_len`; the tail is padding the encoder masks out).
    pub src: Vec<i32>,
    pub src_len: usize,
    /// When the request entered the system; latency is measured from
    /// here, so queue residency counts.
    pub arrived: Instant,
    /// Completion deadline; `None` = infinitely patient (see
    /// [`Request::deadline`]).
    pub deadline: Option<Instant>,
}

impl MtRequest {
    /// Build a request stamped with the current instant, no deadline.
    pub fn new(id: u64, src: Vec<i32>, src_len: usize) -> MtRequest {
        MtRequest { id, src, src_len, arrived: Instant::now(), deadline: None }
    }

    /// [`MtRequest::new`] with a completion deadline `ttl` from now.
    pub fn with_deadline(id: u64, src: Vec<i32>, src_len: usize, ttl: Duration) -> MtRequest {
        let now = Instant::now();
        MtRequest { id, src, src_len, arrived: now, deadline: Some(now + ttl) }
    }

    /// Whether the deadline has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// One admitted MT request plus its admission sequence number — the
/// [`Queued`] twin for the continuous-decode queue. The sequence number
/// doubles as the decode-slot id (unique even when caller ids collide).
struct QueuedMt {
    req: MtRequest,
    seq: u64,
    /// Queue-residency span; ends when the `QueuedMt` drops — at slot
    /// join, shed, or expiry.
    #[allow(dead_code)]
    span: telemetry::Span,
}

/// Latency/throughput summary of a continuous-decode serving run.
#[derive(Clone, Debug, Default)]
pub struct DecodeReport {
    /// Requests served successfully ([`Outcome::Ok`]).
    pub n_requests: usize,
    /// Lockstep panel steps executed (== `schedule.len()`).
    pub n_steps: usize,
    /// Nearest-rank latency percentiles over the served requests.
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub p999: Duration,
    /// Mean live slots per step — the panel-fill figure of merit: at
    /// 1.0 the continuous scheduler degenerates to sequential decode,
    /// at `max_slots` every step ran a full weight-stationary panel.
    pub mean_slot_fill: f64,
    /// Served requests per second of run wall time.
    pub throughput_rps: f64,
    /// Generated tokens per second of run wall time.
    pub tokens_per_sec: f64,
    /// Requests shed by the bounded admission queue.
    pub shed: usize,
    /// Requests expired before reaching a decode slot.
    pub expired: usize,
    /// Requests rejected at admission as contract-invalid.
    pub invalid: usize,
    /// Served responses that completed before their deadline.
    pub on_time: usize,
    /// On-time completions per second.
    pub goodput_rps: f64,
    /// Per-step live-slot counts, in step order — the exact input
    /// [`crate::sysim::engine::gemm_on_array_decode_batched`] needs to
    /// reproduce the run's decode charges analytically.
    pub schedule: Vec<usize>,
}

/// Continuous iteration-level batched decoding server — the
/// LLM-server-style scheduler over the native MT backend. Where
/// [`Server`] batches whole encoder forwards into flushes, this batches
/// individual *decode steps*: up to `max_slots` in-flight translations
/// advance one token per iteration in lockstep, their per-token GEMVs
/// packed into shared `[k, d]` weight-stationary panels
/// ([`crate::infer::ContinuousDecoder`]). A slot that emits EOS or hits
/// `max_len` retires *between* steps and is refilled from the admission
/// queue immediately — joins and leaves never disturb in-flight slots,
/// so every output is bitwise identical to a dedicated per-utterance
/// greedy decode.
///
/// Admission mirrors the encoder path: requests are validated (token
/// buffer shape, `src_len` bounds) and optionally bounded by an
/// [`AdmissionConfig`] with the PR-6 shed policies; queued requests
/// past their deadline are expired before they ever reach a slot.
/// The admission capacity bounds the *waiting* queue only — in-flight
/// slots are capacity the scheduler already granted.
pub struct DecodeServer {
    /// Maximum concurrently-decoding utterances (the panel width).
    max_slots: usize,
    /// Bounded admission; `None` = unbounded FIFO queue.
    admission: Option<AdmissionConfig>,
}

impl DecodeServer {
    pub fn new(max_slots: usize) -> DecodeServer {
        DecodeServer { max_slots, admission: None }
    }

    pub fn max_slots(&self) -> usize {
        self.max_slots
    }

    /// Bound the admission queue (capacity + shed policy).
    pub fn set_admission(&mut self, adm: AdmissionConfig) {
        self.admission = Some(adm);
    }

    /// Validate + admit one incoming request, shedding per policy when
    /// the bounded queue is full — the [`Server::admit`] logic over the
    /// MT request shape.
    fn admit(
        &self,
        req: MtRequest,
        seq_len: usize,
        pending: &mut VecDeque<QueuedMt>,
        seq: &mut u64,
        tally: &mut Tally,
    ) {
        if req.src.len() != seq_len || req.src_len == 0 || req.src_len > seq_len {
            tally.finish_mt(&req, Outcome::Invalid);
            return;
        }
        let mut span = telemetry::Span::detached("request.queue", telemetry::current_span());
        if span.is_live() {
            M_ADMITTED.get().inc();
            span.attr("req_id", req.id);
        }
        let q = QueuedMt { req, seq: *seq, span };
        *seq += 1;
        let Some(adm) = self.admission else {
            pending.push_back(q);
            return;
        };
        if pending.len() < adm.capacity {
            pending.push_back(q);
            return;
        }
        match adm.policy {
            ShedPolicy::RejectNew => tally.finish_mt(&q.req, Outcome::Shed),
            ShedPolicy::DropOldest => {
                if let Some(old) = pending.pop_front() {
                    tally.finish_mt(&old.req, Outcome::Shed);
                    pending.push_back(q);
                } else {
                    tally.finish_mt(&q.req, Outcome::Shed);
                }
            }
            ShedPolicy::DeadlineAware => {
                let mut victim = pending.len(); // == len() means the incoming one
                for i in 0..pending.len() {
                    let cur = if victim == pending.len() {
                        &q
                    } else {
                        &pending[victim] // lint:allow(serve-path-panic) -- victim < pending.len() on this branch
                    };
                    // lint:allow(serve-path-panic) -- i < pending.len() by the loop bound
                    if edf_before(pending[i].req.deadline, pending[i].seq, cur.req.deadline, cur.seq)
                    {
                        victim = i;
                    }
                }
                if victim == pending.len() {
                    tally.finish_mt(&q.req, Outcome::Shed);
                } else if let Some(old) = pending.remove(victim) {
                    tally.finish_mt(&old.req, Outcome::Shed);
                    pending.push_back(q);
                } else {
                    // Unreachable (victim < len() here), but never panic
                    // the decode loop over an admission bookkeeping slip.
                    tally.finish_mt(&q.req, Outcome::Shed);
                }
            }
        }
    }

    /// Drain an MT request channel until it closes, decoding up to
    /// `max_slots` utterances in lockstep. Each iteration: drain
    /// arrivals into the (optionally bounded) queue, expire stale
    /// requests, refill free slots from the queue front — the batched
    /// encode + cross-K/V precompute runs once per join wave,
    /// weight-stationary across the joiners — then advance every live
    /// slot one token. Retired slots respond immediately and their
    /// capacity is re-granted the very next iteration.
    pub fn run(
        &mut self,
        backend: &mut NativeBackend,
        rx: mpsc::Receiver<MtRequest>,
        tx: mpsc::Sender<Response>,
    ) -> Result<DecodeReport> {
        ensure!(self.max_slots > 0, "need at least one decode slot");
        ensure!(
            backend.dims().token_input,
            "continuous decode serving needs an MT (token-input) backend"
        );
        let seq_len = backend.dims().seq_len;
        let mut cd = ContinuousDecoder::new(self.max_slots);
        let mut tally = Tally::new(tx);
        let mut pending: VecDeque<QueuedMt> = VecDeque::new();
        // In-flight requests keyed by admission sequence number (the
        // slot id), so responses carry the caller's id and latency even
        // when caller ids collide.
        let mut inflight: HashMap<u64, MtRequest> = HashMap::new();
        let mut seq = 0u64;
        let (mut id_buf, mut src_buf, mut len_buf) = (Vec::new(), Vec::new(), Vec::new());
        let mut tokens_out = 0usize;
        let run_span = telemetry::Span::begin("serve.decode_run");
        let t0 = Instant::now();
        let mut open = true;
        while open || !pending.is_empty() || cd.live() > 0 {
            // Idle: block until the first request arrives. While slots
            // are live the loop never blocks — new arrivals are drained
            // opportunistically between steps.
            if open && pending.is_empty() && cd.live() == 0 {
                match rx.recv() {
                    Ok(r) => self.admit(r, seq_len, &mut pending, &mut seq, &mut tally),
                    Err(_) => {
                        open = false;
                        continue;
                    }
                }
            }
            while open {
                match rx.try_recv() {
                    Ok(r) => self.admit(r, seq_len, &mut pending, &mut seq, &mut tally),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => open = false,
                }
            }
            if telemetry::active() {
                M_QUEUE_DEPTH.get().set(pending.len() as i64);
            }
            // Refill free slots from the queue front, expiring stale
            // requests on the way — they never reach the backend.
            // Dropping each QueuedMt here ends its queue span.
            id_buf.clear();
            src_buf.clear();
            len_buf.clear();
            let now = Instant::now();
            while cd.live() + id_buf.len() < self.max_slots {
                let Some(q) = pending.pop_front() else { break };
                if q.req.expired(now) {
                    tally.finish_mt(&q.req, Outcome::Expired);
                    continue;
                }
                id_buf.push(q.seq);
                src_buf.extend_from_slice(&q.req.src);
                len_buf.push(q.req.src_len);
                inflight.insert(q.seq, q.req);
            }
            if !id_buf.is_empty() {
                backend.decode_join(&mut cd, &id_buf, &src_buf, &len_buf)?;
            }
            if cd.live() == 0 {
                continue;
            }
            for fin in backend.decode_step(&mut cd)? {
                // Every slot id is inserted at join time; a miss would
                // mean the decoder invented a slot. Drop the orphan
                // rather than panic the serving loop over it.
                let Some(req) = inflight.remove(&fin.id) else {
                    debug_assert!(false, "finished slot {} has no in-flight request", fin.id);
                    continue;
                };
                tokens_out += fin.tokens.len();
                let resp = Response {
                    id: req.id,
                    tokens: fin.tokens,
                    latency: req.arrived.elapsed(),
                    outcome: Outcome::Ok,
                };
                let deadline = req.deadline;
                tally.respond(deadline, resp);
            }
        }
        drop(run_span);
        let total = t0.elapsed().as_secs_f64().max(1e-9);
        let schedule = cd.step_batches().to_vec();
        let mut ok = std::mem::take(&mut tally.lats[0]);
        ok.sort_unstable();
        Ok(DecodeReport {
            n_requests: ok.len(),
            n_steps: schedule.len(),
            p50: percentile(&ok, 50),
            p95: percentile(&ok, 95),
            p99: percentile(&ok, 99),
            p999: permille(&ok, 999),
            mean_slot_fill: schedule.iter().sum::<usize>() as f64
                / schedule.len().max(1) as f64,
            throughput_rps: ok.len() as f64 / total,
            tokens_per_sec: tokens_out as f64 / total,
            shed: tally.lats[Tally::slot(Outcome::Shed)].len(),
            expired: tally.lats[Tally::slot(Outcome::Expired)].len(),
            invalid: tally.lats[Tally::slot(Outcome::Invalid)].len(),
            on_time: tally.on_time,
            goodput_rps: tally.on_time as f64 / total,
            schedule,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    const B: usize = 4;
    const T: usize = 6;
    const F: usize = 3;
    const VOCAB: usize = 8;
    const BLANK: i32 = 0;

    fn test_manifest() -> Manifest {
        Manifest::parse(&format!(
            r#"{{
              "name": "stub_encoder",
              "args": [
                {{"name": "feats", "shape": [{B}, {T}, {F}], "dtype": "float32"}},
                {{"name": "pad_mask", "shape": [{B}, {T}], "dtype": "float32"}},
                {{"name": "mask.ff0", "shape": [2, 2], "dtype": "int32"}},
                {{"name": "block0.ff.w1", "shape": [3], "dtype": "float32"}}
              ],
              "output": {{"shape": [{B}, {T}, {VOCAB}], "dtype": "float32"}},
              "model": {{"n_blocks": 1, "vocab": {VOCAB}, "ctc_blank": {BLANK},
                        "batch": {B}, "seq_len": {T}}}
            }}"#
        ))
        .unwrap()
    }

    fn test_params() -> Bundle {
        let mut b = Bundle::default();
        b.insert("block0.ff.w1", Tensor::from_f32(&[3], &[0.5, -1.0, 2.0]));
        b
    }

    fn test_server(max_wait: Duration) -> Server {
        Server::with_manifest(
            &test_manifest(),
            "stub_encoder",
            test_params(),
            ServeConfig::fixed(B, max_wait),
        )
        .unwrap()
    }

    fn dynamic_server(max_batch: usize, threads: usize) -> Server {
        Server::with_manifest(
            &test_manifest(),
            "stub_encoder",
            test_params(),
            ServeConfig::dynamic(max_batch, threads),
        )
        .unwrap()
    }

    /// A request whose first feature element encodes a token class, so
    /// the stub backend can answer with a decodable prediction.
    fn request(id: u64) -> Request {
        let mut feats = vec![0.0f32; T * F];
        feats[0] = (id % (VOCAB as u64 - 1) + 1) as f32;
        Request::new(id, feats, T)
    }

    fn expected_tokens(id: u64) -> Vec<i32> {
        vec![(id % (VOCAB as u64 - 1) + 1) as i32]
    }

    /// Stub execution backend: validates the argument contract and emits
    /// log-probs whose greedy CTC decode of row `i` is the class encoded
    /// in that row's first feature element (frame 0; all later frames
    /// blank). Records every argument vector for post-run inspection.
    struct StubBackend {
        calls: Vec<Vec<Tensor>>,
    }

    impl StubBackend {
        fn new() -> Self {
            StubBackend { calls: Vec::new() }
        }
    }

    impl ServeBackend for StubBackend {
        fn execute(&mut self, artifact: &str, args: &[Tensor]) -> Result<Tensor> {
            assert_eq!(artifact, "stub_encoder");
            test_manifest().validate_args(args)?;
            self.calls.push(args.to_vec());
            let feats = args[0].f32s();
            let mut lp = vec![0.0f32; B * T * VOCAB];
            for i in 0..B {
                let cls = feats[i * T * F] as usize % VOCAB;
                for tt in 0..T {
                    let base = (i * T + tt) * VOCAB;
                    let hot = if tt == 0 { cls } else { BLANK as usize };
                    lp[base + hot] = 5.0;
                }
            }
            Ok(Tensor::from_f32(&[B, T, VOCAB], &lp))
        }
    }

    /// Run the server over a sequence of requests sent immediately, then
    /// a closed channel.
    fn serve_all(
        server: &mut Server,
        backend: &mut StubBackend,
        ids: &[u64],
    ) -> (ServeReport, Vec<Response>) {
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for &id in ids {
            req_tx.send(request(id)).unwrap();
        }
        drop(req_tx);
        let report = server.run(backend, req_rx, resp_tx).unwrap();
        (report, resp_rx.try_iter().collect())
    }

    #[test]
    fn serve_config_fields() {
        let f = ServeConfig::fixed(16, Duration::from_millis(5));
        assert_eq!(f.max_batch, 16);
        assert_eq!(f.flush, FlushPolicy::Fixed);
        assert_eq!(f.threads, 1);
        let d = ServeConfig::dynamic(32, 4);
        assert_eq!(d.max_batch, 32);
        assert_eq!(d.flush, FlushPolicy::Dynamic);
        assert_eq!(d.threads, 4);
    }

    #[test]
    fn report_shape() {
        let r = ServeReport {
            n_requests: 10,
            n_batches: 2,
            p50: Duration::from_millis(3),
            p95: Duration::from_millis(9),
            p99: Duration::from_millis(11),
            mean_batch_fill: 5.0,
            throughput_rps: 100.0,
            slack_rows: 0,
            ..Default::default()
        };
        assert!(r.p95 >= r.p50);
        assert!(r.p99 >= r.p95);
        // The resilience counters default to a clean run.
        assert_eq!(
            (r.shed, r.expired, r.invalid, r.failed, r.retries),
            (0, 0, 0, 0, 0)
        );
        assert_eq!((r.breaker_trips, r.degrade_steps, r.recover_steps), (0, 0, 0));
        assert!(r.outcomes.is_empty());
        // p999 defaults to zero and the transition log starts empty.
        assert_eq!(r.p999, Duration::default());
        assert!(r.transitions.is_empty());
    }

    #[test]
    fn percentile_nearest_rank_edges() {
        let ms = Duration::from_millis;
        // n = 0: no samples, report zero.
        assert_eq!(percentile(&[], 50), Duration::default());
        assert_eq!(percentile(&[], 95), Duration::default());
        // n = 1: every percentile is the single sample.
        assert_eq!(percentile(&[ms(7)], 50), ms(7));
        assert_eq!(percentile(&[ms(7)], 95), ms(7));
        // n = 2: p50 is the first sample (rank ceil(0.5*2) = 1), p95
        // the second.
        assert_eq!(percentile(&[ms(1), ms(2)], 50), ms(1));
        assert_eq!(percentile(&[ms(1), ms(2)], 95), ms(2));
        // n = 20: p95 is the 19th sample (rank ceil(0.95*20) = 19) —
        // the seed's `n*95/100` indexed the 20th (the max).
        let twenty: Vec<Duration> = (1..=20).map(ms).collect();
        assert_eq!(percentile(&twenty, 50), ms(10));
        assert_eq!(percentile(&twenty, 95), ms(19));
        assert_eq!(percentile(&twenty, 100), ms(20));
        // p99.9 at per-mille resolution: below 1000 samples the nearest
        // rank is the max (rank ceil(999*20/1000) = 20); empty and
        // single-sample inputs behave like the percent variants.
        assert_eq!(permille(&[], 999), Duration::default());
        assert_eq!(permille(&[ms(7)], 999), ms(7));
        assert_eq!(permille(&twenty, 999), ms(20));
        // At n = 2000 the 99.9th leaves the max behind: rank
        // ceil(999*2000/1000) = 1998.
        let many: Vec<Duration> = (1..=2000).map(ms).collect();
        assert_eq!(permille(&many, 999), ms(1998));
        // The percent path delegates: percentile(p) == permille(10p).
        assert_eq!(percentile(&twenty, 95), permille(&twenty, 950));
    }

    #[test]
    fn batches_full_and_partial_with_correct_routing() {
        let mut server = test_server(Duration::from_millis(5));
        let mut backend = StubBackend::new();
        let ids: Vec<u64> = (1..=10).collect();
        let (report, responses) = serve_all(&mut server, &mut backend, &ids);
        // 10 requests at batch 4 -> 4 + 4 + 2.
        assert_eq!(report.n_requests, 10);
        assert_eq!(report.n_batches, 3);
        assert!((report.mean_batch_fill - 10.0 / 3.0).abs() < 1e-9);
        assert_eq!(report.slack_rows, 2, "the tail flush pads 2 of 4 rows");
        assert_eq!(responses.len(), 10);
        for r in &responses {
            assert_eq!(r.tokens, expected_tokens(r.id), "request {}", r.id);
        }
    }

    #[test]
    fn tail_batch_slack_rows_zeroed_and_accounted() {
        // Bugfix regression: the seed padded partial batches with
        // repeats of the last request — fully executed, silently
        // counted in backend statistics. Fixed-shape slack rows must
        // now carry zero features and a zero pad mask, and be reported.
        let mut server = test_server(Duration::from_millis(5));
        let mut backend = StubBackend::new();
        let (report, responses) = serve_all(&mut server, &mut backend, &[7, 8, 9]);
        assert_eq!(report.n_batches, 1);
        assert_eq!(report.slack_rows, 1);
        assert_eq!(responses.len(), 3, "padding rows must not produce responses");
        let feats = backend.calls[0][0].f32s();
        let pad = backend.calls[0][1].f32s();
        for pad_row in 3..B {
            assert!(
                feats[pad_row * T * F..(pad_row + 1) * T * F]
                    .iter()
                    .all(|v| *v == 0.0),
                "slack row {pad_row} features must be zero, not a repeat"
            );
            assert!(
                pad[pad_row * T..(pad_row + 1) * T].iter().all(|v| *v == 0.0),
                "slack row {pad_row} pad mask must be zero"
            );
        }
    }

    #[test]
    fn pad_mask_reflects_feat_len() {
        let mut server = test_server(Duration::from_millis(5));
        let mut backend = StubBackend::new();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let mut short = request(3);
        short.feat_len = 2;
        req_tx.send(short).unwrap();
        drop(req_tx);
        server.run(&mut backend, req_rx, resp_tx).unwrap();
        let _ = resp_rx.try_iter().count();
        let pad = backend.calls[0][1].f32s();
        assert_eq!(&pad[..T], &[1.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn static_args_built_once_and_stable_across_batches() {
        let mut server = test_server(Duration::from_millis(5));
        let mut backend = StubBackend::new();
        let ids: Vec<u64> = (0..8).collect();
        serve_all(&mut server, &mut backend, &ids);
        assert_eq!(backend.calls.len(), 2);
        for call in &backend.calls {
            // mask.* arguments are all-ones i32.
            assert!(call[2].i32s().iter().all(|v| *v == 1));
            // Parameter tensors pass through from the bundle, unchanged.
            assert_eq!(call[3].f32s(), vec![0.5, -1.0, 2.0]);
        }
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        // Two requests separated by much more than max_wait must land in
        // two deadline-flushed batches, not one.
        let mut server = test_server(Duration::from_millis(10));
        let mut backend = StubBackend::new();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let producer = thread::spawn(move || {
            req_tx.send(request(1)).unwrap();
            thread::sleep(Duration::from_millis(300));
            req_tx.send(request(2)).unwrap();
        });
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        producer.join().unwrap();
        assert_eq!(report.n_requests, 2);
        assert_eq!(report.n_batches, 2, "deadline must flush each alone");
        assert!((report.mean_batch_fill - 1.0).abs() < 1e-9);
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 2);
    }

    #[test]
    fn batch_mismatch_rejected_at_construction() {
        let err = Server::with_manifest(
            &test_manifest(),
            "stub_encoder",
            test_params(),
            ServeConfig::fixed(B + 1, Duration::from_millis(1)),
        )
        .err()
        .expect("construction must fail on batch/artifact mismatch");
        assert!(format!("{err:?}").contains("configured batch"));
        // The dynamic policy sizes its own arguments, so any max_batch
        // is legal there.
        assert!(Server::with_manifest(
            &test_manifest(),
            "stub_encoder",
            test_params(),
            ServeConfig::dynamic(B + 5, 2),
        )
        .is_ok());
    }

    #[test]
    fn backend_auto_selects_native_without_artifacts() {
        let dims = crate::infer::testutil::mini_dims();
        let mut backend =
            Backend::auto_with("definitely/_no_artifacts_here", "asr_encoder_ref", dims, 5, 2, 1)
                .unwrap();
        assert!(backend.is_native());
        assert_eq!(backend.label(), "native");
        assert!(backend.describe().contains("native engine"));
        assert!(backend.engine_mut().is_none());
        assert!(backend.native_mut().is_some());
        // The QoS surface works through the same object: teacher-labeled
        // test set, so the dense FP32 point reproduces WER 0.
        let eval = backend.asr_evaluator("unused", 3).unwrap();
        let p = eval
            .evaluate_with(&mut backend, dims.tile, 0.0, Quant::Fp32)
            .unwrap();
        assert_eq!(p.qos, 0.0, "dense FP32 must reproduce its own labels");
    }

    #[test]
    fn backend_auto_prefers_pjrt_when_artifact_exists() {
        // Selection is driven by the artifact file: auto must reach for
        // PJRT, never silently fall back to the native engine. With the
        // vendored xla stub that surfaces as a client-construction
        // error; with a real xla crate swapped in it is Ok(Pjrt) —
        // either way the selection decision is the same.
        let dir = std::env::temp_dir().join(format!(
            "sasp_backend_auto_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("asr_encoder_ref.hlo.txt"), "stub").unwrap();
        let dims = crate::infer::testutil::mini_dims();
        let got = Backend::auto_with(dir.to_str().unwrap(), "asr_encoder_ref", dims, 5, 2, 1);
        // A trailing-slash dir must probe the same artifact path
        // (Path::join, not string formatting).
        let slashed = format!("{}/", dir.to_str().unwrap());
        let got_slashed =
            Backend::auto_with(&slashed, "asr_encoder_ref", dims, 5, 2, 1);
        let _ = std::fs::remove_dir_all(&dir);
        // Err = stub build (PJRT attempted and unavailable) — also fine.
        if let Ok(backend) = got {
            assert!(
                !backend.is_native(),
                "artifact present: auto must not fall back to native"
            );
        }
        if let Ok(backend) = got_slashed {
            assert!(
                !backend.is_native(),
                "trailing-slash dir must still find the artifact"
            );
        }
    }

    #[test]
    fn backend_auto_falls_back_when_artifact_unreadable() {
        // The dir exists but the artifact cannot be opened (here: the
        // artifact path is a directory) — auto must fall back to the
        // native engine instead of deferring the failure to Engine::new.
        let dir = std::env::temp_dir().join(format!(
            "sasp_backend_auto_unreadable_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(dir.join("asr_encoder_ref.hlo.txt")).unwrap();
        let dims = crate::infer::testutil::mini_dims();
        let got = Backend::auto_with(dir.to_str().unwrap(), "asr_encoder_ref", dims, 5, 2, 1);
        let _ = std::fs::remove_dir_all(&dir);
        let backend = got.expect("unreadable artifact must not fail selection");
        assert!(backend.is_native(), "must fall back to the native engine");
    }

    #[test]
    fn native_backend_serves_end_to_end() {
        // The tentpole wiring: Backend::auto -> serve_parts -> Server
        // runs real batched native inference behind the request queue.
        let dims = crate::infer::testutil::mini_dims();
        let mut backend =
            Backend::auto_with("definitely/_no_artifacts_here", "asr_encoder_ref", dims, 5, 2, 1)
                .unwrap();
        let (manifest, params, artifact) = backend.serve_parts("unused").unwrap();
        assert_eq!(manifest.model.batch, 2);
        let mut server = Server::with_manifest(
            &manifest,
            &artifact,
            params,
            ServeConfig::fixed(2, Duration::from_millis(5)),
        )
        .unwrap();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let (t, f) = (dims.seq_len, dims.input_dim);
        for id in 0..3u64 {
            let feats = vec![0.25f32 * (id as f32 + 1.0); t * f];
            req_tx.send(Request::new(id, feats, t)).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.n_requests, 3);
        assert_eq!(report.n_batches, 2, "3 requests at batch 2 -> 2 + 1");
        assert_eq!(report.slack_rows, 0, "any-batch path executes no slack");
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 3);
        for r in &responses {
            assert!(r.tokens.iter().all(|s| *s >= 0 && (*s as usize) < dims.vocab));
        }
        // The batched engine executed exactly the queued rows — the
        // seed padded the tail flush with a repeat and counted it.
        let st = backend.native_mut().unwrap().stats();
        assert_eq!(st.utterances, 3);
    }

    #[test]
    fn tail_batch_native_stats_equal_standalone_batch_of_one() {
        // Bugfix regression (ISSUE 5): native stats for a served tail
        // batch of 1 must equal a standalone batch-of-1 run — the seed
        // executed the padding repeats, inflating TileTiming/throughput
        // /energy accounting.
        let dims = crate::infer::testutil::mini_dims();
        let mut backend =
            Backend::auto_with("definitely/_no_artifacts_here", "asr_encoder_ref", dims, 5, 2, 1)
                .unwrap();
        let (manifest, params, artifact) = backend.serve_parts("unused").unwrap();
        let mut server = Server::with_manifest(
            &manifest,
            &artifact,
            params,
            ServeConfig::fixed(2, Duration::from_millis(2)),
        )
        .unwrap();
        let (t, f) = (dims.seq_len, dims.input_dim);
        let feats: Vec<f32> = (0..t * f).map(|i| (i % 7) as f32 * 0.125).collect();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        req_tx
            .send(Request::new(0, feats.clone(), t))
            .unwrap();
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        let _ = resp_rx.try_iter().count();
        assert_eq!(report.n_requests, 1);
        assert_eq!(report.slack_rows, 0);
        let served = *backend.native_mut().unwrap().stats();

        let mut reference =
            Backend::auto_with("definitely/_no_artifacts_here", "asr_encoder_ref", dims, 5, 2, 1)
                .unwrap();
        let nb = reference.native_mut().unwrap();
        let pad = vec![1.0f32; t];
        let _ = nb.forward_batch(&feats, &pad, 1);
        assert_eq!(
            &served,
            nb.stats(),
            "a tail batch of 1 must cost exactly one utterance"
        );
        assert_eq!(served.utterances, 1);
    }

    /// Any-batch stub: executes exactly the rows it is handed and
    /// records each flush's row count.
    struct AnyBatchStub {
        rows_seen: Vec<usize>,
    }

    impl ServeBackend for AnyBatchStub {
        fn execute(&mut self, artifact: &str, args: &[Tensor]) -> Result<Tensor> {
            let rows = args[0].shape[0];
            self.execute_rows(artifact, args, rows)
        }

        fn any_batch(&self) -> bool {
            true
        }

        fn execute_rows(
            &mut self,
            artifact: &str,
            args: &[Tensor],
            rows: usize,
        ) -> Result<Tensor> {
            assert_eq!(artifact, "stub_encoder");
            assert_eq!(args.len(), 2);
            assert_eq!(args[0].shape, vec![rows, T, F], "feats sized to the flush");
            assert_eq!(args[1].shape, vec![rows, T], "pad mask sized to the flush");
            self.rows_seen.push(rows);
            let feats = args[0].f32s();
            let mut lp = vec![0.0f32; rows * T * VOCAB];
            for i in 0..rows {
                let cls = feats[i * T * F] as usize % VOCAB;
                for tt in 0..T {
                    let base = (i * T + tt) * VOCAB;
                    let hot = if tt == 0 { cls } else { BLANK as usize };
                    lp[base + hot] = 5.0;
                }
            }
            Ok(Tensor::from_f32(&[rows, T, VOCAB], &lp))
        }
    }

    #[test]
    fn dynamic_flush_executes_exact_queued_rows() {
        // The tentpole contract: on an any-batch backend the dynamic
        // policy flushes whatever is queued — one flush of 3, no
        // padding, no slack work.
        let mut server = dynamic_server(8, 2);
        let mut backend = AnyBatchStub { rows_seen: Vec::new() };
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for id in [7u64, 8, 9] {
            req_tx.send(request(id)).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.n_requests, 3);
        assert_eq!(report.n_batches, 1, "everything queued flushes at once");
        assert_eq!(report.slack_rows, 0);
        assert!((report.mean_batch_fill - 3.0).abs() < 1e-9);
        assert_eq!(backend.rows_seen, vec![3]);
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 3);
        for r in &responses {
            assert_eq!(r.tokens, expected_tokens(r.id), "request {}", r.id);
        }
    }

    #[test]
    fn dynamic_flush_respects_max_batch() {
        let mut server = dynamic_server(2, 1);
        let mut backend = AnyBatchStub { rows_seen: Vec::new() };
        let ids: Vec<u64> = (1..=5).collect();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for &id in &ids {
            req_tx.send(request(id)).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.n_requests, 5);
        assert_eq!(backend.rows_seen, vec![2, 2, 1], "capped at max_batch");
        assert_eq!(resp_rx.try_iter().count(), 5);
    }

    #[test]
    fn dynamic_on_fixed_backend_pads_with_slack_accounting() {
        // PJRT stays fixed-batch under the dynamic policy: the flush is
        // padded to the artifact batch with zeroed rows, accounted as
        // slack.
        let mut server = Server::with_manifest(
            &test_manifest(),
            "stub_encoder",
            test_params(),
            ServeConfig::dynamic(B, 1),
        )
        .unwrap();
        let mut backend = StubBackend::new();
        let (report, responses) = serve_all(&mut server, &mut backend, &[1, 2, 3]);
        assert_eq!(report.n_batches, 1);
        assert_eq!(report.slack_rows, 1, "3 of 4 artifact rows are live");
        assert_eq!(responses.len(), 3);
        let pad = backend.calls[0][1].f32s();
        assert!(pad[3 * T..].iter().all(|v| *v == 0.0), "slack pad mask zero");
    }

    #[test]
    fn dynamic_overcap_on_fixed_backend_clamps_to_artifact_batch() {
        // A dynamic max_batch beyond the artifact batch must not abort
        // the run on a fixed-shape backend — each flush is capped at
        // the artifact batch and the surplus rides into the next one.
        let mut server = Server::with_manifest(
            &test_manifest(),
            "stub_encoder",
            test_params(),
            ServeConfig::dynamic(B + 5, 1),
        )
        .unwrap();
        let mut backend = StubBackend::new();
        let ids: Vec<u64> = (1..=6).collect();
        let (report, responses) = serve_all(&mut server, &mut backend, &ids);
        assert_eq!(report.n_requests, 6);
        assert_eq!(report.n_batches, 2, "6 queued at artifact batch 4 -> 4 + 2");
        assert_eq!(report.slack_rows, 2);
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert_eq!(r.tokens, expected_tokens(r.id), "request {}", r.id);
        }
    }

    #[test]
    fn batching_window_measured_from_first_arrival() {
        // Bugfix regression (ISSUE 5): the seed computed the deadline
        // before any request existed, so an idle server woke every
        // `max_wait` and a request arriving late in the window was
        // flushed almost immediately. The window must start at the
        // first request's arrival: a second request 30ms later (well
        // inside the 80ms window, but after the idle stretch exceeded
        // it) still joins the same batch.
        let mut server = test_server(Duration::from_millis(80));
        let mut backend = StubBackend::new();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let producer = thread::spawn(move || {
            thread::sleep(Duration::from_millis(150)); // idle > max_wait
            req_tx.send(request(1)).unwrap();
            thread::sleep(Duration::from_millis(30)); // inside the window
            req_tx.send(request(2)).unwrap();
        });
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        producer.join().unwrap();
        assert_eq!(report.n_requests, 2);
        assert_eq!(
            report.n_batches, 1,
            "second arrival lands inside the first request's window"
        );
        assert_eq!(resp_rx.try_iter().count(), 2);
    }

    #[test]
    fn missing_param_rejected_at_construction() {
        let err = Server::with_manifest(
            &test_manifest(),
            "stub_encoder",
            Bundle::default(), // no block0.ff.w1
            ServeConfig::fixed(B, Duration::from_millis(1)),
        )
        .err()
        .expect("construction must fail without params");
        assert!(format!("{err:?}").contains("block0.ff.w1"));
    }

    // ---- overload & fault tolerance (ISSUE 6) ----

    use crate::coordinator::resilience::{
        BreakerConfig, FaultCounts, FaultInjector, FaultKind, FaultPlan, LadderConfig,
        RetryPolicy,
    };

    fn any_stub() -> AnyBatchStub {
        AnyBatchStub { rows_seen: Vec::new() }
    }

    #[test]
    fn invalid_requests_get_error_responses_not_panics() {
        // Satellite regression: a request whose feat_len exceeds the
        // manifest sequence length, or whose feats payload disagrees
        // with the manifest shape, must yield an `Invalid` response at
        // admission instead of panicking inside the batch kernels —
        // with or without a resilience config.
        let mut server = dynamic_server(8, 1);
        let mut backend = any_stub();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let mut long = request(1);
        long.feat_len = T + 1;
        req_tx.send(long).unwrap();
        req_tx
            .send(Request::new(2, vec![0.0; T * F - 1], T))
            .unwrap();
        req_tx.send(request(3)).unwrap();
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.invalid, 2);
        assert_eq!(report.n_requests, 1);
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 3, "every request gets exactly one response");
        for r in &responses {
            if r.id == 3 {
                assert_eq!(r.outcome, Outcome::Ok);
                assert_eq!(r.tokens, expected_tokens(3));
            } else {
                assert_eq!(r.outcome, Outcome::Invalid, "request {}", r.id);
                assert!(r.tokens.is_empty());
            }
        }
    }

    #[test]
    fn capacity_zero_sheds_everything_including_idle_recv() {
        let mut server = dynamic_server(4, 1);
        server.set_resilience(ResilienceConfig::bounded(0, ShedPolicy::RejectNew));
        let mut backend = any_stub();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for id in 0..5u64 {
            req_tx.send(request(id)).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        // The first request is admitted from the idle-blocked recv()
        // path, the rest from the channel drain — all shed, nothing
        // ever reaches the backend.
        assert_eq!(report.shed, 5);
        assert_eq!(report.n_requests, 0);
        assert_eq!(report.n_batches, 0);
        assert!(backend.rows_seen.is_empty());
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 5);
        assert!(responses.iter().all(|r| r.outcome == Outcome::Shed));

        // DropOldest at capacity 0 has nothing queued to drop: the
        // incoming request itself is shed, not a panic on pop_front.
        server.set_resilience(ResilienceConfig::bounded(0, ShedPolicy::DropOldest));
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        req_tx.send(request(9)).unwrap();
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.shed, 1);
        assert_eq!(resp_rx.try_iter().count(), 1);
    }

    #[test]
    fn capacity_one_reject_new_keeps_first_drop_oldest_keeps_last() {
        let ids: Vec<u64> = (1..=6).collect();
        let run_policy = |policy: ShedPolicy| {
            let mut server = dynamic_server(1, 1);
            server.set_resilience(ResilienceConfig::bounded(1, policy));
            let mut backend = any_stub();
            let (req_tx, req_rx) = mpsc::channel::<Request>();
            let (resp_tx, resp_rx) = mpsc::channel();
            for &id in &ids {
                req_tx.send(request(id)).unwrap();
            }
            drop(req_tx);
            let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
            let served: Vec<u64> = resp_rx
                .try_iter()
                .filter(|r| r.outcome == Outcome::Ok)
                .map(|r| r.id)
                .collect();
            (report, served)
        };
        let (report, served) = run_policy(ShedPolicy::RejectNew);
        assert_eq!((report.n_requests, report.shed), (1, 5));
        assert_eq!(served, vec![1], "the first admitted request keeps its slot");
        let (report, served) = run_policy(ShedPolicy::DropOldest);
        assert_eq!((report.n_requests, report.shed), (1, 5));
        assert_eq!(served, vec![6], "the freshest request survives");
    }

    #[test]
    fn deadline_aware_sheds_earliest_deadline_breaking_ties_by_admission() {
        let mut server = dynamic_server(1, 1);
        server.set_resilience(ResilienceConfig::bounded(1, ShedPolicy::DeadlineAware));
        let mut backend = any_stub();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let nearer = Instant::now() + Duration::from_secs(300);
        let far = Instant::now() + Duration::from_secs(600);
        // r1 and r2 share an identical deadline (a tie): admission
        // order decides, so r1 is shed first, then r2 loses to r3's
        // later deadline.
        let mut r1 = request(1);
        r1.deadline = Some(nearer);
        let mut r2 = request(2);
        r2.deadline = Some(nearer);
        let mut r3 = request(3);
        r3.deadline = Some(far);
        req_tx.send(r1).unwrap();
        req_tx.send(r2).unwrap();
        req_tx.send(r3).unwrap();
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!((report.n_requests, report.shed), (1, 2));
        let mut shed_ids: Vec<u64> = Vec::new();
        let mut ok_ids: Vec<u64> = Vec::new();
        for r in resp_rx.try_iter() {
            match r.outcome {
                Outcome::Shed => shed_ids.push(r.id),
                Outcome::Ok => ok_ids.push(r.id),
                other => panic!("unexpected outcome {other:?}"),
            }
        }
        assert_eq!(shed_ids, vec![1, 2], "tie broken by admission order");
        assert_eq!(ok_ids, vec![3]);

        // A deadline-free request is infinitely patient: the incoming
        // deadline-bearing request is the one shed.
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let patient = request(4); // no deadline
        let mut hurried = request(5);
        hurried.deadline = Some(Instant::now() + Duration::from_secs(300));
        req_tx.send(patient).unwrap();
        req_tx.send(hurried).unwrap();
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!((report.n_requests, report.shed), (1, 1));
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert!(responses
            .iter()
            .any(|r| r.id == 5 && r.outcome == Outcome::Shed));
        assert!(responses
            .iter()
            .any(|r| r.id == 4 && r.outcome == Outcome::Ok));
    }

    #[test]
    fn fully_expired_queue_executes_zero_rows() {
        // A flush whose every request is already past its deadline must
        // execute nothing — expiry runs before the backend is touched,
        // with or without a resilience config.
        let mut server = dynamic_server(8, 1);
        let mut backend = any_stub();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for id in 0..3u64 {
            let mut feats = vec![0.0f32; T * F];
            feats[0] = 1.0;
            req_tx
                .send(Request::with_deadline(id, feats, T, Duration::ZERO))
                .unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.expired, 3);
        assert_eq!(report.n_requests, 0);
        assert_eq!(report.n_batches, 0, "no batch reaches the backend");
        assert!(backend.rows_seen.is_empty());
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 3);
        assert!(responses
            .iter()
            .all(|r| r.outcome == Outcome::Expired && r.tokens.is_empty()));
        assert_eq!(report.on_time, 0);
        assert_eq!(report.goodput_rps, 0.0);
    }

    #[test]
    fn scripted_faults_exhaust_retries_trip_breaker_and_fail_fast() {
        let mut server = dynamic_server(1, 1);
        server.set_resilience(
            ResilienceConfig::bounded(16, ShedPolicy::RejectNew)
                .with_retry(RetryPolicy { max_retries: 1, backoff: Duration::ZERO })
                .with_breaker(BreakerConfig { trip_after: 2, open_flushes: 1 }),
        );
        // Flush 1: fault + fault on retry -> Failed (streak 1).
        // Flush 2: fault + fault -> Failed (streak 2 -> trip, open 1).
        // Flush 3: breaker open -> fail fast, backend untouched.
        // Flush 4: half-open probe succeeds (script exhausted).
        let script = FaultPlan::Script(vec![
            FaultKind::Transient,
            FaultKind::Transient,
            FaultKind::Transient,
            FaultKind::Transient,
        ]);
        let mut backend = FaultInjector::new(any_stub(), script);
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for id in 0..4u64 {
            req_tx.send(request(id)).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.failed, 3);
        assert_eq!(report.n_requests, 1);
        assert_eq!(report.retries, 2);
        assert_eq!(report.breaker_trips, 1);
        assert_eq!(
            report.n_batches, 3,
            "the fail-fast flush never reaches the backend"
        );
        assert_eq!(
            backend.counts(),
            FaultCounts { calls: 5, transient: 4, spikes: 0, hangs: 0 }
        );
        assert_eq!(backend.inner().rows_seen, vec![1], "only the final flush executed");
        let oks: Vec<u64> = resp_rx
            .try_iter()
            .filter(|r| r.outcome == Outcome::Ok)
            .map(|r| r.id)
            .collect();
        assert_eq!(oks, vec![3]);
    }

    /// Any-batch stub that accepts operating-point switches and records
    /// them — what the ladder sees on a switch-capable backend.
    struct LadderStub {
        inner: AnyBatchStub,
        points_set: Vec<OperatingPoint>,
    }

    impl ServeBackend for LadderStub {
        fn execute(&mut self, artifact: &str, args: &[Tensor]) -> Result<Tensor> {
            self.inner.execute(artifact, args)
        }

        fn any_batch(&self) -> bool {
            true
        }

        fn execute_rows(
            &mut self,
            artifact: &str,
            args: &[Tensor],
            rows: usize,
        ) -> Result<Tensor> {
            self.inner.execute_rows(artifact, args, rows)
        }

        fn set_operating_point(&mut self, point: &OperatingPoint) -> Result<bool> {
            self.points_set.push(*point);
            Ok(true)
        }
    }

    #[test]
    fn ladder_degrades_under_pressure_and_recovers_hysteretically() {
        let nominal = OperatingPoint::new(0.25, Quant::Int8);
        let degraded = OperatingPoint::new(0.75, Quant::Int8);
        let mut ladder = LadderConfig::new(vec![nominal, degraded]);
        ladder.high_watermark = 2;
        ladder.low_watermark = 1;
        ladder.patience = 2;
        ladder.recover_after = 1;
        let mut server = dynamic_server(1, 1);
        server.set_resilience(
            ResilienceConfig::bounded(16, ShedPolicy::RejectNew).with_ladder(ladder),
        );
        let mut backend = LadderStub { inner: any_stub(), points_set: Vec::new() };
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for id in 0..8u64 {
            req_tx.send(request(id)).unwrap();
        }
        drop(req_tx);
        // Backlogs at flush time run 8,7,6,...,1: pressure >= 2 for the
        // first seven flushes (step down on the second — patience 2),
        // the last sees backlog 1 <= low watermark and steps back up
        // (recover_after 1).
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.n_requests, 8);
        assert_eq!(report.degrade_steps, 1);
        assert_eq!(report.recover_steps, 1);
        assert_eq!(backend.points_set, vec![nominal, degraded, nominal]);
        assert_eq!(resp_rx.try_iter().count(), 8);
        // The transition log tells the same story, in order: one
        // pressure degrade, one hysteretic recovery, timestamps
        // non-decreasing.
        let t: Vec<(&str, &str, &str)> = report
            .transitions
            .iter()
            .map(|s| (s.from.as_str(), s.to.as_str(), s.trigger.as_str()))
            .collect();
        assert_eq!(
            t,
            vec![
                ("rate=0.25 int8", "rate=0.75 int8", "pressure"),
                ("rate=0.75 int8", "rate=0.25 int8", "recovery"),
            ]
        );
        assert!(report
            .transitions
            .windows(2)
            .all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn breaker_trip_steps_down_ladder_and_keeps_serving() {
        let nominal = OperatingPoint::new(0.25, Quant::Int8);
        let degraded = OperatingPoint::new(0.75, Quant::Int8);
        let mut ladder = LadderConfig::new(vec![nominal, degraded]);
        ladder.high_watermark = 100; // pressure never degrades here
        ladder.low_watermark = 0;
        ladder.recover_after = 100;
        let mut server = dynamic_server(1, 1);
        server.set_resilience(
            ResilienceConfig::bounded(16, ShedPolicy::RejectNew)
                .with_retry(RetryPolicy { max_retries: 0, backoff: Duration::ZERO })
                .with_breaker(BreakerConfig { trip_after: 1, open_flushes: 4 })
                .with_ladder(ladder),
        );
        let script = FaultPlan::Script(vec![FaultKind::Transient]);
        let mut backend =
            FaultInjector::new(LadderStub { inner: any_stub(), points_set: Vec::new() }, script);
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for id in 0..3u64 {
            req_tx.send(request(id)).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        // Flush 1 faults and trips the one-strike breaker — absorbed by
        // a ladder step down, so flushes 2 and 3 execute immediately
        // instead of failing fast through a 4-flush open window.
        assert_eq!(report.failed, 1);
        assert_eq!(report.n_requests, 2);
        assert_eq!(report.breaker_trips, 1);
        assert_eq!(report.degrade_steps, 1);
        assert_eq!(backend.inner().points_set, vec![nominal, degraded]);
        assert_eq!(backend.inner().inner.rows_seen, vec![1, 1]);
        assert_eq!(resp_rx.try_iter().count(), 3);
        // Transition log: the trip opens the breaker, the ladder step
        // absorbs it, and the absorb closes the breaker — in that
        // order, chronologically.
        let t: Vec<(&str, &str, &str)> = report
            .transitions
            .iter()
            .map(|s| (s.from.as_str(), s.to.as_str(), s.trigger.as_str()))
            .collect();
        assert_eq!(
            t,
            vec![
                ("closed", "open", "consecutive-failures"),
                ("rate=0.25 int8", "rate=0.75 int8", "breaker-trip"),
                ("open", "closed", "ladder-absorb"),
            ]
        );
        assert!(report
            .transitions
            .windows(2)
            .all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn seeded_fault_injection_smoke_pinned_counts() {
        // The verify.sh smoke: one fixed seed, pinned outcome counts.
        // Seed 2024 at p_transient=0.4 yields the 15-draw fault pattern
        // F.FF.FFF.F...F. over 8 single-request flushes with 2 retries:
        // flush 3 exhausts its retries (three consecutive faults), every
        // other flush recovers.
        let mut server = dynamic_server(1, 1);
        server.set_resilience(
            ResilienceConfig::bounded(16, ShedPolicy::RejectNew)
                .with_retry(RetryPolicy { max_retries: 2, backoff: Duration::ZERO })
                .with_breaker(BreakerConfig { trip_after: 100, open_flushes: 1 }),
        );
        let plan = FaultPlan::Seeded {
            seed: 2024,
            p_transient: 0.4,
            p_spike: 0.0,
            p_hang: 0.0,
        };
        let mut backend = FaultInjector::new(any_stub(), plan);
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for id in 0..8u64 {
            req_tx.send(request(id)).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.n_requests, 7);
        assert_eq!(report.failed, 1);
        assert_eq!(report.retries, 7);
        assert_eq!(report.shed, 0);
        assert_eq!(report.expired, 0);
        assert_eq!(report.breaker_trips, 0);
        assert_eq!(report.degrade_steps, 0);
        assert_eq!(report.n_batches, 8);
        assert_eq!(report.on_time, 7, "no deadlines: every completion is on time");
        assert_eq!(
            backend.counts(),
            FaultCounts { calls: 15, transient: 8, spikes: 0, hangs: 0 }
        );
        // Per-outcome latency buckets cover exactly the outcomes seen.
        let ok = report
            .outcomes
            .iter()
            .find(|o| o.outcome == Outcome::Ok)
            .expect("ok bucket");
        assert_eq!(ok.count, 7);
        assert!(ok.p99 >= ok.p50);
        let failed = report
            .outcomes
            .iter()
            .find(|o| o.outcome == Outcome::Failed)
            .expect("failed bucket");
        assert_eq!(failed.count, 1);
        assert_eq!(report.outcomes.len(), 2);
        assert_eq!(resp_rx.try_iter().count(), 8);
    }

    #[test]
    fn degraded_steps_bitwise_match_standalone_operating_points() {
        // The ladder's core guarantee: serving at a degraded point is
        // bitwise identical to a standalone run prepared at that point —
        // re-staging always starts from the master weights, so the
        // ladder adds no new numerics, only scheduling.
        let dims = crate::infer::testutil::mini_dims();
        let points = [
            OperatingPoint::new(0.0, Quant::Fp32),
            OperatingPoint::new(0.5, Quant::Int8),
        ];
        crate::util::prop::check("serve: degraded step bitwise identity", 3, |rng| {
            let mut backend = Backend::auto_with(
                "definitely/_no_artifacts_here",
                "asr_encoder_ref",
                dims,
                5,
                2,
                1,
            )
            .unwrap();
            let (manifest, params, artifact) = backend.serve_parts("unused").unwrap();
            let (t, f) = (dims.seq_len, dims.input_dim);
            let vocab = dims.vocab;
            let blank = manifest.model.ctc_blank as i32;
            let feats: Vec<Vec<f32>> = (0..4)
                .map(|_| (0..t * f).map(|_| rng.f32() - 0.5).collect())
                .collect();
            let mut ladder = LadderConfig::new(points.to_vec());
            ladder.high_watermark = 1; // degrade after the first flush
            ladder.low_watermark = 0;
            ladder.patience = 1;
            ladder.recover_after = 100;
            let mut server = Server::with_manifest(
                &manifest,
                &artifact,
                params,
                ServeConfig::dynamic(1, 1),
            )
            .unwrap();
            server.set_resilience(
                ResilienceConfig::bounded(16, ShedPolicy::RejectNew).with_ladder(ladder),
            );
            let (req_tx, req_rx) = mpsc::channel::<Request>();
            let (resp_tx, resp_rx) = mpsc::channel();
            for (id, fts) in feats.iter().enumerate() {
                req_tx.send(Request::new(id as u64, fts.clone(), t)).unwrap();
            }
            drop(req_tx);
            let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
            if report.degrade_steps != 1 {
                return (
                    false,
                    format!("expected 1 degrade step, got {}", report.degrade_steps),
                );
            }
            let mut responses: Vec<Response> = resp_rx.try_iter().collect();
            responses.sort_by_key(|r| r.id);
            // Flush 1 ran at points[0]; flushes 2..4 at points[1]
            // (backlog 4 >= high watermark 1 with patience 1).
            for (i, resp) in responses.iter().enumerate() {
                let point = if i == 0 { points[0] } else { points[1] };
                let mut standalone = Backend::auto_with(
                    "definitely/_no_artifacts_here",
                    "asr_encoder_ref",
                    dims,
                    5,
                    2,
                    1,
                )
                .unwrap();
                let nb = standalone.native_mut().unwrap();
                nb.prepare(dims.tile, point.rate, point.quant).unwrap();
                let pad = vec![1.0f32; t];
                let lp = nb.forward_batch(&feats[i], &pad, 1);
                let want = ctc_greedy(&lp[..t * vocab], t, vocab, blank);
                if resp.tokens != want {
                    return (
                        false,
                        format!(
                            "request {i} tokens {:?} != standalone {:?} at {point:?}",
                            resp.tokens, want
                        ),
                    );
                }
            }
            (true, String::new())
        });
    }

    #[test]
    fn batcher_survives_worker_panic() {
        // Satellite regression: a panic inside one sharded
        // forward_batch worker used to propagate through
        // std::thread::scope and kill the whole server. It must now
        // fail only that shard's requests and keep serving.
        let dims = crate::infer::testutil::mini_dims();
        let mut backend = Backend::auto_with(
            "definitely/_no_artifacts_here",
            "asr_encoder_ref",
            dims,
            5,
            4,
            2,
        )
        .unwrap();
        const MARKER: f32 = 1234.5;
        backend.native_mut().unwrap().set_panic_marker(Some(MARKER));
        let (manifest, params, artifact) = backend.serve_parts("unused").unwrap();
        let mut server =
            Server::with_manifest(&manifest, &artifact, params, ServeConfig::dynamic(2, 2))
                .unwrap();
        let (t, f) = (dims.seq_len, dims.input_dim);
        let clean = |id: u64| {
            let feats: Vec<f32> = (0..t * f)
                .map(|i| ((id as usize + i) % 5) as f32 * 0.1)
                .collect();
            Request::new(id, feats, t)
        };
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        // Flush 1 = {poison, clean 1} across two single-row shards;
        // flush 2 = {clean 2, clean 3} must serve normally afterwards.
        let mut poison = clean(0);
        poison.feats[0] = MARKER;
        req_tx.send(poison).unwrap();
        for id in 1..4u64 {
            req_tx.send(clean(id)).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.failed, 1, "only the poisoned request fails");
        assert_eq!(report.n_requests, 3);
        assert_eq!(report.n_batches, 2);
        let mut responses: Vec<Response> = resp_rx.try_iter().collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses[0].outcome, Outcome::Failed);
        assert!(responses[0].tokens.is_empty());
        // The surviving shard's output is bitwise-clean: identical to a
        // standalone single-threaded run of the same utterance.
        let mut reference = Backend::auto_with(
            "definitely/_no_artifacts_here",
            "asr_encoder_ref",
            dims,
            5,
            4,
            1,
        )
        .unwrap();
        let nb = reference.native_mut().unwrap();
        let pad = vec![1.0f32; t];
        for id in 1..4u64 {
            let lp = nb.forward_batch(&clean(id).feats, &pad, 1);
            let want = ctc_greedy(
                &lp[..t * dims.vocab],
                t,
                dims.vocab,
                manifest.model.ctc_blank as i32,
            );
            assert_eq!(responses[id as usize].outcome, Outcome::Ok);
            assert_eq!(responses[id as usize].tokens, want, "request {id}");
        }
    }

    // ---- serve-path panic-freedom regressions ------------------------
    //
    // One test per panic site converted to an error path in the static-
    // analysis pass (`serve-path-panic` rule): each drives the exact
    // code path that used to `assert!`/`unwrap` and checks the failure
    // now surfaces as a `Response` outcome or an `Err`, never a panic.

    #[test]
    fn panicfree_run_batch_surfaces_malformed_flush_as_error() {
        // The old `assert!` on flush size would kill the batcher; a
        // malformed flush must come back as a backend-style error so
        // the retry/breaker machinery can see it.
        let mut server = test_server(Duration::from_millis(1));
        let mut backend = StubBackend::new();
        let err = server.run_batch(&mut backend, &[]).unwrap_err();
        assert!(format!("{err:?}").contains("flush of 0 rows"), "{err:?}");
        let over: Vec<Request> = (0..B as u64 + 1).map(request).collect();
        let err = server.run_batch(&mut backend, &over).unwrap_err();
        assert!(format!("{err:?}").contains("outside 1..="), "{err:?}");
        assert!(backend.calls.is_empty(), "malformed flushes never execute");
        // The server stays fully serviceable after both rejections.
        let (report, responses) = serve_all(&mut server, &mut backend, &[1, 2, 3, 4]);
        assert_eq!(report.n_requests, 4);
        assert!(responses.iter().all(|r| r.outcome == Outcome::Ok));
    }

    #[test]
    fn panicfree_mixed_expiry_sweep_answers_every_request() {
        // The pre-execution expiry sweep (retain-based, no index math)
        // on a *partially* expired queue: expired requests answer
        // `Expired`, live ones still execute, none are lost.
        let mut server = dynamic_server(8, 1);
        let mut backend = any_stub();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for id in [1u64, 2] {
            let mut feats = vec![0.0f32; T * F];
            feats[0] = (id % (VOCAB as u64 - 1) + 1) as f32;
            req_tx
                .send(Request::with_deadline(id, feats, T, Duration::ZERO))
                .unwrap();
        }
        for id in [3u64, 4] {
            let mut r = request(id);
            r.deadline = Some(Instant::now() + Duration::from_secs(600));
            req_tx.send(r).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.expired, 2);
        assert_eq!(report.n_requests, 2);
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 4, "every request gets exactly one response");
        for r in &responses {
            match r.id {
                1 | 2 => assert_eq!(r.outcome, Outcome::Expired, "request {}", r.id),
                _ => {
                    assert_eq!(r.outcome, Outcome::Ok, "request {}", r.id);
                    assert_eq!(r.tokens, expected_tokens(r.id));
                }
            }
        }
    }

    #[test]
    fn panicfree_open_breaker_fail_fast_answers_failed() {
        // The fail-fast branch of an open breaker answers the whole
        // flush `Failed` without touching the backend — exercised
        // through the restructured error arm rather than an unwrap on
        // the breaker state.
        let mut server = dynamic_server(1, 1);
        server.set_resilience(
            ResilienceConfig::bounded(16, ShedPolicy::RejectNew)
                .with_retry(RetryPolicy { max_retries: 0, backoff: Duration::ZERO })
                .with_breaker(BreakerConfig { trip_after: 1, open_flushes: 1 }),
        );
        // Flush 1: fault, no retries -> Failed (streak 1 -> trip).
        // Flush 2: breaker open -> fail fast, backend untouched.
        // Flush 3: half-open probe succeeds (script exhausted).
        let script = FaultPlan::Script(vec![FaultKind::Transient]);
        let mut backend = FaultInjector::new(any_stub(), script);
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for id in 0..3u64 {
            req_tx.send(request(id)).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.failed, 2);
        assert_eq!(report.n_requests, 1);
        assert_eq!(report.breaker_trips, 1);
        assert_eq!(report.n_batches, 2, "the fail-fast flush never reaches the backend");
        assert_eq!(backend.inner().rows_seen, vec![1], "only the probe executed");
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 3, "every request gets exactly one response");
        let oks: Vec<u64> = responses
            .iter()
            .filter(|r| r.outcome == Outcome::Ok)
            .map(|r| r.id)
            .collect();
        assert_eq!(oks, vec![2]);
    }

    #[test]
    fn panicfree_backend_error_without_resilience_aborts_the_run() {
        // Legacy contract: with no resilience config a backend error
        // aborts the run as `Err` — it must not panic, and it must not
        // silently drop the batch either.
        let mut server = dynamic_server(1, 1);
        let script = FaultPlan::Script(vec![FaultKind::Transient]);
        let mut backend = FaultInjector::new(any_stub(), script);
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, _resp_rx) = mpsc::channel();
        req_tx.send(request(1)).unwrap();
        drop(req_tx);
        let err = server
            .run(&mut backend, req_rx, resp_tx)
            .expect_err("a backend fault without resilience aborts the run");
        assert!(format!("{err:?}").contains("transient"), "{err:?}");
        assert!(backend.inner().rows_seen.is_empty(), "the faulted flush never executed");
    }

    #[test]
    fn panicfree_deadline_aware_victim_search_answers_every_request() {
        // The index-free victim selection in `admit` under sustained
        // DeadlineAware pressure: a capacity-1 queue over requests with
        // mixed (and missing) deadlines answers each exactly once,
        // partitioned into Ok and Shed.
        let mut server = dynamic_server(1, 1);
        server.set_resilience(ResilienceConfig::bounded(1, ShedPolicy::DeadlineAware));
        let mut backend = any_stub();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let now = Instant::now();
        for (id, ttl) in [(1u64, Some(30u64)), (2, Some(600)), (3, None), (4, Some(90))] {
            let mut r = request(id);
            r.deadline = ttl.map(|s| now + Duration::from_secs(s));
            req_tx.send(r).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 4, "every request gets exactly one response");
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2, 3, 4], "no duplicate or dropped responses");
        assert_eq!(report.n_requests + report.shed, 4);
        assert!(report.shed >= 1, "capacity 1 under 4 queued requests must shed");
        assert!(responses
            .iter()
            .all(|r| matches!(r.outcome, Outcome::Ok | Outcome::Shed)));
    }

    // ---- continuous-decode (MT) serving ------------------------------

    /// A pruned+quantized native MT backend over the deterministic
    /// synthetic mini model — same fixture the infer tests use.
    fn mt_backend() -> NativeBackend {
        use crate::infer::decoder::testutil::mini_dec_dims;
        use crate::infer::synth::synth_decoder_weights;
        use crate::infer::testutil::mini_dims;
        let dims = ModelDims {
            token_input: true,
            ctc_blank: -1,
            ..mini_dims()
        };
        let enc = synth_weights(&dims, 43);
        let dec = synth_decoder_weights(&mini_dec_dims(), 43);
        let mut be = NativeBackend::new_mt(enc, dec, 4).unwrap();
        be.prepare(8, 0.3, Quant::Int8).unwrap();
        be
    }

    /// A deterministic ragged MT batch: `n` utterances of `seq_len`
    /// tokens each, valid prefixes between half and full length.
    fn mt_sources(be: &NativeBackend, n: usize, seed: u64) -> (Vec<i32>, Vec<usize>) {
        let dims = *be.dims();
        let mut rng = crate::util::rng::Rng::new(seed);
        let t = dims.seq_len;
        let mut src = vec![0i32; n * t];
        let mut lens = Vec::with_capacity(n);
        for u in 0..n {
            let len = t / 2 + rng.index(t / 2);
            for tok in src[u * t..u * t + len].iter_mut() {
                *tok = rng.index(dims.vocab) as i32;
            }
            lens.push(len);
        }
        (src, lens)
    }

    fn mt_request(src: &[i32], lens: &[usize], t: usize, u: usize) -> MtRequest {
        MtRequest::new(u as u64, src[u * t..(u + 1) * t].to_vec(), lens[u])
    }

    #[test]
    fn decode_server_matches_sequential_translate_and_reports_panel_fill() {
        // The serving-loop face of the tentpole contract: continuous
        // iteration-level scheduling through the bounded-admission
        // server produces exactly the per-utterance sequential
        // translations, and the report's schedule shows multi-slot
        // panels (the batching actually happened).
        let mut oracle = mt_backend();
        let (src, lens) = mt_sources(&oracle, 6, 11);
        let want = oracle.translate(&src, &lens).unwrap();
        let t = oracle.dims().seq_len;

        let mut be = mt_backend();
        let (req_tx, req_rx) = mpsc::channel::<MtRequest>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for u in 0..6 {
            req_tx.send(mt_request(&src, &lens, t, u)).unwrap();
        }
        drop(req_tx);
        let mut server = DecodeServer::new(3);
        let report = server.run(&mut be, req_rx, resp_tx).unwrap();

        let mut responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 6, "every request gets exactly one response");
        responses.sort_by_key(|r| r.id);
        for (u, resp) in responses.iter().enumerate() {
            assert_eq!(resp.outcome, Outcome::Ok);
            assert_eq!(resp.tokens, want[u], "utterance {u}");
        }
        assert_eq!(report.n_requests, 6);
        assert_eq!(report.shed + report.expired + report.invalid, 0);
        assert_eq!(report.n_steps, report.schedule.len());
        // All six requests were queued before the run started, so the
        // first step runs a full panel and the mean fill beats the
        // sequential degenerate case.
        assert_eq!(report.schedule[0], 3, "first step fills every slot");
        assert!(report.schedule.iter().all(|&k| (1..=3).contains(&k)));
        assert!(report.mean_slot_fill > 1.0, "panels actually batched");
        // The backend's recorded step count is the schedule's sum — the
        // analytic replay contract.
        assert_eq!(
            be.decode_stats().steps,
            report.schedule.iter().sum::<usize>()
        );
        assert_eq!(be.decode_stats().utterances, 6);
    }

    #[test]
    fn decode_server_bounded_admission_sheds_and_flags_invalid() {
        // Capacity-2 RejectNew queue, six valid requests pre-queued plus
        // one contract-invalid buffer: two serve, four shed, the bad one
        // is rejected at admission — every request still gets exactly
        // one response.
        let mut be = mt_backend();
        let (src, lens) = mt_sources(&be, 6, 13);
        let t = be.dims().seq_len;
        let (req_tx, req_rx) = mpsc::channel::<MtRequest>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for u in 0..6 {
            req_tx.send(mt_request(&src, &lens, t, u)).unwrap();
        }
        req_tx
            .send(MtRequest::new(99, vec![1i32; t - 1], 1))
            .unwrap();
        drop(req_tx);
        let mut server = DecodeServer::new(2);
        server.set_admission(AdmissionConfig {
            capacity: 2,
            policy: ShedPolicy::RejectNew,
        });
        let report = server.run(&mut be, req_rx, resp_tx).unwrap();
        assert_eq!(report.n_requests, 2);
        assert_eq!(report.shed, 4);
        assert_eq!(report.invalid, 1);
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 7);
        assert_eq!(
            responses.iter().filter(|r| r.outcome == Outcome::Shed).count(),
            4
        );
        let bad = responses.iter().find(|r| r.id == 99).unwrap();
        assert_eq!(bad.outcome, Outcome::Invalid);
        assert!(bad.tokens.is_empty());
    }

    #[test]
    fn decode_server_expires_stale_requests_before_they_reach_a_slot() {
        // A request born past its deadline is expired at refill time —
        // it never occupies a slot and never reaches the backend; the
        // patient requests around it decode normally and goodput counts
        // only on-time completions.
        let mut be = mt_backend();
        let (src, lens) = mt_sources(&be, 3, 17);
        let t = be.dims().seq_len;
        let (req_tx, req_rx) = mpsc::channel::<MtRequest>();
        let (resp_tx, resp_rx) = mpsc::channel();
        req_tx.send(mt_request(&src, &lens, t, 0)).unwrap();
        req_tx
            .send(MtRequest::with_deadline(
                1,
                src[t..2 * t].to_vec(),
                lens[1],
                Duration::ZERO,
            ))
            .unwrap();
        req_tx.send(mt_request(&src, &lens, t, 2)).unwrap();
        drop(req_tx);
        let mut server = DecodeServer::new(2);
        let report = server.run(&mut be, req_rx, resp_tx).unwrap();
        assert_eq!(report.n_requests, 2);
        assert_eq!(report.expired, 1);
        assert_eq!(report.on_time, 2, "deadline-free completions are on time");
        let mut responses: Vec<Response> = resp_rx.try_iter().collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses[1].outcome, Outcome::Expired);
        assert!(responses[1].tokens.is_empty());
        assert_eq!(responses[0].outcome, Outcome::Ok);
        assert_eq!(responses[2].outcome, Outcome::Ok);
        assert_eq!(be.decode_stats().utterances, 2, "expired never decoded");
    }

    #[test]
    fn decode_server_deadline_aware_sheds_the_tightest_deadline() {
        // Capacity-1 DeadlineAware queue: with a deadline-free request
        // queued, an incoming deadlined request is the candidate least
        // likely to finish and is shed — same EDF semantics as the
        // encoder queue's `sheds_before`.
        let mut be = mt_backend();
        let (src, lens) = mt_sources(&be, 2, 19);
        let t = be.dims().seq_len;
        let (req_tx, req_rx) = mpsc::channel::<MtRequest>();
        let (resp_tx, resp_rx) = mpsc::channel();
        req_tx.send(mt_request(&src, &lens, t, 0)).unwrap();
        req_tx
            .send(MtRequest::with_deadline(
                1,
                src[t..2 * t].to_vec(),
                lens[1],
                Duration::from_secs(3600),
            ))
            .unwrap();
        drop(req_tx);
        // One slot, so the loop admits both before the first refill:
        // request 0 blocks the single queue slot.
        let mut server = DecodeServer::new(1);
        server.set_admission(AdmissionConfig {
            capacity: 1,
            policy: ShedPolicy::DeadlineAware,
        });
        let report = server.run(&mut be, req_rx, resp_tx).unwrap();
        assert_eq!(report.n_requests, 1);
        assert_eq!(report.shed, 1);
        let mut responses: Vec<Response> = resp_rx.try_iter().collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses[0].outcome, Outcome::Ok);
        assert_eq!(responses[1].outcome, Outcome::Shed);
        // Sequential schedule: a single slot degenerates to per-
        // utterance decode, the report says so.
        assert!(report.schedule.iter().all(|&k| k == 1));
        assert!((report.mean_slot_fill - 1.0).abs() < 1e-12);
    }

    #[test]
    fn panicfree_decode_victim_search_answers_every_request() {
        // DecodeServer's index-free DeadlineAware victim selection
        // under pressure: capacity 1 over four MT requests with mixed
        // (and missing) deadlines answers each exactly once — the shed
        // path must never lose or duplicate a response.
        let mut be = mt_backend();
        let (src, lens) = mt_sources(&be, 4, 23);
        let t = be.dims().seq_len;
        let (req_tx, req_rx) = mpsc::channel::<MtRequest>();
        let (resp_tx, resp_rx) = mpsc::channel();
        req_tx.send(mt_request(&src, &lens, t, 0)).unwrap();
        for (u, ttl_s) in [(1usize, 30u64), (2, 3600), (3, 90)] {
            req_tx
                .send(MtRequest::with_deadline(
                    u as u64,
                    src[u * t..(u + 1) * t].to_vec(),
                    lens[u],
                    Duration::from_secs(ttl_s),
                ))
                .unwrap();
        }
        drop(req_tx);
        let mut server = DecodeServer::new(1);
        server.set_admission(AdmissionConfig {
            capacity: 1,
            policy: ShedPolicy::DeadlineAware,
        });
        let report = server.run(&mut be, req_rx, resp_tx).unwrap();
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 4, "every request gets exactly one response");
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3], "no duplicate or dropped responses");
        assert_eq!(report.n_requests + report.shed, 4);
        assert!(report.shed >= 1, "capacity 1 under 4 queued requests must shed");
        assert!(responses
            .iter()
            .all(|r| matches!(r.outcome, Outcome::Ok | Outcome::Shed)));
    }
}
