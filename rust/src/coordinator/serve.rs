//! Batched inference serving loop — the edge-deployment face of the
//! coordinator. Requests (utterances) arrive on a queue; a batcher thread
//! forms fixed-size batches (padding the tail with repeats, exactly like
//! the evaluator) under a deadline; the execution backend runs them; the
//! caller gets decoded hypotheses plus latency metrics.
//!
//! Implemented over std threads/channels (no tokio in the vendor set);
//! the PJRT client is kept on the worker thread, requests cross via mpsc.
//!
//! §Perf: everything static is hoisted into [`Server::new`] — the
//! artifact is loaded once, and the positional argument vector (weights,
//! masks, parameter tensors) is built once. The seed implementation
//! re-called `engine.load()`, cloned the manifest, and cloned **every
//! parameter tensor** on every batch; the steady-state loop now only
//! rewrites the `feats`/`pad_mask` bytes in place.

use std::path::Path;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::data::{load_bundle, Bundle, DType, Tensor};
use crate::infer::{synth_testset, synth_weights, ModelDims, NativeBackend};
use crate::qos::decode::ctc_greedy;
use crate::qos::{AsrEvaluator, EvalMeta, PjrtState, QosBackend};
use crate::runtime::{Engine, Manifest};
use crate::systolic::Quant;

/// The execution surface the server needs. Production uses the PJRT
/// [`Engine`] or the native engine ([`crate::infer::NativeBackend`],
/// which also publishes the [`Manifest`] it serves — the fully offline
/// path); tests drive the batching logic with a stub.
pub trait ServeBackend {
    fn execute(&mut self, artifact: &str, args: &[Tensor]) -> Result<Tensor>;
}

impl ServeBackend for Engine {
    fn execute(&mut self, artifact: &str, args: &[Tensor]) -> Result<Tensor> {
        Engine::execute(self, artifact, args)
    }
}

/// The auto-selected execution backend — **one** selection path shared
/// by `serve`, `asr_pipeline`, and the QoS harness
/// ([`crate::harness::QosCache`]): PJRT over compiled artifacts when
/// they exist, the batched native engine otherwise. Implements both
/// [`ServeBackend`] and [`QosBackend`], so callers configure/execute
/// without knowing which engine is underneath.
pub enum Backend {
    /// The PJRT engine plus the per-configuration QoS state of the
    /// artifact it serves.
    Pjrt { engine: Engine, qos: PjrtState },
    /// The batched weight-stationary native engine (no artifacts).
    Native(Box<NativeBackend>),
}

impl Backend {
    /// The ASR encoder artifact every serving surface defaults to.
    pub const ASR_ARTIFACT: &'static str = "asr_encoder_ref";

    /// Pick the backend for `dir`: PJRT when the compiled ASR artifact
    /// exists there, otherwise the batched native engine over the
    /// deterministic synthetic tiny-ASR model (the fully offline path).
    pub fn auto(dir: &str) -> Result<Backend> {
        Self::auto_with(dir, Self::ASR_ARTIFACT, ModelDims::tiny_asr(), 7, 4)
    }

    /// [`Self::auto`] with explicit artifact name and native fallback
    /// parameters (synthetic model dims/seed, serving batch).
    pub fn auto_with(
        dir: &str,
        artifact: &str,
        dims: ModelDims,
        seed: u64,
        batch: usize,
    ) -> Result<Backend> {
        if Path::new(&format!("{dir}/{artifact}.hlo.txt")).exists() {
            Ok(Backend::Pjrt {
                engine: Engine::new(dir)?,
                qos: PjrtState::new(artifact),
            })
        } else {
            let native = NativeBackend::new(synth_weights(&dims, seed), batch)?;
            Ok(Backend::Native(Box::new(native)))
        }
    }

    pub fn is_native(&self) -> bool {
        matches!(self, Backend::Native(_))
    }

    pub fn label(&self) -> &'static str {
        match self {
            Backend::Pjrt { .. } => "pjrt",
            Backend::Native(_) => "native",
        }
    }

    /// Human-readable backend description for example/CLI banners.
    pub fn describe(&self) -> String {
        match self {
            Backend::Pjrt { engine, .. } => format!("PJRT ({})", engine.platform()),
            Backend::Native(nb) => {
                let m = nb.model();
                let quant = match m.quant {
                    Quant::Fp32 => "FP32",
                    Quant::Int8 => "INT8",
                };
                format!(
                    "native engine (batched weight-stationary, {}x{} tile, {quant})",
                    m.tile, m.tile
                )
            }
        }
    }

    /// The native engine, when that is what auto-selection picked.
    pub fn native_mut(&mut self) -> Option<&mut NativeBackend> {
        match self {
            Backend::Pjrt { .. } => None,
            Backend::Native(nb) => Some(nb),
        }
    }

    /// The PJRT engine, when artifacts were found.
    pub fn engine_mut(&mut self) -> Option<&mut Engine> {
        match self {
            Backend::Pjrt { engine, .. } => Some(engine),
            Backend::Native(_) => None,
        }
    }

    /// What [`Server::with_manifest`] needs for this backend: the
    /// serving manifest, the parameter bundle, and the artifact name.
    /// PJRT loads both from `dir`; the native engine publishes its own
    /// manifest and needs no parameter arguments.
    pub fn serve_parts(&mut self, dir: &str) -> Result<(Manifest, Bundle, String)> {
        match self {
            Backend::Pjrt { engine, qos } => {
                let artifact = qos.artifact().to_string();
                let manifest = engine.load(&artifact)?.manifest.clone();
                let params = load_bundle(format!("{dir}/params_asr.bin"))?;
                Ok((manifest, params, artifact))
            }
            Backend::Native(nb) => Ok((
                nb.manifest().clone(),
                Bundle::default(),
                nb.manifest().name.clone(),
            )),
        }
    }

    /// Build the matching ASR QoS evaluator: artifact bundles for PJRT,
    /// a teacher-labeled synthetic test set of `n_utts` utterances
    /// (deterministic, baseline WER 0) for the native engine.
    pub fn asr_evaluator(&mut self, dir: &str, n_utts: usize) -> Result<AsrEvaluator> {
        match self {
            Backend::Pjrt { engine, qos } => {
                let artifact = qos.artifact().to_string();
                AsrEvaluator::new(engine, dir, &artifact)
            }
            Backend::Native(nb) => {
                let dims = *nb.dims();
                let testset = synth_testset(nb.weights(), n_utts, 11)?;
                let meta = EvalMeta {
                    n_blocks: dims.n_blocks,
                    batch: nb.batch(),
                    vocab: dims.vocab,
                    blank: dims.ctc_blank,
                    tile_hint: dims.tile,
                };
                AsrEvaluator::from_parts("native", nb.weights().to_bundle(), &testset, &meta)
            }
        }
    }
}

impl ServeBackend for Backend {
    fn execute(&mut self, artifact: &str, args: &[Tensor]) -> Result<Tensor> {
        match self {
            Backend::Pjrt { engine, .. } => engine.execute(artifact, args),
            Backend::Native(nb) => nb.execute(artifact, args),
        }
    }
}

impl QosBackend for Backend {
    fn configure(&mut self, params: &Bundle, tile: usize, quant: Quant) -> Result<()> {
        match self {
            Backend::Pjrt { engine, qos } => qos.configure(engine, params),
            Backend::Native(nb) => nb.configure(params, tile, quant),
        }
    }

    fn run_asr(&mut self, feats: &[f32], pad: &[f32], batch: usize) -> Result<Vec<f32>> {
        match self {
            Backend::Pjrt { engine, qos } => qos.run_asr(engine, feats, pad, batch),
            Backend::Native(nb) => nb.run_asr(feats, pad, batch),
        }
    }

    fn run_mt(&mut self, src: &[i32], batch: usize) -> Result<Vec<f32>> {
        match self {
            Backend::Pjrt { engine, qos } => qos.run_mt(engine, src, batch),
            Backend::Native(nb) => nb.run_mt(src, batch),
        }
    }

    fn translate(&mut self, src: &[i32], src_len: &[usize], batch: usize) -> Result<Vec<Vec<i32>>> {
        match self {
            // The PJRT encoder artifacts have no autoregressive decoder.
            Backend::Pjrt { .. } => {
                anyhow::bail!("PJRT backend has no autoregressive MT decoder")
            }
            Backend::Native(nb) => QosBackend::translate(&mut **nb, src, src_len, batch),
        }
    }
}

/// Serving-loop configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Model batch size (must match the artifact).
    pub batch: usize,
    /// Max time the batcher waits to fill a batch before flushing.
    pub max_wait: Duration,
}

/// One inference request: an utterance.
pub struct Request {
    pub id: u64,
    pub feats: Vec<f32>,
    pub feat_len: usize,
}

/// One response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency: Duration,
}

/// Latency/throughput summary of a serving run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub n_requests: usize,
    pub n_batches: usize,
    pub p50: Duration,
    pub p95: Duration,
    pub mean_batch_fill: f64,
    pub throughput_rps: f64,
}

/// Single-threaded synchronous server core: batching logic + execution.
/// (The `serve` example wraps it with a producer thread; keeping the core
/// synchronous makes it deterministic and unit-testable.)
pub struct Server {
    pub cfg: ServeConfig,
    artifact: String,
    /// Prebuilt positional arguments; only the `feats`/`pad_mask` slots
    /// are rewritten (in place) per batch.
    args: Vec<Tensor>,
    feats_idx: usize,
    pad_idx: usize,
    seq_len: usize,
    feat_dim: usize,
    vocab: usize,
    blank: i32,
}

impl Server {
    /// Load the artifact once and build the static argument vector.
    pub fn new(
        engine: &mut Engine,
        artifact: &str,
        params: Bundle,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let manifest = engine.load(artifact)?.manifest.clone();
        Server::with_manifest(&manifest, artifact, params, cfg)
    }

    /// Engine-free constructor over an already-loaded manifest — what the
    /// stub-backed tests use, and what [`Server::new`] delegates to.
    pub fn with_manifest(
        manifest: &Manifest,
        artifact: &str,
        params: Bundle,
        cfg: ServeConfig,
    ) -> Result<Server> {
        // Shared manifest contract (data args zeroed, masks all-ones,
        // params by name) — same assembly the QoS backends use.
        let args = manifest.assemble_args(&params)?;
        let feats_idx = manifest
            .arg_index("feats")
            .context("artifact has no 'feats' argument")?;
        let pad_idx = manifest
            .arg_index("pad_mask")
            .context("artifact has no 'pad_mask' argument")?;
        let feat_dim = *manifest.args[feats_idx]
            .shape
            .last()
            .context("feats argument has no shape")?;
        // The batch the caller configured must be the batch the artifact
        // was compiled for — the reusable argument tensors are sized from
        // the manifest, so a mismatch caught here would otherwise surface
        // as an out-of-bounds slice (or silent zero-row padding) in the
        // serving loop.
        let seq_len = manifest.model.seq_len;
        ensure!(
            manifest.args[feats_idx].shape == [cfg.batch, seq_len, feat_dim],
            "feats shape {:?} != configured batch {} x seq {} x feat {}",
            manifest.args[feats_idx].shape,
            cfg.batch,
            seq_len,
            feat_dim
        );
        ensure!(
            manifest.args[pad_idx].shape == [cfg.batch, seq_len],
            "pad_mask shape {:?} != configured batch {} x seq {}",
            manifest.args[pad_idx].shape,
            cfg.batch,
            seq_len
        );
        Ok(Server {
            cfg,
            artifact: artifact.to_string(),
            args,
            feats_idx,
            pad_idx,
            seq_len: manifest.model.seq_len,
            feat_dim,
            vocab: manifest.model.vocab,
            blank: manifest.model.ctc_blank as i32,
        })
    }

    /// Drain a request channel until it closes, serving batches.
    pub fn run(
        &mut self,
        backend: &mut impl ServeBackend,
        rx: mpsc::Receiver<Request>,
        tx: mpsc::Sender<Response>,
    ) -> Result<ServeReport> {
        let mut latencies: Vec<Duration> = Vec::new();
        let mut fills: Vec<usize> = Vec::new();
        let t0 = Instant::now();
        let mut n_requests = 0usize;
        let mut pending: Vec<(Request, Instant)> = Vec::new();
        let mut open = true;
        while open || !pending.is_empty() {
            // Fill up to batch or deadline.
            let deadline = Instant::now() + self.cfg.max_wait;
            while open && pending.len() < self.cfg.batch {
                let timeout = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(timeout) {
                    Ok(r) => pending.push((r, Instant::now())),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                    }
                }
            }
            if pending.is_empty() {
                continue;
            }
            let take = pending.len().min(self.cfg.batch);
            let batch: Vec<(Request, Instant)> = pending.drain(..take).collect();
            fills.push(batch.len());
            let responses = self.run_batch(backend, &batch)?;
            for r in responses {
                latencies.push(r.latency);
                n_requests += 1;
                let _ = tx.send(r);
            }
        }
        latencies.sort_unstable();
        let total = t0.elapsed().as_secs_f64();
        let n = latencies.len().max(1);
        Ok(ServeReport {
            n_requests,
            n_batches: fills.len(),
            p50: latencies.get(n / 2).copied().unwrap_or_default(),
            p95: latencies.get(n * 95 / 100).copied().unwrap_or_default(),
            mean_batch_fill: fills.iter().sum::<usize>() as f64
                / fills.len().max(1) as f64,
            throughput_rps: n_requests as f64 / total.max(1e-9),
        })
    }

    /// Execute one batch (padding the tail with repeats of the last
    /// request, discarded on output). Steady state writes only the
    /// `feats`/`pad_mask` bytes — no loads, clones, or allocations of
    /// the parameter arguments.
    fn run_batch(
        &mut self,
        backend: &mut impl ServeBackend,
        batch: &[(Request, Instant)],
    ) -> Result<Vec<Response>> {
        assert!(!batch.is_empty() && batch.len() <= self.cfg.batch);
        let (b, t, f) = (self.cfg.batch, self.seq_len, self.feat_dim);

        {
            let feats = &mut self.args[self.feats_idx];
            debug_assert_eq!(feats.data.len(), b * t * f * 4);
            for i in 0..b {
                let (req, _) = &batch[i.min(batch.len() - 1)];
                // Strict: a wrong-length request must not silently leave
                // stale frames from the previous batch in this row (the
                // argument tensor is reused across batches).
                assert_eq!(
                    req.feats.len(),
                    t * f,
                    "request {} feats length != seq_len x feat_dim",
                    req.id
                );
                write_f32s(feats, i * t * f, &req.feats);
            }
        }
        {
            let pad = &mut self.args[self.pad_idx];
            pad.data.fill(0);
            let one = 1.0f32.to_le_bytes();
            for i in 0..b {
                let (req, _) = &batch[i.min(batch.len() - 1)];
                for tt in 0..req.feat_len.min(t) {
                    let at = (i * t + tt) * 4;
                    pad.data[at..at + 4].copy_from_slice(&one);
                }
            }
        }

        let out = backend.execute(&self.artifact, &self.args)?;
        let lp = out.f32s();
        let mut responses = Vec::with_capacity(batch.len());
        for (i, (req, arrived)) in batch.iter().enumerate() {
            let tokens = ctc_greedy(
                &lp[i * t * self.vocab..(i + 1) * t * self.vocab],
                req.feat_len.min(t),
                self.vocab,
                self.blank,
            );
            responses.push(Response {
                id: req.id,
                tokens,
                latency: arrived.elapsed(),
            });
        }
        Ok(responses)
    }
}

/// Overwrite `count(vals)` f32 elements of `t` starting at element
/// `offset`, in place (no tensor reconstruction).
fn write_f32s(t: &mut Tensor, offset: usize, vals: &[f32]) {
    debug_assert_eq!(t.dtype, DType::F32);
    let start = offset * 4;
    let dst = &mut t.data[start..start + vals.len() * 4];
    for (chunk, v) in dst.chunks_exact_mut(4).zip(vals) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;

    const B: usize = 4;
    const T: usize = 6;
    const F: usize = 3;
    const VOCAB: usize = 8;
    const BLANK: i32 = 0;

    fn test_manifest() -> Manifest {
        Manifest::parse(&format!(
            r#"{{
              "name": "stub_encoder",
              "args": [
                {{"name": "feats", "shape": [{B}, {T}, {F}], "dtype": "float32"}},
                {{"name": "pad_mask", "shape": [{B}, {T}], "dtype": "float32"}},
                {{"name": "mask.ff0", "shape": [2, 2], "dtype": "int32"}},
                {{"name": "block0.ff.w1", "shape": [3], "dtype": "float32"}}
              ],
              "output": {{"shape": [{B}, {T}, {VOCAB}], "dtype": "float32"}},
              "model": {{"n_blocks": 1, "vocab": {VOCAB}, "ctc_blank": {BLANK},
                        "batch": {B}, "seq_len": {T}}}
            }}"#
        ))
        .unwrap()
    }

    fn test_params() -> Bundle {
        let mut b = Bundle::default();
        b.insert("block0.ff.w1", Tensor::from_f32(&[3], &[0.5, -1.0, 2.0]));
        b
    }

    fn test_server(max_wait: Duration) -> Server {
        Server::with_manifest(
            &test_manifest(),
            "stub_encoder",
            test_params(),
            ServeConfig { batch: B, max_wait },
        )
        .unwrap()
    }

    /// A request whose first feature element encodes a token class, so
    /// the stub backend can answer with a decodable prediction.
    fn request(id: u64) -> Request {
        let mut feats = vec![0.0f32; T * F];
        feats[0] = (id % (VOCAB as u64 - 1) + 1) as f32;
        Request { id, feats, feat_len: T }
    }

    fn expected_tokens(id: u64) -> Vec<i32> {
        vec![(id % (VOCAB as u64 - 1) + 1) as i32]
    }

    /// Stub execution backend: validates the argument contract and emits
    /// log-probs whose greedy CTC decode of row `i` is the class encoded
    /// in that row's first feature element (frame 0; all later frames
    /// blank). Records every argument vector for post-run inspection.
    struct StubBackend {
        calls: Vec<Vec<Tensor>>,
    }

    impl StubBackend {
        fn new() -> Self {
            StubBackend { calls: Vec::new() }
        }
    }

    impl ServeBackend for StubBackend {
        fn execute(&mut self, artifact: &str, args: &[Tensor]) -> Result<Tensor> {
            assert_eq!(artifact, "stub_encoder");
            test_manifest().validate_args(args)?;
            self.calls.push(args.to_vec());
            let feats = args[0].f32s();
            let mut lp = vec![0.0f32; B * T * VOCAB];
            for i in 0..B {
                let cls = feats[i * T * F] as usize % VOCAB;
                for tt in 0..T {
                    let base = (i * T + tt) * VOCAB;
                    let hot = if tt == 0 { cls } else { BLANK as usize };
                    lp[base + hot] = 5.0;
                }
            }
            Ok(Tensor::from_f32(&[B, T, VOCAB], &lp))
        }
    }

    /// Run the server over a sequence of requests sent immediately, then
    /// a closed channel.
    fn serve_all(
        server: &mut Server,
        backend: &mut StubBackend,
        ids: &[u64],
    ) -> (ServeReport, Vec<Response>) {
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        for &id in ids {
            req_tx.send(request(id)).unwrap();
        }
        drop(req_tx);
        let report = server.run(backend, req_rx, resp_tx).unwrap();
        (report, resp_rx.try_iter().collect())
    }

    #[test]
    fn serve_config_fields() {
        let c = ServeConfig { batch: 16, max_wait: Duration::from_millis(5) };
        assert_eq!(c.batch, 16);
    }

    #[test]
    fn report_shape() {
        let r = ServeReport {
            n_requests: 10,
            n_batches: 2,
            p50: Duration::from_millis(3),
            p95: Duration::from_millis(9),
            mean_batch_fill: 5.0,
            throughput_rps: 100.0,
        };
        assert!(r.p95 >= r.p50);
    }

    #[test]
    fn batches_full_and_partial_with_correct_routing() {
        let mut server = test_server(Duration::from_millis(5));
        let mut backend = StubBackend::new();
        let ids: Vec<u64> = (1..=10).collect();
        let (report, responses) = serve_all(&mut server, &mut backend, &ids);
        // 10 requests at batch 4 -> 4 + 4 + 2.
        assert_eq!(report.n_requests, 10);
        assert_eq!(report.n_batches, 3);
        assert!((report.mean_batch_fill - 10.0 / 3.0).abs() < 1e-9);
        assert_eq!(responses.len(), 10);
        for r in &responses {
            assert_eq!(r.tokens, expected_tokens(r.id), "request {}", r.id);
        }
    }

    #[test]
    fn tail_batch_padded_with_last_request_and_discarded() {
        let mut server = test_server(Duration::from_millis(5));
        let mut backend = StubBackend::new();
        let (report, responses) = serve_all(&mut server, &mut backend, &[7, 8, 9]);
        assert_eq!(report.n_batches, 1);
        assert_eq!(responses.len(), 3, "padding rows must not produce responses");
        // The executed feats tensor repeats the last request in rows 3..B.
        let feats = backend.calls[0][0].f32s();
        let last_row = &feats[2 * T * F..3 * T * F];
        for pad_row in 3..B {
            assert_eq!(
                &feats[pad_row * T * F..(pad_row + 1) * T * F],
                last_row,
                "row {pad_row} must repeat the last real request"
            );
        }
    }

    #[test]
    fn pad_mask_reflects_feat_len() {
        let mut server = test_server(Duration::from_millis(5));
        let mut backend = StubBackend::new();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let mut short = request(3);
        short.feat_len = 2;
        req_tx.send(short).unwrap();
        drop(req_tx);
        server.run(&mut backend, req_rx, resp_tx).unwrap();
        let _ = resp_rx.try_iter().count();
        let pad = backend.calls[0][1].f32s();
        assert_eq!(&pad[..T], &[1.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn static_args_built_once_and_stable_across_batches() {
        let mut server = test_server(Duration::from_millis(5));
        let mut backend = StubBackend::new();
        let ids: Vec<u64> = (0..8).collect();
        serve_all(&mut server, &mut backend, &ids);
        assert_eq!(backend.calls.len(), 2);
        for call in &backend.calls {
            // mask.* arguments are all-ones i32.
            assert!(call[2].i32s().iter().all(|v| *v == 1));
            // Parameter tensors pass through from the bundle, unchanged.
            assert_eq!(call[3].f32s(), vec![0.5, -1.0, 2.0]);
        }
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        // Two requests separated by much more than max_wait must land in
        // two deadline-flushed batches, not one.
        let mut server = test_server(Duration::from_millis(10));
        let mut backend = StubBackend::new();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let producer = thread::spawn(move || {
            req_tx.send(request(1)).unwrap();
            thread::sleep(Duration::from_millis(300));
            req_tx.send(request(2)).unwrap();
        });
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        producer.join().unwrap();
        assert_eq!(report.n_requests, 2);
        assert_eq!(report.n_batches, 2, "deadline must flush each alone");
        assert!((report.mean_batch_fill - 1.0).abs() < 1e-9);
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 2);
    }

    #[test]
    fn batch_mismatch_rejected_at_construction() {
        let err = Server::with_manifest(
            &test_manifest(),
            "stub_encoder",
            test_params(),
            ServeConfig { batch: B + 1, max_wait: Duration::from_millis(1) },
        )
        .err()
        .expect("construction must fail on batch/artifact mismatch");
        assert!(format!("{err:?}").contains("configured batch"));
    }

    #[test]
    fn backend_auto_selects_native_without_artifacts() {
        let dims = crate::infer::testutil::mini_dims();
        let mut backend =
            Backend::auto_with("definitely/_no_artifacts_here", "asr_encoder_ref", dims, 5, 2)
                .unwrap();
        assert!(backend.is_native());
        assert_eq!(backend.label(), "native");
        assert!(backend.describe().contains("native engine"));
        assert!(backend.engine_mut().is_none());
        assert!(backend.native_mut().is_some());
        // The QoS surface works through the same object: teacher-labeled
        // test set, so the dense FP32 point reproduces WER 0.
        let eval = backend.asr_evaluator("unused", 3).unwrap();
        let p = eval
            .evaluate_with(&mut backend, dims.tile, 0.0, Quant::Fp32)
            .unwrap();
        assert_eq!(p.qos, 0.0, "dense FP32 must reproduce its own labels");
    }

    #[test]
    fn backend_auto_prefers_pjrt_when_artifact_exists() {
        // Selection is driven by the artifact file: auto must reach for
        // PJRT, never silently fall back to the native engine. With the
        // vendored xla stub that surfaces as a client-construction
        // error; with a real xla crate swapped in it is Ok(Pjrt) —
        // either way the selection decision is the same.
        let dir = std::env::temp_dir().join(format!(
            "sasp_backend_auto_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("asr_encoder_ref.hlo.txt"), "stub").unwrap();
        let dims = crate::infer::testutil::mini_dims();
        let got = Backend::auto_with(dir.to_str().unwrap(), "asr_encoder_ref", dims, 5, 2);
        let _ = std::fs::remove_dir_all(&dir);
        // Err = stub build (PJRT attempted and unavailable) — also fine.
        if let Ok(backend) = got {
            assert!(
                !backend.is_native(),
                "artifact present: auto must not fall back to native"
            );
        }
    }

    #[test]
    fn native_backend_serves_end_to_end() {
        // The tentpole wiring: Backend::auto -> serve_parts -> Server
        // runs real batched native inference behind the request queue.
        let dims = crate::infer::testutil::mini_dims();
        let mut backend =
            Backend::auto_with("definitely/_no_artifacts_here", "asr_encoder_ref", dims, 5, 2)
                .unwrap();
        let (manifest, params, artifact) = backend.serve_parts("unused").unwrap();
        assert_eq!(manifest.model.batch, 2);
        let mut server = Server::with_manifest(
            &manifest,
            &artifact,
            params,
            ServeConfig { batch: 2, max_wait: Duration::from_millis(5) },
        )
        .unwrap();
        let (req_tx, req_rx) = mpsc::channel::<Request>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let (t, f) = (dims.seq_len, dims.input_dim);
        for id in 0..3u64 {
            let feats = vec![0.25f32 * (id as f32 + 1.0); t * f];
            req_tx.send(Request { id, feats, feat_len: t }).unwrap();
        }
        drop(req_tx);
        let report = server.run(&mut backend, req_rx, resp_tx).unwrap();
        assert_eq!(report.n_requests, 3);
        assert_eq!(report.n_batches, 2, "3 requests at batch 2 -> 2 + 1");
        let responses: Vec<Response> = resp_rx.try_iter().collect();
        assert_eq!(responses.len(), 3);
        for r in &responses {
            assert!(r.tokens.iter().all(|s| *s >= 0 && (*s as usize) < dims.vocab));
        }
        // The batched engine saw every forward row (incl. tail padding).
        let st = backend.native_mut().unwrap().stats();
        assert_eq!(st.utterances, 4);
    }

    #[test]
    fn missing_param_rejected_at_construction() {
        let err = Server::with_manifest(
            &test_manifest(),
            "stub_encoder",
            Bundle::default(), // no block0.ff.w1
            ServeConfig { batch: B, max_wait: Duration::from_millis(1) },
        )
        .err()
        .expect("construction must fail without params");
        assert!(format!("{err:?}").contains("block0.ff.w1"));
    }
}
