//! Batched inference serving loop — the edge-deployment face of the
//! coordinator. Requests (utterances) arrive on a queue; a batcher thread
//! forms fixed-size batches (padding the tail with repeats, exactly like
//! the evaluator) under a deadline; the PJRT executable runs them; the
//! caller gets decoded hypotheses plus latency metrics.
//!
//! Implemented over std threads/channels (no tokio in the vendor set);
//! the PJRT client is kept on the worker thread, requests cross via mpsc.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::{Bundle, Tensor};
use crate::qos::decode::ctc_greedy;
use crate::runtime::Engine;

/// Serving-loop configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Model batch size (must match the artifact).
    pub batch: usize,
    /// Max time the batcher waits to fill a batch before flushing.
    pub max_wait: Duration,
}

/// One inference request: an utterance.
pub struct Request {
    pub id: u64,
    pub feats: Vec<f32>,
    pub feat_len: usize,
}

/// One response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency: Duration,
}

/// Latency/throughput summary of a serving run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub n_requests: usize,
    pub n_batches: usize,
    pub p50: Duration,
    pub p95: Duration,
    pub mean_batch_fill: f64,
    pub throughput_rps: f64,
}

/// Single-threaded synchronous server core: batching logic + execution.
/// (The `serve` example wraps it with a producer thread; keeping the core
/// synchronous makes it deterministic and unit-testable.)
pub struct Server {
    pub cfg: ServeConfig,
    artifact: String,
    params: Bundle,
    seq_len: usize,
    feat_dim: usize,
    vocab: usize,
    blank: i32,
}

impl Server {
    pub fn new(
        engine: &mut Engine,
        artifact: &str,
        params: Bundle,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let m = engine.load(artifact)?.manifest.clone();
        Ok(Server {
            cfg,
            artifact: artifact.to_string(),
            params,
            seq_len: m.model.seq_len,
            feat_dim: m
                .args
                .first()
                .map(|a| *a.shape.last().unwrap())
                .unwrap_or(0),
            vocab: m.model.vocab,
            blank: m.model.ctc_blank as i32,
        })
    }

    /// Drain a request channel until it closes, serving batches.
    pub fn run(
        &self,
        engine: &mut Engine,
        rx: mpsc::Receiver<Request>,
        tx: mpsc::Sender<Response>,
    ) -> Result<ServeReport> {
        let mut latencies: Vec<Duration> = Vec::new();
        let mut fills: Vec<usize> = Vec::new();
        let t0 = Instant::now();
        let mut n_requests = 0usize;
        let mut pending: Vec<(Request, Instant)> = Vec::new();
        let mut open = true;
        while open || !pending.is_empty() {
            // Fill up to batch or deadline.
            let deadline = Instant::now() + self.cfg.max_wait;
            while open && pending.len() < self.cfg.batch {
                let timeout = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(timeout) {
                    Ok(r) => pending.push((r, Instant::now())),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                    }
                }
            }
            if pending.is_empty() {
                continue;
            }
            let take = pending.len().min(self.cfg.batch);
            let batch: Vec<(Request, Instant)> = pending.drain(..take).collect();
            fills.push(batch.len());
            let responses = self.run_batch(engine, &batch)?;
            for r in responses {
                latencies.push(r.latency);
                n_requests += 1;
                let _ = tx.send(r);
            }
        }
        latencies.sort_unstable();
        let total = t0.elapsed().as_secs_f64();
        let n = latencies.len().max(1);
        Ok(ServeReport {
            n_requests,
            n_batches: fills.len(),
            p50: latencies.get(n / 2).copied().unwrap_or_default(),
            p95: latencies.get(n * 95 / 100).copied().unwrap_or_default(),
            mean_batch_fill: fills.iter().sum::<usize>() as f64
                / fills.len().max(1) as f64,
            throughput_rps: n_requests as f64 / total.max(1e-9),
        })
    }

    /// Execute one batch (padding the tail with repeats of the last
    /// request, discarded on output).
    fn run_batch(
        &self,
        engine: &mut Engine,
        batch: &[(Request, Instant)],
    ) -> Result<Vec<Response>> {
        assert!(!batch.is_empty() && batch.len() <= self.cfg.batch);
        let (b, t, f) = (self.cfg.batch, self.seq_len, self.feat_dim);
        let mut feats = vec![0.0f32; b * t * f];
        let mut pad = vec![0.0f32; b * t];
        for i in 0..b {
            let (req, _) = &batch[i.min(batch.len() - 1)];
            feats[i * t * f..(i + 1) * t * f].copy_from_slice(&req.feats);
            for tt in 0..req.feat_len.min(t) {
                pad[i * t + tt] = 1.0;
            }
        }
        let manifest = engine.load(&self.artifact)?.manifest.clone();
        let mut args = Vec::with_capacity(manifest.args.len());
        for spec in &manifest.args {
            match spec.name.as_str() {
                "feats" => args.push(Tensor::from_f32(&[b, t, f], &feats)),
                "pad_mask" => args.push(Tensor::from_f32(&[b, t], &pad)),
                name if name.starts_with("mask.") => {
                    let numel: usize = spec.shape.iter().product();
                    args.push(Tensor::from_i32(&spec.shape, &vec![1; numel]));
                }
                name => args.push(self.params.require(name)?.clone()),
            }
        }
        let out = engine.execute(&self.artifact, &args)?;
        let lp = out.f32s();
        let mut responses = Vec::with_capacity(batch.len());
        for (i, (req, arrived)) in batch.iter().enumerate() {
            let tokens = ctc_greedy(
                &lp[i * t * self.vocab..(i + 1) * t * self.vocab],
                req.feat_len.min(t),
                self.vocab,
                self.blank,
            );
            responses.push(Response {
                id: req.id,
                tokens,
                latency: arrived.elapsed(),
            });
        }
        Ok(responses)
    }
}

#[cfg(test)]
mod tests {
    // The batching logic is validated end-to-end by examples/serve.rs and
    // the integration suite; pure helpers are covered elsewhere. Here we
    // check the report math on synthetic latency lists.
    use super::*;

    #[test]
    fn serve_config_fields() {
        let c = ServeConfig { batch: 16, max_wait: Duration::from_millis(5) };
        assert_eq!(c.batch, 16);
    }

    #[test]
    fn report_shape() {
        let r = ServeReport {
            n_requests: 10,
            n_batches: 2,
            p50: Duration::from_millis(3),
            p95: Duration::from_millis(9),
            mean_batch_fill: 5.0,
            throughput_rps: 100.0,
        };
        assert!(r.p95 >= r.p50);
    }
}
