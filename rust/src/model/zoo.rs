//! The model zoo of Table 1, plus the tiny trained stand-ins whose
//! weights actually exist in `artifacts/`.
//!
//! Sequence lengths: the paper simulates real utterances; we use
//! representative fixed lengths (LibriSpeech utterances after ESPnet's
//! 4x subsampling land near 256 frames; MuST-C sentences near 64
//! tokens). Lengths scale every configuration identically, so relative
//! results are unaffected.

use super::EncoderSpec;

/// ESPnet ASR on LibriSpeech (Table 1 row 1): 18 encoder blocks,
/// d_model 512, FF 2048, 4 heads. QoS 3.5 % WER, SASP target 5 %.
pub fn espnet_asr() -> EncoderSpec {
    EncoderSpec {
        name: "espnet_asr_librispeech",
        n_blocks: 18,
        d_model: 512,
        d_ff: 2048,
        n_heads: 4,
        seq_len: 256,
    }
}

/// ESPnet2 ASR on LibriSpeech (Table 1 row 2): 12 blocks, 8 heads.
pub fn espnet2_asr() -> EncoderSpec {
    EncoderSpec {
        name: "espnet2_asr_librispeech",
        n_blocks: 12,
        d_model: 512,
        d_ff: 2048,
        n_heads: 8,
        seq_len: 256,
    }
}

/// MuST-C cascade, ASR stage encoder (Table 1 row 3, first figures):
/// 18 blocks, d_model 128, FF 2048, 4 heads.
pub fn mustc_asr_encoder() -> EncoderSpec {
    EncoderSpec {
        name: "mustc_asr_encoder",
        n_blocks: 18,
        d_model: 128,
        d_ff: 2048,
        n_heads: 4,
        seq_len: 256,
    }
}

/// MuST-C cascade, MT stage encoder (Table 1 row 3, second figures):
/// 6 blocks, d_model 128, FF 1024, 4 heads.
pub fn mustc_mt_encoder() -> EncoderSpec {
    EncoderSpec {
        name: "mustc_mt_encoder",
        n_blocks: 6,
        d_model: 128,
        d_ff: 1024,
        n_heads: 4,
        seq_len: 64,
    }
}

/// The trained tiny ASR model (artifacts/params_asr.bin): 4 blocks,
/// d_model 64, FF 256 — shapes must match `python/compile/model.py`.
pub fn tiny_asr() -> EncoderSpec {
    EncoderSpec {
        name: "tiny_asr",
        n_blocks: 4,
        d_model: 64,
        d_ff: 256,
        n_heads: 4,
        seq_len: 96,
    }
}

/// The trained tiny MT model (artifacts/params_mt.bin).
pub fn tiny_mt() -> EncoderSpec {
    EncoderSpec {
        name: "tiny_mt",
        n_blocks: 2,
        d_model: 64,
        d_ff: 256,
        n_heads: 4,
        seq_len: 32,
    }
}

/// All Table 1 workloads in Fig. 7 order.
pub fn fig7_workloads() -> Vec<EncoderSpec> {
    vec![espnet_asr(), espnet2_asr(), mustc_asr_encoder()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shapes() {
        let a = espnet_asr();
        assert_eq!((a.n_blocks, a.d_model, a.d_ff, a.n_heads), (18, 512, 2048, 4));
        let b = espnet2_asr();
        assert_eq!((b.n_blocks, b.n_heads), (12, 8));
        let c = mustc_mt_encoder();
        assert_eq!((c.n_blocks, c.d_model, c.d_ff), (6, 128, 1024));
    }

    #[test]
    fn tiny_matches_python_model_config() {
        // Must agree with ASR_TINY / MT_TINY in python/compile/model.py.
        let t = tiny_asr();
        assert_eq!((t.n_blocks, t.d_model, t.d_ff, t.n_heads, t.seq_len),
                   (4, 64, 256, 4, 96));
        let m = tiny_mt();
        assert_eq!((m.n_blocks, m.d_model, m.d_ff, m.seq_len), (2, 64, 256, 32));
    }

    #[test]
    fn dimensions_tile_aligned_for_paper_sizes() {
        // Table 1 dims divide all studied tile sizes 4..32.
        for spec in [espnet_asr(), espnet2_asr()] {
            for t in [4usize, 8, 16, 32] {
                assert_eq!(spec.d_model % t, 0);
                assert_eq!(spec.d_ff % t, 0);
            }
        }
    }
}
