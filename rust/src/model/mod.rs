//! Transformer workload inventory: the GEMM-level description of the
//! paper's models (Table 1) that drives the full-system simulation.
//!
//! Timing/energy results (Figs. 7, 8, 10, 11; Table 3) depend only on the
//! *shapes* of the GEMMs an encoder executes — these are taken verbatim
//! from Table 1. QoS results use the trained tiny model whose artifacts
//! live in `artifacts/` (see DESIGN.md §2 for the substitution argument).

pub mod zoo;

pub use zoo::{espnet2_asr, espnet_asr, mustc_mt_encoder, tiny_asr, tiny_mt};

/// What a GEMM computes — determines whether SASP may prune it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKind {
    /// Attention Q/K/V/O projection (weight GEMM, accelerated, unpruned —
    /// §3.1: attention is much more pruning-sensitive than feed-forward).
    AttnProj,
    /// Attention score / context GEMM (activation×activation — no
    /// stationary weights to prune; still runs on the array).
    AttnDyn,
    /// Feed-forward GEMM — the SASP pruning target.
    FeedForward,
}

impl GemmKind {
    /// Whether SASP structured pruning applies (feed-forward only).
    pub fn prunable(self) -> bool {
        matches!(self, GemmKind::FeedForward)
    }

    /// Whether the weights are stationary (reusable across the M
    /// dimension). Dynamic attention GEMMs re-program per tile pass.
    pub fn weight_stationary(self) -> bool {
        !matches!(self, GemmKind::AttnDyn)
    }
}

/// One GEMM: `[m, k] x [k, n]`.
#[derive(Clone, Copy, Debug)]
pub struct GemmShape {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub kind: GemmKind,
}

impl GemmShape {
    pub fn macs(&self) -> u64 {
        (self.m * self.k * self.n) as u64
    }

    /// Number of `tile x tile` weight tiles (K and N padded up).
    pub fn n_tiles(&self, tile: usize) -> usize {
        self.k.div_ceil(tile) * self.n.div_ceil(tile)
    }
}

/// One encoder block's GEMMs, in execution order.
#[derive(Clone, Debug)]
pub struct LayerGemms {
    /// Block index within the encoder.
    pub index: usize,
    pub gemms: Vec<GemmShape>,
}

/// A whole encoder workload.
#[derive(Clone, Debug)]
pub struct EncoderSpec {
    pub name: &'static str,
    pub n_blocks: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    /// Representative sequence length for the simulated inference.
    pub seq_len: usize,
}

impl EncoderSpec {
    /// Expand to the per-block GEMM list.
    pub fn layers(&self) -> Vec<LayerGemms> {
        let (t, d, f, h) = (self.seq_len, self.d_model, self.d_ff, self.n_heads);
        let dh = d / h;
        (0..self.n_blocks)
            .map(|index| {
                let mut gemms = Vec::new();
                // Q, K, V, O projections.
                for _ in 0..4 {
                    gemms.push(GemmShape { m: t, k: d, n: d, kind: GemmKind::AttnProj });
                }
                // Per-head scores (T x dh x T) and context (T x T x dh).
                for _ in 0..h {
                    gemms.push(GemmShape { m: t, k: dh, n: t, kind: GemmKind::AttnDyn });
                    gemms.push(GemmShape { m: t, k: t, n: dh, kind: GemmKind::AttnDyn });
                }
                // Feed-forward pair.
                gemms.push(GemmShape { m: t, k: d, n: f, kind: GemmKind::FeedForward });
                gemms.push(GemmShape { m: t, k: f, n: d, kind: GemmKind::FeedForward });
                LayerGemms { index, gemms }
            })
            .collect()
    }

    /// Total MACs of one inference.
    pub fn total_macs(&self) -> u64 {
        self.layers()
            .iter()
            .flat_map(|l| l.gemms.iter())
            .map(|g| g.macs())
            .sum()
    }

    /// MACs in prunable (feed-forward) GEMMs.
    pub fn ff_macs(&self) -> u64 {
        self.layers()
            .iter()
            .flat_map(|l| l.gemms.iter())
            .filter(|g| g.kind.prunable())
            .map(|g| g.macs())
            .sum()
    }

    /// Elements touched by non-GEMM ops (LayerNorm, softmax, residual,
    /// activation) per inference — the software-executed remainder.
    pub fn non_gemm_elems(&self) -> u64 {
        let (t, d, f, h) = (
            self.seq_len as u64,
            self.d_model as u64,
            self.d_ff as u64,
            self.n_heads as u64,
        );
        // Per block: 2 LayerNorms (t*d), softmax (h*t*t), residuals
        // (2*t*d), ReLU (t*f).
        self.n_blocks as u64 * (2 * t * d + h * t * t + 2 * t * d + t * f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_expansion_counts() {
        let spec = zoo::espnet_asr();
        let layers = spec.layers();
        assert_eq!(layers.len(), 18);
        // 4 proj + 2*heads dyn + 2 ff
        assert_eq!(layers[0].gemms.len(), 4 + 2 * spec.n_heads + 2);
    }

    #[test]
    fn ff_dominates_espnet_asr() {
        // §4.3: feed-forward accounts for the largest part of the
        // workload in the Table 1 models.
        let spec = zoo::espnet_asr();
        assert!(spec.ff_macs() as f64 / spec.total_macs() as f64 > 0.5);
    }

    #[test]
    fn macs_closed_form() {
        let g = GemmShape { m: 2, k: 3, n: 4, kind: GemmKind::FeedForward };
        assert_eq!(g.macs(), 24);
        assert_eq!(g.n_tiles(2), 2 * 2);
        assert_eq!(g.n_tiles(4), 1 * 1);
    }

    #[test]
    fn prunability() {
        assert!(GemmKind::FeedForward.prunable());
        assert!(!GemmKind::AttnProj.prunable());
        assert!(!GemmKind::AttnDyn.prunable());
        assert!(!GemmKind::AttnDyn.weight_stationary());
    }

    #[test]
    fn non_gemm_is_small_fraction() {
        // The paper: GEMMs exceed 97% of runtime; element counts must be
        // orders of magnitude below MACs.
        let spec = zoo::espnet_asr();
        assert!(spec.non_gemm_elems() * 50 < spec.total_macs());
    }
}
