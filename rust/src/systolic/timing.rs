//! Closed-form per-tile cost model — the contract between the functional
//! array simulation (which validates these formulas on small tiles) and
//! the full-system simulator (which applies them millions of times).
//!
//! Costs per weight-stationary tile pass (array `R x C`, input block of
//! `M` rows):
//!
//! - **program**: `ceil(R*C / weights_per_word)` 32-bit bus writes
//!   (FP32: one weight per word; INT8: four, §3.2).
//! - **stream**: `M*R` input words in, `M*C` output words out; one input
//!   and one output activation move per custom instruction, so the
//!   instruction count is `M * max(R, C)` with perfect overlap.
//! - **array cycles**: `M + R + C - 2` (fill + stream + drain through the
//!   skew registers), validated against the per-cycle simulation.
//! - **MACs**: `M*R*C` (for energy accounting).
//!
//! A *skipped* (pruned) tile costs nothing — that is the SASP saving.

use super::ArrayConfig;

/// Active-PE-cycle occupancy of a tile pass: where the `R*C` PEs spend
/// (or save) their cycles while the closed-form schedule runs.
///
/// - **active**: PE-cycles doing steady-state MAC work. Each input
///   element visits each PE of its row exactly once, so a live pass is
///   `M*R*C` — one PE-cycle per MAC (validated against the wavefront
///   simulation, which counts the PEs inside the active anti-diagonal
///   band cycle by cycle).
/// - **bubble**: fill/drain PE-cycles — the array is busy
///   (`M + R + C - 2` cycles, all `R*C` PEs powered) but the wavefront
///   hasn't reached / has already left a PE: `(R + C - 2) * R * C`.
/// - **stall**: PE-cycles idled while the tile's weights reprogram over
///   the bus (`prog_words * R * C` at one word per cycle).
/// - **skipped**: the steady-state PE-cycles a pruned tile *would* have
///   cost — the SASP saving, counted so utilization reports can show
///   where the skipped work landed.
///
/// Invariant: `active + bubble == array_cycles * R * C` for any pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Occupancy {
    /// PE-cycles of steady-state MAC work.
    pub active_pe_cycles: usize,
    /// Fill/drain PE-cycles (busy but no useful work at that PE).
    pub bubble_pe_cycles: usize,
    /// PE-cycles idled behind weight reprogramming.
    pub stall_pe_cycles: usize,
    /// PE-cycles of work avoided by pruning-skipped tiles.
    pub skipped_pe_cycles: usize,
}

impl Occupancy {
    /// Accumulate another pass's occupancy.
    pub fn add(&mut self, o: &Occupancy) {
        self.active_pe_cycles += o.active_pe_cycles;
        self.bubble_pe_cycles += o.bubble_pe_cycles;
        self.stall_pe_cycles += o.stall_pe_cycles;
        self.skipped_pe_cycles += o.skipped_pe_cycles;
    }

    /// PE-cycles the array is powered while busy (active + bubbles).
    pub fn busy_pe_cycles(&self) -> usize {
        self.active_pe_cycles + self.bubble_pe_cycles
    }

    /// Fraction of busy PE-cycles doing useful work (0 when never busy).
    pub fn utilization(&self) -> f64 {
        let busy = self.busy_pe_cycles();
        if busy == 0 {
            return 0.0;
        }
        self.active_pe_cycles as f64 / busy as f64
    }
}

/// Cost of one tile pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TileTiming {
    /// 32-bit words written to program the weight tile.
    pub prog_words: usize,
    /// Input activation words streamed in.
    pub in_words: usize,
    /// Output activation words streamed out.
    pub out_words: usize,
    /// Custom stream-compute instructions issued.
    pub stream_insts: usize,
    /// Cycles the array itself is busy.
    pub array_cycles: usize,
    /// MAC operations performed.
    pub macs: usize,
    /// Where the PE-cycles of this pass went (or were saved).
    pub occ: Occupancy,
}

impl TileTiming {
    /// Cost of programming + computing one live tile.
    pub fn live(cfg: &ArrayConfig, m: usize) -> TileTiming {
        let (r, c) = (cfg.rows, cfg.cols);
        let prog_words = (r * c).div_ceil(cfg.quant.weights_per_word());
        TileTiming {
            prog_words,
            in_words: m * r,
            out_words: m * c,
            stream_insts: m * r.max(c),
            array_cycles: m + r + c - 2,
            macs: m * r * c,
            occ: Occupancy {
                active_pe_cycles: m * r * c,
                bubble_pe_cycles: (r + c - 2) * r * c,
                stall_pe_cycles: prog_words * r * c,
                skipped_pe_cycles: 0,
            },
        }
    }

    /// Cost of a pruned tile: fully skipped (§3.1 / Fig. 3) — no weight
    /// programming, no streaming, no compute.
    pub fn skipped() -> TileTiming {
        TileTiming::default()
    }

    /// Occupancy-only record of a pruned tile pass: the steady-state
    /// PE-cycles the skip avoided (`batch * m * R * C` — what
    /// [`Self::live`]/[`Self::batched`] would have charged as active
    /// work). Every cost field stays zero: a skipped tile moves no
    /// words and holds the array for no cycles; this only makes the
    /// saving visible to utilization reports.
    pub fn skipped_pass(cfg: &ArrayConfig, m: usize, batch: usize) -> TileTiming {
        TileTiming {
            occ: Occupancy {
                skipped_pe_cycles: batch * m * cfg.rows * cfg.cols,
                ..Occupancy::default()
            },
            ..TileTiming::default()
        }
    }

    /// Reuse of an already-programmed tile for another input block (the
    /// weight-stationary win when M is split across batches).
    pub fn reuse(cfg: &ArrayConfig, m: usize) -> TileTiming {
        let mut t = TileTiming::live(cfg, m);
        t.prog_words = 0;
        t.occ.stall_pe_cycles = 0;
        t
    }

    /// Cost of one live tile streamed by `batch` consecutive input
    /// blocks of `m` rows under weight-stationary reuse: programmed once
    /// ([`Self::live`]), then reused for the remaining `batch - 1`
    /// blocks ([`Self::reuse`]). This is the closed form the batched
    /// serving engine ([`crate::infer::batch`]) charges per live tile —
    /// the cross-utterance saving is exactly `(batch-1) * prog_words`.
    pub fn batched(cfg: &ArrayConfig, m: usize, batch: usize) -> TileTiming {
        assert!(batch > 0, "a batched tile pass needs at least one block");
        let live = TileTiming::live(cfg, m);
        TileTiming {
            prog_words: live.prog_words,
            in_words: batch * live.in_words,
            out_words: batch * live.out_words,
            stream_insts: batch * live.stream_insts,
            array_cycles: batch * live.array_cycles,
            macs: batch * live.macs,
            occ: Occupancy {
                // Streaming repeats per block; the reprogramming stall
                // is paid once, like the programming itself.
                active_pe_cycles: batch * live.occ.active_pe_cycles,
                bubble_pe_cycles: batch * live.occ.bubble_pe_cycles,
                stall_pe_cycles: live.occ.stall_pe_cycles,
                skipped_pe_cycles: 0,
            },
        }
    }

    /// Accumulate another tile's cost.
    pub fn add(&mut self, other: &TileTiming) {
        self.prog_words += other.prog_words;
        self.in_words += other.in_words;
        self.out_words += other.out_words;
        self.stream_insts += other.stream_insts;
        self.array_cycles += other.array_cycles;
        self.macs += other.macs;
        self.occ.add(&other.occ);
    }

    /// Total 32-bit bus words moved (weights + activations).
    pub fn total_words(&self) -> usize {
        self.prog_words + self.in_words + self.out_words
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::{ArrayConfig, Quant, SystolicArray};
    use crate::util::prop::check;

    #[test]
    fn live_tile_counts_8x8() {
        let cfg = ArrayConfig::square(8, Quant::Fp32);
        let t = TileTiming::live(&cfg, 32);
        assert_eq!(t.prog_words, 64);
        assert_eq!(t.in_words, 32 * 8);
        assert_eq!(t.out_words, 32 * 8);
        assert_eq!(t.stream_insts, 32 * 8);
        assert_eq!(t.array_cycles, 32 + 8 + 8 - 2);
        assert_eq!(t.macs, 32 * 64);
    }

    #[test]
    fn int8_packs_four_weights_per_word() {
        let cfg = ArrayConfig::square(8, Quant::Int8);
        assert_eq!(TileTiming::live(&cfg, 1).prog_words, 16);
        let odd = ArrayConfig { rows: 3, cols: 3, quant: Quant::Int8 };
        assert_eq!(TileTiming::live(&odd, 1).prog_words, 3); // ceil(9/4)
    }

    #[test]
    fn skipped_tile_is_free() {
        assert_eq!(TileTiming::skipped().total_words(), 0);
        assert_eq!(TileTiming::skipped().array_cycles, 0);
    }

    #[test]
    fn reuse_drops_programming_only() {
        let cfg = ArrayConfig::square(4, Quant::Fp32);
        let live = TileTiming::live(&cfg, 16);
        let reuse = TileTiming::reuse(&cfg, 16);
        assert_eq!(reuse.prog_words, 0);
        assert_eq!(reuse.in_words, live.in_words);
        assert_eq!(reuse.array_cycles, live.array_cycles);
    }

    #[test]
    fn batched_is_live_plus_reuse() {
        // The batched closed form is exactly one programming pass plus
        // batch-1 reuse passes — elementwise, for every field.
        for quant in [Quant::Fp32, Quant::Int8] {
            let cfg = ArrayConfig::square(8, quant);
            for (m, b) in [(1usize, 1usize), (16, 2), (96, 4), (7, 5)] {
                let got = TileTiming::batched(&cfg, m, b);
                let mut want = TileTiming::live(&cfg, m);
                for _ in 1..b {
                    want.add(&TileTiming::reuse(&cfg, m));
                }
                assert_eq!(got, want, "m={m} b={b} {quant:?}");
            }
            assert_eq!(
                TileTiming::batched(&cfg, 32, 1),
                TileTiming::live(&cfg, 32),
                "batch 1 degenerates to a plain live pass"
            );
        }
    }

    #[test]
    fn batched_saving_is_programming_only() {
        let cfg = ArrayConfig::square(8, Quant::Int8);
        let (m, b) = (24usize, 6usize);
        let per_utt = TileTiming::live(&cfg, m);
        let batched = TileTiming::batched(&cfg, m, b);
        // Streaming/compute scale with the batch; programming does not.
        assert_eq!(batched.in_words, b * per_utt.in_words);
        assert_eq!(batched.macs, b * per_utt.macs);
        assert_eq!(batched.prog_words, per_utt.prog_words);
        assert_eq!(
            b * per_utt.total_words() - batched.total_words(),
            (b - 1) * per_utt.prog_words,
            "the reuse saving is exactly (batch-1) programming passes"
        );
    }

    #[test]
    fn closed_form_matches_cycle_simulation() {
        check("timing == per-cycle sim", 20, |rng| {
            let r = rng.index(6) + 1;
            let c = rng.index(6) + 1;
            let m = rng.index(8) + 1;
            let cfg = ArrayConfig { rows: r, cols: c, quant: Quant::Fp32 };
            let mut arr = SystolicArray::new(cfg);
            arr.program_weights(&vec![1.0; r * c], 1.0);
            let _ = arr.compute(&vec![1.0; m * r], m);
            let t = TileTiming::live(&cfg, m);
            (arr.last_compute_cycles == t.array_cycles
                && arr.last_program_words == t.prog_words,
             format!("m={m} r={r} c={c} sim={} form={}",
                     arr.last_compute_cycles, t.array_cycles))
        });
    }

    #[test]
    fn analytic_occupancy_matches_wavefront_active_pe_cycles() {
        // The occupancy==wavefront cross-check at single-tile scope: the
        // closed-form active/bubble split must equal the per-cycle
        // simulation's count of PEs inside the active anti-diagonal
        // band, exactly, on random shapes x array sizes x quant modes.
        check("occupancy == wavefront active PEs", 48, |rng| {
            let r = rng.index(7) + 1;
            let c = rng.index(7) + 1;
            let m = rng.index(10) + 1;
            let quant = if rng.chance(0.5) { Quant::Fp32 } else { Quant::Int8 };
            let cfg = ArrayConfig { rows: r, cols: c, quant };
            let mut arr = SystolicArray::new(cfg);
            arr.program_weights(&vec![1.0; r * c], 1.0);
            let _ = arr.compute(&vec![1.0; m * r], m);
            let t = TileTiming::live(&cfg, m);
            let n_pes = r * c;
            let ok = arr.last_active_pe_cycles == t.occ.active_pe_cycles
                && t.occ.active_pe_cycles + t.occ.bubble_pe_cycles
                    == t.array_cycles * n_pes
                && t.occ.stall_pe_cycles == t.prog_words * n_pes
                && t.occ.skipped_pe_cycles == 0;
            (ok, format!(
                "m={m} r={r} c={c} {quant:?} sim_active={} analytic={:?}",
                arr.last_active_pe_cycles, t.occ
            ))
        });
    }

    #[test]
    fn occupancy_constructors_are_consistent() {
        let cfg = ArrayConfig::square(8, Quant::Int8);
        let m = 24;
        let live = TileTiming::live(&cfg, m);
        assert_eq!(live.occ.active_pe_cycles, m * 64);
        assert_eq!(live.occ.bubble_pe_cycles, 14 * 64);
        assert_eq!(live.occ.stall_pe_cycles, live.prog_words * 64);
        // Reuse drops the reprogramming stall along with the words.
        let reuse = TileTiming::reuse(&cfg, m);
        assert_eq!(reuse.occ.stall_pe_cycles, 0);
        assert_eq!(reuse.occ.active_pe_cycles, live.occ.active_pe_cycles);
        // A skipped pass saves exactly the steady-state work and costs
        // nothing else.
        let skip = TileTiming::skipped_pass(&cfg, m, 3);
        assert_eq!(skip.occ.skipped_pe_cycles, 3 * m * 64);
        assert_eq!(skip.total_words(), 0);
        assert_eq!(skip.array_cycles, 0);
        assert_eq!(skip.macs, 0);
        // Utilization of the busy window: active / (active + bubble).
        let u = live.occ.utilization();
        assert!((u - m as f64 / (m + 14) as f64).abs() < 1e-12);
        assert_eq!(Occupancy::default().utilization(), 0.0);
        assert_eq!(live.occ.busy_pe_cycles(), live.array_cycles * 64);
    }

    #[test]
    fn add_accumulates() {
        let cfg = ArrayConfig::square(4, Quant::Fp32);
        let mut acc = TileTiming::skipped();
        acc.add(&TileTiming::live(&cfg, 8));
        acc.add(&TileTiming::live(&cfg, 8));
        assert_eq!(acc.macs, 2 * 8 * 16);
        assert_eq!(acc.prog_words, 32);
    }
}
