//! Closed-form per-tile cost model — the contract between the functional
//! array simulation (which validates these formulas on small tiles) and
//! the full-system simulator (which applies them millions of times).
//!
//! Costs per weight-stationary tile pass (array `R x C`, input block of
//! `M` rows):
//!
//! - **program**: `ceil(R*C / weights_per_word)` 32-bit bus writes
//!   (FP32: one weight per word; INT8: four, §3.2).
//! - **stream**: `M*R` input words in, `M*C` output words out; one input
//!   and one output activation move per custom instruction, so the
//!   instruction count is `M * max(R, C)` with perfect overlap.
//! - **array cycles**: `M + R + C - 2` (fill + stream + drain through the
//!   skew registers), validated against the per-cycle simulation.
//! - **MACs**: `M*R*C` (for energy accounting).
//!
//! A *skipped* (pruned) tile costs nothing — that is the SASP saving.

use super::ArrayConfig;

/// Cost of one tile pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TileTiming {
    /// 32-bit words written to program the weight tile.
    pub prog_words: usize,
    /// Input activation words streamed in.
    pub in_words: usize,
    /// Output activation words streamed out.
    pub out_words: usize,
    /// Custom stream-compute instructions issued.
    pub stream_insts: usize,
    /// Cycles the array itself is busy.
    pub array_cycles: usize,
    /// MAC operations performed.
    pub macs: usize,
}

impl TileTiming {
    /// Cost of programming + computing one live tile.
    pub fn live(cfg: &ArrayConfig, m: usize) -> TileTiming {
        let (r, c) = (cfg.rows, cfg.cols);
        TileTiming {
            prog_words: (r * c).div_ceil(cfg.quant.weights_per_word()),
            in_words: m * r,
            out_words: m * c,
            stream_insts: m * r.max(c),
            array_cycles: m + r + c - 2,
            macs: m * r * c,
        }
    }

    /// Cost of a pruned tile: fully skipped (§3.1 / Fig. 3) — no weight
    /// programming, no streaming, no compute.
    pub fn skipped() -> TileTiming {
        TileTiming::default()
    }

    /// Reuse of an already-programmed tile for another input block (the
    /// weight-stationary win when M is split across batches).
    pub fn reuse(cfg: &ArrayConfig, m: usize) -> TileTiming {
        let mut t = TileTiming::live(cfg, m);
        t.prog_words = 0;
        t
    }

    /// Cost of one live tile streamed by `batch` consecutive input
    /// blocks of `m` rows under weight-stationary reuse: programmed once
    /// ([`Self::live`]), then reused for the remaining `batch - 1`
    /// blocks ([`Self::reuse`]). This is the closed form the batched
    /// serving engine ([`crate::infer::batch`]) charges per live tile —
    /// the cross-utterance saving is exactly `(batch-1) * prog_words`.
    pub fn batched(cfg: &ArrayConfig, m: usize, batch: usize) -> TileTiming {
        assert!(batch > 0, "a batched tile pass needs at least one block");
        let live = TileTiming::live(cfg, m);
        TileTiming {
            prog_words: live.prog_words,
            in_words: batch * live.in_words,
            out_words: batch * live.out_words,
            stream_insts: batch * live.stream_insts,
            array_cycles: batch * live.array_cycles,
            macs: batch * live.macs,
        }
    }

    /// Accumulate another tile's cost.
    pub fn add(&mut self, other: &TileTiming) {
        self.prog_words += other.prog_words;
        self.in_words += other.in_words;
        self.out_words += other.out_words;
        self.stream_insts += other.stream_insts;
        self.array_cycles += other.array_cycles;
        self.macs += other.macs;
    }

    /// Total 32-bit bus words moved (weights + activations).
    pub fn total_words(&self) -> usize {
        self.prog_words + self.in_words + self.out_words
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::{ArrayConfig, Quant, SystolicArray};
    use crate::util::prop::check;

    #[test]
    fn live_tile_counts_8x8() {
        let cfg = ArrayConfig::square(8, Quant::Fp32);
        let t = TileTiming::live(&cfg, 32);
        assert_eq!(t.prog_words, 64);
        assert_eq!(t.in_words, 32 * 8);
        assert_eq!(t.out_words, 32 * 8);
        assert_eq!(t.stream_insts, 32 * 8);
        assert_eq!(t.array_cycles, 32 + 8 + 8 - 2);
        assert_eq!(t.macs, 32 * 64);
    }

    #[test]
    fn int8_packs_four_weights_per_word() {
        let cfg = ArrayConfig::square(8, Quant::Int8);
        assert_eq!(TileTiming::live(&cfg, 1).prog_words, 16);
        let odd = ArrayConfig { rows: 3, cols: 3, quant: Quant::Int8 };
        assert_eq!(TileTiming::live(&odd, 1).prog_words, 3); // ceil(9/4)
    }

    #[test]
    fn skipped_tile_is_free() {
        assert_eq!(TileTiming::skipped().total_words(), 0);
        assert_eq!(TileTiming::skipped().array_cycles, 0);
    }

    #[test]
    fn reuse_drops_programming_only() {
        let cfg = ArrayConfig::square(4, Quant::Fp32);
        let live = TileTiming::live(&cfg, 16);
        let reuse = TileTiming::reuse(&cfg, 16);
        assert_eq!(reuse.prog_words, 0);
        assert_eq!(reuse.in_words, live.in_words);
        assert_eq!(reuse.array_cycles, live.array_cycles);
    }

    #[test]
    fn batched_is_live_plus_reuse() {
        // The batched closed form is exactly one programming pass plus
        // batch-1 reuse passes — elementwise, for every field.
        for quant in [Quant::Fp32, Quant::Int8] {
            let cfg = ArrayConfig::square(8, quant);
            for (m, b) in [(1usize, 1usize), (16, 2), (96, 4), (7, 5)] {
                let got = TileTiming::batched(&cfg, m, b);
                let mut want = TileTiming::live(&cfg, m);
                for _ in 1..b {
                    want.add(&TileTiming::reuse(&cfg, m));
                }
                assert_eq!(got, want, "m={m} b={b} {quant:?}");
            }
            assert_eq!(
                TileTiming::batched(&cfg, 32, 1),
                TileTiming::live(&cfg, 32),
                "batch 1 degenerates to a plain live pass"
            );
        }
    }

    #[test]
    fn batched_saving_is_programming_only() {
        let cfg = ArrayConfig::square(8, Quant::Int8);
        let (m, b) = (24usize, 6usize);
        let per_utt = TileTiming::live(&cfg, m);
        let batched = TileTiming::batched(&cfg, m, b);
        // Streaming/compute scale with the batch; programming does not.
        assert_eq!(batched.in_words, b * per_utt.in_words);
        assert_eq!(batched.macs, b * per_utt.macs);
        assert_eq!(batched.prog_words, per_utt.prog_words);
        assert_eq!(
            b * per_utt.total_words() - batched.total_words(),
            (b - 1) * per_utt.prog_words,
            "the reuse saving is exactly (batch-1) programming passes"
        );
    }

    #[test]
    fn closed_form_matches_cycle_simulation() {
        check("timing == per-cycle sim", 20, |rng| {
            let r = rng.index(6) + 1;
            let c = rng.index(6) + 1;
            let m = rng.index(8) + 1;
            let cfg = ArrayConfig { rows: r, cols: c, quant: Quant::Fp32 };
            let mut arr = SystolicArray::new(cfg);
            arr.program_weights(&vec![1.0; r * c], 1.0);
            let _ = arr.compute(&vec![1.0; m * r], m);
            let t = TileTiming::live(&cfg, m);
            (arr.last_compute_cycles == t.array_cycles
                && arr.last_program_words == t.prog_words,
             format!("m={m} r={r} c={c} sim={} form={}",
                     arr.last_compute_cycles, t.array_cycles))
        });
    }

    #[test]
    fn add_accumulates() {
        let cfg = ArrayConfig::square(4, Quant::Fp32);
        let mut acc = TileTiming::skipped();
        acc.add(&TileTiming::live(&cfg, 8));
        acc.add(&TileTiming::live(&cfg, 8));
        assert_eq!(acc.macs, 2 * 8 * 16);
        assert_eq!(acc.prog_words, 32);
    }
}
