//! Cycle-level model of the paper's weight-stationary systolic array
//! (Fig. 4): a mesh of MAC processing elements with nearest-neighbor
//! links, stationary weights, left-to-right input streaming, top-to-bottom
//! partial-sum flow, and diagonal skew registers at the periphery.
//!
//! Two views of the same hardware:
//!
//! - [`array::SystolicArray`] — a functional *per-cycle* simulation used
//!   to validate numerics (including the hybrid FP32×INT8 PE) and to
//!   cross-check the closed-form cycle counts on small tiles.
//! - [`scheduler::TileScheduler`] — whole masked GEMMs executed
//!   functionally on one array (tile grid + pruning skips), the
//!   cross-validation bridge to the analytic layer.
//! - [`timing`] — closed-form per-tile cycle/transfer counts used by the
//!   full-system simulator ([`crate::sysim`]), where per-cycle simulation
//!   of full transformer inference would be intractable.

pub mod array;
pub mod pe;
pub mod scheduler;
pub mod timing;

pub use array::SystolicArray;
pub use pe::{Pe, PeWeight};
pub use scheduler::{ScheduleStats, TileScheduler};
pub use timing::{Occupancy, TileTiming};

/// Weight data format of the array instance (paper: FP32_FP32 vs
/// FP32_INT8; activations are always FP32).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Quant {
    /// FP32 weights, one weight per 32-bit bus word.
    Fp32,
    /// Sign-magnitude INT8 weights, four per 32-bit bus word, hybrid
    /// multiplier PEs.
    Int8,
}

impl Quant {
    /// Weights transferred per 32-bit bus access (§3.2).
    pub fn weights_per_word(self) -> usize {
        match self {
            Quant::Fp32 => 1,
            Quant::Int8 => 4,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Quant::Fp32 => "FP32_FP32",
            Quant::Int8 => "FP32_INT8",
        }
    }
}

/// Geometry + format of one array instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ArrayConfig {
    /// Rows (= SASP tile K-dimension).
    pub rows: usize,
    /// Columns (= SASP tile N-dimension).
    pub cols: usize,
    pub quant: Quant,
}

impl ArrayConfig {
    pub fn square(n: usize, quant: Quant) -> Self {
        ArrayConfig { rows: n, cols: n, quant }
    }

    pub fn n_pes(&self) -> usize {
        self.rows * self.cols
    }

    /// SASP tile dimension (paper uses square arrays; asserted here).
    pub fn tile(&self) -> usize {
        assert_eq!(self.rows, self.cols, "SASP uses square arrays");
        self.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_per_word() {
        assert_eq!(Quant::Fp32.weights_per_word(), 1);
        assert_eq!(Quant::Int8.weights_per_word(), 4);
    }

    #[test]
    fn config_basics() {
        let c = ArrayConfig::square(8, Quant::Int8);
        assert_eq!(c.n_pes(), 64);
        assert_eq!(c.tile(), 8);
        assert_eq!(c.quant.label(), "FP32_INT8");
    }
}
