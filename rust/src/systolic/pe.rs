//! One processing element: a stationary weight register, a multiplier
//! (FP32 or hybrid FP32×INT8), an FP32 adder, and the dataflow registers
//! that pass the activation right and the partial sum down.

use crate::arith::{ftz_add, ftz_mul, hybrid_mul, SignMag8};

/// The stationary weight held by a PE.
#[derive(Clone, Copy, Debug)]
pub enum PeWeight {
    Fp32(f32),
    /// Sign-magnitude INT8 plus the per-tensor dequantization scale, which
    /// in the real datapath is folded outside the array; the functional
    /// model applies it at output readout (see `scale_out`).
    Int8(SignMag8),
}

impl PeWeight {
    pub fn is_zero(&self) -> bool {
        match self {
            PeWeight::Fp32(w) => *w == 0.0,
            PeWeight::Int8(w) => w.is_zero(),
        }
    }
}

/// Functional PE state for the per-cycle simulation.
#[derive(Clone, Debug)]
pub struct Pe {
    pub weight: PeWeight,
    /// Activation register (flows left→right).
    pub x_reg: f32,
    /// Partial-sum register (flows top→bottom).
    pub psum_reg: f32,
}

impl Pe {
    pub fn new(weight: PeWeight) -> Self {
        Pe { weight, x_reg: 0.0, psum_reg: 0.0 }
    }

    /// One cycle: consume `x_in` (from the left) and `psum_in` (from
    /// above), produce the registered outputs for the next cycle.
    ///
    /// The RTL pipelines the multiplier+adder; latency is hidden by the
    /// streaming I/O (§3.3), so the functional model computes the MAC
    /// combinationally and the *timing* model accounts for fill/drain.
    pub fn step(&mut self, x_in: f32, psum_in: f32) -> (f32, f32) {
        let prod = match self.weight {
            PeWeight::Fp32(w) => ftz_mul(x_in, w),
            PeWeight::Int8(w) => hybrid_mul(x_in, w),
        };
        let psum_out = ftz_add(psum_in, prod);
        let x_out = self.x_reg;
        self.x_reg = x_in;
        self.psum_reg = psum_out;
        (x_out, psum_out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_mac() {
        let mut pe = Pe::new(PeWeight::Fp32(2.0));
        let (_, psum) = pe.step(3.0, 1.0);
        assert_eq!(psum, 7.0);
    }

    #[test]
    fn int8_mac_uses_hybrid_multiplier() {
        let mut pe = Pe::new(PeWeight::Int8(SignMag8::from_i8(-3)));
        let (_, psum) = pe.step(2.0, 0.5);
        assert_eq!(psum, 0.5 - 6.0);
    }

    #[test]
    fn x_propagates_with_one_cycle_delay() {
        let mut pe = Pe::new(PeWeight::Fp32(0.0));
        let (x0, _) = pe.step(5.0, 0.0);
        assert_eq!(x0, 0.0); // register starts empty
        let (x1, _) = pe.step(7.0, 0.0);
        assert_eq!(x1, 5.0);
    }

    #[test]
    fn zero_weight_passes_psum() {
        let mut pe = Pe::new(PeWeight::Int8(SignMag8::from_i8(0)));
        let (_, psum) = pe.step(123.0, 4.5);
        assert_eq!(psum, 4.5);
    }
}
