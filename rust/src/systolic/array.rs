//! Functional per-cycle simulation of the weight-stationary mesh.
//!
//! The peripheral skew registers of Fig. 4 (which delay row r's input
//! stream by r cycles and de-skew the outputs) are modeled by the
//! injection/collection schedule; the mesh itself is simulated register
//! by register, PE by PE, so numerics — including FTZ float behaviour and
//! the hybrid multiplier's truncation — are exactly those of the RTL.

use crate::arith::SignMag8;

use super::pe::{Pe, PeWeight};
use super::{ArrayConfig, Quant};

/// A configured array instance holding a programmed weight tile.
pub struct SystolicArray {
    pub cfg: ArrayConfig,
    pes: Vec<Pe>,
    /// Dequantization scale applied at output readout (INT8 mode).
    scale: f32,
    /// Cycles consumed by the last `compute` call.
    pub last_compute_cycles: usize,
    /// 32-bit bus words consumed by the last `program_weights` call.
    pub last_program_words: usize,
}

impl SystolicArray {
    pub fn new(cfg: ArrayConfig) -> Self {
        let pes = (0..cfg.n_pes())
            .map(|_| Pe::new(PeWeight::Fp32(0.0)))
            .collect();
        SystolicArray {
            cfg,
            pes,
            scale: 1.0,
            last_compute_cycles: 0,
            last_program_words: 0,
        }
    }

    fn idx(&self, r: usize, c: usize) -> usize {
        r * self.cfg.cols + c
    }

    /// Program a weight tile (row-major `rows x cols`). In INT8 mode the
    /// f32 weights are quantized with the given per-tensor scale
    /// (`w_q = round(w / scale)`), mirroring the PTQ path.
    ///
    /// Returns the number of 32-bit bus words transferred — `R*C` for
    /// FP32, `ceil(R*C/4)` for INT8 (four weights packed per word, §3.2).
    pub fn program_weights(&mut self, tile: &[f32], scale: f32) -> usize {
        assert_eq!(tile.len(), self.cfg.n_pes());
        self.scale = scale;
        for r in 0..self.cfg.rows {
            for c in 0..self.cfg.cols {
                let w = tile[r * self.cfg.cols + c];
                let pw = match self.cfg.quant {
                    Quant::Fp32 => PeWeight::Fp32(w),
                    Quant::Int8 => {
                        let q = (w / scale).round_ties_even().clamp(-127.0, 127.0);
                        PeWeight::Int8(SignMag8::from_i8(q as i8))
                    }
                };
                let i = self.idx(r, c);
                self.pes[i] = Pe::new(pw);
            }
        }
        let words = self.cfg.n_pes().div_ceil(self.cfg.quant.weights_per_word());
        self.last_program_words = words;
        words
    }

    /// Stream an `m x rows` input block through the array cycle by cycle;
    /// returns the `m x cols` output block (de-skewed) and records the
    /// cycle count (`m + rows + cols - 2`).
    pub fn compute(&mut self, x: &[f32], m: usize) -> Vec<f32> {
        let (rows, cols) = (self.cfg.rows, self.cfg.cols);
        assert_eq!(x.len(), m * rows);
        let total_cycles = m + rows + cols - 2;
        let mut out = vec![0.0f32; m * cols];

        // Double-buffered register state.
        let mut x_regs = vec![0.0f32; rows * cols];
        let mut psum_regs = vec![0.0f32; rows * cols];

        for t in 0..total_cycles {
            let x_prev = x_regs.clone();
            let psum_prev = psum_regs.clone();
            for r in 0..rows {
                for c in 0..cols {
                    // Left edge: the skew registers deliver x[t-r][r].
                    let x_in = if c == 0 {
                        if t >= r && t - r < m {
                            x[(t - r) * rows + r]
                        } else {
                            0.0
                        }
                    } else {
                        x_prev[self.idx(r, c - 1)]
                    };
                    let psum_in = if r == 0 {
                        0.0
                    } else {
                        psum_prev[self.idx(r - 1, c)]
                    };
                    let i = self.idx(r, c);
                    let (_, psum_out) = {
                        // step() updates the PE's internal registers; we
                        // mirror them into the double buffers.
                        let pe = &mut self.pes[i];
                        pe.x_reg = 0.0; // value comes from x_prev buffer
                        pe.step(x_in, psum_in)
                    };
                    x_regs[i] = x_in;
                    psum_regs[i] = psum_out;
                }
            }
            // Collect de-skewed outputs from the bottom row.
            for c in 0..cols {
                if t >= rows - 1 + c {
                    let mrow = t - (rows - 1) - c;
                    if mrow < m {
                        let v = psum_regs[self.idx(rows - 1, c)];
                        out[mrow * cols + c] = match self.cfg.quant {
                            Quant::Fp32 => v,
                            Quant::Int8 => v * self.scale,
                        };
                    }
                }
            }
        }
        self.last_compute_cycles = total_cycles;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += x[i * k + kk] * w[kk * n + j];
                }
                y[i * n + j] = acc;
            }
        }
        y
    }

    #[test]
    fn identity_weights_pass_inputs() {
        let cfg = ArrayConfig::square(4, Quant::Fp32);
        let mut arr = SystolicArray::new(cfg);
        let mut eye = vec![0.0f32; 16];
        for i in 0..4 {
            eye[i * 4 + i] = 1.0;
        }
        arr.program_weights(&eye, 1.0);
        let x: Vec<f32> = (0..8).map(|v| v as f32).collect(); // 2x4
        let y = arr.compute(&x, 2);
        assert_eq!(y, x);
    }

    #[test]
    fn cycle_count_closed_form() {
        let cfg = ArrayConfig { rows: 3, cols: 5, quant: Quant::Fp32 };
        let mut arr = SystolicArray::new(cfg);
        arr.program_weights(&vec![1.0; 15], 1.0);
        let _ = arr.compute(&vec![1.0; 7 * 3], 7);
        assert_eq!(arr.last_compute_cycles, 7 + 3 + 5 - 2);
    }

    #[test]
    fn program_words_fp32_vs_int8() {
        let mut a = SystolicArray::new(ArrayConfig::square(8, Quant::Fp32));
        assert_eq!(a.program_weights(&vec![0.5; 64], 1.0), 64);
        let mut b = SystolicArray::new(ArrayConfig::square(8, Quant::Int8));
        assert_eq!(b.program_weights(&vec![0.5; 64], 0.01), 16);
    }

    #[test]
    fn fp32_matches_reference_matmul() {
        check("systolic fp32 == matmul", 24, |rng: &mut Rng| {
            let (m, r, c) = (rng.index(6) + 1, rng.index(5) + 1, rng.index(5) + 1);
            let x: Vec<f32> = (0..m * r).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..r * c).map(|_| rng.normal() as f32).collect();
            let mut arr = SystolicArray::new(ArrayConfig {
                rows: r,
                cols: c,
                quant: Quant::Fp32,
            });
            arr.program_weights(&w, 1.0);
            let got = arr.compute(&x, m);
            let want = matmul(&x, &w, m, r, c);
            let ok = got
                .iter()
                .zip(&want)
                .all(|(g, w)| (g - w).abs() <= 1e-4 * w.abs().max(1.0));
            (ok, format!("m={m} r={r} c={c} got={got:?} want={want:?}"))
        });
    }

    #[test]
    fn int8_matches_quantized_reference() {
        check("systolic int8 == dequant matmul", 16, |rng: &mut Rng| {
            let (m, n) = (rng.index(4) + 1, rng.index(3) + 2);
            let x: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
            let amax = w.iter().fold(0.0f32, |a, v| a.max(v.abs()));
            let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            let mut arr = SystolicArray::new(ArrayConfig {
                rows: n,
                cols: n,
                quant: Quant::Int8,
            });
            arr.program_weights(&w, scale);
            let got = arr.compute(&x, m);
            // Reference: quantize, dequantize, matmul.
            let wq: Vec<f32> = w
                .iter()
                .map(|v| {
                    (v / scale).round_ties_even().clamp(-127.0, 127.0) * scale
                })
                .collect();
            let want = matmul(&x, &wq, m, n, n);
            let ok = got.iter().zip(&want).all(|(g, w)| {
                (g - w).abs() <= 2e-3 * w.abs().max(1.0)
            });
            (ok, format!("m={m} n={n}"))
        });
    }

    #[test]
    fn zero_tile_outputs_zero() {
        let mut arr = SystolicArray::new(ArrayConfig::square(4, Quant::Fp32));
        arr.program_weights(&vec![0.0; 16], 1.0);
        let y = arr.compute(&vec![3.0; 4 * 4], 4);
        assert!(y.iter().all(|v| *v == 0.0));
    }
}
