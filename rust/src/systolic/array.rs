//! Functional per-cycle simulation of the weight-stationary mesh.
//!
//! The peripheral skew registers of Fig. 4 (which delay row r's input
//! stream by r cycles and de-skew the outputs) are modeled by the
//! injection/collection schedule; the mesh itself is simulated register
//! by register, PE by PE, so numerics — including FTZ float behaviour and
//! the hybrid multiplier's truncation — are exactly those of the RTL.
//!
//! §Perf: this is the innermost loop of the functional layer (the tile
//! scheduler calls it once per live tile). Three structural properties
//! keep it allocation-free and work-proportional without changing a
//! single output bit:
//!
//! 1. **Preallocated double buffers.** The x/psum register planes are
//!    two pairs of `Vec`s owned by the array, swapped with
//!    [`std::mem::swap`] each cycle. The seed implementation cloned both
//!    planes *per simulated cycle* — two heap allocations plus two
//!    memcpys per cycle.
//! 2. **Wavefront iteration.** At cycle `t`, only PEs on the active
//!    anti-diagonals `t-m+1 <= r+c <= t` carry data: the value `x[i][r]`
//!    enters PE `(r,0)` at cycle `i+r` and reaches `(r,c)` at `i+r+c`,
//!    so a PE outside that band only moves zeros. Skipping it is
//!    bit-identical because (a) an active PE's left/top neighbours were
//!    active one cycle earlier (the band shifts by one per cycle), so
//!    every register an active PE reads was written on the previous
//!    cycle, and (b) outputs are only collected inside the band.
//! 3. **In-place weight reprogramming.** `program_weights` rewrites the
//!    stationary-weight storage (kept in quant-specialized arrays so the
//!    MAC loop has no per-element enum dispatch) instead of
//!    reconstructing the PE vector.
//!
//! The per-PE datapath is the same `ftz_mul`/`hybrid_mul` + `ftz_add`
//! sequence as [`super::pe::Pe::step`] — `Pe` remains the documented
//! single-PE reference model and is cross-checked in the tests below.

use crate::arith::{ftz_add, ftz_mul, hybrid_mul, SignMag8};

use super::{ArrayConfig, Quant};

/// A configured array instance holding a programmed weight tile.
pub struct SystolicArray {
    pub cfg: ArrayConfig,
    /// Stationary weights, row-major (FP32 mode).
    w_fp32: Vec<f32>,
    /// Stationary weights, row-major (INT8 mode).
    w_int8: Vec<SignMag8>,
    /// Dequantization scale applied at output readout (INT8 mode).
    scale: f32,
    // Double-buffered register planes, allocated once per array and
    // reused across `compute` calls (zeroed at the start of each call).
    x_cur: Vec<f32>,
    x_nxt: Vec<f32>,
    psum_cur: Vec<f32>,
    psum_nxt: Vec<f32>,
    /// Cycles consumed by the last `compute` call.
    pub last_compute_cycles: usize,
    /// 32-bit bus words consumed by the last `program_weights` call.
    pub last_program_words: usize,
    /// PE-cycles spent inside the active anti-diagonal band during the
    /// last `compute` call — the simulated ground truth for the
    /// closed-form [`super::Occupancy`] active count.
    pub last_active_pe_cycles: usize,
}

impl SystolicArray {
    pub fn new(cfg: ArrayConfig) -> Self {
        let n = cfg.n_pes();
        SystolicArray {
            cfg,
            w_fp32: vec![0.0; if cfg.quant == Quant::Fp32 { n } else { 0 }],
            w_int8: vec![
                SignMag8::from_i8(0);
                if cfg.quant == Quant::Int8 { n } else { 0 }
            ],
            scale: 1.0,
            x_cur: vec![0.0; n],
            x_nxt: vec![0.0; n],
            psum_cur: vec![0.0; n],
            psum_nxt: vec![0.0; n],
            last_compute_cycles: 0,
            last_program_words: 0,
            last_active_pe_cycles: 0,
        }
    }

    /// Program a weight tile (row-major `rows x cols`). In INT8 mode the
    /// f32 weights are quantized with the given per-tensor scale
    /// (`w_q = round(w / scale)`), mirroring the PTQ path. Reprograms the
    /// stationary storage in place — no allocation after the first call.
    ///
    /// Returns the number of 32-bit bus words transferred — `R*C` for
    /// FP32, `ceil(R*C/4)` for INT8 (four weights packed per word, §3.2).
    pub fn program_weights(&mut self, tile: &[f32], scale: f32) -> usize {
        assert_eq!(tile.len(), self.cfg.n_pes());
        self.scale = scale;
        match self.cfg.quant {
            Quant::Fp32 => {
                self.w_fp32.clear();
                self.w_fp32.extend_from_slice(tile);
            }
            Quant::Int8 => {
                self.w_int8.clear();
                self.w_int8.extend(tile.iter().map(|w| {
                    let q = (w / scale).round_ties_even().clamp(-127.0, 127.0);
                    SignMag8::from_i8(q as i8)
                }));
            }
        }
        let words = self.cfg.n_pes().div_ceil(self.cfg.quant.weights_per_word());
        self.last_program_words = words;
        words
    }

    /// Stream an `m x rows` input block through the array cycle by cycle;
    /// returns the `m x cols` output block (de-skewed) and records the
    /// cycle count (`m + rows + cols - 2`).
    pub fn compute(&mut self, x: &[f32], m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * self.cfg.cols];
        self.compute_into(x, m, &mut out);
        out
    }

    /// Zero-allocation variant of [`compute`](Self::compute): writes the
    /// de-skewed `m x cols` output block into `out` (which must have
    /// exactly that length).
    pub fn compute_into(&mut self, x: &[f32], m: usize, out: &mut [f32]) {
        let (rows, cols) = (self.cfg.rows, self.cfg.cols);
        assert!(m > 0, "empty input block");
        assert_eq!(x.len(), m * rows);
        assert_eq!(out.len(), m * cols);
        let total_cycles = m + rows + cols - 2;

        // Take the register planes out of `self` so the cycle loop can
        // borrow weights immutably alongside them; restored below.
        let mut x_cur = std::mem::take(&mut self.x_cur);
        let mut x_nxt = std::mem::take(&mut self.x_nxt);
        let mut psum_cur = std::mem::take(&mut self.psum_cur);
        let mut psum_nxt = std::mem::take(&mut self.psum_nxt);
        for plane in [&mut x_cur, &mut x_nxt, &mut psum_cur, &mut psum_nxt] {
            plane.clear();
            plane.resize(rows * cols, 0.0);
        }

        let scale = self.scale;
        let active = match self.cfg.quant {
            Quant::Fp32 => {
                let w = &self.w_fp32;
                wavefront(
                    x,
                    m,
                    rows,
                    cols,
                    total_cycles,
                    &mut x_cur,
                    &mut x_nxt,
                    &mut psum_cur,
                    &mut psum_nxt,
                    out,
                    |x_in, i| ftz_mul(x_in, w[i]),
                    |v| v,
                )
            }
            Quant::Int8 => {
                let w = &self.w_int8;
                wavefront(
                    x,
                    m,
                    rows,
                    cols,
                    total_cycles,
                    &mut x_cur,
                    &mut x_nxt,
                    &mut psum_cur,
                    &mut psum_nxt,
                    out,
                    |x_in, i| hybrid_mul(x_in, w[i]),
                    |v| v * scale,
                )
            }
        };

        self.x_cur = x_cur;
        self.x_nxt = x_nxt;
        self.psum_cur = psum_cur;
        self.psum_nxt = psum_nxt;
        self.last_compute_cycles = total_cycles;
        self.last_active_pe_cycles = active;
    }
}

/// The shared cycle loop, monomorphized per weight format. `mul` is the
/// PE multiplier `(x_in, pe_index) -> product`; `dequant` is the output
/// readout transform (identity for FP32, `* scale` for INT8). Returns
/// the number of PE-cycles spent inside the active band — the simulated
/// occupancy the closed-form model is cross-checked against.
#[allow(clippy::too_many_arguments)]
fn wavefront(
    x: &[f32],
    m: usize,
    rows: usize,
    cols: usize,
    total_cycles: usize,
    x_cur: &mut Vec<f32>,
    x_nxt: &mut Vec<f32>,
    psum_cur: &mut Vec<f32>,
    psum_nxt: &mut Vec<f32>,
    out: &mut [f32],
    mul: impl Fn(f32, usize) -> f32,
    dequant: impl Fn(f32) -> f32,
) -> usize {
    let mut active_pe_cycles = 0usize;
    for t in 0..total_cycles {
        // Active anti-diagonal band: lo <= r+c <= hi.
        let lo = (t + 1).saturating_sub(m);
        let hi = t.min(rows + cols - 2);

        let r_first = lo.saturating_sub(cols - 1);
        let r_last = rows.min(hi + 1); // exclusive
        for r in r_first..r_last {
            let c_first = lo.saturating_sub(r);
            let c_last = cols.min(hi + 1 - r); // exclusive; r <= hi here
            active_pe_cycles += c_last.saturating_sub(c_first);
            let base = r * cols;
            for c in c_first..c_last {
                let i = base + c;
                // Left edge: the skew registers deliver x[t-r][r]; the
                // band guarantees 0 <= t-r < m when c == 0.
                let x_in = if c == 0 { x[(t - r) * rows + r] } else { x_cur[i - 1] };
                let psum_in = if r == 0 { 0.0 } else { psum_cur[i - cols] };
                let psum_out = ftz_add(psum_in, mul(x_in, i));
                x_nxt[i] = x_in;
                psum_nxt[i] = psum_out;
            }
        }

        // Collect de-skewed outputs from the bottom row (they were
        // computed this cycle, i.e. live in the `nxt` plane).
        if t + 1 >= rows {
            let c_first = lo.saturating_sub(rows - 1);
            let c_last = cols.min(hi + 2 - rows); // exclusive
            let bottom = (rows - 1) * cols;
            for c in c_first..c_last {
                let mrow = t + 1 - rows - c;
                out[mrow * cols + c] = dequant(psum_nxt[bottom + c]);
            }
        }

        std::mem::swap(x_cur, x_nxt);
        std::mem::swap(psum_cur, psum_nxt);
    }
    active_pe_cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::Pe;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += x[i * k + kk] * w[kk * n + j];
                }
                y[i * n + j] = acc;
            }
        }
        y
    }

    /// The seed's exhaustive simulation — every PE stepped every cycle
    /// through the reference [`Pe`] model — kept as the oracle the
    /// wavefront implementation must match bit for bit.
    fn dense_reference(
        cfg: &ArrayConfig,
        tile: &[f32],
        scale: f32,
        x: &[f32],
        m: usize,
    ) -> Vec<f32> {
        use super::super::pe::PeWeight;
        let (rows, cols) = (cfg.rows, cfg.cols);
        let mut pes: Vec<Pe> = tile
            .iter()
            .map(|w| {
                Pe::new(match cfg.quant {
                    Quant::Fp32 => PeWeight::Fp32(*w),
                    Quant::Int8 => {
                        let q = (w / scale).round_ties_even().clamp(-127.0, 127.0);
                        PeWeight::Int8(SignMag8::from_i8(q as i8))
                    }
                })
            })
            .collect();
        let total_cycles = m + rows + cols - 2;
        let mut out = vec![0.0f32; m * cols];
        let mut x_regs = vec![0.0f32; rows * cols];
        let mut psum_regs = vec![0.0f32; rows * cols];
        for t in 0..total_cycles {
            let x_prev = x_regs.clone();
            let psum_prev = psum_regs.clone();
            for r in 0..rows {
                for c in 0..cols {
                    let i = r * cols + c;
                    let x_in = if c == 0 {
                        if t >= r && t - r < m { x[(t - r) * rows + r] } else { 0.0 }
                    } else {
                        x_prev[i - 1]
                    };
                    let psum_in = if r == 0 { 0.0 } else { psum_prev[i - cols] };
                    let (_, psum_out) = pes[i].step(x_in, psum_in);
                    x_regs[i] = x_in;
                    psum_regs[i] = psum_out;
                }
            }
            for c in 0..cols {
                if t >= rows - 1 + c {
                    let mrow = t - (rows - 1) - c;
                    if mrow < m {
                        let v = psum_regs[(rows - 1) * cols + c];
                        out[mrow * cols + c] = match cfg.quant {
                            Quant::Fp32 => v,
                            Quant::Int8 => v * scale,
                        };
                    }
                }
            }
        }
        out
    }

    #[test]
    fn identity_weights_pass_inputs() {
        let cfg = ArrayConfig::square(4, Quant::Fp32);
        let mut arr = SystolicArray::new(cfg);
        let mut eye = vec![0.0f32; 16];
        for i in 0..4 {
            eye[i * 4 + i] = 1.0;
        }
        arr.program_weights(&eye, 1.0);
        let x: Vec<f32> = (0..8).map(|v| v as f32).collect(); // 2x4
        let y = arr.compute(&x, 2);
        assert_eq!(y, x);
    }

    #[test]
    fn cycle_count_closed_form() {
        let cfg = ArrayConfig { rows: 3, cols: 5, quant: Quant::Fp32 };
        let mut arr = SystolicArray::new(cfg);
        arr.program_weights(&vec![1.0; 15], 1.0);
        let _ = arr.compute(&vec![1.0; 7 * 3], 7);
        assert_eq!(arr.last_compute_cycles, 7 + 3 + 5 - 2);
    }

    #[test]
    fn program_words_fp32_vs_int8() {
        let mut a = SystolicArray::new(ArrayConfig::square(8, Quant::Fp32));
        assert_eq!(a.program_weights(&vec![0.5; 64], 1.0), 64);
        let mut b = SystolicArray::new(ArrayConfig::square(8, Quant::Int8));
        assert_eq!(b.program_weights(&vec![0.5; 64], 0.01), 16);
    }

    #[test]
    fn fp32_matches_reference_matmul() {
        check("systolic fp32 == matmul", 24, |rng: &mut Rng| {
            let (m, r, c) = (rng.index(6) + 1, rng.index(5) + 1, rng.index(5) + 1);
            let x: Vec<f32> = (0..m * r).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..r * c).map(|_| rng.normal() as f32).collect();
            let mut arr = SystolicArray::new(ArrayConfig {
                rows: r,
                cols: c,
                quant: Quant::Fp32,
            });
            arr.program_weights(&w, 1.0);
            let got = arr.compute(&x, m);
            let want = matmul(&x, &w, m, r, c);
            let ok = got
                .iter()
                .zip(&want)
                .all(|(g, w)| (g - w).abs() <= 1e-4 * w.abs().max(1.0));
            (ok, format!("m={m} r={r} c={c} got={got:?} want={want:?}"))
        });
    }

    #[test]
    fn int8_matches_quantized_reference() {
        check("systolic int8 == dequant matmul", 16, |rng: &mut Rng| {
            let (m, n) = (rng.index(4) + 1, rng.index(3) + 2);
            let x: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
            let amax = w.iter().fold(0.0f32, |a, v| a.max(v.abs()));
            let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            let mut arr = SystolicArray::new(ArrayConfig {
                rows: n,
                cols: n,
                quant: Quant::Int8,
            });
            arr.program_weights(&w, scale);
            let got = arr.compute(&x, m);
            // Reference: quantize, dequantize, matmul.
            let wq: Vec<f32> = w
                .iter()
                .map(|v| {
                    (v / scale).round_ties_even().clamp(-127.0, 127.0) * scale
                })
                .collect();
            let want = matmul(&x, &wq, m, n, n);
            let ok = got.iter().zip(&want).all(|(g, w)| {
                (g - w).abs() <= 2e-3 * w.abs().max(1.0)
            });
            (ok, format!("m={m} n={n}"))
        });
    }

    #[test]
    fn zero_tile_outputs_zero() {
        let mut arr = SystolicArray::new(ArrayConfig::square(4, Quant::Fp32));
        arr.program_weights(&vec![0.0; 16], 1.0);
        let y = arr.compute(&vec![3.0; 4 * 4], 4);
        assert!(y.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn wavefront_bit_identical_to_dense_reference() {
        // The perf rewrite must not change a single output bit vs the
        // exhaustive every-PE-every-cycle simulation (both quant modes,
        // rectangular arrays, M above and below the array dimension).
        check("wavefront == dense per-cycle sim (bitwise)", 32, |rng: &mut Rng| {
            let (m, r, c) =
                (rng.index(10) + 1, rng.index(6) + 1, rng.index(6) + 1);
            let quant = if rng.chance(0.5) { Quant::Fp32 } else { Quant::Int8 };
            let cfg = ArrayConfig { rows: r, cols: c, quant };
            let x: Vec<f32> = (0..m * r).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..r * c).map(|_| rng.normal() as f32).collect();
            let amax = w.iter().fold(0.0f32, |a, v| a.max(v.abs()));
            let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            let mut arr = SystolicArray::new(cfg);
            arr.program_weights(&w, scale);
            let got = arr.compute(&x, m);
            let want = dense_reference(&cfg, &w, scale, &x, m);
            let same = got
                .iter()
                .zip(&want)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            (same, format!("m={m} r={r} c={c} {quant:?} got={got:?} want={want:?}"))
        });
    }

    #[test]
    fn compute_into_matches_compute_and_reuses_buffers() {
        let mut rng = Rng::new(11);
        let cfg = ArrayConfig::square(8, Quant::Int8);
        let mut arr = SystolicArray::new(cfg);
        let w: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        arr.program_weights(&w, 0.01);
        let mut out = vec![0.0f32; 32 * 8];
        for trial in 0..3 {
            let x: Vec<f32> = (0..32 * 8).map(|_| rng.normal() as f32).collect();
            arr.compute_into(&x, 32, &mut out);
            let want = arr.compute(&x, 32);
            assert_eq!(out, want, "trial {trial}");
        }
    }

    #[test]
    fn reprogramming_reuses_state_cleanly() {
        // Back-to-back program/compute cycles on one array must behave
        // like fresh arrays (no stale register or weight state).
        let mut rng = Rng::new(5);
        let cfg = ArrayConfig::square(4, Quant::Fp32);
        let mut arr = SystolicArray::new(cfg);
        for _ in 0..4 {
            let w: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
            let x: Vec<f32> = (0..3 * 4).map(|_| rng.normal() as f32).collect();
            arr.program_weights(&w, 1.0);
            let got = arr.compute(&x, 3);
            let mut fresh = SystolicArray::new(cfg);
            fresh.program_weights(&w, 1.0);
            assert_eq!(got, fresh.compute(&x, 3));
        }
    }

    #[test]
    fn active_pe_cycles_count_band_membership() {
        // The wavefront's running count must equal the brute-force
        // census: PE (r,c) is active at cycle t iff t-m+1 <= r+c <= t,
        // i.e. exactly m cycles per PE — m*rows*cols in total.
        check("active PE count == band census", 24, |rng: &mut Rng| {
            let (m, r, c) = (rng.index(9) + 1, rng.index(6) + 1, rng.index(6) + 1);
            let cfg = ArrayConfig { rows: r, cols: c, quant: Quant::Fp32 };
            let mut arr = SystolicArray::new(cfg);
            arr.program_weights(&vec![1.0; r * c], 1.0);
            let _ = arr.compute(&vec![1.0; m * r], m);
            let mut census = 0usize;
            for t in 0..m + r + c - 2 {
                for rr in 0..r {
                    for cc in 0..c {
                        let d = rr + cc;
                        if d <= t && t < d + m {
                            census += 1;
                        }
                    }
                }
            }
            let ok = arr.last_active_pe_cycles == census
                && census == m * r * c;
            (ok, format!(
                "m={m} r={r} c={c} sim={} census={census}",
                arr.last_active_pe_cycles
            ))
        });
    }

    #[test]
    fn single_row_and_single_column_arrays() {
        // Degenerate geometries exercise the band-boundary arithmetic.
        for (r, c) in [(1usize, 5usize), (5, 1), (1, 1)] {
            let cfg = ArrayConfig { rows: r, cols: c, quant: Quant::Fp32 };
            let mut arr = SystolicArray::new(cfg);
            let w: Vec<f32> = (0..r * c).map(|i| i as f32 + 1.0).collect();
            arr.program_weights(&w, 1.0);
            let m = 4;
            let x: Vec<f32> = (0..m * r).map(|i| i as f32 - 2.0).collect();
            let got = arr.compute(&x, m);
            let want = matmul(&x, &w, m, r, c);
            assert_eq!(got, want, "r={r} c={c}");
        }
    }
}
