//! Tile-grid scheduler: a whole masked GEMM executed *functionally* on
//! one programmed array.
//!
//! The analytic engine ([`crate::sysim::engine::gemm_on_array`]) accounts
//! for a GEMM as a `ceil(K/t) x ceil(N/t)` grid of weight tiles where
//! pruned tiles are skipped outright. This module performs the same
//! schedule for real on the per-cycle [`SystolicArray`]: program the live
//! tile, stream the input block, accumulate the partial outputs — and
//! skip pruned tiles exactly as the cost model says (no programming, no
//! streaming, no compute). That gives
//!
//! - a **cross-validation path** between the functional and analytic
//!   layers (the per-cycle counts the array reports must reproduce the
//!   closed-form [`TileTiming`] sums the system simulator charges), and
//! - a realistic **macro-benchmark** for the simulator hot path (many
//!   program/compute passes on one array, the way a real workload drives
//!   it).
//!
//! §Perf: all staging buffers (weight tile, input block, output block)
//! are owned by the scheduler and reused across tiles *and* calls; the
//! steady-state loop performs no allocation.

use crate::arith::ftz_add;
use crate::sysim::TileMask;

use super::{ArrayConfig, SystolicArray, TileTiming};

/// Execution statistics of one scheduled GEMM.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScheduleStats {
    /// Tiles programmed and streamed.
    pub tiles_live: usize,
    /// Tiles skipped via the mask (the SASP saving).
    pub tiles_skipped: usize,
    /// Array cycles summed over live tiles, as reported by the per-cycle
    /// simulation.
    pub array_cycles: usize,
    /// 32-bit bus words spent programming weights.
    pub program_words: usize,
    /// Active-PE-cycles summed over live tiles, as counted by the
    /// per-cycle wavefront simulation — the ground truth for
    /// `timing.occ.active_pe_cycles`.
    pub sim_active_pe_cycles: usize,
    /// Closed-form cost of the same schedule (must agree with the
    /// per-cycle counts — asserted in tests, used by callers to
    /// cross-check the analytic layer).
    pub timing: TileTiming,
}

/// A systolic array plus the staging buffers to run whole GEMMs on it.
pub struct TileScheduler {
    pub array: SystolicArray,
    /// Weight-tile staging buffer (`t x t`, zero-padded at edges).
    wt: Vec<f32>,
    /// Input-block staging buffer (`m x t`).
    xt: Vec<f32>,
    /// Output-block staging buffer (`m x t`).
    yt: Vec<f32>,
}

impl TileScheduler {
    pub fn new(cfg: ArrayConfig) -> Self {
        let t = cfg.tile();
        TileScheduler {
            array: SystolicArray::new(cfg),
            wt: vec![0.0; t * t],
            xt: Vec::new(),
            yt: Vec::new(),
        }
    }

    /// Execute `y = x[m,k] * w[k,n]` (row-major) on the array, skipping
    /// the tiles `mask` marks dead (`None` = dense). `w_scale` is the
    /// per-tensor quantization scale used in INT8 mode (pass 1.0 for
    /// FP32). `y` is cleared and resized to `m*n`.
    ///
    /// Tile grid layout matches the cost model: `(ceil(k/t), ceil(n/t))`
    /// with the K index major — identical to the [`TileMask`] layout the
    /// pruning layer emits.
    pub fn gemm_into(
        &mut self,
        x: &[f32],
        w: &[f32],
        m: usize,
        k: usize,
        n: usize,
        mask: Option<&TileMask>,
        w_scale: f32,
        y: &mut Vec<f32>,
    ) -> ScheduleStats {
        let cfg = self.array.cfg;
        let t = cfg.tile();
        assert_eq!(x.len(), m * k, "x must be m x k");
        assert_eq!(w.len(), k * n, "w must be k x n");
        let kt = k.div_ceil(t);
        let nt = n.div_ceil(t);
        if let Some(ms) = mask {
            assert_eq!((ms.kt, ms.nt), (kt, nt), "mask/gemm tile grid mismatch");
        }
        if m == 0 {
            // Nothing to stream: an empty result, no tile passes (the
            // array's compute rejects empty input blocks).
            y.clear();
            return ScheduleStats::default();
        }

        y.clear();
        y.resize(m * n, 0.0);
        self.xt.clear();
        self.xt.resize(m * t, 0.0);
        self.yt.clear();
        self.yt.resize(m * t, 0.0);

        let mut stats = ScheduleStats::default();

        // j-outer / k-inner, the data arrangement of §3.1/Fig. 3: the
        // output block stays hot across the K accumulation sweep.
        for j in 0..nt {
            let n0 = j * t;
            let n_valid = t.min(n - n0);
            for i in 0..kt {
                if let Some(ms) = mask {
                    if !ms.is_live(i, j) {
                        stats.tiles_skipped += 1;
                        stats.timing.add(&TileTiming::skipped_pass(&cfg, m, 1));
                        continue;
                    }
                }
                let k0 = i * t;
                let k_valid = t.min(k - k0);

                // Stage the weight tile, zero-padding past the matrix edge.
                self.wt.fill(0.0);
                for rr in 0..k_valid {
                    let src = (k0 + rr) * n + n0;
                    self.wt[rr * t..rr * t + n_valid]
                        .copy_from_slice(&w[src..src + n_valid]);
                }
                stats.program_words += self.array.program_weights(&self.wt, w_scale);

                // Stage the input block (m x t, zero-padded K edge).
                self.xt.fill(0.0);
                for mm in 0..m {
                    let src = mm * k + k0;
                    for rr in 0..k_valid {
                        self.xt[mm * t + rr] = x[src + rr];
                    }
                }

                self.array.compute_into(&self.xt, m, &mut self.yt);
                stats.array_cycles += self.array.last_compute_cycles;
                stats.sim_active_pe_cycles += self.array.last_active_pe_cycles;

                // Accumulate the partial outputs (PE-adder semantics).
                for mm in 0..m {
                    let dst = mm * n + n0;
                    let src = mm * t;
                    for cc in 0..n_valid {
                        y[dst + cc] = ftz_add(y[dst + cc], self.yt[src + cc]);
                    }
                }

                stats.tiles_live += 1;
                stats.timing.add(&TileTiming::live(&cfg, m));
            }
        }
        stats
    }

    /// Allocating convenience wrapper around [`gemm_into`](Self::gemm_into).
    pub fn gemm(
        &mut self,
        x: &[f32],
        w: &[f32],
        m: usize,
        k: usize,
        n: usize,
        mask: Option<&TileMask>,
        w_scale: f32,
    ) -> (Vec<f32>, ScheduleStats) {
        let mut y = Vec::new();
        let stats = self.gemm_into(x, w, m, k, n, mask, w_scale, &mut y);
        (y, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::Quant;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Reference: matmul over weights with dead tiles zeroed — the SASP
    /// identity (skipping == multiplying by zeros).
    fn masked_matmul(
        x: &[f32],
        w: &[f32],
        m: usize,
        k: usize,
        n: usize,
        mask: Option<&TileMask>,
        t: usize,
    ) -> Vec<f32> {
        let nt = n.div_ceil(t);
        let mut y = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    let live = mask.map_or(true, |ms| {
                        ms.live[(kk / t) * nt + j / t]
                    });
                    if live {
                        acc += x[i * k + kk] * w[kk * n + j];
                    }
                }
                y[i * n + j] = acc;
            }
        }
        y
    }

    fn random_mask(rng: &mut Rng, kt: usize, nt: usize, p_dead: f64) -> TileMask {
        TileMask {
            kt,
            nt,
            live: (0..kt * nt).map(|_| !rng.chance(p_dead)).collect(),
        }
    }

    #[test]
    fn masked_gemm_matches_reference_matmul() {
        check("scheduler == masked matmul", 20, |rng: &mut Rng| {
            let t = [2usize, 4, 8][rng.index(3)];
            // Include shapes not divisible by the tile size.
            let m = rng.index(12) + 1;
            let k = rng.index(3 * t) + 1;
            let n = rng.index(3 * t) + 1;
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let mask = random_mask(rng, k.div_ceil(t), n.div_ceil(t), 0.3);
            let mut sched = TileScheduler::new(ArrayConfig::square(t, Quant::Fp32));
            let (got, stats) = sched.gemm(&x, &w, m, k, n, Some(&mask), 1.0);
            let want = masked_matmul(&x, &w, m, k, n, Some(&mask), t);
            let close = got
                .iter()
                .zip(&want)
                .all(|(g, r)| (g - r).abs() <= 1e-4 * r.abs().max(1.0));
            let counts_ok = stats.tiles_live == mask.live_count()
                && stats.tiles_skipped == mask.n_tiles() - mask.live_count();
            (close && counts_ok, format!("t={t} m={m} k={k} n={n}"))
        });
    }

    #[test]
    fn int8_gemm_matches_fake_quantized_reference() {
        let mut rng = Rng::new(9);
        let (t, m, k, n) = (4usize, 6, 12, 8);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let amax = w.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        let scale = amax / 127.0;
        let mask = random_mask(&mut rng, 3, 2, 0.4);
        let mut sched = TileScheduler::new(ArrayConfig::square(t, Quant::Int8));
        let (got, _) = sched.gemm(&x, &w, m, k, n, Some(&mask), scale);
        // Reference over fake-quantized weights (per-tensor scale).
        let wq: Vec<f32> = w
            .iter()
            .map(|v| (v / scale).round_ties_even().clamp(-127.0, 127.0) * scale)
            .collect();
        let want = masked_matmul(&x, &wq, m, k, n, Some(&mask), t);
        for (g, r) in got.iter().zip(&want) {
            assert!((g - r).abs() <= 2e-3 * r.abs().max(1.0), "{g} vs {r}");
        }
    }

    #[test]
    fn dense_equals_full_mask() {
        let mut rng = Rng::new(3);
        let (t, m, k, n) = (4usize, 5, 8, 8);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut sched = TileScheduler::new(ArrayConfig::square(t, Quant::Fp32));
        let (dense, ds) = sched.gemm(&x, &w, m, k, n, None, 1.0);
        let full = TileMask::full(2, 2);
        let (masked, ms) = sched.gemm(&x, &w, m, k, n, Some(&full), 1.0);
        assert_eq!(dense, masked);
        assert_eq!(ds, ms);
        assert_eq!(ds.tiles_live, 4);
        assert_eq!(ds.tiles_skipped, 0);
    }

    #[test]
    fn per_cycle_counts_reproduce_closed_form_timing() {
        // The cross-layer contract: the functional schedule's measured
        // cycle/word counts must equal the analytic per-tile charges the
        // system simulator applies for the same mask.
        let mut rng = Rng::new(17);
        let (t, m, k, n) = (8usize, 16, 32, 24);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        for quant in [Quant::Fp32, Quant::Int8] {
            let cfg = ArrayConfig::square(t, quant);
            let mask = random_mask(&mut rng, 4, 3, 0.5);
            let mut sched = TileScheduler::new(cfg);
            let (_, stats) = sched.gemm(&x, &w, m, k, n, Some(&mask), 0.02);
            let live = mask.live_count();
            let per_tile = TileTiming::live(&cfg, m);
            assert_eq!(stats.array_cycles, live * per_tile.array_cycles, "{quant:?}");
            assert_eq!(stats.program_words, live * per_tile.prog_words, "{quant:?}");
            assert_eq!(stats.timing.macs, live * per_tile.macs, "{quant:?}");
            assert_eq!(stats.timing.array_cycles, stats.array_cycles, "{quant:?}");
        }
    }

    #[test]
    fn occupancy_matches_wavefront_on_random_masks() {
        // The tentpole cross-check at GEMM scope: the closed-form
        // occupancy split must agree exactly with the per-cycle
        // wavefront simulation on random shapes x masks x array sizes,
        // and the skipped savings must be exactly the dead tiles'
        // steady-state work.
        check("analytic occupancy == wavefront", 24, |rng: &mut Rng| {
            let t = [2usize, 3, 4, 8][rng.index(4)];
            let m = rng.index(12) + 1;
            let k = rng.index(3 * t) + 1;
            let n = rng.index(3 * t) + 1;
            let quant = if rng.chance(0.5) { Quant::Fp32 } else { Quant::Int8 };
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let mask = random_mask(rng, k.div_ceil(t), n.div_ceil(t), 0.4);
            let cfg = ArrayConfig::square(t, quant);
            let mut sched = TileScheduler::new(cfg);
            let (_, stats) = sched.gemm(&x, &w, m, k, n, Some(&mask), 0.05);
            let occ = stats.timing.occ;
            let dead = mask.n_tiles() - mask.live_count();
            let n_pes = cfg.n_pes();
            let ok = occ.active_pe_cycles == stats.sim_active_pe_cycles
                && occ.active_pe_cycles + occ.bubble_pe_cycles
                    == stats.array_cycles * n_pes
                && occ.stall_pe_cycles == stats.program_words * n_pes
                && occ.skipped_pe_cycles == dead * m * n_pes;
            (ok, format!(
                "t={t} m={m} k={k} n={n} {quant:?} sim={} occ={occ:?}",
                stats.sim_active_pe_cycles
            ))
        });
    }

    #[test]
    fn fully_pruned_column_is_zero_and_free() {
        let mut rng = Rng::new(23);
        let (t, m, k, n) = (4usize, 3, 8, 8);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        // Kill every tile feeding output columns 0..t.
        let mask = TileMask { kt: 2, nt: 2, live: vec![false, true, false, true] };
        let mut sched = TileScheduler::new(ArrayConfig::square(t, Quant::Fp32));
        let (y, stats) = sched.gemm(&x, &w, m, k, n, Some(&mask), 1.0);
        for mm in 0..m {
            for cc in 0..t {
                assert_eq!(y[mm * n + cc], 0.0);
            }
        }
        assert_eq!(stats.tiles_live, 2);
        assert_eq!(stats.tiles_skipped, 2);
        // And the live half actually produced outputs.
        assert!(y.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn scheduler_reuses_buffers_across_calls() {
        // Steady state must be allocation-free; behaviourally we check
        // that interleaved shapes/masks don't leak state between calls.
        let mut rng = Rng::new(31);
        let mut sched = TileScheduler::new(ArrayConfig::square(4, Quant::Fp32));
        for (m, k, n) in [(3usize, 8usize, 4usize), (5, 4, 8), (2, 10, 6)] {
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let (got, _) = sched.gemm(&x, &w, m, k, n, None, 1.0);
            let want = masked_matmul(&x, &w, m, k, n, None, 4);
            for (g, r) in got.iter().zip(&want) {
                assert!((g - r).abs() <= 1e-4 * r.abs().max(1.0));
            }
        }
    }
}
