//! Artifact manifests: the positional argument contract emitted by
//! `python/compile/aot.py` next to each HLO artifact.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::{DType, Tensor};
use crate::util::json::Json;

/// One positional argument.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// Metadata of the model baked into the artifact (subset used by the
/// coordinator; missing fields default to 0/false for kernel artifacts).
#[derive(Clone, Debug, Default)]
pub struct ModelMeta {
    pub d_model: usize,
    pub d_ff: usize,
    pub n_blocks: usize,
    pub vocab: usize,
    pub tile: usize,
    pub ctc_blank: i64,
    pub batch: usize,
    pub seq_len: usize,
    pub token_input: bool,
}

/// Parsed `<name>_manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub args: Vec<ArgSpec>,
    pub output_shape: Vec<usize>,
    pub output_dtype: DType,
    pub model: ModelMeta,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let name = v
            .get("name")
            .as_str()
            .context("manifest missing 'name'")?
            .to_string();
        let mut args = Vec::new();
        for a in v.get("args").as_arr().context("manifest missing 'args'")? {
            args.push(ArgSpec {
                name: a.get("name").as_str().context("arg name")?.to_string(),
                shape: shape_of(a.get("shape"))?,
                dtype: DType::from_name(
                    a.get("dtype").as_str().context("arg dtype")?,
                )?,
            });
        }
        let out = v.get("output");
        let output_shape = shape_of(out.get("shape"))?;
        let output_dtype = DType::from_name(
            out.get("dtype").as_str().unwrap_or("float32"),
        )?;
        let m = v.get("model");
        let model = ModelMeta {
            d_model: m.get("d_model").as_usize().unwrap_or(0),
            d_ff: m.get("d_ff").as_usize().unwrap_or(0),
            n_blocks: m.get("n_blocks").as_usize().unwrap_or(0),
            vocab: m.get("vocab").as_usize().unwrap_or(0),
            tile: m
                .get("tile")
                .as_usize()
                .or_else(|| v.get("tile").as_usize())
                .unwrap_or(0),
            ctc_blank: m.get("ctc_blank").as_i64().unwrap_or(-1),
            batch: m.get("batch").as_usize().unwrap_or(0),
            seq_len: m.get("seq_len").as_usize().unwrap_or(0),
            token_input: m.get("token_input").as_bool().unwrap_or(false),
        };
        Ok(Manifest { name, args, output_shape, output_dtype, model })
    }

    /// Check a positional argument list against the contract.
    pub fn validate_args(&self, args: &[Tensor]) -> Result<()> {
        if args.len() != self.args.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.name,
                self.args.len(),
                args.len()
            );
        }
        for (i, (spec, t)) in self.args.iter().zip(args).enumerate() {
            if spec.shape != t.shape {
                bail!(
                    "{}: arg {i} ('{}') shape {:?} != expected {:?}",
                    self.name, spec.name, t.shape, spec.shape
                );
            }
            if spec.dtype != t.dtype {
                bail!(
                    "{}: arg {i} ('{}') dtype {:?} != expected {:?}",
                    self.name, spec.name, t.dtype, spec.dtype
                );
            }
        }
        Ok(())
    }

    /// Index of the first argument whose name matches.
    pub fn arg_index(&self, name: &str) -> Option<usize> {
        self.args.iter().position(|a| a.name == name)
    }

    /// Assemble the positional argument tensors per the manifest
    /// contract: data inputs (`feats`/`pad_mask`/`src`) start as zeros
    /// (rewritten per batch/chunk by the caller), `mask.*` arguments are
    /// all-ones (pruning is encoded by zeroed weights), and every other
    /// argument is a parameter pulled from the bundle by name. Shared by
    /// the serving loop and the QoS backends so the contract lives in
    /// one place.
    pub fn assemble_args(&self, params: &crate::data::Bundle) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(self.args.len());
        for spec in &self.args {
            let t = match spec.name.as_str() {
                "feats" | "pad_mask" | "src" => Tensor::zeros(&spec.shape, spec.dtype),
                name if name.starts_with("mask.") => {
                    let numel: usize = spec.shape.iter().product();
                    Tensor::from_i32(&spec.shape, &vec![1i32; numel])
                }
                name => params
                    .require(name)
                    .with_context(|| format!("param arg {name}"))?
                    .clone(),
            };
            out.push(t);
        }
        Ok(out)
    }
}

fn shape_of(v: &Json) -> Result<Vec<usize>> {
    v.as_arr()
        .context("shape must be an array")?
        .iter()
        .map(|d| d.as_usize().context("shape dim"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "demo",
      "args": [
        {"name": "x", "shape": [2, 3], "dtype": "float32"},
        {"name": "mask", "shape": [1], "dtype": "int32"}
      ],
      "output": {"shape": [2, 4], "dtype": "float32"},
      "model": {"d_model": 64, "tile": 8, "ctc_blank": 27, "batch": 16,
                "seq_len": 96, "n_blocks": 4, "vocab": 28, "d_ff": 256,
                "token_input": false}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "demo");
        assert_eq!(m.args.len(), 2);
        assert_eq!(m.args[1].dtype, DType::I32);
        assert_eq!(m.output_shape, vec![2, 4]);
        assert_eq!(m.model.ctc_blank, 27);
        assert_eq!(m.model.tile, 8);
        assert_eq!(m.arg_index("mask"), Some(1));
        assert_eq!(m.arg_index("nope"), None);
    }

    #[test]
    fn validates_shapes_and_dtypes() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let good = vec![
            Tensor::from_f32(&[2, 3], &[0.0; 6]),
            Tensor::from_i32(&[1], &[1]),
        ];
        assert!(m.validate_args(&good).is_ok());
        let bad_shape = vec![
            Tensor::from_f32(&[3, 2], &[0.0; 6]),
            Tensor::from_i32(&[1], &[1]),
        ];
        assert!(m.validate_args(&bad_shape).is_err());
        let bad_dtype = vec![
            Tensor::from_f32(&[2, 3], &[0.0; 6]),
            Tensor::from_f32(&[1], &[1.0]),
        ];
        assert!(m.validate_args(&bad_dtype).is_err());
        assert!(m.validate_args(&good[..1]).is_err());
    }
}
