//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client,
//! and executes them from the coordinator hot path. Python never runs
//! here.
//!
//! Interchange is HLO *text* (see `/opt/xla-example/README.md`): jax ≥0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids.

pub mod manifest;

pub use manifest::{ArgSpec, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::{DType, Tensor};

/// A compiled artifact ready to execute.
pub struct LoadedModel {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT engine: one CPU client + a cache of compiled artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, LoadedModel>,
}

impl Engine {
    /// Create an engine over an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt` (cached after the first call).
    pub fn load(&mut self, name: &str) -> Result<&LoadedModel> {
        if !self.cache.contains_key(name) {
            let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
            let man_path = self.dir.join(format!("{name}_manifest.json"));
            let manifest = Manifest::load(&man_path)?;
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path
                    .to_str()
                    .context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing {}", hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), LoadedModel { manifest, exe });
        }
        Ok(&self.cache[name])
    }

    /// Execute a loaded artifact on positional tensors. Arguments are
    /// validated against the manifest contract (names give diagnostics).
    pub fn execute(&mut self, name: &str, args: &[Tensor]) -> Result<Tensor> {
        self.load(name)?;
        self.cache[name].manifest.validate_args(args)?;
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()?;
        self.execute_literals(name, &literals)
    }

    /// Execute on pre-converted literals — the hot path for repeated
    /// invocations with mostly-unchanged arguments (§Perf L3: the QoS
    /// evaluator converts the 55 weight tensors once per configuration
    /// and reuses the literals across test-set chunks).
    pub fn execute_literals(
        &mut self,
        name: &str,
        literals: &[xla::Literal],
    ) -> Result<Tensor> {
        // Compile outside the borrow of the execution path.
        self.load(name)?;
        let model = &self.cache[name];
        let result = model
            .exe
            .execute::<xla::Literal>(literals)
            .with_context(|| format!("executing {name}"))?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?
            .to_tuple1()
            .context("unwrapping 1-tuple result")?;
        literal_to_tensor(&out, &model.manifest.output_shape, model.manifest.output_dtype)
    }
}

/// Convert a [`Tensor`] into an `xla::Literal` of matching shape/dtype.
/// All dtypes go through the untyped-bytes constructor (zero-copy on the
/// XLA side and uniform across element types).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let ty = match t.dtype {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
        DType::I8 => xla::ElementType::S8,
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        ty, &t.shape, &t.data,
    )?)
}

/// Convert an output literal back into a [`Tensor`].
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize], dtype: DType) -> Result<Tensor> {
    let t = match dtype {
        DType::F32 => Tensor::from_f32(shape, &lit.to_vec::<f32>()?),
        DType::I32 => Tensor::from_i32(shape, &lit.to_vec::<i32>()?),
        DType::I8 => bail!("i8 outputs not produced by any artifact"),
    };
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-dependent tests live in rust/tests/integration.rs (they need
    // built artifacts); here we only cover the pure conversion helpers.

    #[test]
    fn tensor_literal_roundtrip_f32() {
        let t = Tensor::from_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit, &[2, 3], DType::F32).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn tensor_literal_roundtrip_i32() {
        let t = Tensor::from_i32(&[4], &[-7, 0, 1, 2]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit, &[4], DType::I32).unwrap();
        assert_eq!(back.i32s(), vec![-7, 0, 1, 2]);
    }
}
