//! Lightweight span events for the serving stack.
//!
//! A span is a named begin/end interval with an id, an explicit parent
//! id, and a small set of typed attributes; an instant is a point
//! event. Events are recorded into per-thread buffers that spill
//! wholesale into one global sink under a mutex — the hot path is a
//! `Vec::push`, and the lock is taken once per [`SPILL`] events (and
//! once at thread exit), never per event.
//!
//! Recording is globally opt-in: while [`active`] is `false` (the
//! default, and what [`crate::telemetry::Telemetry::noop`] leaves in
//! place) every instrumentation site costs exactly one relaxed atomic
//! load and an early return — no clock read, no allocation, no buffer
//! touch. [`crate::telemetry::Telemetry::start`] flips the flag on and
//! [`crate::telemetry::Telemetry::finish`] drains the events.
//!
//! Parenting: [`Span::begin`] nests under the innermost live span on
//! the *current thread* (a thread-local stack, so scoped guards must
//! drop LIFO — every call site here is a lexical scope). Cross-thread
//! and non-LIFO lifetimes use explicit parents: [`Span::begin_with_parent`]
//! for a worker-thread root under a captured [`current_span`], and
//! [`Span::detached`] for spans whose lifetime interleaves arbitrarily
//! (per-request queue spans held inside the pending queue).

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

/// Per-thread events buffered before one locked spill into the sink.
const SPILL: usize = 8192;

static ACTIVE: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static SINK: Mutex<Vec<SpanEvent>> = Mutex::new(Vec::new());

/// Is telemetry recording globally enabled? One relaxed load — this is
/// the branch every disabled instrumentation site pays.
#[inline]
pub fn active() -> bool {
    // ordering: Relaxed — ACTIVE is a sampling gate, not a publication
    // flag; a thread that reads a stale value merely records (or skips)
    // a few boundary events. Event data itself is published through the
    // SINK mutex, which supplies the happens-before edge.
    ACTIVE.load(Ordering::Relaxed)
}

pub(crate) fn set_active(on: bool) {
    if on {
        // Pin the epoch before the first event so timestamps are
        // monotone from the first session of the process.
        let _ = EPOCH.get_or_init(Instant::now);
    }
    // ordering: Relaxed — matches the relaxed loads in `active()`.
    // Session start/stop does not need to be a global fence: the
    // session owner drains events under the SINK mutex, so anything a
    // worker buffered before observing the flip is still collected (or
    // deliberately dropped) at the same lock. This store was SeqCst
    // historically, which bought no ordering the readers could use.
    ACTIVE.store(on, Ordering::Relaxed);
}

/// Microseconds since the process-wide trace epoch.
fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Small per-thread integer id, stable for the thread's lifetime.
fn tid() -> u64 {
    thread_local! {
        static TID: Cell<u64> = const { Cell::new(0) };
    }
    TID.with(|c| {
        let v = c.get();
        if v != 0 {
            v
        } else {
            // ordering: Relaxed — a unique-id counter; only atomicity
            // of the increment matters, never inter-thread ordering.
            let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            c.set(v);
            v
        }
    })
}

/// One typed span/instant attribute value.
#[derive(Clone, Debug, PartialEq)]
pub enum AttrVal {
    U(u64),
    F(f64),
    S(String),
}

impl From<u64> for AttrVal {
    fn from(v: u64) -> Self {
        AttrVal::U(v)
    }
}

impl From<usize> for AttrVal {
    fn from(v: usize) -> Self {
        AttrVal::U(v as u64)
    }
}

impl From<f64> for AttrVal {
    fn from(v: f64) -> Self {
        AttrVal::F(v)
    }
}

impl From<&str> for AttrVal {
    fn from(v: &str) -> Self {
        AttrVal::S(v.to_string())
    }
}

impl From<String> for AttrVal {
    fn from(v: String) -> Self {
        AttrVal::S(v)
    }
}

/// Interval vs point vs counter-sample event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Span,
    Instant,
    /// A sampled counter track (Chrome `ph: "C"`): each attribute is one
    /// series of the track, plotted over time by Perfetto.
    Counter,
}

/// One recorded event, as drained by
/// [`crate::telemetry::Telemetry::finish`] and written by the Chrome
/// trace exporter.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub name: &'static str,
    pub kind: EventKind,
    /// Span id (0 for instants).
    pub id: u64,
    /// Parent span id (0 = root).
    pub parent: u64,
    /// Small per-thread integer id (not the OS tid).
    pub tid: u64,
    /// Microseconds since the trace epoch.
    pub start_us: u64,
    /// Interval length in microseconds (0 for instants).
    pub dur_us: u64,
    pub attrs: Vec<(&'static str, AttrVal)>,
}

struct LocalBuf {
    events: Vec<SpanEvent>,
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        // Worker threads flush whatever they buffered when they exit,
        // so scoped shards never lose events.
        if !self.events.is_empty() {
            lock_sink().append(&mut self.events);
        }
    }
}

thread_local! {
    static BUF: RefCell<LocalBuf> = RefCell::new(LocalBuf { events: Vec::new() });
    static STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

fn lock_sink() -> MutexGuard<'static, Vec<SpanEvent>> {
    SINK.lock().unwrap_or_else(PoisonError::into_inner)
}

fn record(ev: SpanEvent) {
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        b.events.push(ev);
        if b.events.len() >= SPILL {
            let mut events = std::mem::take(&mut b.events);
            lock_sink().append(&mut events);
        }
    });
}

/// Innermost live [`Span::begin`] span on this thread (0 = none) — the
/// parent to hand to worker threads via [`Span::begin_with_parent`].
pub fn current_span() -> u64 {
    STACK.with(|s| s.borrow().last().copied().unwrap_or(0))
}

/// Record a point event under the current thread's innermost span.
/// Costs one branch when telemetry is off.
#[inline]
pub fn instant(name: &'static str, attrs: Vec<(&'static str, AttrVal)>) {
    if !active() {
        return;
    }
    record(SpanEvent {
        name,
        kind: EventKind::Instant,
        id: 0,
        parent: current_span(),
        tid: tid(),
        start_us: now_us(),
        dur_us: 0,
        attrs,
    });
}

/// Record one sample on a named counter track. Each attribute becomes a
/// series of the track; Perfetto renders the samples as a stacked graph
/// over the trace timeline. Costs one branch when telemetry is off.
#[inline]
pub fn counter(name: &'static str, attrs: Vec<(&'static str, AttrVal)>) {
    if !active() {
        return;
    }
    record(SpanEvent {
        name,
        kind: EventKind::Counter,
        id: 0,
        parent: 0,
        tid: tid(),
        start_us: now_us(),
        dur_us: 0,
        attrs,
    });
}

/// RAII interval span. Inert (one branch at construction, nothing at
/// drop) while telemetry is off; otherwise records one [`SpanEvent`]
/// when dropped.
#[derive(Debug)]
pub struct Span {
    live: bool,
    on_stack: bool,
    name: &'static str,
    id: u64,
    parent: u64,
    start_us: u64,
    attrs: Vec<(&'static str, AttrVal)>,
}

impl Span {
    /// Begin a span nested under the current thread's innermost span.
    /// Must be dropped LIFO with respect to other `begin` spans on the
    /// same thread (i.e. used as a lexical scope guard).
    #[inline]
    pub fn begin(name: &'static str) -> Span {
        if !active() {
            return Span::inert(name);
        }
        Span::begin_live(name, current_span(), true)
    }

    /// Begin a scoped span under an explicit parent id — the root span
    /// of a worker thread, parented to the spawner's [`current_span`].
    #[inline]
    pub fn begin_with_parent(name: &'static str, parent: u64) -> Span {
        if !active() {
            return Span::inert(name);
        }
        Span::begin_live(name, parent, true)
    }

    /// Begin a span that does not participate in the thread's scope
    /// stack — for lifetimes that end in arbitrary order (one queue
    /// span per pending request).
    #[inline]
    pub fn detached(name: &'static str, parent: u64) -> Span {
        if !active() {
            return Span::inert(name);
        }
        Span::begin_live(name, parent, false)
    }

    fn inert(name: &'static str) -> Span {
        Span {
            live: false,
            on_stack: false,
            name,
            id: 0,
            parent: 0,
            start_us: 0,
            attrs: Vec::new(),
        }
    }

    fn begin_live(name: &'static str, parent: u64, on_stack: bool) -> Span {
        // ordering: Relaxed — a unique-id counter; only atomicity of
        // the increment matters, never inter-thread ordering.
        let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
        if on_stack {
            STACK.with(|s| s.borrow_mut().push(id));
        }
        Span {
            live: true,
            on_stack,
            name,
            id,
            parent,
            start_us: now_us(),
            attrs: Vec::new(),
        }
    }

    /// Attach an attribute (no-op on an inert span).
    #[inline]
    pub fn attr(&mut self, key: &'static str, val: impl Into<AttrVal>) {
        if self.live {
            self.attrs.push((key, val.into()));
        }
    }

    /// The span id (0 when inert) — handed to children on other threads.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Is this span actually recording?
    pub fn is_live(&self) -> bool {
        self.live
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        if self.on_stack {
            STACK.with(|s| {
                let mut s = s.borrow_mut();
                if s.last() == Some(&self.id) {
                    s.pop();
                } else {
                    // Out-of-order drop of a scoped span: degrade
                    // gracefully rather than corrupting the stack.
                    s.retain(|&x| x != self.id);
                }
            });
        }
        let end = now_us();
        record(SpanEvent {
            name: self.name,
            kind: EventKind::Span,
            id: self.id,
            parent: self.parent,
            tid: tid(),
            start_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            attrs: std::mem::take(&mut self.attrs),
        });
    }
}

/// Discard everything buffered so far (session start). Only the calling
/// thread's local buffer and the shared sink are cleared; other threads
/// that outlive a session flush into the *next* drain.
pub(crate) fn clear() {
    BUF.with(|b| b.borrow_mut().events.clear());
    lock_sink().clear();
}

/// Flush this thread's buffer and drain the sink (session end).
pub(crate) fn take_events() -> Vec<SpanEvent> {
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        if !b.events.is_empty() {
            let mut events = std::mem::take(&mut b.events);
            lock_sink().append(&mut events);
        }
    });
    std::mem::take(&mut *lock_sink())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Telemetry;

    #[test]
    fn concurrent_span_shard_merge_drains_every_thread() {
        // ACTIVE is a Relaxed sampling gate (see `active`): all event
        // publication rides the SINK mutex, so a concurrent hammer must
        // lose nothing. 8 threads x 300 spans recorded inside one
        // session arrive in the drained trace exactly once each, with
        // process-unique span ids and one tid per worker.
        const THREADS: usize = 8;
        const SPANS: usize = 300;
        let t = Telemetry::start();
        std::thread::scope(|s| {
            for w in 0..THREADS {
                s.spawn(move || {
                    for i in 0..SPANS {
                        let mut sp = Span::begin("t.conc");
                        sp.attr("w", w as u64);
                        sp.attr("i", i as u64);
                    }
                });
            }
        });
        let trace = t.finish();
        let conc: Vec<_> = trace.events.iter().filter(|e| e.name == "t.conc").collect();
        assert_eq!(conc.len(), THREADS * SPANS, "every span drained exactly once");
        let mut ids: Vec<u64> = conc.iter().map(|e| e.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), THREADS * SPANS, "span ids are process-unique");
        let mut tids: Vec<u64> = conc.iter().map(|e| e.tid).collect();
        tids.sort_unstable();
        tids.dedup();
        assert_eq!(tids.len(), THREADS, "one tid per worker thread");
    }

    #[test]
    fn inert_spans_record_nothing() {
        // No session: spans and instants must be free and eventless.
        let before = current_span();
        {
            let mut s = Span::begin("t.noop");
            assert!(!s.is_live());
            assert_eq!(s.id(), 0);
            s.attr("k", 1u64);
            instant("t.noop_instant", vec![]);
        }
        assert_eq!(current_span(), before);
    }

    #[test]
    fn session_records_nested_spans_with_parent_ids() {
        let t = Telemetry::start();
        let (outer_id, inner_id);
        {
            let outer = Span::begin("t.outer");
            outer_id = outer.id();
            assert_eq!(current_span(), outer_id);
            {
                let mut inner = Span::begin("t.inner");
                inner_id = inner.id();
                inner.attr("answer", 42u64);
                instant("t.mark", vec![("x", AttrVal::from(7u64))]);
            }
            assert_eq!(current_span(), outer_id);
        }
        let trace = t.finish();
        assert!(!active(), "finish() disables recording");
        // Drop order: inner, instant recorded at instant time, outer.
        let inner = trace.events.iter().find(|e| e.name == "t.inner").unwrap();
        let outer = trace.events.iter().find(|e| e.name == "t.outer").unwrap();
        let mark = trace.events.iter().find(|e| e.name == "t.mark").unwrap();
        assert_eq!(inner.id, inner_id);
        assert_eq!(inner.parent, outer_id);
        assert_eq!(outer.parent, 0);
        assert_eq!(mark.kind, EventKind::Instant);
        assert_eq!(mark.parent, inner_id);
        assert_eq!(mark.attrs, vec![("x", AttrVal::U(7))]);
        assert_eq!(inner.attrs, vec![("answer", AttrVal::U(42))]);
        assert!(outer.dur_us >= inner.dur_us);
        assert!(outer.start_us <= inner.start_us);
    }

    #[test]
    fn worker_thread_events_flush_on_thread_exit() {
        let t = Telemetry::start();
        let parent = {
            let root = Span::begin("t.root");
            let root_id = root.id();
            std::thread::scope(|s| {
                s.spawn(move || {
                    let _w = Span::begin_with_parent("t.worker", root_id);
                });
            });
            root_id
        };
        let trace = t.finish();
        let worker = trace.events.iter().find(|e| e.name == "t.worker").unwrap();
        let root = trace.events.iter().find(|e| e.name == "t.root").unwrap();
        assert_eq!(worker.parent, parent);
        assert_ne!(worker.tid, root.tid, "worker recorded under its own tid");
    }

    #[test]
    fn detached_spans_interleave_without_stack_corruption() {
        let t = Telemetry::start();
        let a = Span::detached("t.a", 0);
        let b = Span::detached("t.b", 0);
        assert_eq!(current_span(), 0, "detached spans stay off the stack");
        drop(a); // non-LIFO on purpose
        drop(b);
        let trace = t.finish();
        assert_eq!(trace.events.len(), 2);
    }
}
