//! Chrome trace-event JSON export.
//!
//! Writes the drained [`SpanEvent`]s as a Chrome/Perfetto trace — the
//! JSON object form (`{"traceEvents": [...]}`) with complete (`"X"`)
//! events for spans and instant (`"i"`) events for point marks, all
//! timestamps in microseconds since the trace epoch. Load the file at
//! <https://ui.perfetto.dev> or `chrome://tracing`.
//!
//! Emission streams through [`JsonWriter`], so a million-event trace
//! costs one pass and constant memory, never a buffered document.

use std::io::{self, Write};

use super::spans::{AttrVal, EventKind, SpanEvent};
use crate::util::json::JsonWriter;

/// Stream `events` as Chrome trace-event JSON into `w`.
pub fn write_chrome_trace<W: Write>(events: &[SpanEvent], w: W) -> io::Result<W> {
    let mut jw = JsonWriter::new(w);
    jw.begin_obj()?;
    jw.key("displayTimeUnit")?;
    jw.str_val("ms")?;
    jw.key("traceEvents")?;
    jw.begin_arr()?;
    for ev in events {
        jw.begin_obj()?;
        jw.key("name")?;
        jw.str_val(ev.name)?;
        jw.key("cat")?;
        jw.str_val("sasp")?;
        jw.key("ph")?;
        jw.str_val(match ev.kind {
            EventKind::Span => "X",
            EventKind::Instant => "i",
            EventKind::Counter => "C",
        })?;
        jw.key("ts")?;
        jw.u64_val(ev.start_us)?;
        match ev.kind {
            EventKind::Span => {
                jw.key("dur")?;
                jw.u64_val(ev.dur_us)?;
            }
            EventKind::Instant => {
                // Instant scope: thread.
                jw.key("s")?;
                jw.str_val("t")?;
            }
            // Counter samples carry only ts + args (the series values).
            EventKind::Counter => {}
        }
        jw.key("pid")?;
        jw.u64_val(1)?;
        jw.key("tid")?;
        jw.u64_val(ev.tid)?;
        jw.key("args")?;
        jw.begin_obj()?;
        if ev.id != 0 {
            jw.key("span_id")?;
            jw.u64_val(ev.id)?;
        }
        if ev.parent != 0 {
            jw.key("parent_id")?;
            jw.u64_val(ev.parent)?;
        }
        for (k, v) in &ev.attrs {
            jw.key(k)?;
            match v {
                AttrVal::U(u) => jw.u64_val(*u)?,
                AttrVal::F(f) => jw.num_val(*f)?,
                AttrVal::S(s) => jw.str_val(s)?,
            }
        }
        jw.end()?; // args
        jw.end()?; // event
    }
    jw.end()?; // traceEvents
    jw.end()?; // root
    jw.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn ev(name: &'static str, kind: EventKind, id: u64, parent: u64) -> SpanEvent {
        SpanEvent {
            name,
            kind,
            id,
            parent,
            tid: 3,
            start_us: 10,
            dur_us: if kind == EventKind::Span { 5 } else { 0 },
            attrs: vec![("rows", AttrVal::U(4)), ("policy", AttrVal::S("fixed".into()))],
        }
    }

    #[test]
    fn chrome_trace_round_trips_through_json_parse() {
        let events = vec![
            ev("serve.flush", EventKind::Span, 7, 0),
            ev("resilience.ladder", EventKind::Instant, 0, 7),
        ];
        let bytes = write_chrome_trace(&events, Vec::new()).unwrap();
        let v = Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap();
        let te = v.get("traceEvents").as_arr().unwrap();
        assert_eq!(te.len(), 2);

        let span = &te[0];
        assert_eq!(span.get("ph").as_str(), Some("X"));
        assert_eq!(span.get("name").as_str(), Some("serve.flush"));
        assert_eq!(span.get("ts").as_i64(), Some(10));
        assert_eq!(span.get("dur").as_i64(), Some(5));
        assert_eq!(span.get("tid").as_i64(), Some(3));
        assert_eq!(span.get("args").get("span_id").as_i64(), Some(7));
        assert_eq!(span.get("args").get("rows").as_i64(), Some(4));
        assert_eq!(span.get("args").get("policy").as_str(), Some("fixed"));

        let inst = &te[1];
        assert_eq!(inst.get("ph").as_str(), Some("i"));
        assert_eq!(inst.get("s").as_str(), Some("t"));
        assert_eq!(inst.get("args").get("parent_id").as_i64(), Some(7));
        assert_eq!(inst.get("dur"), &Json::Null, "instants carry no duration");
    }

    #[test]
    fn counter_events_export_as_c_phase_tracks() {
        let events = vec![SpanEvent {
            name: "array_utilization",
            kind: EventKind::Counter,
            id: 0,
            parent: 0,
            tid: 2,
            start_us: 42,
            dur_us: 0,
            attrs: vec![
                ("active", AttrVal::U(640)),
                ("bubble", AttrVal::U(96)),
            ],
        }];
        let bytes = write_chrome_trace(&events, Vec::new()).unwrap();
        let v = Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap();
        let c = &v.get("traceEvents").as_arr().unwrap()[0];
        assert_eq!(c.get("ph").as_str(), Some("C"));
        assert_eq!(c.get("ts").as_i64(), Some(42));
        assert_eq!(c.get("args").get("active").as_i64(), Some(640));
        assert_eq!(c.get("args").get("bubble").as_i64(), Some(96));
        assert_eq!(c.get("dur"), &Json::Null, "counters carry no duration");
        assert_eq!(c.get("s"), &Json::Null, "counters carry no instant scope");
    }
}
