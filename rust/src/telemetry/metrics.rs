//! Process-global metrics: named counters, gauges, and log-linear
//! histograms.
//!
//! Hot-path writes are lock-free: every counter/histogram is sharded
//! into [`NSHARDS`] cache-line-padded atomic cells, each thread writes
//! (relaxed) to the shard picked by its stable thread index, and a
//! scrape merges the shards by summing — the registry mutex is only
//! taken when a [`LazyCounter`]-style handle first resolves its name,
//! never per update. Like the span layer, updates are gated by
//! [`crate::telemetry::spans::active`] *at the instrumentation site*
//! (one branch covers a whole block of updates), so the raw
//! [`Counter::add`]/[`Histogram::observe`] primitives here are ungated
//! and directly unit-testable.
//!
//! Histograms use log-linear buckets: values 0..4 are exact, and every
//! octave above is split into 4 linear sub-buckets, giving ~6%..25%
//! relative resolution over the full `u64` range in 252 buckets.
//! Reconstructed quantiles therefore bracket the exact nearest-rank
//! quantile within one bucket (property-tested below).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

use crate::util::json::Json;

/// Write shards per metric; threads stripe across them by a stable
/// per-thread index, so concurrent writers rarely share a cache line.
pub const NSHARDS: usize = 8;

/// Linear sub-buckets per octave (4 = 2 bits).
const SUB: u64 = 4;
const SUB_BITS: u64 = 2;

/// Total log-linear buckets covering all of `u64`.
pub const NBUCKETS: usize = SUB as usize + (64 - SUB_BITS as usize) * SUB as usize;

static NEXT_SHARD: AtomicU64 = AtomicU64::new(0);

/// Stable per-thread shard index in `0..NSHARDS`.
fn shard_index() -> usize {
    thread_local! {
        static IX: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
    }
    IX.with(|c| {
        let v = c.get();
        if v != usize::MAX {
            v
        } else {
            // ordering: Relaxed — a striping counter; only atomicity of
            // the increment matters, the shard pick carries no data.
            let v = (NEXT_SHARD.fetch_add(1, Ordering::Relaxed) as usize) % NSHARDS;
            c.set(v);
            v
        }
    })
}

/// One cache line per shard cell so concurrent writers don't false-share.
#[repr(align(64))]
#[derive(Default)]
struct PadCell(AtomicU64);

/// Monotone counter, sharded per thread and summed on scrape.
#[derive(Default)]
pub struct Counter {
    shards: [PadCell; NSHARDS],
}

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    #[inline]
    pub fn add(&self, v: u64) {
        // ordering: Relaxed — monotone count merged by summation at
        // scrape time; no reader depends on cross-shard ordering.
        self.shards[shard_index()].0.fetch_add(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn value(&self) -> u64 {
        // ordering: Relaxed — a scrape is a statistical snapshot; exact
        // point-in-time totals across shards are not promised.
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }

    fn reset(&self) {
        for s in &self.shards {
            // ordering: Relaxed — reset races with writers by design;
            // the registry only resets between sessions.
            s.0.store(0, Ordering::Relaxed);
        }
    }
}

/// Last-write-wins signed gauge (queue depth, ladder step). A gauge has
/// one logical writer at a time, so it is a single atomic, not sharded.
#[derive(Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    #[inline]
    pub fn set(&self, v: i64) {
        // ordering: Relaxed — last-write-wins by contract (one logical
        // writer); the gauge carries no synchronization duty.
        self.v.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        // ordering: Relaxed — atomic increment only; see `set`.
        self.v.fetch_add(d, Ordering::Relaxed);
    }

    pub fn value(&self) -> i64 {
        // ordering: Relaxed — scrape-time snapshot; see `set`.
        self.v.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.set(0);
    }
}

/// Bucket index of a sample value.
pub fn bucket_index(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let exp = 63 - u64::from(v.leading_zeros());
    let group = (exp - SUB_BITS) as usize;
    let offset = ((v >> (exp - SUB_BITS)) - SUB) as usize;
    SUB as usize + group * SUB as usize + offset
}

/// Inclusive `[lo, hi]` value range of a bucket.
pub fn bucket_bounds(ix: usize) -> (u64, u64) {
    if ix < SUB as usize {
        return (ix as u64, ix as u64);
    }
    let group = (ix - SUB as usize) / SUB as usize;
    let offset = ((ix - SUB as usize) % SUB as usize) as u64;
    let lo = (SUB + offset) << group;
    let hi = if group == 64 - SUB_BITS as usize - 1 && offset == SUB - 1 {
        u64::MAX
    } else {
        ((SUB + offset + 1) << group) - 1
    };
    (lo, hi)
}

struct HistShard {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl HistShard {
    fn new() -> HistShard {
        HistShard {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

/// Log-linear-bucket histogram of `u64` samples (we record latencies as
/// microseconds), sharded per thread like [`Counter`].
pub struct Histogram {
    shards: Vec<HistShard>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { shards: (0..NSHARDS).map(|_| HistShard::new()).collect() }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        let s = &self.shards[shard_index()];
        // ordering: Relaxed — bucket/count/sum are merged by summation
        // at scrape; a scrape racing an observe may see a torn triple
        // (count without sum), which the snapshot contract accepts.
        s.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        s.count.fetch_add(1, Ordering::Relaxed);
        s.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Merge the shards into one snapshot.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut counts = vec![0u64; NBUCKETS];
        let (mut count, mut sum) = (0u64, 0u64);
        for s in &self.shards {
            for (acc, b) in counts.iter_mut().zip(&s.buckets) {
                // ordering: Relaxed — scrape-time merge; same snapshot
                // contract as `observe` above.
                *acc += b.load(Ordering::Relaxed);
            }
            count += s.count.load(Ordering::Relaxed);
            sum = sum.wrapping_add(s.sum.load(Ordering::Relaxed));
        }
        HistogramSnapshot { counts, count, sum }
    }

    fn reset(&self) {
        for s in &self.shards {
            for b in &s.buckets {
                // ordering: Relaxed — reset only runs between sessions;
                // see `Counter::reset`.
                b.store(0, Ordering::Relaxed);
            }
            s.count.store(0, Ordering::Relaxed);
            s.sum.store(0, Ordering::Relaxed);
        }
    }
}

/// Shard-merged histogram state at scrape time.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bucket counts, indexed like [`bucket_index`].
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl HistogramSnapshot {
    /// Inclusive `[lo, hi]` bucket range bracketing the exact
    /// nearest-rank `permille/1000` quantile of the recorded samples
    /// (the exact quantile lies inside the returned bucket, so the
    /// bracket is tight to one bucket width). `(0, 0)` when empty.
    pub fn quantile_bounds(&self, permille: u64) -> (u64, u64) {
        if self.count == 0 {
            return (0, 0);
        }
        let rank = (permille * self.count).div_ceil(1000).clamp(1, self.count);
        let mut cum = 0u64;
        for (ix, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_bounds(ix);
            }
        }
        bucket_bounds(NBUCKETS - 1)
    }
}

enum AnyMetric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

/// Name → metric registry. Resolution takes the mutex; the resolved
/// `&'static` handles it hands out never do.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<&'static str, AnyMetric>>,
}

fn lock<'a>(
    m: &'a Mutex<BTreeMap<&'static str, AnyMetric>>,
) -> MutexGuard<'a, BTreeMap<&'static str, AnyMetric>> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Registry {
    /// Resolve (registering on first use) the counter named `name`.
    /// Panics if the name is already registered as another kind.
    pub fn counter(&self, name: &'static str) -> &'static Counter {
        let mut m = lock(&self.metrics);
        let entry = m
            .entry(name)
            .or_insert_with(|| AnyMetric::Counter(Box::leak(Box::new(Counter::new()))));
        match entry {
            AnyMetric::Counter(c) => *c,
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    }

    pub fn gauge(&self, name: &'static str) -> &'static Gauge {
        let mut m = lock(&self.metrics);
        let entry =
            m.entry(name).or_insert_with(|| AnyMetric::Gauge(Box::leak(Box::new(Gauge::new()))));
        match entry {
            AnyMetric::Gauge(g) => *g,
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    }

    pub fn histogram(&self, name: &'static str) -> &'static Histogram {
        let mut m = lock(&self.metrics);
        let entry = m
            .entry(name)
            .or_insert_with(|| AnyMetric::Histogram(Box::leak(Box::new(Histogram::new()))));
        match entry {
            AnyMetric::Histogram(h) => *h,
            _ => panic!("metric {name:?} already registered with a different type"),
        }
    }

    /// Merge every registered metric's shards into one snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = lock(&self.metrics);
        let mut snap = MetricsSnapshot::default();
        for (name, metric) in m.iter() {
            match metric {
                AnyMetric::Counter(c) => {
                    snap.counters.insert(name.to_string(), c.value());
                }
                AnyMetric::Gauge(g) => {
                    snap.gauges.insert(name.to_string(), g.value());
                }
                AnyMetric::Histogram(h) => {
                    snap.histograms.insert(name.to_string(), h.snapshot());
                }
            }
        }
        snap
    }

    /// Zero every registered metric (session start).
    pub(crate) fn reset(&self) {
        let m = lock(&self.metrics);
        for metric in m.values() {
            match metric {
                AnyMetric::Counter(c) => c.reset(),
                AnyMetric::Gauge(g) => g.reset(),
                AnyMetric::Histogram(h) => h.reset(),
            }
        }
    }
}

/// The process-global registry.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::default)
}

/// A named counter handle usable as a `static`: the registry is
/// consulted once, then updates are lock-free forever.
pub struct LazyCounter {
    name: &'static str,
    cell: OnceLock<&'static Counter>,
}

impl LazyCounter {
    pub const fn new(name: &'static str) -> LazyCounter {
        LazyCounter { name, cell: OnceLock::new() }
    }

    #[inline]
    pub fn get(&self) -> &'static Counter {
        self.cell.get_or_init(|| registry().counter(self.name))
    }
}

/// [`LazyCounter`]'s gauge counterpart.
pub struct LazyGauge {
    name: &'static str,
    cell: OnceLock<&'static Gauge>,
}

impl LazyGauge {
    pub const fn new(name: &'static str) -> LazyGauge {
        LazyGauge { name, cell: OnceLock::new() }
    }

    #[inline]
    pub fn get(&self) -> &'static Gauge {
        self.cell.get_or_init(|| registry().gauge(self.name))
    }
}

/// [`LazyCounter`]'s histogram counterpart.
pub struct LazyHistogram {
    name: &'static str,
    cell: OnceLock<&'static Histogram>,
}

impl LazyHistogram {
    pub const fn new(name: &'static str) -> LazyHistogram {
        LazyHistogram { name, cell: OnceLock::new() }
    }

    #[inline]
    pub fn get(&self) -> &'static Histogram {
        self.cell.get_or_init(|| registry().histogram(self.name))
    }
}

/// Everything the registry knew at scrape time, in deterministic
/// (name-sorted) order.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl MetricsSnapshot {
    /// Prometheus text exposition, deterministic and family-grouped:
    /// series are emitted in sorted full-name order (the `BTreeMap`
    /// sorts name + label set together), and each **family** (the name
    /// up to the first `{`) gets exactly one `# TYPE` line with all of
    /// its labeled series beneath — labeled families like
    /// `sasp_layer_macs_total{layer="..."}` render as one valid block,
    /// not one TYPE line per series. Histogram buckets are emitted
    /// sparsely (only buckets that hold samples) with cumulative
    /// counts and inclusive upper bounds as `le` labels, plus the
    /// conventional `+Inf`/`_sum`/`_count` series.
    pub fn render_prometheus(&self) -> String {
        use std::fmt::Write;
        fn family(name: &str) -> &str {
            name.split('{').next().unwrap_or(name)
        }
        let mut out = String::new();
        let mut last_family = "";
        for (name, v) in &self.counters {
            let fam = family(name);
            if fam != last_family {
                let _ = writeln!(out, "# TYPE {fam} counter");
                last_family = fam;
            }
            let _ = writeln!(out, "{name} {v}");
        }
        let mut last_family = "";
        for (name, v) in &self.gauges {
            let fam = family(name);
            if fam != last_family {
                let _ = writeln!(out, "# TYPE {fam} gauge");
                last_family = fam;
            }
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, h) in &self.histograms {
            let _ = writeln!(out, "# TYPE {name} histogram");
            let mut cum = 0u64;
            for (ix, &c) in h.counts.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                cum += c;
                let (_, hi) = bucket_bounds(ix);
                let _ = writeln!(out, "{name}_bucket{{le=\"{hi}\"}} {cum}");
            }
            let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count);
            let _ = writeln!(out, "{name}_sum {}", h.sum);
            let _ = writeln!(out, "{name}_count {}", h.count);
        }
        out
    }

    /// JSON form (counters/gauges as numbers, histograms as sparse
    /// `[lo, hi, count]` bucket triples).
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (name, v) in &self.counters {
            counters.insert(name.clone(), Json::num(*v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (name, v) in &self.gauges {
            gauges.insert(name.clone(), Json::num(*v as f64));
        }
        let mut hists = BTreeMap::new();
        for (name, h) in &self.histograms {
            let buckets: Vec<Json> = h
                .counts
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(ix, &c)| {
                    let (lo, hi) = bucket_bounds(ix);
                    Json::Arr(vec![
                        Json::num(lo as f64),
                        Json::num(hi as f64),
                        Json::num(c as f64),
                    ])
                })
                .collect();
            let mut o = BTreeMap::new();
            o.insert("count".to_string(), Json::num(h.count as f64));
            o.insert("sum".to_string(), Json::num(h.sum as f64));
            o.insert("buckets".to_string(), Json::Arr(buckets));
            hists.insert(name.clone(), Json::Obj(o));
        }
        let mut root = BTreeMap::new();
        root.insert("counters".to_string(), Json::Obj(counters));
        root.insert("gauges".to_string(), Json::Obj(gauges));
        root.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn bucket_index_and_bounds_agree_over_the_full_range() {
        // Exact low buckets, contiguity, and containment at the seams.
        for v in 0..64u64 {
            let ix = bucket_index(v);
            let (lo, hi) = bucket_bounds(ix);
            assert!(lo <= v && v <= hi, "v={v} ix={ix} lo={lo} hi={hi}");
        }
        for ix in 0..NBUCKETS - 1 {
            let (_, hi) = bucket_bounds(ix);
            let (lo_next, _) = bucket_bounds(ix + 1);
            assert_eq!(hi + 1, lo_next, "buckets must tile contiguously at ix={ix}");
        }
        assert_eq!(bucket_bounds(NBUCKETS - 1).1, u64::MAX);
        assert_eq!(bucket_index(u64::MAX), NBUCKETS - 1);
        // Powers of two start fresh octave groups.
        for shift in SUB_BITS..63 {
            let v = 1u64 << shift;
            let (lo, hi) = bucket_bounds(bucket_index(v));
            assert!(lo <= v && v <= hi);
            assert_eq!(lo, v, "an octave boundary starts its bucket");
        }
    }

    #[test]
    fn histogram_quantiles_bracket_exact_nearest_rank() {
        prop::check("histogram_quantiles_bracket_exact_nearest_rank", 64, |rng| {
            let n = 1 + rng.index(400);
            let h = Histogram::new();
            let mut samples: Vec<u64> = (0..n)
                .map(|_| {
                    // Spread samples across many octaves so the test
                    // exercises both exact and log-linear buckets.
                    let shift = rng.index(48) as u32;
                    (rng.f64() * (1u64 << shift) as f64) as u64
                })
                .collect();
            for &s in &samples {
                h.observe(s);
            }
            samples.sort_unstable();
            let snap = h.snapshot();
            for pm in [1u64, 100, 500, 900, 950, 990, 999, 1000] {
                let rank = (pm * n as u64).div_ceil(1000).clamp(1, n as u64) as usize;
                let exact = samples[rank - 1];
                let (lo, hi) = snap.quantile_bounds(pm);
                if !(lo <= exact && exact <= hi) {
                    return (
                        false,
                        format!("pm={pm} exact={exact} outside bracket [{lo}, {hi}] (n={n})"),
                    );
                }
            }
            (true, format!("n={n} bracketed"))
        });
    }

    #[test]
    fn histogram_shard_merge_equals_single_thread() {
        prop::check("histogram_shard_merge_equals_single_thread", 8, |rng| {
            let n = 64 + rng.index(256);
            let samples: Vec<u64> = (0..n)
                .map(|_| {
                    let shift = rng.index(40) as u32;
                    (rng.f64() * (1u64 << shift) as f64) as u64
                })
                .collect();
            let single = Histogram::new();
            for &s in &samples {
                single.observe(s);
            }
            let sharded = Histogram::new();
            std::thread::scope(|scope| {
                for chunk in samples.chunks(n.div_ceil(4)) {
                    let h = &sharded;
                    scope.spawn(move || {
                        for &s in chunk {
                            h.observe(s);
                        }
                    });
                }
            });
            let (a, b) = (single.snapshot(), sharded.snapshot());
            if a != b {
                return (
                    false,
                    format!(
                        "shard-merged snapshot differs: single count={} sum={}, \
                         sharded count={} sum={}",
                        a.count, a.sum, b.count, b.sum
                    ),
                );
            }
            (true, format!("n={n} identical"))
        });
    }

    #[test]
    fn counters_merge_across_threads() {
        let c = Counter::new();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let c = &c;
                scope.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.value(), 4000);
        let g = Gauge::new();
        g.set(7);
        g.add(-3);
        assert_eq!(g.value(), 4);
    }

    #[test]
    fn registry_resolves_each_name_once_and_snapshots_deterministically() {
        let r = Registry::default();
        let a = r.counter("t_requests_total");
        let b = r.counter("t_requests_total");
        assert!(std::ptr::eq(a, b), "same name resolves to the same counter");
        a.add(3);
        r.gauge("t_queue_depth").set(2);
        r.histogram("t_latency_us").observe(5);
        r.histogram("t_latency_us").observe(900);
        let snap = r.snapshot();
        assert_eq!(snap.counters["t_requests_total"], 3);
        assert_eq!(snap.gauges["t_queue_depth"], 2);
        assert_eq!(snap.histograms["t_latency_us"].count, 2);
        assert_eq!(snap.histograms["t_latency_us"].sum, 905);

        let text = snap.render_prometheus();
        assert!(text.contains("# TYPE t_requests_total counter"));
        assert!(text.contains("t_requests_total 3"));
        assert!(text.contains("t_latency_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("t_latency_us_sum 905"));
        assert_eq!(text, snap.render_prometheus(), "exposition is deterministic");

        let json = snap.to_json();
        let parsed = Json::parse(&json.to_string()).unwrap();
        assert_eq!(parsed.get("counters").get("t_requests_total").as_i64(), Some(3));
        let hist = parsed.get("histograms").get("t_latency_us");
        assert_eq!(hist.get("count").as_i64(), Some(2));
        assert_eq!(hist.get("buckets").as_arr().map(|b| b.len()), Some(2));
    }

    #[test]
    fn exposition_groups_labeled_series_under_one_type_line_in_sorted_order() {
        // Labeled series of one family must share a single `# TYPE`
        // line (one TYPE per series is invalid exposition), and both
        // families and label sets come out in sorted, pinned order —
        // registration order is deliberately scrambled.
        let r = Registry::default();
        r.counter("z_last_total").inc();
        r.counter("t_layer_macs_total{layer=\"qkv\"}").add(2);
        r.counter("t_layer_macs_total{layer=\"ff1\"}").add(1);
        r.counter("a_first_total").add(7);
        r.gauge("t_depth{shard=\"1\"}").set(4);
        r.gauge("t_depth{shard=\"0\"}").set(3);
        let text = r.snapshot().render_prometheus();

        let counter_lines: Vec<&str> = text
            .lines()
            .take_while(|l| !l.contains("gauge"))
            .collect();
        assert_eq!(
            counter_lines,
            vec![
                "# TYPE a_first_total counter",
                "a_first_total 7",
                "# TYPE t_layer_macs_total counter",
                "t_layer_macs_total{layer=\"ff1\"} 1",
                "t_layer_macs_total{layer=\"qkv\"} 2",
                "# TYPE z_last_total counter",
                "z_last_total 1",
            ],
            "family-grouped, name-and-label sorted:\n{text}"
        );
        assert_eq!(
            text.matches("# TYPE t_layer_macs_total counter").count(),
            1,
            "one TYPE line per family:\n{text}"
        );
        let gauge_block = &text[text.find("# TYPE t_depth gauge").unwrap()..];
        assert!(gauge_block.starts_with(
            "# TYPE t_depth gauge\nt_depth{shard=\"0\"} 3\nt_depth{shard=\"1\"} 4\n"
        ));
        // Determinism: a second scrape renders byte-identically.
        assert_eq!(text, r.snapshot().render_prometheus());
    }
}
