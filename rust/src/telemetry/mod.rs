//! Zero-dependency, low-overhead observability for the serving stack.
//!
//! Three layers, all built on std atomics and one spill mutex:
//!
//! - [`metrics`] — a process-global registry of named counters, gauges,
//!   and log-linear-bucket histograms, sharded per thread and merged on
//!   scrape, with deterministic Prometheus-style text exposition.
//! - [`spans`] — begin/end interval spans with explicit parent ids and
//!   instant events, buffered per thread; the serving loop tags every
//!   request's admit → queue → batch-window → flush → per-shard forward
//!   → decode → respond lifecycle, and the kernels attach their
//!   [`crate::systolic::TileTiming`] accounting to per-GEMM spans.
//! - [`export`] — a streaming Chrome trace-event JSON writer
//!   (Perfetto-loadable), built on [`crate::util::json::JsonWriter`].
//!
//! Recording is **run-time opt-in** and off by default. Every
//! instrumentation site is gated on one relaxed atomic load
//! ([`spans::active`]): with no active session a span/instant/metric
//! update costs one branch — no clock read, no allocation, no lock
//! (guarded in `scripts/verify.sh`: telemetry-off ≤ 1.02x and
//! telemetry-on ≤ 1.10x of the uninstrumented serving hot path).
//!
//! ```no_run
//! use sasp::telemetry::Telemetry;
//! let session = Telemetry::start(); // enable recording
//! // ... run instrumented work (e.g. coordinator::serve) ...
//! let trace = session.finish(); // drain events + scrape metrics
//! let f = std::fs::File::create("trace.json").unwrap();
//! sasp::telemetry::write_chrome_trace(&trace.events, f).unwrap();
//! println!("{}", trace.metrics.render_prometheus());
//! ```

use std::sync::{Mutex, MutexGuard, PoisonError};

pub mod export;
pub mod metrics;
pub mod spans;

pub use export::write_chrome_trace;
pub use metrics::{
    Counter, Gauge, Histogram, HistogramSnapshot, LazyCounter, LazyGauge, LazyHistogram,
    MetricsSnapshot,
};
pub use spans::{active, counter, current_span, instant, AttrVal, EventKind, Span, SpanEvent};

/// Sessions are process-exclusive: concurrent `start()`s (parallel
/// tests, nested reports) serialize here instead of stealing each
/// other's events.
static SESSION: Mutex<()> = Mutex::new(());

/// A recording session handle. [`Telemetry::start`] enables global
/// collection and [`Telemetry::finish`] drains it; [`Telemetry::noop`]
/// is the disabled handle — it changes nothing, and every
/// instrumentation site stays at its one-branch cost.
pub struct Telemetry {
    recording: bool,
    _session: Option<MutexGuard<'static, ()>>,
}

/// Everything one session recorded.
#[derive(Default)]
pub struct Trace {
    /// Span + instant events in record order.
    pub events: Vec<SpanEvent>,
    /// Metrics scraped (shard-merged) at session end.
    pub metrics: MetricsSnapshot,
}

impl Trace {
    /// Events with the given name (tests and report summaries).
    pub fn named(&self, name: &str) -> impl Iterator<Item = &SpanEvent> {
        self.events.iter().filter(move |e| e.name == name)
    }
}

impl Telemetry {
    /// The disabled handle: recording stays off, every instrumented
    /// site costs its single branch.
    pub fn noop() -> Telemetry {
        Telemetry { recording: false, _session: None }
    }

    /// Begin an exclusive recording session: zero the metric registry,
    /// discard stale buffered events, enable collection.
    pub fn start() -> Telemetry {
        let guard = SESSION.lock().unwrap_or_else(PoisonError::into_inner);
        metrics::registry().reset();
        spans::clear();
        spans::set_active(true);
        Telemetry { recording: true, _session: Some(guard) }
    }

    pub fn is_recording(&self) -> bool {
        self.recording
    }

    /// Disable collection and return everything recorded. On a
    /// [`Telemetry::noop`] handle this returns an empty trace.
    pub fn finish(mut self) -> Trace {
        if !self.recording {
            return Trace::default();
        }
        self.recording = false;
        spans::set_active(false);
        Trace { events: spans::take_events(), metrics: metrics::registry().snapshot() }
    }
}

impl Drop for Telemetry {
    fn drop(&mut self) {
        // A session dropped without finish() must not leave global
        // recording enabled.
        if self.recording {
            spans::set_active(false);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_handle_neither_enables_nor_drains() {
        let t = Telemetry::noop();
        assert!(!t.is_recording());
        assert!(!active());
        let trace = t.finish();
        assert!(trace.events.is_empty());
        assert!(trace.metrics.counters.is_empty());
    }

    #[test]
    fn start_resets_metrics_between_sessions() {
        let c = metrics::registry().counter("telemetry_mod_test_total");
        {
            let t = Telemetry::start();
            c.add(5);
            let trace = t.finish();
            assert_eq!(trace.metrics.counters["telemetry_mod_test_total"], 5);
        }
        {
            let t = Telemetry::start();
            c.add(2);
            let trace = t.finish();
            assert_eq!(
                trace.metrics.counters["telemetry_mod_test_total"], 2,
                "second session starts from zero"
            );
        }
    }

    #[test]
    fn dropped_session_disables_recording() {
        {
            let _t = Telemetry::start();
            assert!(active());
        }
        assert!(!active());
    }
}
