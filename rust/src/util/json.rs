//! Minimal JSON parser + emitter (serde_json is not in the vendor set).
//!
//! Supports the full JSON data model minus exotic number forms; good
//! enough for artifact manifests, config files, and report output. The
//! parser is recursive-descent over bytes with proper string escapes.
//!
//! [`Json`] values buffer whole documents; [`JsonWriter`] is the
//! incremental counterpart — it streams nested objects/arrays to any
//! [`io::Write`] in constant memory, which is what the telemetry trace
//! exporter uses to emit million-event Chrome traces without building
//! the document in RAM.

use std::collections::BTreeMap;
use std::fmt;
use std::io;

/// A JSON value. Objects use `BTreeMap` so emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    /// Compact emission; strings are escaped per RFC 8259.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped<W: fmt::Write>(f: &mut W, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

/// Where the writer is inside a container, for comma/colon placement.
#[derive(Clone, Copy)]
enum Ctx {
    /// Inside an array; `first` until the first element is written.
    Arr { first: bool },
    /// Inside an object; `pending` between a key and its value.
    Obj { first: bool, pending: bool },
}

/// Incremental JSON emitter: streams nested objects/arrays straight to
/// an [`io::Write`] in constant memory (one small scratch buffer),
/// producing exactly the compact form [`Json`]'s `Display` emits — so
/// anything written here parses back via [`Json::parse`].
///
/// Protocol errors (a value where a key is required, `end` with
/// nothing open, `finish` with containers still open) are programmer
/// errors and panic; I/O errors are returned.
pub struct JsonWriter<W: io::Write> {
    w: W,
    stack: Vec<Ctx>,
    scratch: String,
}

impl<W: io::Write> JsonWriter<W> {
    pub fn new(w: W) -> JsonWriter<W> {
        JsonWriter { w, stack: Vec::new(), scratch: String::new() }
    }

    /// Comma bookkeeping before a value (or container) is emitted.
    fn before_value(&mut self) -> io::Result<()> {
        match self.stack.last_mut() {
            None => Ok(()),
            Some(Ctx::Arr { first }) => {
                let sep = !*first;
                *first = false;
                if sep {
                    self.w.write_all(b",")?;
                }
                Ok(())
            }
            Some(Ctx::Obj { pending, .. }) => {
                assert!(*pending, "JsonWriter: object value written without a key");
                *pending = false;
                Ok(())
            }
        }
    }

    pub fn begin_obj(&mut self) -> io::Result<()> {
        self.before_value()?;
        self.w.write_all(b"{")?;
        self.stack.push(Ctx::Obj { first: true, pending: false });
        Ok(())
    }

    pub fn begin_arr(&mut self) -> io::Result<()> {
        self.before_value()?;
        self.w.write_all(b"[")?;
        self.stack.push(Ctx::Arr { first: true });
        Ok(())
    }

    /// Write the key of the next object member.
    pub fn key(&mut self, k: &str) -> io::Result<()> {
        match self.stack.last_mut() {
            Some(Ctx::Obj { first, pending }) => {
                assert!(!*pending, "JsonWriter: two keys in a row");
                let sep = !*first;
                *first = false;
                *pending = true;
                if sep {
                    self.w.write_all(b",")?;
                }
            }
            _ => panic!("JsonWriter: key() outside an object"),
        }
        self.scratch.clear();
        let _ = write_escaped(&mut self.scratch, k);
        self.scratch.push(':');
        self.w.write_all(self.scratch.as_bytes())
    }

    pub fn str_val(&mut self, s: &str) -> io::Result<()> {
        self.before_value()?;
        self.scratch.clear();
        let _ = write_escaped(&mut self.scratch, s);
        self.w.write_all(self.scratch.as_bytes())
    }

    /// Emit a number in the same form as [`Json`]'s `Display` (integer
    /// form when exact), so round-trips through [`Json::parse`] are
    /// value-identical.
    pub fn num_val(&mut self, n: f64) -> io::Result<()> {
        use fmt::Write;
        self.before_value()?;
        self.scratch.clear();
        if n.fract() == 0.0 && n.abs() < 1e15 {
            let _ = write!(self.scratch, "{}", n as i64);
        } else {
            let _ = write!(self.scratch, "{n}");
        }
        self.w.write_all(self.scratch.as_bytes())
    }

    pub fn u64_val(&mut self, n: u64) -> io::Result<()> {
        use fmt::Write;
        self.before_value()?;
        self.scratch.clear();
        let _ = write!(self.scratch, "{n}");
        self.w.write_all(self.scratch.as_bytes())
    }

    pub fn bool_val(&mut self, b: bool) -> io::Result<()> {
        self.before_value()?;
        self.w.write_all(if b { b"true" } else { b"false" })
    }

    pub fn null_val(&mut self) -> io::Result<()> {
        self.before_value()?;
        self.w.write_all(b"null")
    }

    /// Embed an already-built [`Json`] value (compact `Display` form).
    pub fn value(&mut self, v: &Json) -> io::Result<()> {
        use fmt::Write;
        self.before_value()?;
        self.scratch.clear();
        let _ = write!(self.scratch, "{v}");
        self.w.write_all(self.scratch.as_bytes())
    }

    /// Close the innermost open object or array.
    pub fn end(&mut self) -> io::Result<()> {
        match self.stack.pop() {
            Some(Ctx::Arr { .. }) => self.w.write_all(b"]"),
            Some(Ctx::Obj { pending, .. }) => {
                assert!(!pending, "JsonWriter: object closed after a key with no value");
                self.w.write_all(b"}")
            }
            None => panic!("JsonWriter: end() with nothing open"),
        }
    }

    /// Flush and return the underlying writer. Panics if containers
    /// are still open (the document would be truncated).
    pub fn finish(mut self) -> io::Result<W> {
        assert!(self.stack.is_empty(), "JsonWriter: finish() with open containers");
        self.w.flush()?;
        Ok(self.w)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || b".eE+-".contains(&c))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self
                        .peek()
                        .ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: keep it simple, replace.
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{FFFD}'),
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(*v.get("d"), Json::Null);
        assert_eq!(*v.get("missing"), Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let v = Json::obj(vec![
            ("x", Json::Num(1.5)),
            ("s", Json::str("he\"llo\n")),
            ("a", Json::Arr(vec![Json::Bool(false), Json::Null])),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn manifest_like_document() {
        let text = r#"{
          "name": "asr_encoder_ref",
          "args": [{"name": "feats", "shape": [16, 96, 40], "dtype": "float32"}],
          "model": {"d_model": 64, "tile": 8}
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("name").as_str(), Some("asr_encoder_ref"));
        let arg0 = &v.get("args").as_arr().unwrap()[0];
        let shape: Vec<usize> = arg0
            .get("shape")
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![16, 96, 40]);
        assert_eq!(v.get("model").get("tile").as_i64(), Some(8));
    }

    #[test]
    fn json_writer_output_parses_back() {
        let mut w = JsonWriter::new(Vec::new());
        w.begin_obj().unwrap();
        w.key("name").unwrap();
        w.str_val("tr\"ace\n").unwrap();
        w.key("events").unwrap();
        w.begin_arr().unwrap();
        for i in 0..3u64 {
            w.begin_obj().unwrap();
            w.key("ts").unwrap();
            w.u64_val(i * 1000).unwrap();
            w.key("dur").unwrap();
            w.num_val(i as f64 + 0.5).unwrap();
            w.key("ok").unwrap();
            w.bool_val(i % 2 == 0).unwrap();
            w.key("parent").unwrap();
            w.null_val().unwrap();
            w.end().unwrap();
        }
        w.end().unwrap();
        w.key("meta").unwrap();
        w.value(&Json::obj(vec![("unit", Json::str("us"))])).unwrap();
        w.end().unwrap();
        let bytes = w.finish().unwrap();
        let v = Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap();
        assert_eq!(v.get("name").as_str(), Some("tr\"ace\n"));
        let events = v.get("events").as_arr().unwrap();
        assert_eq!(events.len(), 3);
        assert_eq!(events[1].get("ts").as_i64(), Some(1000));
        assert_eq!(events[1].get("dur").as_f64(), Some(1.5));
        assert_eq!(events[1].get("ok"), &Json::Bool(false));
        assert_eq!(events[2].get("parent"), &Json::Null);
        assert_eq!(v.get("meta").get("unit").as_str(), Some("us"));
    }

    #[test]
    fn json_writer_matches_display_emission() {
        // The streaming writer and the buffered Display emitter must
        // agree byte-for-byte on the same document.
        let doc = Json::obj(vec![
            ("a", Json::Arr(vec![Json::num(1.0), Json::num(2.5), Json::str("x")])),
            ("b", Json::Bool(true)),
        ]);
        let mut w = JsonWriter::new(Vec::new());
        w.begin_obj().unwrap();
        w.key("a").unwrap();
        w.begin_arr().unwrap();
        w.num_val(1.0).unwrap();
        w.num_val(2.5).unwrap();
        w.str_val("x").unwrap();
        w.end().unwrap();
        w.key("b").unwrap();
        w.bool_val(true).unwrap();
        w.end().unwrap();
        let bytes = w.finish().unwrap();
        assert_eq!(std::str::from_utf8(&bytes).unwrap(), doc.to_string());
    }

    #[test]
    fn string_escaping_covers_quotes_backslashes_controls_and_non_ascii() {
        // Every string class the trace exporter can emit (span names,
        // attribute values, file paths) must escape to valid JSON and
        // round-trip through the crate's own parser unchanged.
        let cases: &[(&str, &str)] = &[
            ("quote\"inside", r#""quote\"inside""#),
            (r"back\slash", r#""back\\slash""#),
            ("C:\\path\\to\"file\"", r#""C:\\path\\to\"file\"""#),
            ("line\nfeed", r#""line\nfeed""#),
            ("tab\there", r#""tab\there""#),
            ("cr\rhere", r#""cr\rhere""#),
            ("nul\u{0}byte", r#""nul\u0000byte""#),
            ("bell\u{7}esc\u{1b}", r#""bell\u0007esc\u001b""#),
            // Non-ASCII passes through raw (UTF-8, not \u-escaped).
            ("naïve — 日本語 🚀", "\"naïve — 日本語 🚀\""),
            ("", r#""""#),
        ];
        for (raw, want) in cases {
            // Via the streaming writer, as a value and as a key.
            let mut w = JsonWriter::new(Vec::new());
            w.begin_obj().unwrap();
            w.key(raw).unwrap();
            w.str_val(raw).unwrap();
            w.end().unwrap();
            let bytes = w.finish().unwrap();
            let text = String::from_utf8(bytes).unwrap();
            assert_eq!(text, format!("{{{want}:{want}}}"), "emission for {raw:?}");
            let v = Json::parse(&text).unwrap();
            assert_eq!(v.get(raw).as_str(), Some(*raw), "round-trip for {raw:?}");
            // And via the buffered Display emitter — byte-identical.
            assert_eq!(Json::str(*raw).to_string(), *want);
        }
    }

    #[test]
    fn every_control_char_round_trips() {
        // All 32 C0 controls in one string: the writer must produce
        // parseable JSON (short escapes where they exist, \u00xx
        // otherwise) that parses back to the identical string.
        let raw: String = (0u32..0x20).map(|c| char::from_u32(c).unwrap()).collect();
        let mut w = JsonWriter::new(Vec::new());
        w.str_val(&raw).unwrap();
        let text = String::from_utf8(w.finish().unwrap()).unwrap();
        assert!(
            text.bytes().all(|b| (0x20..0x80).contains(&b)),
            "controls must be escaped to printable ASCII: {text:?}"
        );
        assert_eq!(Json::parse(&text).unwrap(), Json::Str(raw));
    }

    #[test]
    fn json_writer_root_scalar_and_empty_containers() {
        let mut w = JsonWriter::new(Vec::new());
        w.begin_arr().unwrap();
        w.begin_obj().unwrap();
        w.end().unwrap();
        w.begin_arr().unwrap();
        w.end().unwrap();
        w.end().unwrap();
        let bytes = w.finish().unwrap();
        assert_eq!(std::str::from_utf8(&bytes).unwrap(), "[{},[]]");
        assert!(Json::parse("[{},[]]").is_ok());
    }
}
