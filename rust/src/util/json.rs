//! Minimal JSON parser + emitter (serde_json is not in the vendor set).
//!
//! Supports the full JSON data model minus exotic number forms; good
//! enough for artifact manifests, config files, and report output. The
//! parser is recursive-descent over bytes with proper string escapes.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    /// Compact emission; strings are escaped per RFC 8259.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || b".eE+-".contains(&c))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self
                        .peek()
                        .ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: keep it simple, replace.
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{FFFD}'),
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(*v.get("d"), Json::Null);
        assert_eq!(*v.get("missing"), Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let v = Json::obj(vec![
            ("x", Json::Num(1.5)),
            ("s", Json::str("he\"llo\n")),
            ("a", Json::Arr(vec![Json::Bool(false), Json::Null])),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn manifest_like_document() {
        let text = r#"{
          "name": "asr_encoder_ref",
          "args": [{"name": "feats", "shape": [16, 96, 40], "dtype": "float32"}],
          "model": {"d_model": 64, "tile": 8}
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("name").as_str(), Some("asr_encoder_ref"));
        let arg0 = &v.get("args").as_arr().unwrap()[0];
        let shape: Vec<usize> = arg0
            .get("shape")
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![16, 96, 40]);
        assert_eq!(v.get("model").get("tile").as_i64(), Some(8));
    }
}
