//! Infrastructure the vendored crate set does not provide: a JSON
//! parser/emitter, a deterministic RNG, a micro-benchmark harness, and a
//! small property-testing runner.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
