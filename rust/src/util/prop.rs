//! Tiny property-testing runner (proptest is not in the vendor set).
//!
//! Runs a property over `n` seeded random cases; on failure it reports the
//! failing case index and seed so the case can be replayed exactly:
//!
//! ```no_run
//! // (no_run: doctest binaries don't get the crate's xla rpath flags)
//! use sasp::util::prop::check;
//! check("addition commutes", 256, |rng| {
//!     let (a, b) = (rng.next_u64() as u32, rng.next_u64() as u32);
//!     let ok = a.wrapping_add(b) == b.wrapping_add(a);
//!     (ok, format!("a={a} b={b}"))
//! });
//! ```

use super::rng::Rng;

/// Run `prop` for `cases` seeded cases. The property returns
/// `(holds, context)`; on the first failure this panics with the seed and
/// the property's own context string.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng) -> (bool, String)) {
    // A fixed base seed keeps CI deterministic; per-case seeds derive
    // from it so cases are independent and individually replayable.
    let base = 0x5A5E_D001_CAFE_F00Du64;
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let (ok, ctx) = prop(&mut rng);
        assert!(
            ok,
            "property '{name}' failed at case {case} (seed {seed:#x}): {ctx}"
        );
    }
}

/// Replay a single case by seed (for debugging a reported failure).
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Rng) -> (bool, String)) -> (bool, String) {
    let mut rng = Rng::new(seed);
    prop(&mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u64 xor involution", 64, |rng| {
            let x = rng.next_u64();
            let k = rng.next_u64();
            ((x ^ k) ^ k == x, format!("x={x}"))
        });
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn failing_property_panics_with_context() {
        check("always-false", 4, |_| (false, "ctx".into()));
    }
}
