//! Micro-benchmark harness (criterion is not in the vendor set).
//!
//! Each `cargo bench` target is a plain binary that uses [`Bench`] to run
//! warmup + timed iterations and print a stable, parseable report:
//!
//! ```text
//! bench <name>  iters=256  median=1.234ms  p95=1.301ms  mean=1.245ms
//! ```

use std::time::{Duration, Instant};

/// One benchmark runner with fixed warmup/measure budgets.
pub struct Bench {
    /// Target wall-clock budget for the measurement phase.
    pub measure_budget: Duration,
    /// Warmup budget before measuring.
    pub warmup_budget: Duration,
    /// Hard cap on measured iterations.
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            measure_budget: Duration::from_millis(800),
            warmup_budget: Duration::from_millis(200),
            max_iters: 10_000,
        }
    }
}

/// Summary statistics for one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p95: Duration,
    pub mean: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn report(&self) -> String {
        format!(
            "bench {:40} iters={:<6} median={:>12?} p95={:>12?} mean={:>12?} min={:>12?}",
            self.name, self.iters, self.median, self.p95, self.mean, self.min
        )
    }

    /// Median in nanoseconds (for speedup math in harness code).
    pub fn median_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

impl Bench {
    /// Quick-profile configuration for CI-ish runs.
    pub fn quick() -> Self {
        Bench {
            measure_budget: Duration::from_millis(250),
            warmup_budget: Duration::from_millis(50),
            max_iters: 2_000,
        }
    }

    /// Run `f` repeatedly, print and return stats. `f`'s return value is
    /// passed through `std::hint::black_box` to keep the work alive.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup_budget {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure_budget && samples.len() < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let stats = Stats {
            name: name.to_string(),
            iters: n,
            median: samples[n / 2],
            p95: samples[(n * 95 / 100).min(n - 1)],
            mean: total / n as u32,
            min: samples[0],
        };
        println!("{}", stats.report());
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bench {
            measure_budget: Duration::from_millis(20),
            warmup_budget: Duration::from_millis(2),
            max_iters: 100,
        };
        let s = b.run("noop", || 1 + 1);
        assert!(s.iters >= 1);
        assert!(s.median <= s.p95);
        assert!(s.min <= s.median);
    }

    #[test]
    fn median_ns_positive_for_real_work() {
        let b = Bench {
            measure_budget: Duration::from_millis(10),
            warmup_budget: Duration::from_millis(1),
            max_iters: 50,
        };
        let s = b.run("sum", || (0..1000u64).sum::<u64>());
        assert!(s.median_ns() > 0.0);
    }
}
