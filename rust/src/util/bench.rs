//! Micro-benchmark harness (criterion is not in the vendor set).
//!
//! Each `cargo bench` target is a plain binary that uses [`Bench`] to run
//! warmup + timed iterations and print a stable, parseable report:
//!
//! ```text
//! bench <name>  iters=256  median=1.234ms  p95=1.301ms  mean=1.245ms
//! ```
//!
//! When `BENCH_HOTPATH_JSON=<path>` is set (or [`Bench::json_path`] is
//! assigned directly), every case is additionally appended to a JSON
//! array at that path (rewritten after each case, so partial results
//! survive an abort) — the machine-readable perf trajectory
//! `scripts/verify.sh` records as `BENCH_hotpath.json` and
//! EXPERIMENTS.md tracks across PRs.

use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::json::Json;

/// One benchmark runner with fixed warmup/measure budgets.
pub struct Bench {
    /// Target wall-clock budget for the measurement phase.
    pub measure_budget: Duration,
    /// Warmup budget before measuring.
    pub warmup_budget: Duration,
    /// Hard cap on measured iterations.
    pub max_iters: usize,
    /// Cumulative JSON report destination (`None` = disabled).
    /// Initialized from `BENCH_HOTPATH_JSON`; tests assign it directly
    /// rather than mutating process-global environment state.
    pub json_path: Option<PathBuf>,
    /// Cases this runner has recorded (the report file is rewritten from
    /// this after every case).
    cases: Mutex<Vec<Json>>,
}

impl Default for Bench {
    /// 800 ms measure / 200 ms warmup, overridable via
    /// `BENCH_MEASURE_MS` / `BENCH_WARMUP_MS` (the short-budget smoke in
    /// `scripts/verify.sh` uses these).
    fn default() -> Self {
        fn env_ms(key: &str, default: u64) -> Duration {
            Duration::from_millis(
                std::env::var(key)
                    .ok()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(default),
            )
        }
        Bench {
            measure_budget: env_ms("BENCH_MEASURE_MS", 800),
            warmup_budget: env_ms("BENCH_WARMUP_MS", 200),
            max_iters: 10_000,
            json_path: std::env::var_os("BENCH_HOTPATH_JSON").map(PathBuf::from),
            cases: Mutex::new(Vec::new()),
        }
    }
}

/// Summary statistics for one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p95: Duration,
    pub mean: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn report(&self) -> String {
        format!(
            "bench {:40} iters={:<6} median={:>12?} p95={:>12?} mean={:>12?} min={:>12?}",
            self.name, self.iters, self.median, self.p95, self.mean, self.min
        )
    }

    /// Median in nanoseconds (for speedup math in harness code).
    pub fn median_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

impl Bench {
    /// Quick-profile configuration for CI-ish runs.
    pub fn quick() -> Self {
        Bench {
            measure_budget: Duration::from_millis(250),
            warmup_budget: Duration::from_millis(50),
            max_iters: 2_000,
            ..Bench::default()
        }
    }

    /// Run `f` repeatedly, print and return stats. `f`'s return value is
    /// passed through `std::hint::black_box` to keep the work alive.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup_budget {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure_budget && samples.len() < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let stats = Stats {
            name: name.to_string(),
            iters: n,
            median: samples[n / 2],
            p95: samples[(n * 95 / 100).min(n - 1)],
            mean: total / n as u32,
            min: samples[0],
        };
        println!("{}", stats.report());
        self.record_json(&stats);
        stats
    }

    /// Record an externally measured value as a one-iteration case —
    /// for metrics a timed closure cannot express (e.g. a serving run's
    /// internal p99 latency). Prints and lands in the JSON report like
    /// any other case, so `scripts/verify.sh` can guard on it.
    pub fn record(&self, name: &str, value: Duration) -> Stats {
        let stats = Stats {
            name: name.to_string(),
            iters: 1,
            median: value,
            p95: value,
            mean: value,
            min: value,
        };
        println!("{}", stats.report());
        self.record_json(&stats);
        stats
    }

    /// Append `stats` to the JSON report (no-op when `json_path` is
    /// unset). The file is rewritten after each case as: everything a
    /// *previous* writer left there (minus entries this runner is
    /// superseding by name) + this runner's cases — so `cargo bench`
    /// running several bench binaries against one report path (they all
    /// inherit `BENCH_HOTPATH_JSON`) accumulates instead of clobbering.
    /// Bench binaries run sequentially, so there are no concurrent
    /// writers within a `cargo bench` invocation.
    fn record_json(&self, stats: &Stats) {
        let Some(path) = &self.json_path else {
            return;
        };
        let mut cases = self.cases.lock().unwrap();
        cases.push(Json::obj(vec![
            ("name", Json::str(stats.name.as_str())),
            ("iters", Json::num(stats.iters as f64)),
            ("median_ns", Json::num(stats.median.as_nanos() as f64)),
            ("p95_ns", Json::num(stats.p95.as_nanos() as f64)),
            ("mean_ns", Json::num(stats.mean.as_nanos() as f64)),
            ("min_ns", Json::num(stats.min.as_nanos() as f64)),
        ]));
        let mut merged: Vec<Json> = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|v| v.as_arr().map(<[Json]>::to_vec))
            .unwrap_or_default()
            .into_iter()
            .filter(|prev| {
                !cases.iter().any(|mine| mine.get("name") == prev.get("name"))
            })
            .collect();
        merged.extend(cases.iter().cloned());
        let doc = Json::Arr(merged).to_string();
        if let Err(e) = std::fs::write(path, doc) {
            eprintln!("bench: could not write {}: {e}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Bench {
        let mut b = Bench::default();
        b.measure_budget = Duration::from_millis(10);
        b.warmup_budget = Duration::from_millis(1);
        b.max_iters = 100;
        b.json_path = None;
        b
    }

    #[test]
    fn runs_and_reports() {
        let s = tiny().run("noop", || 1 + 1);
        assert!(s.iters >= 1);
        assert!(s.median <= s.p95);
        assert!(s.min <= s.median);
    }

    #[test]
    fn json_report_written_when_path_set() {
        let path = std::env::temp_dir().join(format!(
            "bench_hotpath_test_{}.json",
            std::process::id()
        ));
        let mut b = tiny();
        b.json_path = Some(path.clone());
        b.run("json-emission-case", || 2 + 2);
        b.run("second-case", || 3 + 3);
        let text = std::fs::read_to_string(&path).expect("report file written");
        let _ = std::fs::remove_file(&path);
        let doc = Json::parse(&text).expect("valid json");
        let arr = doc.as_arr().expect("array of cases");
        assert_eq!(arr.len(), 2, "one entry per case");
        let case = arr
            .iter()
            .find(|c| c.get("name").as_str() == Some("json-emission-case"))
            .expect("case recorded");
        assert!(case.get("median_ns").as_f64().unwrap() >= 0.0);
        assert!(case.get("iters").as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn json_report_merges_with_prior_writers() {
        // Several bench binaries share one report path under
        // `cargo bench`; a later writer must keep earlier entries.
        let path = std::env::temp_dir().join(format!(
            "bench_hotpath_merge_{}.json",
            std::process::id()
        ));
        std::fs::write(
            &path,
            r#"[{"name":"earlier-binary-case","median_ns":42}]"#,
        )
        .unwrap();
        let mut b = tiny();
        b.json_path = Some(path.clone());
        b.run("merge-case", || 1);
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let arr = Json::parse(&text).unwrap().as_arr().unwrap().to_vec();
        assert!(arr.iter().any(|c| c.get("name").as_str() == Some("earlier-binary-case")));
        assert!(arr.iter().any(|c| c.get("name").as_str() == Some("merge-case")));
    }

    #[test]
    fn record_emits_one_iteration_case() {
        let path = std::env::temp_dir().join(format!(
            "bench_hotpath_record_{}.json",
            std::process::id()
        ));
        let mut b = tiny();
        b.json_path = Some(path.clone());
        let s = b.record("external-p99", Duration::from_micros(123));
        assert_eq!(s.iters, 1);
        assert_eq!(s.median, Duration::from_micros(123));
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let arr = Json::parse(&text).unwrap().as_arr().unwrap().to_vec();
        let case = arr
            .iter()
            .find(|c| c.get("name").as_str() == Some("external-p99"))
            .expect("recorded");
        assert_eq!(case.get("median_ns").as_f64().unwrap(), 123_000.0);
    }

    #[test]
    fn median_ns_positive_for_real_work() {
        let s = tiny().run("sum", || (0..1000u64).sum::<u64>());
        assert!(s.median_ns() > 0.0);
    }
}
