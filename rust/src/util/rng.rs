//! Deterministic xoshiro256** RNG (no `rand` crate in the vendor set).
//!
//! Used everywhere randomness is needed — property tests, workload
//! generators, the serving-example traffic model — so every run is
//! reproducible from a seed.

/// xoshiro256** 1.0 by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[lo, hi)` (panics if `lo >= hi`).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.range(0, n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_inclusive_exclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        for _ in 0..10_000 {
            let x = r.range(3, 7);
            assert!((3..7).contains(&x));
            seen_lo |= x == 3;
        }
        assert!(seen_lo);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
