//! Address-level trace simulation of one tiled GEMM — the validation
//! harness for the analytic stream classification in [`super::engine`].
//!
//! The engine claims (module docs there): weight lines are cold misses,
//! touched once per live tile; input/output panel lines miss once at L2
//! and hit L1 on re-touch under the j-outer/k-inner loop order with
//! per-tile staging. This module actually *walks the addresses* of that
//! loop order through the functional L1-D + L2 caches and reports what
//! happened, so the claim is tested rather than assumed
//! (`trace_matches_analytics` below and in `rust/tests/`).

use crate::model::GemmShape;
use crate::systolic::ArrayConfig;

use super::cache::{Cache, CacheConfig};
use super::engine::TileMask;

/// Hit/miss tallies from a traced execution.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCounts {
    pub l1d_hits: u64,
    pub l1d_misses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
}

/// Two-level data-side hierarchy fed by the trace.
pub struct TraceSim {
    pub l1d: Cache,
    pub l2: Cache,
}

impl Default for TraceSim {
    fn default() -> Self {
        TraceSim {
            l1d: Cache::new(CacheConfig::l1()),
            l2: Cache::new(CacheConfig::l2()),
        }
    }
}

/// Distinct address regions, set-staggered (offset by disjoint L2 set
/// ranges) the way a page-coloring allocator would place them — so the
/// traced misses reflect capacity/compulsory behaviour, not the accident
/// of three buffers sharing set 0.
const W_BASE: u64 = 0x1000_0000;
const X_BASE: u64 = 0x4000_0000 + 2048 * 64;
const O_BASE: u64 = 0x7000_0000 + 4096 * 64;

impl TraceSim {
    /// Non-temporal touch: weight programming streams through L2 without
    /// allocating in L1 (SA_PROG uses non-temporal loads — a 512 KiB
    /// weight stream through a 32 KiB L1 would evict every activation
    /// panel; the engine's classification assumes exactly this).
    fn touch_nt(&mut self, addr: u64, c: &mut TraceCounts) {
        if self.l2.access(addr) {
            c.l2_hits += 1;
        } else {
            c.l2_misses += 1;
        }
    }

    fn touch(&mut self, addr: u64, c: &mut TraceCounts) {
        if self.l1d.access(addr) {
            c.l1d_hits += 1;
        } else {
            c.l1d_misses += 1;
            if self.l2.access(addr) {
                c.l2_hits += 1;
            } else {
                c.l2_misses += 1;
            }
        }
    }

    /// Trace one weight-stationary tiled GEMM in the engine's loop order
    /// (j outer, k inner; weights tiled-contiguous; inputs staged per
    /// tile; outputs accumulated in place). Word-granular accesses.
    pub fn trace_gemm(
        &mut self,
        g: &GemmShape,
        cfg: &ArrayConfig,
        mask: Option<&TileMask>,
    ) -> TraceCounts {
        self.trace_gemm_order(g, cfg, mask, LoopOrder::JOuter)
    }

    /// Loop-order ablation entry point (see [`LoopOrder`]).
    pub fn trace_gemm_order(
        &mut self,
        g: &GemmShape,
        cfg: &ArrayConfig,
        mask: Option<&TileMask>,
        order: LoopOrder,
    ) -> TraceCounts {
        let t = cfg.tile();
        let (kt, nt) = (g.k / t, g.n / t);
        let wbytes: u64 = match cfg.quant {
            crate::systolic::Quant::Fp32 => 4,
            crate::systolic::Quant::Int8 => 1,
        };
        let mut c = TraceCounts::default();
        let tiles: Vec<(usize, usize)> = match order {
            LoopOrder::JOuter => (0..nt)
                .flat_map(|j| (0..kt).map(move |k| (k, j)))
                .collect(),
            LoopOrder::KOuter => (0..kt)
                .flat_map(|k| (0..nt).map(move |j| (k, j)))
                .collect(),
        };
        for (k, j) in tiles {
            {
                if let Some(m) = mask {
                    if !m.is_live(k, j) {
                        continue; // SASP: pruned tile touches nothing
                    }
                }
            }
            {
                // Program: weight tile, stored contiguously in tiled
                // layout at its (k, j) slot.
                let tile_base =
                    W_BASE + ((k * nt + j) * t * t) as u64 * wbytes;
                let mut a = tile_base;
                while a < tile_base + (t * t) as u64 * wbytes {
                    self.touch_nt(a, &mut c); // non-temporal: L2 only
                    a += 4; // one 32-bit bus word per access
                }
                // Stream: M rows; read the staged input block for this
                // k-tile, read+write the output block for this j-tile.
                // Panels are *staged* in tiled layout (the
                // accelerator-driven data arrangement of paper ref [1]):
                // each m x t block is contiguous, so blocks spread across
                // cache sets instead of aliasing on the power-of-two row
                // stride of the row-major panel.
                for row in 0..g.m {
                    for w in 0..t {
                        let x_addr =
                            X_BASE + ((k * g.m * t) + row * t + w) as u64 * 4;
                        self.touch(x_addr, &mut c);
                        let o_addr =
                            O_BASE + ((j * g.m * t) + row * t + w) as u64 * 4;
                        self.touch(o_addr, &mut c);
                    }
                }
            }
        }
        c
    }
}

/// Tile visit order — the "accelerator-driven data arrangement" ablation
/// (paper ref [1]): `JOuter` keeps the output block L1-resident across
/// the K accumulation sweep (the layout the engine models); `KOuter`
/// sweeps all output columns per K tile, blowing the output reuse
/// distance past L1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopOrder {
    JOuter,
    KOuter,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GemmKind;
    use crate::systolic::Quant;

    fn ff(m: usize, k: usize, n: usize) -> GemmShape {
        GemmShape { m, k, n, kind: GemmKind::FeedForward }
    }

    fn cfg8() -> ArrayConfig {
        ArrayConfig::square(8, Quant::Fp32)
    }

    #[test]
    fn weight_lines_are_cold_in_l2() {
        // Analytic claim: every weight line misses L2 exactly once.
        let g = ff(32, 64, 64);
        let mut sim = TraceSim::default();
        let c = sim.trace_gemm(&g, &cfg8(), None);
        let weight_lines = (g.k * g.n * 4 / 64) as u64;
        // L2 misses = weight lines + unique input lines + unique output
        // lines (all cold; everything else re-hits).
        let in_lines = (g.m * g.k * 4 / 64) as u64;
        let out_lines = (g.m * g.n * 4 / 64) as u64;
        assert_eq!(c.l2_misses, weight_lines + in_lines + out_lines);
    }

    #[test]
    fn pruned_tiles_touch_nothing() {
        let g = ff(16, 32, 32);
        let mut dense_sim = TraceSim::default();
        let dense = dense_sim.trace_gemm(&g, &cfg8(), None);
        let mut mask = TileMask::full(4, 4);
        for i in 0..8 {
            mask.live[i] = false; // prune half
        }
        let mut pruned_sim = TraceSim::default();
        let pruned = pruned_sim.trace_gemm(&g, &cfg8(), Some(&mask));
        let total =
            |c: &TraceCounts| c.l1d_hits + c.l1d_misses;
        assert!(total(&pruned) < total(&dense));
        // Fully pruned GEMM: zero accesses.
        let mut sim = TraceSim::default();
        let none = sim.trace_gemm(
            &g,
            &cfg8(),
            Some(&TileMask { kt: 4, nt: 4, live: vec![false; 16] }),
        );
        assert_eq!(none, TraceCounts::default());
    }

    #[test]
    fn output_block_stays_l1_resident_across_k() {
        // j-outer loop order: the output block is re-touched kt times and
        // must hit L1 after the first touch (the engine charges it once).
        let g = ff(32, 64, 16); // small N so output panel is tiny
        let mut sim = TraceSim::default();
        let c = sim.trace_gemm(&g, &cfg8(), None);
        let out_lines = (g.m * g.n * 4 / 64) as u64;
        let kt = (g.k / 8) as u64;
        // Output touches: m*t per tile * kt*nt tiles = m*n*kt words; all
        // but the first line-touch must be L1 hits. Verify via upper
        // bound on l1d misses: unique lines only.
        let unique = out_lines
            + (g.m * g.k * 4 / 64) as u64
            + (g.k * g.n * 4 / 64) as u64;
        assert!(
            c.l1d_misses <= unique + unique / 8, // small conflict slack
            "l1 misses {} vs unique lines {unique} (kt={kt})",
            c.l1d_misses
        );
    }

    #[test]
    fn int8_weights_quarter_the_weight_lines() {
        let g = ff(8, 64, 64);
        let mut f = TraceSim::default();
        let cf = f.trace_gemm(&g, &ArrayConfig::square(8, Quant::Fp32), None);
        let mut i = TraceSim::default();
        let ci = i.trace_gemm(&g, &ArrayConfig::square(8, Quant::Int8), None);
        // Same streaming; weight region shrinks 4x -> fewer L2 misses.
        assert!(ci.l2_misses < cf.l2_misses);
        let diff = cf.l2_misses - ci.l2_misses;
        let fp32_weight_lines = (g.k * g.n * 4 / 64) as u64;
        assert_eq!(diff, fp32_weight_lines - fp32_weight_lines / 4);
    }

    #[test]
    fn k_outer_order_thrashes_l1() {
        // The data-arrangement ablation: k-outer ordering must produce
        // strictly more L1 misses than j-outer on a shape whose output
        // panel exceeds L1 but fits L2.
        // Input panel (16 KiB) fits L1; output panel (128 KiB) does not:
        // j-outer keeps both hot per iteration, k-outer re-sweeps the
        // output panel per K tile.
        let g = ff(64, 64, 512);
        let mut a = TraceSim::default();
        let j = a.trace_gemm_order(&g, &cfg8(), None, LoopOrder::JOuter);
        let mut b = TraceSim::default();
        let k = b.trace_gemm_order(&g, &cfg8(), None, LoopOrder::KOuter);
        assert!(k.l1d_misses > j.l1d_misses * 2,
                "k-outer {} vs j-outer {}", k.l1d_misses, j.l1d_misses);
    }

    #[test]
    fn trace_matches_engine_analytics() {
        // The analytic engine's DRAM count (weight lines) must equal the
        // traced L2 weight-miss count for a live-tile run.
        use crate::sysim::engine::gemm_on_array;
        use crate::sysim::SimParams;
        let g = ff(32, 64, 64);
        let cfg = cfg8();
        let p = SimParams::default();
        let analytic = gemm_on_array(&g, &cfg, &p, None);
        let mut sim = TraceSim::default();
        let traced = sim.trace_gemm(&g, &cfg, None);
        let in_out_lines = ((g.m * g.k + g.m * g.n) * 4 / 64) as u64;
        let traced_weight_misses = traced.l2_misses - in_out_lines;
        assert_eq!(analytic.counts.dram_accesses, traced_weight_misses);
    }
}
