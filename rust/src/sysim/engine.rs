//! Per-GEMM tiled-execution accounting — the heart of the system model.
//!
//! A GEMM `[M,K]x[K,N]` is tiled into `ceil(K/t) x ceil(N/t)` weight
//! tiles (t = array dimension). Per §3.1/Fig. 3, execution follows the
//! accelerator-driven data arrangement of the paper's companion works
//! ([1], [2]): the j (output-column) loop is outermost so the output
//! block stays L1-resident across the K accumulation sweep, input blocks
//! are staged per tile, and weight tiles are stored contiguously in
//! tiled layout.
//!
//! Cost structure per **live** tile:
//! - `SA_CTRL` setup + `ceil(t²/wpw)` `SA_PROG` + `M·t` `SA_STREAM`
//!   issue cycles (single-issue, in-order);
//! - weight lines are cold (first and only touch) → L2 + DRAM latency;
//! - unique input/output lines stall once at L2 latency; repeats hit L1.
//!
//! A **pruned** tile is skipped entirely: no instructions, no weight
//! fetch, no streaming — the SASP saving (the input/output blocks it
//! shared with live tiles in the same row/column are still touched by
//! those tiles).

use crate::hwmodel::SysCounts;
use crate::model::{GemmKind, GemmShape};
use crate::systolic::{ArrayConfig, Occupancy, Quant, TileTiming};

use super::params::SimParams;

/// Live/pruned map over a GEMM's weight tiles (row-major `kt x nt`).
#[derive(Clone, Debug, PartialEq)]
pub struct TileMask {
    pub kt: usize,
    pub nt: usize,
    pub live: Vec<bool>,
}

impl TileMask {
    pub fn full(kt: usize, nt: usize) -> Self {
        TileMask { kt, nt, live: vec![true; kt * nt] }
    }

    pub fn n_tiles(&self) -> usize {
        self.kt * self.nt
    }

    pub fn live_count(&self) -> usize {
        self.live.iter().filter(|l| **l).count()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.live_count() as f64 / self.n_tiles().max(1) as f64
    }

    pub fn is_live(&self, k: usize, n: usize) -> bool {
        self.live[k * self.nt + n]
    }
}

/// Cost of one GEMM execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct GemmCost {
    /// Core cycles (issue + memory stalls); the array overlaps with
    /// streaming, so this is the wall-clock contribution.
    pub cycles: f64,
    pub counts: SysCounts,
    /// PE-cycle occupancy breakdown over the array's execution, identical
    /// to the per-tile [`TileTiming`] charges of the functional kernels.
    pub occ: Occupancy,
}

impl GemmCost {
    pub fn add(&mut self, o: &GemmCost) {
        self.cycles += o.cycles;
        self.counts.add(&o.counts);
        self.occ.add(&o.occ);
    }
}

/// Execute a GEMM on the systolic array.
///
/// `mask` applies only to prunable (feed-forward) GEMMs; `None` means all
/// tiles are live. Dynamic attention GEMMs stream their "weights" (K/V
/// activations) at FP32 regardless of the quantization mode — PTQ applies
/// to stored weights only.
pub fn gemm_on_array(
    g: &GemmShape,
    cfg: &ArrayConfig,
    p: &SimParams,
    mask: Option<&TileMask>,
) -> GemmCost {
    gemm_on_array_batched(g, cfg, p, mask, 1)
}

/// Batched weight-stationary execution: `batch` input blocks of `g.m`
/// rows each run through the same tile schedule, with every live tile
/// programmed **once** and streamed by all blocks before the schedule
/// moves on ([`TileTiming::batched`] — one live pass plus `batch - 1`
/// reuse passes per tile). This is the analytic counterpart of the
/// batched serving engine ([`crate::infer::batch`]); `batch == 1`
/// reduces exactly to [`gemm_on_array`].
pub fn gemm_on_array_batched(
    g: &GemmShape,
    cfg: &ArrayConfig,
    p: &SimParams,
    mask: Option<&TileMask>,
    batch: usize,
) -> GemmCost {
    assert!(batch > 0, "batched execution needs at least one input block");
    let t = cfg.tile();
    let kt = g.k.div_ceil(t);
    let nt = g.n.div_ceil(t);
    let n_tiles = kt * nt;
    if let Some(m) = mask {
        assert_eq!((m.kt, m.nt), (kt, nt), "mask/gemm tile grid mismatch");
        assert!(g.kind.prunable() || m.live_count() == m.n_tiles(),
                "only feed-forward GEMMs may be pruned");
    }
    let live = mask.map_or(n_tiles, TileMask::live_count);

    // Weight format for this GEMM: dynamic GEMMs are always FP32.
    let (wpw, wbytes) = match (g.kind, cfg.quant) {
        (GemmKind::AttnDyn, _) | (_, Quant::Fp32) => (1usize, 4usize),
        (_, Quant::Int8) => (4, 1),
    };
    let quant_extra = if wpw == 4 { p.quant_tile_extra_cycles } else { 0.0 };

    let tile_cfg = ArrayConfig {
        rows: t,
        cols: t,
        quant: if wpw == 4 { Quant::Int8 } else { Quant::Fp32 },
    };
    // One programming pass + (batch-1) reuse passes per live tile.
    let per_tile = TileTiming::batched(&tile_cfg, g.m, batch);

    // --- issue cycles ----------------------------------------------------
    // Setup and the quantized-programming surcharge are tied to tile
    // programming, so they are charged once per live tile regardless of
    // how many blocks reuse it; stream_insts already scales with batch.
    let issue = live as f64
        * (per_tile.prog_words as f64 * p.cpi_prog
            + per_tile.stream_insts as f64 * p.cpi_stream
            + p.tile_setup_cycles
            + quant_extra);

    // --- memory stalls ---------------------------------------------------
    let line = p.line_bytes as f64;
    // Weights: cold, tiled-contiguous; only live tiles are fetched, and
    // only once — the reuse passes hit the already-programmed array.
    let weight_lines = (live * t * t) as f64 * wbytes as f64 / line;
    // Inputs/outputs: unique lines touched once at L2 latency (see module
    // docs); sized by the full M x K / M x N panels of every block.
    let in_lines = (batch * g.m * g.k * 4) as f64 / line;
    let out_lines = (batch * g.m * g.n * 4) as f64 / line;
    let stalls = weight_lines * (p.dram_latency + p.l2_latency) as f64
        + (in_lines + out_lines) * p.l2_latency as f64;

    // --- event counts ------------------------------------------------------
    let total_insts = live as f64
        * (per_tile.prog_words + per_tile.stream_insts + 2) as f64;
    let bus_words = live * per_tile.total_words();
    let stream_words = live * (per_tile.in_words + per_tile.out_words);
    let cycles = issue + stalls;

    // Occupancy: live tiles contribute their batched per-tile breakdown;
    // each pruned tile records the `batch * m * t * t` PE-cycles of work
    // it avoided (== [`TileTiming::skipped_pass`]).
    let dead = n_tiles - live;
    let occ = Occupancy {
        active_pe_cycles: live * per_tile.occ.active_pe_cycles,
        bubble_pe_cycles: live * per_tile.occ.bubble_pe_cycles,
        stall_pe_cycles: live * per_tile.occ.stall_pe_cycles,
        skipped_pe_cycles: dead * batch * g.m * t * t,
    };

    let counts = SysCounts {
        core_cycles: cycles as u64,
        array_busy_cycles: (live * per_tile.array_cycles) as u64,
        macs: (live * per_tile.macs) as u64,
        bus_words: bus_words as u64,
        l1i_hits: total_insts as u64,
        // Every streamed word touches L1D; misses counted below as L2/DRAM.
        l1d_hits: stream_words as u64,
        l2_hits: (in_lines + out_lines) as u64 + weight_lines as u64,
        dram_accesses: weight_lines as u64,
    };
    GemmCost { cycles, counts, occ }
}

/// Autoregressive decode-step scheduling: the same weight GEMM executed
/// once per generated token with a single-row (`m = 1`) input — the
/// skinny GEMV shape of KV-cached decoding, where tile occupancy shrinks
/// to one activation row per pass (FlexSA's motivating regime). Each
/// step re-programs the live tiles (the array is shared by every GEMM of
/// a layer between steps), so the per-step cost is exactly
/// [`gemm_on_array`] at `m = 1` and the decode total is linear in
/// `steps`. This is the analytic counterpart of the functional decoder's
/// per-step [`TileTiming`] accounting ([`crate::infer::decoder`]);
/// cross-attention K/V GEMMs are *not* decode-stepped — they run once
/// per utterance at `m = src_len` and are reused every step.
pub fn gemm_on_array_decode(
    g: &GemmShape,
    cfg: &ArrayConfig,
    p: &SimParams,
    mask: Option<&TileMask>,
    steps: usize,
) -> GemmCost {
    let g1 = GemmShape { m: 1, ..*g };
    let per_step = gemm_on_array(&g1, cfg, p, mask);
    let mut total = GemmCost::default();
    for _ in 0..steps {
        total.add(&per_step);
    }
    total
}

/// Continuous (iteration-level) batched decode scheduling: at step `s`
/// the scheduler has `schedule[s]` in-flight decodes, so the per-token
/// `m = 1` GEMVs batch into one weight-stationary `[schedule[s], k]`
/// panel — each live tile programmed once per step and streamed by
/// every live slot ([`gemm_on_array_batched`] at `m = 1`). The batch
/// composition may change every step (slots join and leave between
/// steps), which is why this takes the whole per-step slot-count
/// schedule instead of a single `(steps, batch)` pair. An all-ones
/// schedule degenerates to [`gemm_on_array_decode`]; a zero entry
/// (empty panel — nothing live that step) charges nothing. This is the
/// analytic counterpart of the functional continuous decoder's
/// per-step [`TileTiming::batched`] charges
/// ([`crate::infer::decoder::continuous`]).
pub fn gemm_on_array_decode_batched(
    g: &GemmShape,
    cfg: &ArrayConfig,
    p: &SimParams,
    mask: Option<&TileMask>,
    schedule: &[usize],
) -> GemmCost {
    let g1 = GemmShape { m: 1, ..*g };
    let mut total = GemmCost::default();
    for &k in schedule {
        if k == 0 {
            continue;
        }
        total.add(&gemm_on_array_batched(&g1, cfg, p, mask, k));
    }
    total
}

/// Software-only GEMM on the in-order core (the paper's non-accelerated
/// baseline for Table 3 / Fig. 11 speedups).
pub fn gemm_on_cpu(g: &GemmShape, p: &SimParams) -> GemmCost {
    let macs = g.macs() as f64;
    let cycles = macs * p.cpu_cycles_per_mac;
    let line = p.line_bytes as f64;
    let weight_lines = (g.k * g.n * 4) as f64 / line;
    let counts = SysCounts {
        core_cycles: cycles as u64,
        array_busy_cycles: 0,
        macs: 0, // no array MACs; core energy is per-cycle
        bus_words: 0,
        l1i_hits: macs as u64,
        l1d_hits: (2.0 * macs) as u64,
        l2_hits: weight_lines as u64,
        dram_accesses: weight_lines as u64,
    };
    // No array involved: zero occupancy on every axis.
    GemmCost { cycles, counts, occ: Occupancy::default() }
}

/// Non-GEMM software ops over `elems` elements (LayerNorm, softmax,
/// residual, activation) — NEON-vectorized on the core.
pub fn non_gemm_cost(elems: u64, p: &SimParams) -> GemmCost {
    let cycles = elems as f64 * p.non_gemm_cycles_per_elem;
    GemmCost {
        cycles,
        counts: SysCounts {
            core_cycles: cycles as u64,
            l1i_hits: elems / 4,
            l1d_hits: elems,
            ..Default::default()
        },
        occ: Occupancy::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GemmKind;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn ff(m: usize, k: usize, n: usize) -> GemmShape {
        GemmShape { m, k, n, kind: GemmKind::FeedForward }
    }

    fn cfg(t: usize, q: Quant) -> ArrayConfig {
        ArrayConfig::square(t, q)
    }

    #[test]
    fn full_mask_equals_no_mask() {
        let g = ff(64, 64, 128);
        let p = SimParams::default();
        let c = cfg(8, Quant::Fp32);
        let a = gemm_on_array(&g, &c, &p, None);
        let mask = TileMask::full(8, 16);
        let b = gemm_on_array(&g, &c, &p, Some(&mask));
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.counts, b.counts);
    }

    #[test]
    fn batched_batch_one_equals_per_utterance() {
        let g = ff(96, 64, 256);
        let p = SimParams::default();
        for quant in [Quant::Fp32, Quant::Int8] {
            let c = cfg(8, quant);
            let mut mask = TileMask::full(8, 32);
            for (i, l) in mask.live.iter_mut().enumerate() {
                *l = i % 3 != 0;
            }
            let single = gemm_on_array(&g, &c, &p, Some(&mask));
            let batched = gemm_on_array_batched(&g, &c, &p, Some(&mask), 1);
            assert_eq!(single.cycles, batched.cycles, "{quant:?}");
            assert_eq!(single.counts, batched.counts, "{quant:?}");
            assert_eq!(single.occ, batched.occ, "{quant:?}");
        }
    }

    #[test]
    fn occupancy_conserves_array_cycles_and_skips() {
        // active + bubble must exactly tile the array-busy time across all
        // PEs, and skipped must equal the MAC-work of pruned tiles.
        let g = ff(96, 64, 256);
        let p = SimParams::default();
        let b = 3usize;
        for quant in [Quant::Fp32, Quant::Int8] {
            let c = cfg(8, quant);
            let mut mask = TileMask::full(8, 32);
            for (i, l) in mask.live.iter_mut().enumerate() {
                *l = i % 3 != 0;
            }
            let dead = mask.n_tiles() - mask.live_count();
            let cost = gemm_on_array_batched(&g, &c, &p, Some(&mask), b);
            let occ = cost.occ;
            assert_eq!(
                (occ.active_pe_cycles + occ.bubble_pe_cycles) as u64,
                cost.counts.array_busy_cycles * c.n_pes() as u64,
                "{quant:?}: active+bubble must tile array-busy time"
            );
            // One PE-cycle per MAC in the weight-stationary dataflow.
            assert_eq!(occ.active_pe_cycles as u64, cost.counts.macs, "{quant:?}");
            assert_eq!(
                occ.skipped_pe_cycles,
                dead * b * g.m * 64,
                "{quant:?}: skipped == avoided MAC-work of pruned tiles"
            );
            assert!(occ.utilization() > 0.0 && occ.utilization() < 1.0);
        }
    }

    #[test]
    fn batched_reuse_saves_exactly_programming() {
        // vs running the same block `b` times per-utterance: streaming,
        // MACs and array occupancy scale with b, while weight traffic
        // (programming words, DRAM weight lines) is charged once.
        let g = ff(96, 64, 256);
        let p = SimParams::default();
        let b = 4usize;
        for quant in [Quant::Fp32, Quant::Int8] {
            let c = cfg(8, quant);
            let mut mask = TileMask::full(8, 32);
            for (i, l) in mask.live.iter_mut().enumerate() {
                *l = i % 2 == 0;
            }
            let live = mask.live_count();
            let single = gemm_on_array(&g, &c, &p, Some(&mask));
            let batched = gemm_on_array_batched(&g, &c, &p, Some(&mask), b);
            assert_eq!(batched.counts.macs, b as u64 * single.counts.macs);
            assert_eq!(
                batched.counts.array_busy_cycles,
                b as u64 * single.counts.array_busy_cycles
            );
            assert_eq!(batched.counts.dram_accesses, single.counts.dram_accesses);
            let tile_cfg = ArrayConfig { rows: 8, cols: 8, quant };
            let prog = TileTiming::live(&tile_cfg, g.m).prog_words;
            assert_eq!(
                b as u64 * single.counts.bus_words - batched.counts.bus_words,
                ((b - 1) * live * prog) as u64,
                "{quant:?}: reuse must save exactly (b-1) programming passes"
            );
            assert!(
                batched.cycles < b as f64 * single.cycles,
                "{quant:?}: batched must beat b per-utterance runs"
            );
        }
    }

    #[test]
    fn decode_steps_are_linear_and_match_m1_gemm() {
        let g = ff(96, 64, 256);
        let p = SimParams::default();
        for quant in [Quant::Fp32, Quant::Int8] {
            let c = cfg(8, quant);
            let mut mask = TileMask::full(8, 32);
            for (i, l) in mask.live.iter_mut().enumerate() {
                *l = i % 3 != 0;
            }
            let one = gemm_on_array_decode(&g, &c, &p, Some(&mask), 1);
            let g1 = GemmShape { m: 1, ..g };
            let want = gemm_on_array(&g1, &c, &p, Some(&mask));
            assert_eq!(one.counts, want.counts, "{quant:?}");
            assert_eq!(one.cycles, want.cycles, "{quant:?}");
            let many = gemm_on_array_decode(&g, &c, &p, Some(&mask), 17);
            assert_eq!(many.counts.macs, 17 * one.counts.macs, "{quant:?}");
            assert_eq!(many.counts.bus_words, 17 * one.counts.bus_words);
            assert_eq!(
                many.counts.array_busy_cycles,
                17 * one.counts.array_busy_cycles
            );
        }
    }

    #[test]
    fn decode_step_reprograms_while_batched_reuses() {
        // The decode regime's cost structure: `steps` single-row passes
        // re-program the tiles every step, while the weight-stationary
        // batched schedule of the same total row count programs once —
        // the gap is exactly the repeated programming traffic.
        let g = ff(1, 64, 256);
        let p = SimParams::default();
        let c = cfg(8, Quant::Int8);
        let steps = 24usize;
        let decode = gemm_on_array_decode(&g, &c, &p, None, steps);
        let batched = gemm_on_array_batched(&g, &c, &p, None, steps);
        assert_eq!(decode.counts.macs, batched.counts.macs);
        let tile_cfg = ArrayConfig { rows: 8, cols: 8, quant: Quant::Int8 };
        let prog = TileTiming::live(&tile_cfg, 1).prog_words as u64;
        let n_tiles = 8u64 * 32;
        assert_eq!(
            decode.counts.bus_words - batched.counts.bus_words,
            (steps as u64 - 1) * n_tiles * prog,
            "per-step reprogramming is the decode overhead"
        );
    }

    #[test]
    fn decode_batched_all_ones_schedule_degenerates_to_decode() {
        // slot count 1 every step == the sequential per-utterance decode
        // schedule, exactly.
        let g = ff(96, 64, 256);
        let p = SimParams::default();
        for quant in [Quant::Fp32, Quant::Int8] {
            let c = cfg(8, quant);
            let mut mask = TileMask::full(8, 32);
            for (i, l) in mask.live.iter_mut().enumerate() {
                *l = i % 3 != 0;
            }
            let ones = vec![1usize; 17];
            let cont = gemm_on_array_decode_batched(&g, &c, &p, Some(&mask), &ones);
            let seq = gemm_on_array_decode(&g, &c, &p, Some(&mask), 17);
            assert_eq!(cont.counts, seq.counts, "{quant:?}");
            assert_eq!(cont.cycles, seq.cycles, "{quant:?}");
        }
    }

    #[test]
    fn decode_batched_schedule_sums_per_step_panels() {
        // Each schedule entry charges exactly one `m = 1` batched panel
        // at that slot count; zero entries (empty panel) charge nothing.
        let g = ff(96, 64, 256);
        let p = SimParams::default();
        let c = cfg(8, Quant::Int8);
        let mut mask = TileMask::full(8, 32);
        for (i, l) in mask.live.iter_mut().enumerate() {
            *l = i % 4 != 1;
        }
        let g1 = GemmShape { m: 1, ..g };
        let schedule = [4usize, 4, 0, 3, 1, 2];
        let total = gemm_on_array_decode_batched(&g, &c, &p, Some(&mask), &schedule);
        let mut want = GemmCost::default();
        for &k in schedule.iter().filter(|&&k| k > 0) {
            want.add(&gemm_on_array_batched(&g1, &c, &p, Some(&mask), k));
        }
        assert_eq!(total.counts, want.counts);
        assert_eq!(total.cycles, want.cycles);
        // The full-panel steps amortize programming: per-slot bus words
        // at k=4 are strictly below the sequential (k=1) per-slot cost.
        let full = gemm_on_array_batched(&g1, &c, &p, Some(&mask), 4);
        let one = gemm_on_array_batched(&g1, &c, &p, Some(&mask), 1);
        assert!(
            full.counts.bus_words < 4 * one.counts.bus_words,
            "batched panel must amortize tile programming"
        );
    }

    #[test]
    fn pruning_reduces_cycles_proportionally() {
        let g = ff(256, 512, 2048);
        let p = SimParams::default();
        let c = cfg(8, Quant::Fp32);
        let full = gemm_on_array(&g, &c, &p, None);
        let mut mask = TileMask::full(64, 256);
        // Prune half the tiles.
        for i in 0..mask.live.len() {
            mask.live[i] = i % 2 == 0;
        }
        let half = gemm_on_array(&g, &c, &p, Some(&mask));
        // Issue + weight traffic halves; panel stalls are shared, so the
        // ratio lands between 0.5 and 0.6 for this shape.
        let ratio = half.cycles / full.cycles;
        assert!(ratio > 0.45 && ratio < 0.65, "ratio {ratio}");
        assert_eq!(half.counts.macs * 2, full.counts.macs);
    }

    #[test]
    fn empty_mask_costs_only_panel_stalls() {
        let g = ff(64, 64, 64);
        let p = SimParams::default();
        let c = cfg(8, Quant::Fp32);
        let mask = TileMask { kt: 8, nt: 8, live: vec![false; 64] };
        let cost = gemm_on_array(&g, &c, &p, Some(&mask));
        assert_eq!(cost.counts.macs, 0);
        assert_eq!(cost.counts.bus_words, 0);
        assert!(cost.cycles > 0.0, "panel classification still charged");
    }

    #[test]
    fn int8_reduces_weight_traffic_not_streaming() {
        let g = ff(256, 512, 2048);
        let p = SimParams::default();
        let f = gemm_on_array(&g, &cfg(8, Quant::Fp32), &p, None);
        let i = gemm_on_array(&g, &cfg(8, Quant::Int8), &p, None);
        assert!(i.counts.dram_accesses < f.counts.dram_accesses);
        assert!(i.counts.bus_words < f.counts.bus_words);
        assert_eq!(i.counts.l1d_hits, f.counts.l1d_hits); // stream words equal
    }

    #[test]
    fn dynamic_gemm_ignores_quantization() {
        let g = GemmShape { m: 256, k: 64, n: 256, kind: GemmKind::AttnDyn };
        let p = SimParams::default();
        let f = gemm_on_array(&g, &cfg(8, Quant::Fp32), &p, None);
        let i = gemm_on_array(&g, &cfg(8, Quant::Int8), &p, None);
        assert_eq!(f.cycles, i.cycles);
    }

    #[test]
    #[should_panic(expected = "only feed-forward")]
    fn pruning_attention_rejected() {
        let g = GemmShape { m: 8, k: 8, n: 8, kind: GemmKind::AttnProj };
        let mut mask = TileMask::full(1, 1);
        mask.live[0] = false;
        let _ = gemm_on_array(
            &g,
            &cfg(8, Quant::Fp32),
            &SimParams::default(),
            Some(&mask),
        );
    }

    #[test]
    fn larger_array_fewer_cycles_sublinear() {
        let g = ff(256, 512, 2048);
        let p = SimParams::default();
        let c8 = gemm_on_array(&g, &cfg(8, Quant::Fp32), &p, None).cycles;
        let c32 = gemm_on_array(&g, &cfg(32, Quant::Fp32), &p, None).cycles;
        let gain = c8 / c32;
        assert!(gain > 1.5 && gain < 4.0, "8->32 gain {gain} must be sublinear (<4x)");
    }

    #[test]
    fn cpu_baseline_slower_than_any_array() {
        let g = ff(128, 256, 256);
        let p = SimParams::default();
        let cpu = gemm_on_cpu(&g, &p).cycles;
        for t in [4, 8, 16, 32] {
            let acc = gemm_on_array(&g, &cfg(t, Quant::Fp32), &p, None).cycles;
            assert!(cpu > acc, "t={t}");
        }
    }

    #[test]
    fn prop_cycles_monotone_in_live_tiles() {
        check("cycles monotone in live tiles", 24, |rng: &mut Rng| {
            let g = ff(64, 128, 128);
            let p = SimParams::default();
            let c = cfg(8, Quant::Int8);
            let (kt, nt) = (16, 16);
            let mut live = vec![false; kt * nt];
            for l in live.iter_mut() {
                *l = rng.chance(0.5);
            }
            let m1 = TileMask { kt, nt, live: live.clone() };
            // Add one more live tile (if any dead).
            let dead: Vec<usize> =
                (0..live.len()).filter(|i| !live[*i]).collect();
            if dead.is_empty() {
                return (true, String::new());
            }
            live[dead[rng.index(dead.len())]] = true;
            let m2 = TileMask { kt, nt, live };
            let c1 = gemm_on_array(&g, &c, &p, Some(&m1)).cycles;
            let c2 = gemm_on_array(&g, &c, &p, Some(&m2)).cycles;
            (c2 > c1, format!("c1={c1} c2={c2}"))
        });
    }
}
