//! Full-system simulation — the gem5-X tier of the paper's framework.
//!
//! The paper runs complete inferences inside gem5 (Table 2 system: one
//! in-order ARMv8 core @ 1 GHz, 32 kB L1s, 1 MB L2, DDR4-2400, plus a
//! tightly coupled systolic array driven by custom instructions). Address-
//! level simulation of billions of accesses is intractable for the design
//! sweeps here, so this module implements the same mechanisms at *tile
//! pass* granularity:
//!
//! - [`cache::Cache`] — a functional set-associative LRU cache, used
//!   directly by unit tests and to validate the analytic stream
//!   classification on small GEMMs;
//! - [`isa`] — the custom accelerator instructions of §3.2 and their
//!   issue costs;
//! - [`engine`] — per-GEMM tiled execution accounting (live vs skipped
//!   tiles, programming vs streaming, memory-stall classification);
//! - [`system::System`] — whole-encoder simulation producing
//!   [`crate::hwmodel::SysCounts`], per-layer cycle breakdowns, and the
//!   software-only CPU baseline.

pub mod cache;
pub mod engine;
pub mod isa;
pub mod params;
pub mod system;
pub mod trace;

pub use cache::{Cache, CacheConfig};
pub use engine::{GemmCost, TileMask};
pub use params::SimParams;
pub use system::{RunStats, System};
pub use trace::{LoopOrder, TraceCounts, TraceSim};
