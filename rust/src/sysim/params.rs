//! Tunable constants of the system model, with their calibration story.
//!
//! The mechanisms (tile skipping, weight-word packing, cache/DRAM stalls,
//! per-tile software overhead) come from §3.2; the handful of scalar
//! constants below are calibrated once against the paper's Table 3
//! *no-SASP* speedup column (8.42/19.79/35.22/50.95 for FP32;
//! 8.03/20.18/36.53/61.33 for INT8) — see `rust/tests/calibration.rs`.
//! SASP results are then *predictions* of the model, not fits.

/// Simulation parameters (Table 2 system unless noted).
#[derive(Clone, Copy, Debug)]
pub struct SimParams {
    /// System clock (core, array and L1s run at 1 GHz).
    pub clock_hz: f64,
    /// Issue cycles per SA_STREAM instruction.
    pub cpi_stream: f64,
    /// Issue cycles per SA_PROG instruction.
    pub cpi_prog: f64,
    /// Fixed per-tile software overhead (loop control, address
    /// generation, SA_CTRL pair) in cycles.
    pub tile_setup_cycles: f64,
    /// Extra per-tile overhead in the weight-quantized configuration
    /// (scale setup + word packing bookkeeping). Calibrated so the
    /// FP32_INT8 configuration loses to FP32_FP32 at 4x4 but wins at
    /// >=8x8, the crossover reported in §4.5.
    pub quant_tile_extra_cycles: f64,
    /// Average cycles per MAC for the software (CPU-only) GEMM baseline
    /// on the in-order core, including its own cache behaviour.
    pub cpu_cycles_per_mac: f64,
    /// Cycles per element for non-GEMM ops (LayerNorm, softmax, residual,
    /// ReLU) with NEON vectorization.
    pub non_gemm_cycles_per_elem: f64,
    /// L1 hit latency (cycles) — overlapped for streaming accesses, so it
    /// enters energy accounting but not stall cycles.
    pub l1_latency: u64,
    /// L2 hit latency (cycles), charged per missing line.
    pub l2_latency: u64,
    /// DRAM access latency (cycles), charged per line fetched from DDR4.
    pub dram_latency: u64,
    /// L2 capacity (bytes) for stream-footprint classification.
    pub l2_bytes: usize,
    /// Cache line size (bytes).
    pub line_bytes: usize,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            clock_hz: 1e9,
            cpi_stream: 1.0,
            cpi_prog: 1.0,
            tile_setup_cycles: 30.0,
            quant_tile_extra_cycles: 100.0,
            cpu_cycles_per_mac: 2.5,
            non_gemm_cycles_per_elem: 0.25,
            l1_latency: 2,
            l2_latency: 20,
            dram_latency: 60,
            l2_bytes: 1024 * 1024,
            line_bytes: 64,
        }
    }
}

impl SimParams {
    /// Words per cache line (32-bit words).
    pub fn words_per_line(&self) -> usize {
        self.line_bytes / 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let p = SimParams::default();
        assert_eq!(p.words_per_line(), 16);
        assert!(p.cpu_cycles_per_mac > 1.0);
        assert!(p.dram_latency > p.l2_latency);
    }
}
