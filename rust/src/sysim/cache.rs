//! Functional set-associative LRU cache (Table 2 hierarchy).
//!
//! Used for unit-level validation of the analytic stream classification
//! in [`super::engine`] and available for trace-driven experiments; the
//! full-encoder simulations use the analytic path for tractability.

/// Geometry + access latency of one cache level.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub ways: usize,
    pub line_bytes: usize,
    /// Access latency in cycles (Table 2: L1 = 2, L2 = 20).
    pub latency: u64,
}

impl CacheConfig {
    /// Table 2 L1 (instruction or data): 32 kB, 2-way, 2-cycle.
    pub fn l1() -> Self {
        CacheConfig { size_bytes: 32 * 1024, ways: 2, line_bytes: 64, latency: 2 }
    }

    /// Table 2 L2: 1 MB, 2-way, 20-cycle.
    pub fn l2() -> Self {
        CacheConfig { size_bytes: 1024 * 1024, ways: 2, line_bytes: 64, latency: 20 }
    }

    pub fn n_sets(&self) -> usize {
        self.size_bytes / (self.ways * self.line_bytes)
    }
}

/// One cache level with LRU replacement.
pub struct Cache {
    cfg: CacheConfig,
    /// `tags[set * ways + way]` — line tag or `u64::MAX` when invalid.
    tags: Vec<u64>,
    /// LRU stamps parallel to `tags`.
    stamps: Vec<u64>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Self {
        let slots = cfg.n_sets() * cfg.ways;
        Cache {
            cfg,
            tags: vec![u64::MAX; slots],
            stamps: vec![0; slots],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn cfg(&self) -> &CacheConfig {
        &self.cfg
    }

    /// Access one byte address; returns `true` on hit. Misses allocate
    /// (write-allocate, no distinction between loads and stores — the
    /// paper's hierarchy is writeback/write-allocate).
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        let line = addr / self.cfg.line_bytes as u64;
        let set = (line % self.cfg.n_sets() as u64) as usize;
        let base = set * self.cfg.ways;
        // Hit?
        for w in 0..self.cfg.ways {
            if self.tags[base + w] == line {
                self.stamps[base + w] = self.clock;
                self.hits += 1;
                return true;
            }
        }
        // Miss: replace LRU way.
        self.misses += 1;
        let mut victim = 0;
        for w in 1..self.cfg.ways {
            if self.stamps[base + w] < self.stamps[base + victim] {
                victim = w;
            }
        }
        self.tags[base + victim] = line;
        self.stamps[base + victim] = self.clock;
        false
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cache {
        // 4 sets x 2 ways x 64 B = 512 B.
        Cache::new(CacheConfig { size_bytes: 512, ways: 2, line_bytes: 64, latency: 2 })
    }

    #[test]
    fn geometry() {
        assert_eq!(CacheConfig::l1().n_sets(), 256);
        assert_eq!(CacheConfig::l2().n_sets(), 8192);
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = tiny();
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(63)); // same line
        assert!(!c.access(64)); // next line
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = tiny();
        // Set 0 lines: line numbers ≡ 0 mod 4 → addrs 0, 256, 512.
        c.access(0);
        c.access(256);
        c.access(0); // refresh line 0; line 4 (256) becomes LRU
        c.access(512); // evicts 256
        assert!(c.access(0), "line 0 must survive");
        assert!(!c.access(256), "line 256 must be evicted");
    }

    #[test]
    fn streaming_working_set_larger_than_cache_always_misses() {
        let mut c = tiny();
        // Two sequential passes over 4 KiB (8x capacity).
        for pass in 0..2 {
            for addr in (0..4096u64).step_by(64) {
                c.access(addr);
            }
            if pass == 0 {
                assert_eq!(c.misses, 64);
            }
        }
        // Second pass also misses every line (LRU, no reuse distance fits).
        assert_eq!(c.misses, 128);
        assert_eq!(c.hits, 0);
    }

    #[test]
    fn small_working_set_hits_after_warmup() {
        let mut c = tiny();
        // 256 B working set fits in 512 B cache.
        for _ in 0..4 {
            for addr in (0..256u64).step_by(64) {
                c.access(addr);
            }
        }
        assert_eq!(c.misses, 4);
        assert_eq!(c.hits, 12);
    }

    #[test]
    fn word_granular_accesses_hit_within_line() {
        let mut c = Cache::new(CacheConfig::l1());
        let mut misses = 0;
        for w in 0..16u64 {
            if !c.access(w * 4) {
                misses += 1;
            }
        }
        assert_eq!(misses, 1, "16 words share one 64 B line");
    }
}
