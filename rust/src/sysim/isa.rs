//! The custom ARM ISA extension of §3.2: three instruction families that
//! drive the tightly coupled systolic array, wrapped by the "parametric
//! library functions" the paper injects via inline assembly.
//!
//! | instruction | effect | operands |
//! |---|---|---|
//! | `SA_PROG`    | program one 32-bit weight word (1×FP32 or 4×INT8) | weight word |
//! | `SA_STREAM`  | push one input activation, pop one output | 2×32-bit |
//! | `SA_CTRL`    | tile setup / drain / scale configuration | — |
//!
//! Issue costs are single-cycle on the in-order pipeline; memory operands
//! stall per the cache hierarchy (accounted by [`super::engine`]).

/// One custom instruction (kept as data so traces can be inspected and
/// the engine's counts property-tested against an explicit expansion).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SaInst {
    /// Program one 32-bit weight word into the array.
    Prog,
    /// Stream one input word in and one output word out.
    Stream,
    /// Control: tile setup, drain, or quant-scale configuration.
    Ctrl,
}

impl SaInst {
    /// Issue cycles on the in-order core (excluding memory stalls).
    pub fn issue_cycles(self) -> u64 {
        match self {
            // All three are single-issue custom instructions.
            SaInst::Prog | SaInst::Stream | SaInst::Ctrl => 1,
        }
    }
}

/// Expand the instruction stream for one live tile pass — the explicit
/// (slow) counterpart of the closed-form counts in
/// [`crate::systolic::TileTiming`]; used in tests.
pub fn expand_tile(
    rows: usize,
    cols: usize,
    m: usize,
    weights_per_word: usize,
) -> Vec<SaInst> {
    let mut v = Vec::new();
    v.push(SaInst::Ctrl); // tile setup
    for _ in 0..(rows * cols).div_ceil(weights_per_word) {
        v.push(SaInst::Prog);
    }
    for _ in 0..m * rows.max(cols) {
        v.push(SaInst::Stream);
    }
    v.push(SaInst::Ctrl); // drain
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::{ArrayConfig, Quant, TileTiming};
    use crate::util::prop::check;

    #[test]
    fn expansion_matches_closed_form_counts() {
        check("isa expansion == TileTiming", 32, |rng| {
            let n = [4usize, 8, 16, 32][rng.index(4)];
            let m = rng.index(64) + 1;
            let quant = if rng.chance(0.5) { Quant::Fp32 } else { Quant::Int8 };
            let cfg = ArrayConfig::square(n, quant);
            let t = TileTiming::live(&cfg, m);
            let insts = expand_tile(n, n, m, quant.weights_per_word());
            let progs = insts.iter().filter(|i| **i == SaInst::Prog).count();
            let streams = insts.iter().filter(|i| **i == SaInst::Stream).count();
            ((progs, streams) == (t.prog_words, t.stream_insts),
             format!("n={n} m={m} {quant:?} progs={progs} streams={streams}"))
        });
    }

    #[test]
    fn all_issue_single_cycle() {
        for i in [SaInst::Prog, SaInst::Stream, SaInst::Ctrl] {
            assert_eq!(i.issue_cycles(), 1);
        }
    }
}
