//! Whole-encoder simulation: expand an [`EncoderSpec`] to its GEMMs, run
//! each through the engine with the configured array + per-GEMM masks,
//! add the software-executed remainder, and aggregate cycles / events /
//! per-layer breakdowns.

use crate::hwmodel::{EnergyModel, SysCounts};
use crate::model::{EncoderSpec, GemmKind, LayerGemms};
use crate::systolic::ArrayConfig;

use super::engine::{gemm_on_array, gemm_on_cpu, non_gemm_cost, GemmCost, TileMask};
use super::params::SimParams;

/// Per-layer timing entry (Fig. 8).
#[derive(Clone, Debug)]
pub struct LayerStats {
    pub layer: usize,
    pub cycles: f64,
    /// Mean tile sparsity of the layer's feed-forward GEMMs.
    pub ff_sparsity: f64,
}

/// Result of one simulated inference.
#[derive(Clone, Debug)]
pub struct RunStats {
    pub counts: SysCounts,
    pub cycles: f64,
    pub per_layer: Vec<LayerStats>,
    pub seconds: f64,
    pub energy_j: f64,
}

/// The simulated Table 2 platform.
pub struct System {
    pub params: SimParams,
    pub energy: EnergyModel,
}

impl Default for System {
    fn default() -> Self {
        System { params: SimParams::default(), energy: EnergyModel::default() }
    }
}

impl System {
    /// Simulate one accelerated encoder inference.
    ///
    /// `ff_masks`: one [`TileMask`] per feed-forward GEMM in execution
    /// order (2 per block: w1, w2), or `None` for the unpruned run. The
    /// mask grid must match the array tile size.
    pub fn run_encoder(
        &self,
        spec: &EncoderSpec,
        array: &ArrayConfig,
        ff_masks: Option<&[TileMask]>,
    ) -> RunStats {
        self.run_encoder_layers(spec, &spec.layers(), array, ff_masks)
    }

    /// [`run_encoder`](Self::run_encoder) over a pre-expanded GEMM list.
    ///
    /// §Perf: the layer expansion allocates ~20 `GemmShape` vectors per
    /// call; sweep drivers ([`crate::coordinator::Explorer`]) expand once
    /// and reuse the slice across every design point.
    pub fn run_encoder_layers(
        &self,
        spec: &EncoderSpec,
        layers: &[LayerGemms],
        array: &ArrayConfig,
        ff_masks: Option<&[TileMask]>,
    ) -> RunStats {
        if let Some(masks) = ff_masks {
            let n_ff: usize = layers
                .iter()
                .flat_map(|l| l.gemms.iter())
                .filter(|g| g.kind.prunable())
                .count();
            assert_eq!(masks.len(), n_ff, "need one mask per FF GEMM");
        }

        let mut total = GemmCost::default();
        let mut per_layer = Vec::with_capacity(layers.len());
        let mut ff_idx = 0usize;
        let non_gemm_per_layer =
            non_gemm_cost(spec.non_gemm_elems() / spec.n_blocks as u64, &self.params);

        for layer in layers {
            let mut lcost = GemmCost::default();
            let mut sp_sum = 0.0;
            let mut sp_n = 0usize;
            for g in &layer.gemms {
                let mask = if g.kind == GemmKind::FeedForward {
                    let m = ff_masks.map(|ms| &ms[ff_idx]);
                    ff_idx += 1;
                    if let Some(m) = m {
                        sp_sum += m.sparsity();
                        sp_n += 1;
                    }
                    m
                } else {
                    None
                };
                lcost.add(&gemm_on_array(g, array, &self.params, mask));
            }
            lcost.add(&non_gemm_per_layer);
            per_layer.push(LayerStats {
                layer: layer.index,
                cycles: lcost.cycles,
                ff_sparsity: if sp_n > 0 { sp_sum / sp_n as f64 } else { 0.0 },
            });
            total.add(&lcost);
        }

        self.finish(array, total, per_layer)
    }

    /// Software-only baseline (no accelerator) — the reference for the
    /// Table 3 / Fig. 11 speedup columns.
    pub fn run_encoder_cpu(&self, spec: &EncoderSpec) -> RunStats {
        let mut total = GemmCost::default();
        let mut per_layer = Vec::new();
        let non_gemm_per_layer =
            non_gemm_cost(spec.non_gemm_elems() / spec.n_blocks as u64, &self.params);
        for layer in &spec.layers() {
            let mut lcost = GemmCost::default();
            for g in &layer.gemms {
                lcost.add(&gemm_on_cpu(g, &self.params));
            }
            lcost.add(&non_gemm_per_layer);
            per_layer.push(LayerStats {
                layer: layer.index,
                cycles: lcost.cycles,
                ff_sparsity: 0.0,
            });
            total.add(&lcost);
        }
        let seconds = total.cycles / self.params.clock_hz;
        let energy_j = self.energy.energy_cpu_j(&total.counts);
        RunStats {
            counts: total.counts,
            cycles: total.cycles,
            per_layer,
            seconds,
            energy_j,
        }
    }

    fn finish(
        &self,
        array: &ArrayConfig,
        total: GemmCost,
        per_layer: Vec<LayerStats>,
    ) -> RunStats {
        let seconds = total.cycles / self.params.clock_hz;
        let energy_j = self.energy.energy_j(array, &total.counts);
        RunStats {
            counts: total.counts,
            cycles: total.cycles,
            per_layer,
            seconds,
            energy_j,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::systolic::Quant;

    fn full_masks(spec: &EncoderSpec, tile: usize) -> Vec<TileMask> {
        let mut v = Vec::new();
        for _ in 0..spec.n_blocks {
            v.push(TileMask::full(spec.d_model / tile, spec.d_ff / tile));
            v.push(TileMask::full(spec.d_ff / tile, spec.d_model / tile));
        }
        v
    }

    #[test]
    fn accelerated_beats_cpu_for_all_sizes() {
        let sys = System::default();
        let spec = zoo::espnet_asr();
        let cpu = sys.run_encoder_cpu(&spec);
        for t in [4usize, 8, 16, 32] {
            let acc = sys.run_encoder(
                &spec,
                &ArrayConfig::square(t, Quant::Fp32),
                None,
            );
            let speedup = cpu.cycles / acc.cycles;
            assert!(speedup > 4.0, "t={t} speedup {speedup}");
        }
    }

    #[test]
    fn speedup_grows_sublinearly_with_size() {
        let sys = System::default();
        let spec = zoo::espnet_asr();
        let cpu = sys.run_encoder_cpu(&spec).cycles;
        let s: Vec<f64> = [4usize, 8, 16, 32]
            .iter()
            .map(|t| {
                cpu / sys
                    .run_encoder(&spec, &ArrayConfig::square(*t, Quant::Fp32), None)
                    .cycles
            })
            .collect();
        assert!(s[1] > s[0] && s[2] > s[1] && s[3] > s[2], "monotone {s:?}");
        // Sublinear: doubling size gives < 2x speedup gain at the top end.
        assert!(s[3] / s[2] < 2.0, "sublinear {s:?}");
    }

    #[test]
    fn full_masks_match_unmasked_run() {
        let sys = System::default();
        let spec = zoo::mustc_mt_encoder();
        let array = ArrayConfig::square(8, Quant::Int8);
        let a = sys.run_encoder(&spec, &array, None);
        let masks = full_masks(&spec, 8);
        let b = sys.run_encoder(&spec, &array, Some(&masks));
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn prelayered_run_matches_run_encoder() {
        let sys = System::default();
        let spec = zoo::espnet_asr();
        let layers = spec.layers();
        let array = ArrayConfig::square(8, Quant::Int8);
        let a = sys.run_encoder(&spec, &array, None);
        let b = sys.run_encoder_layers(&spec, &layers, &array, None);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.counts, b.counts);
    }

    #[test]
    fn pruning_speeds_up_and_saves_energy() {
        let sys = System::default();
        let spec = zoo::espnet_asr();
        let array = ArrayConfig::square(8, Quant::Int8);
        let dense = sys.run_encoder(&spec, &array, None);
        let mut masks = full_masks(&spec, 8);
        for m in &mut masks {
            for (i, l) in m.live.iter_mut().enumerate() {
                if i % 4 == 0 {
                    *l = false; // 25 % structured sparsity
                }
            }
        }
        let pruned = sys.run_encoder(&spec, &array, Some(&masks));
        assert!(pruned.cycles < dense.cycles);
        assert!(pruned.energy_j < dense.energy_j);
    }

    #[test]
    fn per_layer_breakdown_covers_all_blocks() {
        let sys = System::default();
        let spec = zoo::espnet2_asr();
        let stats =
            sys.run_encoder(&spec, &ArrayConfig::square(8, Quant::Fp32), None);
        assert_eq!(stats.per_layer.len(), 12);
        let sum: f64 = stats.per_layer.iter().map(|l| l.cycles).sum();
        assert!((sum - stats.cycles).abs() / stats.cycles < 1e-9);
    }

    #[test]
    fn gemm_dominates_runtime() {
        // §4.3: GEMM computations exceed 97 % of inference runtime.
        let sys = System::default();
        let spec = zoo::espnet_asr();
        let acc =
            sys.run_encoder(&spec, &ArrayConfig::square(8, Quant::Fp32), None);
        let non_gemm = crate::sysim::engine::non_gemm_cost(
            spec.non_gemm_elems(),
            &sys.params,
        );
        assert!(non_gemm.cycles / acc.cycles < 0.03,
                "non-GEMM fraction {}", non_gemm.cycles / acc.cycles);
    }
}
