//! # SASP — Systolic Arrays and Structured Pruning co-design framework
//!
//! A rust + JAX + Pallas reproduction of *"Systolic Arrays and Structured
//! Pruning Co-design for Efficient Transformers in Edge Systems"*
//! (Palacios et al., 2024).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! - **Layer 1** (`python/compile/kernels/`): Pallas block-sparse GEMM
//!   kernels — the systolic tile-skip expressed for the TPU stack.
//! - **Layer 2** (`python/compile/model.py`): JAX transformer encoder whose
//!   feed-forward GEMMs run through the Layer-1 kernels; AOT-lowered to
//!   HLO text artifacts.
//! - **Layer 3** (this crate): everything the paper's cross-stack
//!   framework does — structured pruning ([`pruning`]), post-training
//!   quantization ([`quant`]), QoS evaluation over the compiled artifacts
//!   ([`qos`], [`runtime`]), cycle-level systolic-array simulation
//!   ([`systolic`]), gem5-style full-system simulation ([`sysim`]),
//!   synthesis-calibrated hardware cost modeling ([`hwmodel`]), and the
//!   design-space explorer that ties them together ([`coordinator`]).
//!
//! Python runs only at build time (`make artifacts`); the binary is
//! self-contained afterwards. The native inference engine ([`infer`])
//! additionally runs the encoder end-to-end in pure rust, so the QoS and
//! serving surfaces work with no PJRT artifacts at all.

// GEMM-shaped signatures (x, w, dims, mask, tile, output...) exceed
// clippy's argument-count threshold throughout the kernel layers
// (systolic scheduler, sysim engine, infer kernels); the tuple/struct
// alternatives obscure more than they help at these call sites.
#![allow(clippy::too_many_arguments)]
// Crate hygiene, machine-checked by the `lint-hygiene` rule of
// `sasp lint` ([`analysis`]): the whole engine is safe rust, and the
// deny set keeps edition/namespace hygiene from silently regressing.
#![forbid(unsafe_code)]
#![deny(
    keyword_idents,
    macro_use_extern_crate,
    non_ascii_idents,
    unsafe_op_in_unsafe_fn,
    unused_extern_crates
)]

pub mod analysis;
pub mod arith;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod hwmodel;
pub mod infer;
pub mod model;
pub mod pruning;
pub mod qos;
pub mod quant;
pub mod runtime;
pub mod sysim;
pub mod systolic;
pub mod telemetry;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Default artifacts directory (relative to the repo root / cwd).
pub const ARTIFACTS_DIR: &str = "artifacts";
