//! Sign-and-magnitude INT8 — the weight representation of the hybrid PE.
//!
//! The paper (§3.3): *"our design assumes that the INT8 weight is
//! represented using a sign-and-magnitude format"*. Magnitude is 7 bits
//! (0..=127); note sign-magnitude has a negative zero which compares equal
//! in value terms.

/// An INT8 weight in sign-and-magnitude form.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SignMag8 {
    /// true = negative.
    pub sign: bool,
    /// 7-bit magnitude, 0..=127.
    pub mag: u8,
}

impl SignMag8 {
    /// Encode from a two's-complement i8 value in -127..=127
    /// (-128 saturates to -127 — outside the symmetric quantizer range).
    pub fn from_i8(v: i8) -> Self {
        let sign = v < 0;
        let mag = (v as i16).unsigned_abs().min(127) as u8;
        SignMag8 { sign, mag }
    }

    /// Decode to an i8 value.
    pub fn to_i8(self) -> i8 {
        let m = self.mag as i8;
        if self.sign {
            -m
        } else {
            m
        }
    }

    /// Raw 8-bit encoding: sign in bit 7, magnitude in bits 0..7.
    pub fn to_bits(self) -> u8 {
        ((self.sign as u8) << 7) | (self.mag & 0x7F)
    }

    pub fn from_bits(b: u8) -> Self {
        SignMag8 { sign: b & 0x80 != 0, mag: b & 0x7F }
    }

    pub fn is_zero(self) -> bool {
        self.mag == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn roundtrip_all_values() {
        for v in -127i8..=127 {
            assert_eq!(SignMag8::from_i8(v).to_i8(), v, "v={v}");
        }
    }

    #[test]
    fn bits_roundtrip() {
        for b in 0u8..=255 {
            let sm = SignMag8::from_bits(b);
            assert_eq!(sm.to_bits(), b);
        }
    }

    #[test]
    fn i8_min_saturates() {
        assert_eq!(SignMag8::from_i8(-128).to_i8(), -127);
    }

    #[test]
    fn negative_zero_is_zero() {
        let nz = SignMag8 { sign: true, mag: 0 };
        assert!(nz.is_zero());
        assert_eq!(nz.to_i8(), 0);
    }

    #[test]
    fn prop_sign_matches_value() {
        check("signmag sign matches i8 sign", 256, |rng| {
            let v = (rng.next_u64() as i8).max(-127);
            let sm = SignMag8::from_i8(v);
            let ok = (v < 0) == (sm.sign && sm.mag > 0 || v < 0);
            (ok && sm.to_i8() == v, format!("v={v}"))
        });
    }
}
