//! FP16 generalization of the hybrid multiplier — the paper's §3.3
//! closing remark: *"the hybrid multiplier design readily generalizes to
//! different floating-point and integer bitwidths beyond the FP32_INT8
//! considered in this paper, e.g., to support FP16 activations."*
//!
//! Implemented over raw IEEE binary16 bit patterns (1 sign / 5 exponent /
//! 10 mantissa) since rust has no stable `f16`: conversions to/from f32,
//! and the same Fig. 5 datapath — zero bypass, sign XOR, 11-bit expanded
//! mantissa × 7-bit magnitude, shift-align, truncate, exponent adjust.
//! Subnormals flush, overflow saturates, exactly like the FP32 unit.

use super::signmag::SignMag8;

/// Convert an f32 to IEEE binary16 bits (round-to-nearest-even,
/// subnormals flushed to zero — the PE's FTZ convention).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp32 = ((bits >> 23) & 0xFF) as i32;
    let mant32 = bits & 0x7F_FFFF;
    if exp32 == 0 {
        return sign; // zero / f32-subnormal -> signed zero
    }
    let exp16 = exp32 - 127 + 15;
    if exp16 >= 0x1F {
        return sign | 0x7BFF; // saturate to max finite (no infinities)
    }
    if exp16 <= 0 {
        return sign; // would be f16-subnormal -> flushed
    }
    // Round mantissa 23 -> 10 bits, ties to even.
    let shift = 13;
    let mut mant16 = (mant32 >> shift) as u16;
    let rem = mant32 & ((1 << shift) - 1);
    let half = 1 << (shift - 1);
    if rem > half || (rem == half && mant16 & 1 == 1) {
        mant16 += 1;
        if mant16 == 1 << 10 {
            // Mantissa overflow bumps the exponent.
            if exp16 + 1 >= 0x1F {
                return sign | 0x7BFF;
            }
            return sign | (((exp16 + 1) as u16) << 10);
        }
    }
    sign | ((exp16 as u16) << 10) | mant16
}

/// Convert IEEE binary16 bits to f32 (subnormals flush to zero).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    if exp == 0 {
        return f32::from_bits(sign); // zero or flushed subnormal
    }
    let exp32 = exp + 127 - 15;
    f32::from_bits(sign | (exp32 << 23) | (mant << 13))
}

/// The Fig. 5 datapath at FP16: multiply an FP16 activation (bit
/// pattern) by a sign-magnitude INT8 weight, returning the FP16 product
/// bits. Truncates (no rounding) like the FP32 unit.
pub fn hybrid_mul_f16(a_bits: u16, w: SignMag8) -> u16 {
    let sign_a = (a_bits >> 15) & 1;
    let exp_a = ((a_bits >> 10) & 0x1F) as i32;
    let mant_a = (a_bits & 0x3FF) as u32;

    // Step 1: zero bypass (exp 0 covers zero + flushed subnormals).
    if exp_a == 0 || w.is_zero() {
        return 0;
    }
    // Step 2: output sign.
    let sign = (sign_a ^ (w.sign as u16)) << 15;
    // Step 3: expanded 11-bit mantissa x 7-bit magnitude (<= 18 bits).
    let mant11 = (1 << 10) | mant_a;
    let prod = mant11 * w.mag as u32;
    // Step 4: normalize — leading one in [10, 17].
    let p = 31 - prod.leading_zeros();
    let shift = p - 10;
    let mant_out = ((prod >> shift) & 0x3FF) as u16; // truncate
    // Step 5: exponent adjust.
    let exp = exp_a + shift as i32;
    if exp >= 0x1F {
        return sign | 0x7BFF; // saturate
    }
    if exp <= 0 {
        return sign; // flushed
    }
    sign | ((exp as u16) << 10) | mant_out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn f16_roundtrip_exactly_representable() {
        for v in [0.0f32, 1.0, -2.0, 0.5, 1.5, 0.099975586, 65504.0] {
            let h = f32_to_f16_bits(v);
            let back = f16_bits_to_f32(h);
            let rel = ((back - v) / v.abs().max(1e-6)).abs();
            assert!(rel < 1e-3, "v={v} back={back}");
        }
    }

    #[test]
    fn f16_conversion_error_within_half_ulp() {
        check("f32->f16 rel err < 2^-11", 2048, |rng| {
            let v = (rng.normal() as f32) * 10.0_f32.powi(rng.index(6) as i32 - 3);
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            if v == 0.0 || v.abs() < 6.2e-5 {
                return (back.abs() < 6.2e-5, format!("v={v} (flush)"));
            }
            let rel = ((back - v) / v).abs();
            (rel <= 1.0 / 2048.0, format!("v={v} back={back} rel={rel}"))
        });
    }

    #[test]
    fn f16_saturates_no_infinity() {
        assert_eq!(f32_to_f16_bits(1e9), 0x7BFF);
        assert_eq!(f32_to_f16_bits(-1e9), 0xFBFF);
        assert!(f16_bits_to_f32(0x7BFF).is_finite());
    }

    #[test]
    fn hybrid_f16_zero_bypass() {
        assert_eq!(hybrid_mul_f16(f32_to_f16_bits(0.0), SignMag8::from_i8(5)), 0);
        assert_eq!(hybrid_mul_f16(f32_to_f16_bits(3.5), SignMag8::from_i8(0)), 0);
    }

    #[test]
    fn hybrid_f16_exact_for_power_of_two_magnitudes() {
        for k in 0..7 {
            let w = SignMag8::from_i8(1 << k);
            for a in [1.0f32, -1.5, 0.25, 12.0] {
                let got = f16_bits_to_f32(hybrid_mul_f16(f32_to_f16_bits(a), w));
                assert_eq!(got, a * (1 << k) as f32, "k={k} a={a}");
            }
        }
    }

    #[test]
    fn hybrid_f16_tracks_exact_product_within_truncation() {
        check("hybrid f16 < 2 ulp of exact", 2048, |rng| {
            let a = (rng.normal() as f32) * 4.0;
            let wv = (rng.index(255) as i16 - 127) as i8;
            let w = SignMag8::from_i8(wv);
            let a16 = f32_to_f16_bits(a);
            let a_eff = f16_bits_to_f32(a16); // value after f16 rounding
            let got = f16_bits_to_f32(hybrid_mul_f16(a16, w));
            let exact = a_eff as f64 * wv as f64;
            if a_eff == 0.0 || wv == 0 {
                return (got == 0.0, format!("a={a} w={wv}"));
            }
            if exact.abs() >= 65504.0 || exact.abs() < 6.2e-5 {
                return (true, String::new()); // saturation / flush domain
            }
            // Truncation drops < 1 f16 ulp ≈ 2^-10 relative.
            let rel = ((got as f64 - exact) / exact).abs();
            (rel < 1.0 / 512.0, format!("a={a} w={wv} got={got} exact={exact}"))
        });
    }

    #[test]
    fn hybrid_f16_sign_is_xor() {
        let a = f32_to_f16_bits(2.0);
        assert!(f16_bits_to_f32(hybrid_mul_f16(a, SignMag8::from_i8(-3))) < 0.0);
        let na = f32_to_f16_bits(-2.0);
        assert!(f16_bits_to_f32(hybrid_mul_f16(na, SignMag8::from_i8(-3))) > 0.0);
    }
}
