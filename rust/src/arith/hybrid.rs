//! The hybrid FP32×INT8 multiplier of Fig. 5, implemented bit-by-bit.
//!
//! Datapath (paper §3.3, verbatim steps):
//!
//! 1. **Zero bypass**: if either operand is zero the output is zero (a
//!    dedicated multiplexer in the RTL — the general path cannot produce
//!    a correct zero because of the implicit leading '1').
//! 2. **Sign**: XOR of the activation sign and the weight sign
//!    (sign-and-magnitude INT8).
//! 3. **Mantissa**: the FP32 mantissa is expanded by appending the
//!    implicit leading '1' (24 bits), then multiplied by the 7-bit weight
//!    magnitude → up to 31 bits.
//! 4. **Normalize**: right-shift to realign the leading '1' to bit 23 and
//!    **truncate** to 23 fraction bits (no rounding — cheaper hardware).
//! 5. **Exponent**: adjusted by the number of shifts performed.
//!
//! Infinities, NaNs and subnormals are not handled (inputs are flushed /
//! assumed finite); exponent overflow saturates to the largest finite
//! value, underflow flushes to zero — both outside the paper's measured
//! operating range but defined here so the simulator is total.

use super::fp32::{compose, decompose, flush_subnormal};
use super::signmag::SignMag8;

/// Multiply an FP32 activation by a sign-magnitude INT8 weight, returning
/// the FP32 product as computed by the Fig. 5 datapath.
///
/// The result differs from IEEE `a * (w as f32)` only in the final
/// truncation (IEEE rounds to nearest-even; the hybrid unit truncates),
/// i.e. by strictly less than 1 ulp, and never in sign or exponent.
pub fn hybrid_mul(a: f32, w: SignMag8) -> f32 {
    let a = flush_subnormal(a);
    debug_assert!(a.is_finite(), "hybrid_mul domain: finite activations");

    // Step 1: zero bypass mux.
    if a == 0.0 || w.is_zero() {
        return 0.0;
    }

    let (sa, ea, ma) = decompose(a);

    // Step 2: output sign.
    let sign = sa ^ (w.sign as u32);

    // Step 3: expanded mantissa (1.m23 → 24 bits) times magnitude.
    let mant24: u32 = (1 << 23) | ma;
    let prod: u64 = mant24 as u64 * w.mag as u64; // ≤ (2^24-1)*127 < 2^31

    // Step 4: locate leading one. mag ∈ [1,127] ⇒ p ∈ [23, 30].
    let p = 63 - prod.leading_zeros(); // bit index of leading 1
    let shift = p - 23;
    let mant_out = ((prod >> shift) & 0x7F_FFFF) as u32; // truncate

    // Step 5: exponent adjust (weight is an *integer*: each doubling of
    // magnitude adds one to the exponent).
    let exp = ea as i32 + shift as i32;
    if exp >= 0xFF {
        // Saturate (no infinities in this design).
        return compose(sign, 0xFE, 0x7F_FFFF);
    }
    if exp <= 0 {
        // Would be subnormal — flushed.
        return if sign == 1 { -0.0 } else { 0.0 };
    }

    compose(sign, exp as u32, mant_out)
}

/// Reference product at f64 precision (for error-bound tests): the exact
/// mathematical value of `a * w`.
pub fn exact_mul(a: f32, w: SignMag8) -> f64 {
    a as f64 * w.to_i8() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn zero_bypass() {
        assert_eq!(hybrid_mul(0.0, SignMag8::from_i8(77)), 0.0);
        assert_eq!(hybrid_mul(3.5, SignMag8::from_i8(0)), 0.0);
        assert_eq!(hybrid_mul(0.0, SignMag8::from_i8(0)), 0.0);
    }

    #[test]
    fn exact_for_power_of_two_magnitudes() {
        // mag = 2^k ⇒ no mantissa bits are lost ⇒ result is exact.
        for k in 0..7 {
            let w = SignMag8::from_i8(1 << k);
            for a in [1.0f32, -1.5, 0.3, 1234.5678, -9.25e-3] {
                assert_eq!(hybrid_mul(a, w), a * (1 << k) as f32, "k={k} a={a}");
            }
        }
    }

    #[test]
    fn sign_is_xor() {
        assert!(hybrid_mul(2.0, SignMag8::from_i8(-3)) < 0.0);
        assert!(hybrid_mul(-2.0, SignMag8::from_i8(-3)) > 0.0);
        assert!(hybrid_mul(-2.0, SignMag8::from_i8(3)) < 0.0);
    }

    #[test]
    fn truncation_error_below_one_ulp() {
        // |hybrid - exact| < ulp(hybrid): truncation drops < 1 ulp.
        check("hybrid_mul < 1 ulp from exact", 4096, |rng| {
            let a = (rng.normal() as f32) * 10.0_f32.powi(rng.index(8) as i32 - 4);
            let wv = (rng.index(255) as i16 - 127) as i8;
            let w = SignMag8::from_i8(wv);
            let got = hybrid_mul(a, w);
            if a == 0.0 || w.is_zero() {
                return (got == 0.0, format!("a={a} w={wv}"));
            }
            let exact = exact_mul(a, w);
            let ulp = {
                let bits = got.abs().to_bits();
                (f32::from_bits(bits + 1) - got.abs()) as f64
            };
            let err = (got as f64 - exact).abs();
            (err < ulp.max(f64::MIN_POSITIVE),
             format!("a={a} w={wv} got={got} exact={exact} err={err} ulp={ulp}"))
        });
    }

    #[test]
    fn truncation_biases_toward_zero() {
        // Truncation never increases magnitude.
        check("hybrid |result| <= |exact|", 2048, |rng| {
            let a = (rng.normal() as f32) * 3.0;
            let wv = (rng.index(255) as i16 - 127) as i8;
            let w = SignMag8::from_i8(wv);
            let got = hybrid_mul(a, w) as f64;
            let exact = exact_mul(a, w);
            (got.abs() <= exact.abs() + 1e-30,
             format!("a={a} w={wv} got={got} exact={exact}"))
        });
    }

    #[test]
    fn matches_ieee_within_truncation_across_magnitudes() {
        // Exhaustive over weight values for a few activations.
        for wv in -127i8..=127 {
            let w = SignMag8::from_i8(wv);
            for a in [1.0f32, -0.7071, 3.1415926, 1e10, -1e-10] {
                let got = hybrid_mul(a, w);
                let ieee = a * wv as f32;
                if wv == 0 {
                    assert_eq!(got, 0.0);
                    continue;
                }
                let rel = ((got - ieee) / ieee.abs().max(f32::MIN_POSITIVE)).abs();
                assert!(rel < 2.5e-7, "a={a} w={wv} got={got} ieee={ieee}");
            }
        }
    }

    #[test]
    fn exponent_saturation_no_infinity() {
        let big = f32::MAX / 2.0;
        let r = hybrid_mul(big, SignMag8::from_i8(127));
        assert!(r.is_finite(), "saturates instead of inf, got {r}");
    }

    #[test]
    fn subnormal_activation_flushed() {
        let sub = f32::from_bits(1);
        assert_eq!(hybrid_mul(sub, SignMag8::from_i8(100)), 0.0);
    }
}
