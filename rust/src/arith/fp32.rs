//! FP32 helpers with the paper's PE semantics: subnormals are flushed to
//! zero on both inputs and outputs, and infinities/NaNs are out of scope
//! (the PE is only ever fed finite activations/weights; tests enforce the
//! domain).

/// Flush subnormal values (biased exponent 0, nonzero mantissa) to signed
/// zero — what the Fig. 5 datapath does implicitly by not implementing
/// subnormal handling.
#[inline]
pub fn flush_subnormal(x: f32) -> f32 {
    if x != 0.0 && x.abs() < f32::MIN_POSITIVE {
        if x.is_sign_negative() {
            -0.0
        } else {
            0.0
        }
    } else {
        x
    }
}

/// PE adder: IEEE f32 addition with flush-to-zero on inputs and output.
#[inline]
pub fn ftz_add(a: f32, b: f32) -> f32 {
    flush_subnormal(flush_subnormal(a) + flush_subnormal(b))
}

/// PE FP32 multiplier: IEEE f32 multiply with flush-to-zero in/out.
#[inline]
pub fn ftz_mul(a: f32, b: f32) -> f32 {
    flush_subnormal(flush_subnormal(a) * flush_subnormal(b))
}

/// Decompose a finite f32 into (sign, biased exponent, 23-bit mantissa).
#[inline]
pub fn decompose(x: f32) -> (u32, u32, u32) {
    let bits = x.to_bits();
    ((bits >> 31) & 1, (bits >> 23) & 0xFF, bits & 0x7F_FFFF)
}

/// Compose an f32 from (sign, biased exponent, 23-bit mantissa).
#[inline]
pub fn compose(sign: u32, exp: u32, mant: u32) -> f32 {
    f32::from_bits((sign << 31) | ((exp & 0xFF) << 23) | (mant & 0x7F_FFFF))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn subnormals_flushed() {
        let sub = f32::from_bits(0x0000_0001); // smallest positive subnormal
        assert_eq!(flush_subnormal(sub), 0.0);
        assert_eq!(flush_subnormal(-sub), 0.0);
        assert!(flush_subnormal(-sub).is_sign_negative());
    }

    #[test]
    fn normals_pass_through() {
        for v in [1.0f32, -2.5, f32::MIN_POSITIVE, 3.4e38, -1e-30] {
            assert_eq!(flush_subnormal(v), v);
        }
    }

    #[test]
    fn decompose_compose_roundtrip() {
        check("fp32 decompose∘compose = id", 256, |rng| {
            let x = f32::from_bits(rng.next_u64() as u32);
            if !x.is_finite() {
                return (true, String::new());
            }
            let (s, e, m) = decompose(x);
            let ok = compose(s, e, m).to_bits() == x.to_bits();
            (ok, format!("x={x}"))
        });
    }

    #[test]
    fn ftz_mul_matches_ieee_on_normal_products() {
        check("ftz_mul == ieee for normal results", 512, |rng| {
            let a = (rng.normal() as f32) * 8.0;
            let b = (rng.normal() as f32) * 8.0;
            let ieee = a * b;
            if ieee != 0.0 && ieee.abs() < f32::MIN_POSITIVE {
                return (true, String::new()); // subnormal product: FTZ differs
            }
            (ftz_mul(a, b) == ieee, format!("a={a} b={b}"))
        });
    }

    #[test]
    fn ftz_add_flushes_subnormal_result() {
        let a = f32::MIN_POSITIVE;
        let b = -f32::MIN_POSITIVE * 0.5; // forces subnormal intermediate
        let r = ftz_add(a, b);
        assert!(r == 0.0 || r.abs() >= f32::MIN_POSITIVE);
    }
}
