//! Bit-level arithmetic substrates of the systolic-array PE (§3.3).
//!
//! The paper's PE contains an FP32 adder and either an FP32 multiplier or
//! the hybrid FP32×INT8 multiplier of Fig. 5. Neither handles infinities,
//! NaNs, or subnormals (an area/energy optimization); we reproduce that
//! behaviour exactly so the functional systolic simulator is bit-faithful
//! to the described RTL.

pub mod fp32;
pub mod fp16;
pub mod hybrid;
pub mod signmag;

pub use fp32::{flush_subnormal, ftz_add, ftz_mul};
pub use fp16::{f16_bits_to_f32, f32_to_f16_bits, hybrid_mul_f16};
pub use hybrid::hybrid_mul;
pub use signmag::SignMag8;
