//! Typed configuration for experiments and the simulated platform,
//! loadable from JSON files in `configs/` (overridable per-field, so a
//! config file only lists what it changes).

use std::path::Path;

use anyhow::{Context, Result};

use crate::sysim::SimParams;
use crate::systolic::Quant;
use crate::util::json::Json;

/// Experiment sweep definition (defaults reproduce the paper's grid).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Systolic array sizes (square), paper: 4..32.
    pub sizes: Vec<usize>,
    /// Structured pruning rates to sweep.
    pub rates: Vec<f64>,
    /// Quantization schemes.
    pub quants: Vec<Quant>,
    /// ASR QoS target (WER, Table 1: 5 %) — expressed on the stand-in
    /// task as a multiple of its baseline WER (see DESIGN.md §2).
    pub wer_target_ratio: f64,
    /// MT QoS target (BLEU floor ratio, Table 1: 27/31).
    pub bleu_floor_ratio: f64,
    /// Artifacts directory.
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            sizes: vec![4, 8, 16, 32],
            rates: (0..=10).map(|i| i as f64 * 0.05).collect(),
            quants: vec![Quant::Fp32, Quant::Int8],
            // Paper: 3.5 % baseline -> 5 % target = 1.43x.
            wer_target_ratio: 5.0 / 3.5,
            // Paper: 31 BLEU -> 27 BLEU floor.
            bleu_floor_ratio: 27.0 / 31.0,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a JSON file; absent keys keep their defaults.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_json(&text)
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        if let Some(arr) = v.get("sizes").as_arr() {
            cfg.sizes = arr.iter().filter_map(Json::as_usize).collect();
        }
        if let Some(arr) = v.get("rates").as_arr() {
            cfg.rates = arr.iter().filter_map(Json::as_f64).collect();
        }
        if let Some(arr) = v.get("quants").as_arr() {
            cfg.quants = arr
                .iter()
                .filter_map(Json::as_str)
                .filter_map(|s| match s {
                    "FP32_FP32" => Some(Quant::Fp32),
                    "FP32_INT8" => Some(Quant::Int8),
                    _ => None,
                })
                .collect();
        }
        if let Some(x) = v.get("wer_target_ratio").as_f64() {
            cfg.wer_target_ratio = x;
        }
        if let Some(x) = v.get("bleu_floor_ratio").as_f64() {
            cfg.bleu_floor_ratio = x;
        }
        if let Some(s) = v.get("artifacts_dir").as_str() {
            cfg.artifacts_dir = s.to_string();
        }
        Ok(cfg)
    }
}

/// Simulated platform configuration (Table 2), convertible to
/// [`SimParams`]. JSON override follows the same partial-update rule.
#[derive(Clone, Debug)]
pub struct PlatformConfig {
    pub clock_ghz: f64,
    pub l1_kb: usize,
    pub l2_kb: usize,
    pub l1_latency: u64,
    pub l2_latency: u64,
    pub dram_latency: u64,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        // Table 2.
        PlatformConfig {
            clock_ghz: 1.0,
            l1_kb: 32,
            l2_kb: 1024,
            l1_latency: 2,
            l2_latency: 20,
            dram_latency: 60,
        }
    }
}

impl PlatformConfig {
    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let mut c = PlatformConfig::default();
        if let Some(x) = v.get("clock_ghz").as_f64() {
            c.clock_ghz = x;
        }
        if let Some(x) = v.get("l1_kb").as_usize() {
            c.l1_kb = x;
        }
        if let Some(x) = v.get("l2_kb").as_usize() {
            c.l2_kb = x;
        }
        if let Some(x) = v.get("l1_latency").as_f64() {
            c.l1_latency = x as u64;
        }
        if let Some(x) = v.get("l2_latency").as_f64() {
            c.l2_latency = x as u64;
        }
        if let Some(x) = v.get("dram_latency").as_f64() {
            c.dram_latency = x as u64;
        }
        Ok(c)
    }

    pub fn sim_params(&self) -> SimParams {
        SimParams {
            clock_hz: self.clock_ghz * 1e9,
            l1_latency: self.l1_latency,
            l2_latency: self.l2_latency,
            dram_latency: self.dram_latency,
            l2_bytes: self.l2_kb * 1024,
            ..SimParams::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_grid() {
        let c = ExperimentConfig::default();
        assert_eq!(c.sizes, vec![4, 8, 16, 32]);
        assert_eq!(c.quants.len(), 2);
        assert!((c.wer_target_ratio - 1.4285).abs() < 1e-3);
    }

    #[test]
    fn partial_json_override() {
        let c = ExperimentConfig::from_json(
            r#"{"sizes": [8, 16], "quants": ["FP32_INT8"]}"#,
        )
        .unwrap();
        assert_eq!(c.sizes, vec![8, 16]);
        assert_eq!(c.quants, vec![Quant::Int8]);
        // Untouched fields keep defaults.
        assert_eq!(c.rates.len(), 11);
    }

    #[test]
    fn platform_to_sim_params() {
        let p = PlatformConfig::from_json(r#"{"l2_kb": 2048}"#).unwrap();
        let sp = p.sim_params();
        assert_eq!(sp.l2_bytes, 2048 * 1024);
        assert_eq!(sp.clock_hz, 1e9);
    }

    #[test]
    fn bad_json_rejected() {
        assert!(ExperimentConfig::from_json("{nope").is_err());
    }
}
