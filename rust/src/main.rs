//! `sasp` — the SASP co-design framework CLI (Layer-3 leader binary).
//!
//! ```text
//! sasp report <id>        regenerate a paper table/figure
//!        ids: table1 table2 table3 fig6 fig7 fig8 fig9 fig10 fig11
//!             mt headline serve overload decode trace util all
//!        (serve measures the serving runtime's latency/throughput
//!         frontier — fixed vs dynamic batching, 1/2/4 worker threads —
//!         offline on the native backend; overload measures goodput
//!         under bounded admission, deadlines, and the degradation
//!         ladder; decode measures the continuous iteration-level
//!         batched MT decoding frontier — offered load x panel width
//!         against sequential per-utterance decode, with panel fill and
//!         decode-scope PE utilization; trace replays a serve run under a recording
//!         telemetry session and writes a Perfetto-loadable Chrome
//!         trace (default trace.json, override with --out) plus the
//!         metrics snapshot; util records a batched encode run and
//!         reports per-layer PE utilization, cycle/energy attribution,
//!         roofline classification, and the utilization x pruning x
//!         array-shape frontier, cross-checked against the analytic
//!         engine; these are wall-clock, so not in `all`)
//! sasp sweep              full design-space sweep (timing only)
//! sasp qos <tile> <rate> <fp32|int8>
//!                         evaluate one QoS point (PJRT when artifacts
//!                         exist, batched native engine otherwise)
//! sasp info               platform + artifact inventory
//! sasp lint [--json] [--write-baseline]
//!                         codebase-contract lints over rust/src with a
//!                         committed ratchet baseline (see the
//!                         `analysis` module docs); nonzero exit on any
//!                         fresh finding or stale baseline entry.
//!                         `--src <dir>`/`--baseline <path>` override
//!                         the autodetected tree and baseline file.
//! ```
//!
//! Flags: `--artifacts <dir>` (default `artifacts`), `--config <json>`,
//! `--out <path>` (trace JSON destination for `report trace`),
//! `--metrics-out <path>` (write the telemetry metrics snapshot as
//! Prometheus-style text; on `report serve`/`report overload`/`report
//! decode` this records the whole sweep under one telemetry session).

use anyhow::{bail, Context, Result};

use sasp::config::ExperimentConfig;
use sasp::coordinator::{Explorer, SweepPoint};
use sasp::harness::{self, QosCache};
use sasp::model::zoo;
use sasp::runtime::Engine;
use sasp::systolic::Quant;

struct Cli {
    cmd: String,
    args: Vec<String>,
    artifacts: String,
    config: Option<String>,
    out: Option<String>,
    metrics_out: Option<String>,
}

fn parse_cli() -> Result<Cli> {
    let mut argv = std::env::args().skip(1).collect::<Vec<_>>();
    let mut artifacts = "artifacts".to_string();
    let mut config = None;
    let mut out = None;
    let mut metrics_out = None;
    let mut rest = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--artifacts" => {
                i += 1;
                artifacts = argv.get(i).context("--artifacts needs a value")?.clone();
            }
            "--config" => {
                i += 1;
                config = Some(argv.get(i).context("--config needs a value")?.clone());
            }
            "--out" => {
                i += 1;
                out = Some(argv.get(i).context("--out needs a value")?.clone());
            }
            "--metrics-out" => {
                i += 1;
                metrics_out =
                    Some(argv.get(i).context("--metrics-out needs a value")?.clone());
            }
            _ => rest.push(argv[i].clone()),
        }
        i += 1;
    }
    argv = rest;
    if argv.is_empty() {
        bail!("usage: sasp <report|sweep|qos|info|lint> ... (see README)");
    }
    Ok(Cli {
        cmd: argv[0].clone(),
        args: argv[1..].to_vec(),
        artifacts,
        config,
        out,
        metrics_out,
    })
}

fn load_config(cli: &Cli) -> Result<ExperimentConfig> {
    let mut cfg = match &cli.config {
        Some(p) => ExperimentConfig::load(p)?,
        None => ExperimentConfig::default(),
    };
    cfg.artifacts_dir = cli.artifacts.clone();
    Ok(cfg)
}

fn qos_stack(cfg: &ExperimentConfig) -> Result<QosCache> {
    // Auto-selected: PJRT over compiled artifacts when they exist, the
    // batched native engine (synthetic teacher-labeled test set)
    // otherwise — QoS reports regenerate on a fresh checkout.
    let qos = QosCache::auto(&cfg.artifacts_dir)?;
    eprintln!("QoS backend: {}", qos.backend_label());
    Ok(qos)
}

/// Run a report generator, optionally under a recording telemetry
/// session whose metrics snapshot lands in `--metrics-out`.
fn render_with_metrics(
    cli: &Cli,
    f: impl FnOnce() -> Result<sasp::harness::Report>,
) -> Result<String> {
    let Some(path) = &cli.metrics_out else {
        return Ok(f()?.render());
    };
    let session = sasp::telemetry::Telemetry::start();
    let report = f();
    let trace = session.finish();
    let report = report?;
    std::fs::write(path, trace.metrics.render_prometheus())
        .with_context(|| format!("write {path}"))?;
    eprintln!("metrics -> {path}");
    Ok(report.render())
}

fn cmd_report(cli: &Cli) -> Result<()> {
    let cfg = load_config(cli)?;
    let id = cli.args.first().map(String::as_str).unwrap_or("all");
    // Timing-only reports need no PJRT.
    match id {
        "table1" => return Ok(print!("{}", harness::table1().render())),
        "table2" => return Ok(print!("{}", harness::table2().render())),
        "fig6" => return Ok(print!("{}", harness::fig6().render())),
        "fig8" => return Ok(print!("{}", harness::fig8().render())),
        "serve" => {
            let out = render_with_metrics(cli, harness::serve_report)?;
            return Ok(print!("{out}"));
        }
        "overload" => {
            let out = render_with_metrics(cli, harness::overload_report)?;
            return Ok(print!("{out}"));
        }
        "decode" => {
            let out = render_with_metrics(cli, harness::decode_report)?;
            return Ok(print!("{out}"));
        }
        "trace" => {
            // `trace` runs its own telemetry session and always writes
            // the Chrome trace (default trace.json).
            let trace_out = cli.out.clone().unwrap_or_else(|| "trace.json".to_string());
            let report = harness::trace_report(
                Some(std::path::Path::new(&trace_out)),
                cli.metrics_out.as_deref().map(std::path::Path::new),
            )?;
            return Ok(print!("{}", report.render()));
        }
        "util" => {
            // `util` runs its own telemetry session (the report *is*
            // the scraped snapshot) and cross-checks the recorded
            // attribution against the analytic engine.
            let report = harness::util_report(
                cli.metrics_out.as_deref().map(std::path::Path::new),
            )?;
            return Ok(print!("{}", report.render()));
        }
        _ => {}
    }
    let mut qos = qos_stack(&cfg)?;
    let out = match id {
        "fig7" => harness::fig7(&mut qos, &cfg)?.render(),
        "fig9" => harness::fig9(&mut qos, &cfg)?.render(),
        "fig10" => harness::fig10(&mut qos, &cfg)?.render(),
        "fig11" => harness::fig11(&mut qos, &cfg)?.render(),
        "table3" => harness::table3(&mut qos, &cfg)?.render(),
        "mt" => harness::mt_report(&mut qos, &cfg)?.render(),
        "headline" => harness::headline(&mut qos)?.render(),
        "all" => {
            let mut s = String::new();
            s += &harness::table1().render();
            s += &harness::table2().render();
            s += &harness::fig6().render();
            s += &harness::fig7(&mut qos, &cfg)?.render();
            s += &harness::fig8().render();
            s += &harness::fig9(&mut qos, &cfg)?.render();
            s += &harness::fig10(&mut qos, &cfg)?.render();
            s += &harness::fig11(&mut qos, &cfg)?.render();
            s += &harness::table3(&mut qos, &cfg)?.render();
            s += &harness::mt_report(&mut qos, &cfg)?.render();
            s += &harness::headline(&mut qos)?.render();
            s
        }
        other => bail!("unknown report id '{other}'"),
    };
    print!("{out}");
    Ok(())
}

fn cmd_sweep(cli: &Cli) -> Result<()> {
    let cfg = load_config(cli)?;
    println!(
        "{:<26} {:>5} {:>10} {:>6} {:>10} {:>10} {:>10}",
        "workload", "size", "quant", "rate", "speedup", "energy J", "area mm²"
    );
    let grid = SweepPoint::grid(&cfg.sizes, &cfg.quants, &cfg.rates);
    for spec in [zoo::espnet_asr(), zoo::espnet2_asr(), zoo::mustc_asr_encoder()] {
        let ex = Explorer::new(spec.clone());
        for (sp, p) in grid.iter().zip(ex.sweep(&grid)) {
            println!(
                "{:<26} {:>5} {:>10} {:>6.2} {:>10.2} {:>10.4} {:>10.3}",
                spec.name,
                sp.tile,
                sp.quant.label(),
                sp.rate,
                p.speedup_vs_cpu,
                p.energy_j,
                p.area_mm2
            );
        }
    }
    Ok(())
}

fn cmd_qos(cli: &Cli) -> Result<()> {
    let cfg = load_config(cli)?;
    if cli.args.len() < 3 {
        bail!("usage: sasp qos <tile> <rate> <fp32|int8>");
    }
    let tile: usize = cli.args[0].parse().context("tile")?;
    let rate: f64 = cli.args[1].parse().context("rate")?;
    let quant = match cli.args[2].as_str() {
        "fp32" => Quant::Fp32,
        "int8" => Quant::Int8,
        q => bail!("unknown quant '{q}'"),
    };
    let mut qos = qos_stack(&cfg)?;
    let wer = qos.wer(tile, rate, quant)?;
    println!("tile={tile} rate={rate} quant={} WER={wer:.4}", quant.label());
    Ok(())
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let cfg = load_config(cli)?;
    match Engine::new(&cfg.artifacts_dir) {
        Ok(engine) => println!("platform: {}", engine.platform()),
        Err(e) => println!(
            "platform: PJRT unavailable ({e:#}); QoS surfaces fall back to \
             the batched native engine"
        ),
    }
    println!("artifacts dir: {}", cfg.artifacts_dir);
    let entries = match std::fs::read_dir(&cfg.artifacts_dir) {
        Ok(rd) => {
            let mut v: Vec<_> = rd.filter_map(|e| e.ok()).map(|e| e.path()).collect();
            v.sort();
            v
        }
        Err(_) => {
            println!("  (no artifacts directory — run `make artifacts` for PJRT)");
            Vec::new()
        }
    };
    for p in entries {
        if p.extension().map_or(false, |e| e == "txt" || e == "bin" || e == "json") {
            println!(
                "  {} ({} bytes)",
                p.file_name().unwrap().to_string_lossy(),
                p.metadata()?.len()
            );
        }
    }
    Ok(())
}

fn cmd_lint(cli: &Cli) -> Result<()> {
    use std::path::{Path, PathBuf};
    let mut json = false;
    let mut write_baseline = false;
    let mut src: Option<PathBuf> = None;
    let mut baseline: Option<PathBuf> = None;
    let mut i = 0;
    while i < cli.args.len() {
        match cli.args[i].as_str() {
            "--json" => json = true,
            "--write-baseline" => write_baseline = true,
            "--src" => {
                i += 1;
                src = Some(PathBuf::from(cli.args.get(i).context("--src needs a value")?));
            }
            "--baseline" => {
                i += 1;
                baseline =
                    Some(PathBuf::from(cli.args.get(i).context("--baseline needs a value")?));
            }
            other => bail!("unknown lint flag '{other}'"),
        }
        i += 1;
    }
    // Autodetect the tree: `cargo run` from rust/ sees `src/`, the repo
    // root sees `rust/src/`.
    let src = match src {
        Some(p) => p,
        None if Path::new("src/lib.rs").is_file() => PathBuf::from("src"),
        None if Path::new("rust/src/lib.rs").is_file() => PathBuf::from("rust/src"),
        None => bail!("cannot find the crate source tree; pass --src <dir>"),
    };
    // The baseline lives next to Cargo.toml: <src>/../lint-baseline.json.
    let baseline = baseline.unwrap_or_else(|| {
        src.parent()
            .map(|p| p.join("lint-baseline.json"))
            .unwrap_or_else(|| PathBuf::from("lint-baseline.json"))
    });

    if write_baseline {
        let (findings, files) = sasp::analysis::scan_tree(&src)?;
        let old = sasp::analysis::Baseline::load(&baseline)?;
        let refreshed = old.refreshed(&findings);
        refreshed.save(&baseline)?;
        eprintln!(
            "lint baseline: {} entries from {} files -> {}",
            refreshed.entries.len(),
            files,
            baseline.display()
        );
        return Ok(());
    }

    let report = sasp::analysis::run(&src, &baseline)?;
    if json {
        println!("{}", sasp::analysis::render_json(&report));
    } else {
        print!("{}", sasp::analysis::render_human(&report));
    }
    if !report.clean() {
        bail!(
            "lint failed: {} fresh finding(s), {} stale baseline entr(y/ies) \
             (baseline: {})",
            report.fresh.len(),
            report.stale.len(),
            baseline.display()
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let cli = parse_cli()?;
    match cli.cmd.as_str() {
        "report" => cmd_report(&cli),
        "sweep" => cmd_sweep(&cli),
        "qos" => cmd_qos(&cli),
        "info" => cmd_info(&cli),
        "lint" => cmd_lint(&cli),
        other => bail!("unknown command '{other}'"),
    }
}
