//! Quality-of-service tier: WER / BLEU metrics, CTC decoding, and the
//! evaluators that run the pruned+quantized model on the held-out test
//! set — the paper's "inference is performed on a target dataset, in
//! order to gather QoS metrics" (§3.1). Execution is pluggable via
//! [`QosBackend`]: PJRT artifacts ([`PjrtBackend`]) or the native rust
//! engine ([`crate::infer::NativeBackend`]).

pub mod decode;
pub mod eval;
pub mod metrics;

pub use decode::ctc_greedy;
pub use eval::{AsrEvaluator, EvalMeta, MtEvaluator, PjrtBackend, PjrtState, QosBackend, QosPoint};
pub use metrics::{bleu, edit_distance, sentence_bleu, token_error_rate};
