//! Error-rate metrics: Levenshtein edit distance, WER-style token error
//! rate (the ASR metric), and corpus BLEU (the MT metric).

/// Levenshtein distance between two token sequences.
pub fn edit_distance(a: &[i32], b: &[i32]) -> usize {
    let (la, lb) = (a.len(), b.len());
    if la == 0 {
        return lb;
    }
    let mut prev: Vec<usize> = (0..=lb).collect();
    let mut cur = vec![0usize; lb + 1];
    for i in 1..=la {
        cur[0] = i;
        for j in 1..=lb {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[lb]
}

/// Corpus-level token error rate: `sum(edit) / sum(ref_len)` — the WER
/// of the synthetic character task (each character is a token; the paper
/// reports WER on LibriSpeech words, same definition over its tokens).
pub fn token_error_rate(refs: &[Vec<i32>], hyps: &[Vec<i32>]) -> f64 {
    assert_eq!(refs.len(), hyps.len());
    let mut errs = 0usize;
    let mut total = 0usize;
    for (r, h) in refs.iter().zip(hyps) {
        errs += edit_distance(h, r);
        total += r.len();
    }
    errs as f64 / total.max(1) as f64
}

/// Corpus BLEU-N with brevity penalty (uniform weights, the standard MT
/// metric of Table 1's MuST-C row).
pub fn bleu(refs: &[Vec<i32>], hyps: &[Vec<i32>], max_n: usize) -> f64 {
    assert_eq!(refs.len(), hyps.len());
    let mut log_sum = 0.0f64;
    for n in 1..=max_n {
        let (mut matched, mut total) = (0usize, 0usize);
        for (r, h) in refs.iter().zip(hyps) {
            if h.len() < n {
                continue;
            }
            let mut ref_counts = std::collections::HashMap::new();
            for w in r.windows(n) {
                *ref_counts.entry(w).or_insert(0usize) += 1;
            }
            for w in h.windows(n) {
                total += 1;
                if let Some(c) = ref_counts.get_mut(w) {
                    if *c > 0 {
                        *c -= 1;
                        matched += 1;
                    }
                }
            }
        }
        if total == 0 || matched == 0 {
            return 0.0;
        }
        log_sum += (matched as f64 / total as f64).ln() / max_n as f64;
    }
    let hyp_len: usize = hyps.iter().map(Vec::len).sum();
    let ref_len: usize = refs.iter().map(Vec::len).sum();
    let bp = if hyp_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len.max(1) as f64).exp()
    };
    100.0 * bp * log_sum.exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance(&[], &[]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 3]), 1); // deletion
        assert_eq!(edit_distance(&[1, 2], &[1, 2, 3]), 1); // insertion
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 9, 3]), 1); // substitution
        assert_eq!(edit_distance(&[], &[1, 2]), 2);
    }

    #[test]
    fn ter_identity_is_zero() {
        let refs = vec![vec![1, 2, 3], vec![4, 5]];
        assert_eq!(token_error_rate(&refs, &refs), 0.0);
    }

    #[test]
    fn ter_all_wrong_is_one() {
        let refs = vec![vec![1, 2], vec![3]];
        let hyps = vec![vec![9, 9], vec![9]];
        assert_eq!(token_error_rate(&refs, &hyps), 1.0);
    }

    #[test]
    fn bleu_perfect_is_100() {
        let refs = vec![vec![1, 2, 3, 4, 5, 6]];
        assert!((bleu(&refs, &refs, 4) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn bleu_zero_overlap_is_0() {
        let refs = vec![vec![1, 2, 3, 4, 5]];
        let hyps = vec![vec![6, 7, 8, 9, 10]];
        assert_eq!(bleu(&refs, &hyps, 4), 0.0);
    }

    #[test]
    fn bleu_brevity_penalizes_short_hyps() {
        let refs = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let full = bleu(&refs, &refs, 2);
        let short = bleu(&refs, &[vec![1, 2, 3, 4]], 2);
        assert!(short < full);
        assert!(short > 0.0);
    }

    #[test]
    fn prop_edit_distance_metric_properties() {
        check("edit distance symmetry + triangle", 48, |rng: &mut Rng| {
            let mk = |rng: &mut Rng| -> Vec<i32> {
                (0..rng.index(8)).map(|_| rng.index(4) as i32).collect()
            };
            let (a, b, c) = (mk(rng), mk(rng), mk(rng));
            let dab = edit_distance(&a, &b);
            let dba = edit_distance(&b, &a);
            let dac = edit_distance(&a, &c);
            let dcb = edit_distance(&c, &b);
            let sym = dab == dba;
            let tri = dab <= dac + dcb;
            (sym && tri, format!("a={a:?} b={b:?} c={c:?}"))
        });
    }

    #[test]
    fn prop_ter_monotone_in_errors() {
        check("ter grows with corruption", 24, |rng: &mut Rng| {
            let r: Vec<i32> = (0..12).map(|_| rng.index(10) as i32).collect();
            let mut h1 = r.clone();
            h1[rng.index(12)] = 99;
            let mut h2 = h1.clone();
            h2[(rng.index(11) + 1) % 12] = 98;
            let refs = vec![r];
            let t1 = token_error_rate(&refs, &[h1]);
            let t2 = token_error_rate(&refs, &[h2]);
            (t2 >= t1, format!("t1={t1} t2={t2}"))
        });
    }
}
