//! Error-rate metrics: Levenshtein edit distance, WER-style token error
//! rate (the ASR metric), and corpus BLEU (the MT metric).

/// Levenshtein distance between two token sequences.
pub fn edit_distance(a: &[i32], b: &[i32]) -> usize {
    let (la, lb) = (a.len(), b.len());
    if la == 0 {
        return lb;
    }
    let mut prev: Vec<usize> = (0..=lb).collect();
    let mut cur = vec![0usize; lb + 1];
    for i in 1..=la {
        cur[0] = i;
        for j in 1..=lb {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[lb]
}

/// Corpus-level token error rate: `sum(edit) / sum(ref_len)` — the WER
/// of the synthetic character task (each character is a token; the paper
/// reports WER on LibriSpeech words, same definition over its tokens).
pub fn token_error_rate(refs: &[Vec<i32>], hyps: &[Vec<i32>]) -> f64 {
    assert_eq!(refs.len(), hyps.len());
    let mut errs = 0usize;
    let mut total = 0usize;
    for (r, h) in refs.iter().zip(hyps) {
        errs += edit_distance(h, r);
        total += r.len();
    }
    errs as f64 / total.max(1) as f64
}

/// Corpus BLEU-N with brevity penalty (uniform weights over the
/// *effective* order, the standard MT metric of Table 1's MuST-C row).
///
/// Clipped n-gram counts are pooled over the whole corpus (corpus BLEU,
/// not a mean of sentence scores). An order is dropped from the
/// geometric mean only when the **reference** corpus has no n-grams of
/// that order (effective-order smoothing for short-reference corpora) —
/// so a corpus whose hypotheses equal its references scores exactly 100
/// even when every sentence is shorter than `max_n`, while a *degraded*
/// hypothesis corpus that cannot express an order the references do
/// express scores 0 at that order (standard corpus-BLEU behavior — no
/// credit for collapsing to short outputs). A corpus with zero matches
/// at any reference-expressible order scores 0.
pub fn bleu(refs: &[Vec<i32>], hyps: &[Vec<i32>], max_n: usize) -> f64 {
    assert_eq!(refs.len(), hyps.len());
    assert!(max_n > 0, "max_n must be positive");
    let mut precisions: Vec<f64> = Vec::with_capacity(max_n);
    for n in 1..=max_n {
        let (mut matched, mut total, mut ref_total) = (0usize, 0usize, 0usize);
        for (r, h) in refs.iter().zip(hyps) {
            ref_total += r.len().saturating_sub(n - 1);
            if h.len() < n {
                continue;
            }
            let mut ref_counts = std::collections::HashMap::new();
            for w in r.windows(n) {
                *ref_counts.entry(w).or_insert(0usize) += 1;
            }
            for w in h.windows(n) {
                total += 1;
                if let Some(c) = ref_counts.get_mut(w) {
                    if *c > 0 {
                        *c -= 1;
                        matched += 1;
                    }
                }
            }
        }
        if ref_total == 0 {
            continue; // order beyond the reference corpus — drop it
        }
        if matched == 0 {
            return 0.0; // includes total == 0: hyps can't express the order
        }
        precisions.push(matched as f64 / total as f64);
    }
    if precisions.is_empty() {
        return 0.0; // no reference content at any order
    }
    let log_sum: f64 =
        precisions.iter().map(|p| p.ln()).sum::<f64>() / precisions.len() as f64;
    let hyp_len: usize = hyps.iter().map(Vec::len).sum();
    let ref_len: usize = refs.iter().map(Vec::len).sum();
    let bp = if hyp_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len.max(1) as f64).exp()
    };
    100.0 * bp * log_sum.exp()
}

/// Sentence BLEU: [`bleu`] of a single pair. Averaging this over a
/// corpus is **not** corpus BLEU — corpus BLEU pools the clipped counts
/// before taking precisions (see the aggregation test below).
pub fn sentence_bleu(r: &[i32], h: &[i32], max_n: usize) -> f64 {
    bleu(&[r.to_vec()], &[h.to_vec()], max_n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance(&[], &[]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 3]), 1); // deletion
        assert_eq!(edit_distance(&[1, 2], &[1, 2, 3]), 1); // insertion
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 9, 3]), 1); // substitution
        assert_eq!(edit_distance(&[], &[1, 2]), 2);
    }

    #[test]
    fn ter_identity_is_zero() {
        let refs = vec![vec![1, 2, 3], vec![4, 5]];
        assert_eq!(token_error_rate(&refs, &refs), 0.0);
    }

    #[test]
    fn ter_all_wrong_is_one() {
        let refs = vec![vec![1, 2], vec![3]];
        let hyps = vec![vec![9, 9], vec![9]];
        assert_eq!(token_error_rate(&refs, &hyps), 1.0);
    }

    #[test]
    fn bleu_perfect_is_100() {
        let refs = vec![vec![1, 2, 3, 4, 5, 6]];
        assert!((bleu(&refs, &refs, 4) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn bleu_zero_overlap_is_0() {
        let refs = vec![vec![1, 2, 3, 4, 5]];
        let hyps = vec![vec![6, 7, 8, 9, 10]];
        assert_eq!(bleu(&refs, &hyps, 4), 0.0);
    }

    #[test]
    fn bleu_brevity_penalizes_short_hyps() {
        let refs = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let full = bleu(&refs, &refs, 2);
        let short = bleu(&refs, &[vec![1, 2, 3, 4]], 2);
        assert!(short < full);
        assert!(short > 0.0);
    }

    #[test]
    fn bleu_empty_hypothesis_is_zero() {
        let refs = vec![vec![1, 2, 3, 4, 5]];
        let hyps = vec![vec![]];
        assert_eq!(bleu(&refs, &hyps, 4), 0.0);
        // A whole corpus of empty hypotheses (and even empty references)
        // scores 0, never NaN or 100.
        let empty: Vec<Vec<i32>> = vec![vec![], vec![]];
        assert_eq!(bleu(&empty, &empty, 4), 0.0);
    }

    #[test]
    fn bleu_empty_reference_is_zero() {
        let refs = vec![vec![]];
        let hyps = vec![vec![1, 2, 3, 4]];
        let b = bleu(&refs, &hyps, 4);
        assert_eq!(b, 0.0, "nothing can match an empty reference: {b}");
    }

    #[test]
    fn bleu_hypothesis_shorter_than_max_ngram_uses_effective_order() {
        // A perfect 3-token corpus has no 4-grams; the geometric mean
        // ranges over the expressible orders only, so identity is still
        // exactly 100 (and a 1-token identity corpus too).
        let refs = vec![vec![7, 8, 9]];
        assert!((bleu(&refs, &refs, 4) - 100.0).abs() < 1e-9);
        let one = vec![vec![5]];
        assert!((bleu(&one, &one, 4) - 100.0).abs() < 1e-9);
        // Imperfect short hypotheses still score strictly below 100 on
        // the orders they can express (any expressible order with zero
        // matches — here the 3-gram — zeroes the whole score).
        let hyp = vec![vec![7, 8, 1]];
        let b2 = bleu(&refs, &hyp, 2);
        assert!(b2 > 0.0 && b2 < 100.0, "{b2}");
        assert_eq!(bleu(&refs, &hyp, 4), 0.0, "unmatched 3-gram zeroes BLEU-4");
        // Degraded (collapsed-short) hypotheses get no effective-order
        // credit: the references *can* express 4-grams, so BLEU-4 is 0,
        // exactly as standard corpus BLEU scores it.
        let long_refs = vec![vec![1, 2, 3, 4]];
        let short_hyp = vec![vec![1, 2, 3]];
        assert_eq!(bleu(&long_refs, &short_hyp, 4), 0.0);
        assert!(bleu(&long_refs, &short_hyp, 3) > 0.0, "expressible orders score");
    }

    #[test]
    fn bleu_brevity_penalty_boundary() {
        // BP is exactly 1 at equal corpus length, and exp(1 - r/c) the
        // moment the hypothesis corpus is one token short.
        let refs = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let equal = bleu(&refs, &refs, 2);
        assert!((equal - 100.0).abs() < 1e-9);
        let shorter = vec![vec![1, 2, 3, 4, 5, 6, 7]];
        let b = bleu(&refs, &shorter, 1);
        // Unigram precision is 1 (7/7 match), so the score is pure BP.
        let want = 100.0 * (1.0 - 8.0 / 7.0f64).exp();
        assert!((b - want).abs() < 1e-9, "{b} vs {want}");
        // Longer-than-reference hypotheses get no brevity bonus: the
        // extra token costs precision instead.
        let longer = vec![vec![1, 2, 3, 4, 5, 6, 7, 8, 9]];
        let bl = bleu(&refs, &longer, 1);
        assert!((bl - 100.0 * 8.0 / 9.0).abs() < 1e-9, "{bl}");
    }

    #[test]
    fn bleu_corpus_pools_counts_not_sentence_scores() {
        // Corpus BLEU pools clipped counts across sentences; averaging
        // per-sentence BLEU is a different (wrong) aggregation. One
        // perfect long sentence + one disjoint short one: the mean of
        // sentence scores is 50, the pooled corpus score is not.
        let refs = vec![vec![1, 2, 3, 4, 5, 6, 7, 8], vec![9, 9]];
        let hyps = vec![vec![1, 2, 3, 4, 5, 6, 7, 8], vec![4, 4]];
        let corpus = bleu(&refs, &hyps, 2);
        let s0 = sentence_bleu(&refs[0], &hyps[0], 2);
        let s1 = sentence_bleu(&refs[1], &hyps[1], 2);
        assert!((s0 - 100.0).abs() < 1e-9);
        assert_eq!(s1, 0.0);
        let mean = (s0 + s1) / 2.0;
        assert!(corpus > 0.0, "pooled counts keep the corpus score positive");
        assert!(
            (corpus - mean).abs() > 1.0,
            "corpus {corpus} must not equal mean-of-sentences {mean}"
        );
        // Pooled unigrams: 8 matched of 10; pooled bigrams: 7 of 8.
        let want = 100.0 * ((8.0f64 / 10.0).ln() / 2.0 + (7.0f64 / 8.0).ln() / 2.0).exp();
        assert!((corpus - want).abs() < 1e-9, "{corpus} vs {want}");
    }

    #[test]
    fn prop_edit_distance_metric_properties() {
        check("edit distance symmetry + triangle", 48, |rng: &mut Rng| {
            let mk = |rng: &mut Rng| -> Vec<i32> {
                (0..rng.index(8)).map(|_| rng.index(4) as i32).collect()
            };
            let (a, b, c) = (mk(rng), mk(rng), mk(rng));
            let dab = edit_distance(&a, &b);
            let dba = edit_distance(&b, &a);
            let dac = edit_distance(&a, &c);
            let dcb = edit_distance(&c, &b);
            let sym = dab == dba;
            let tri = dab <= dac + dcb;
            (sym && tri, format!("a={a:?} b={b:?} c={c:?}"))
        });
    }

    #[test]
    fn prop_ter_monotone_in_errors() {
        check("ter grows with corruption", 24, |rng: &mut Rng| {
            let r: Vec<i32> = (0..12).map(|_| rng.index(10) as i32).collect();
            let mut h1 = r.clone();
            h1[rng.index(12)] = 99;
            let mut h2 = h1.clone();
            h2[(rng.index(11) + 1) % 12] = 98;
            let refs = vec![r];
            let t1 = token_error_rate(&refs, &[h1]);
            let t2 = token_error_rate(&refs, &[h2]);
            (t2 >= t1, format!("t1={t1} t2={t2}"))
        });
    }
}
