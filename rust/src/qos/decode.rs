//! CTC greedy (best-path) decoding — mirrors `python/compile/ctc.py`'s
//! `greedy_decode` (cross-validated by the integration tests through the
//! compiled artifacts).

/// Decode one utterance from row-major `[t_total, vocab]` log-probs:
/// argmax per frame over the first `t_len` frames, collapse repeats,
/// drop blanks.
pub fn ctc_greedy(log_probs: &[f32], t_len: usize, vocab: usize, blank: i32) -> Vec<i32> {
    assert!(log_probs.len() >= t_len * vocab);
    let mut out = Vec::new();
    let mut prev = -1i32;
    for t in 0..t_len {
        let row = &log_probs[t * vocab..(t + 1) * vocab];
        let mut best = 0usize;
        for (i, v) in row.iter().enumerate() {
            if *v > row[best] {
                best = i;
            }
        }
        let sym = best as i32;
        if sym != prev && sym != blank {
            out.push(sym);
        }
        prev = sym;
    }
    out
}

/// Per-position argmax decode (the MT head): `[seq, vocab]` → tokens.
pub fn argmax_decode(logits: &[f32], seq: usize, vocab: usize) -> Vec<i32> {
    assert!(logits.len() >= seq * vocab);
    (0..seq)
        .map(|t| {
            let row = &logits[t * vocab..(t + 1) * vocab];
            let mut best = 0usize;
            for (i, v) in row.iter().enumerate() {
                if *v > row[best] {
                    best = i;
                }
            }
            best as i32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_hot_frames(path: &[i32], vocab: usize) -> Vec<f32> {
        let mut lp = vec![-10.0f32; path.len() * vocab];
        for (t, s) in path.iter().enumerate() {
            lp[t * vocab + *s as usize] = 0.0;
        }
        lp
    }

    #[test]
    fn collapses_repeats_and_drops_blanks() {
        // vocab 3, blank 2: path [0,0,2,1,1,2,1] -> [0,1,1]
        let lp = one_hot_frames(&[0, 0, 2, 1, 1, 2, 1], 3);
        assert_eq!(ctc_greedy(&lp, 7, 3, 2), vec![0, 1, 1]);
    }

    #[test]
    fn respects_t_len() {
        let lp = one_hot_frames(&[0, 0, 1, 1, 1], 3);
        assert_eq!(ctc_greedy(&lp, 2, 3, 2), vec![0]);
    }

    #[test]
    fn all_blank_decodes_empty() {
        let lp = one_hot_frames(&[2, 2, 2], 3);
        assert!(ctc_greedy(&lp, 3, 3, 2).is_empty());
    }

    #[test]
    fn argmax_decode_picks_max_per_row() {
        let logits = vec![0.1, 0.9, 0.0, /* row 2 */ 5.0, 1.0, 2.0];
        assert_eq!(argmax_decode(&logits, 2, 3), vec![1, 0]);
    }

    #[test]
    fn empty_logits_decode_empty() {
        // Zero valid frames / zero positions over an empty buffer must
        // yield empty hypotheses, not panic.
        assert!(ctc_greedy(&[], 0, 3, 2).is_empty());
        assert!(argmax_decode(&[], 0, 3).is_empty());
        // Zero valid frames with a non-empty buffer: frames are ignored.
        let lp = one_hot_frames(&[0, 1], 3);
        assert!(ctc_greedy(&lp, 0, 3, 2).is_empty());
    }

    #[test]
    fn all_blank_long_sequence_decodes_empty() {
        // All-blank across a long utterance, including blank at a
        // non-zero index, collapses to nothing.
        let path = vec![1i32; 50];
        let lp = one_hot_frames(&path, 4);
        assert!(ctc_greedy(&lp, 50, 4, 1).is_empty());
    }

    #[test]
    fn argmax_tie_breaks_to_lowest_index() {
        // Exact ties keep the first maximum (strict `>` comparison) —
        // the deterministic contract both serving paths rely on.
        let logits = vec![
            2.0, 2.0, 1.0, // tie between 0 and 1 -> 0
            -1.0, 3.0, 3.0, // tie between 1 and 2 -> 1
            7.0, 7.0, 7.0, // three-way tie -> 0
        ];
        assert_eq!(argmax_decode(&logits, 3, 3), vec![0, 1, 0]);
    }

    #[test]
    fn ctc_tie_breaks_to_lowest_index() {
        // A frame tied between the blank (2) and symbol 0 resolves to
        // symbol 0 (lowest index), which is then emitted.
        let mut lp = vec![-10.0f32; 2 * 3];
        lp[0] = 0.0; // frame 0: symbol 0
        lp[2] = 0.0; // frame 0: blank, tied with symbol 0
        lp[3 + 2] = 0.0; // frame 1: blank alone
        assert_eq!(ctc_greedy(&lp, 2, 3, 2), vec![0]);
    }

    #[test]
    fn repeated_symbol_across_blank_re_emitted() {
        // [0, blank, 0] emits 0 twice — the blank resets the repeat
        // collapse (standard CTC best-path semantics).
        let lp = one_hot_frames(&[0, 2, 0], 3);
        assert_eq!(ctc_greedy(&lp, 3, 3, 2), vec![0, 0]);
    }
}
