//! QoS evaluators: prune + quantize the trained weights, run the AOT
//! artifact over the held-out test set via PJRT, decode, and score.
//!
//! Pruning at an arbitrary tile size is evaluated through the *dense*
//! artifact by zeroing weight tiles — numerically identical to skipping
//! them (validated against the Pallas-mask artifact in the integration
//! tests). The INT8 configuration fake-quantizes weights (quantize →
//! dequantize), which is value-identical to dequantizing inside the
//! kernel and preserves pruned zeros exactly.

use anyhow::{Context, Result};

use crate::data::{load_bundle, Bundle, Tensor};
use crate::pruning::{global_prune, tile_l1_norms, PrunePlan, TileNorms};
use crate::quant::fake_quantize;
use crate::runtime::Engine;
use crate::systolic::Quant;

use super::decode::{argmax_decode, ctc_greedy};
use super::metrics::{bleu, token_error_rate};

/// One evaluated configuration.
#[derive(Clone, Copy, Debug)]
pub struct QosPoint {
    pub tile: usize,
    pub rate: f64,
    pub quant: Quant,
    /// WER for ASR, BLEU for MT.
    pub qos: f64,
    pub achieved_rate: f64,
}

/// Shared plumbing for both evaluators.
struct ModelHarness {
    artifact: String,
    params: Bundle,
    ff_names: Vec<String>,
}

impl ModelHarness {
    fn new(engine: &mut Engine, artifact: &str, params_path: &str) -> Result<Self> {
        let model = engine.load(artifact)?;
        let n_blocks = model.manifest.model.n_blocks;
        let params = load_bundle(params_path)?;
        let ff_names: Vec<String> = (0..n_blocks)
            .flat_map(|i| {
                [format!("block{i}.ff.w1"), format!("block{i}.ff.w2")]
            })
            .collect();
        for n in &ff_names {
            params.require(n)?;
        }
        Ok(ModelHarness { artifact: artifact.to_string(), params, ff_names })
    }

    /// Prune (at `tile`) + optionally fake-quantize a copy of the params.
    fn prepare_params(&self, tile: usize, rate: f64, quant: Quant) -> Result<(Bundle, PrunePlan)> {
        let mut params = self.params.clone();
        let norms: Vec<TileNorms> = self
            .ff_names
            .iter()
            .map(|n| tile_l1_norms(params.require(n).unwrap(), tile))
            .collect();
        let plan = global_prune(&norms, rate);
        for (name, mask) in self.ff_names.iter().zip(&plan.masks) {
            let w = params.get_mut(name).unwrap();
            crate::pruning::norms::apply_mask_to_weights(w, mask, tile);
        }
        if quant == Quant::Int8 {
            // PTQ applies to all stored weight matrices (attention,
            // feed-forward, projections) — not norms/biases.
            let names: Vec<String> = params
                .entries
                .iter()
                .filter(|(n, t)| t.shape.len() == 2 && n.ends_with('w') || n.ends_with(".w1") || n.ends_with(".w2") || n.ends_with(".wq") || n.ends_with(".wk") || n.ends_with(".wv") || n.ends_with(".wo"))
                .map(|(n, _)| n.clone())
                .collect();
            for n in names {
                fake_quantize(params.get_mut(&n).unwrap());
            }
        }
        Ok((params, plan))
    }

    /// Assemble the positional args for one data chunk, following the
    /// manifest contract: data inputs, then all-ones masks (weights are
    /// already zeroed), then parameters by name.
    fn assemble_args(
        &self,
        engine: &mut Engine,
        params: &Bundle,
        data: &[(&str, Tensor)],
    ) -> Result<Vec<Tensor>> {
        let manifest = engine.load(&self.artifact)?.manifest.clone();
        let mut out = Vec::with_capacity(manifest.args.len());
        for spec in &manifest.args {
            if let Some((_, t)) = data.iter().find(|(n, _)| *n == spec.name) {
                out.push(t.clone());
            } else if spec.name.starts_with("mask.") {
                let numel: usize = spec.shape.iter().product();
                out.push(Tensor::from_i32(&spec.shape, &vec![1i32; numel]));
            } else {
                out.push(
                    params
                        .require(&spec.name)
                        .with_context(|| format!("param arg {}", spec.name))?
                        .clone(),
                );
            }
        }
        Ok(out)
    }
}

/// ASR evaluator over `artifacts/testset_asr.bin`.
pub struct AsrEvaluator {
    harness: ModelHarness,
    feats: Vec<f32>,
    feat_len: Vec<i32>,
    refs: Vec<Vec<i32>>,
    batch: usize,
    seq_len: usize,
    feat_dim: usize,
    vocab: usize,
    blank: i32,
}

impl AsrEvaluator {
    pub fn new(engine: &mut Engine, dir: &str, artifact: &str) -> Result<Self> {
        let harness =
            ModelHarness::new(engine, artifact, &format!("{dir}/params_asr.bin"))?;
        let ts = load_bundle(format!("{dir}/testset_asr.bin"))?;
        let feats_t = ts.require("feats")?;
        let (n, seq_len, feat_dim) =
            (feats_t.shape[0], feats_t.shape[1], feats_t.shape[2]);
        let feat_len = ts.require("feat_len")?.i32s();
        let labels = ts.require("labels")?;
        let label_len = ts.require("label_len")?.i32s();
        let lmax = labels.shape[1];
        let lvals = labels.i32s();
        let refs: Vec<Vec<i32>> = (0..n)
            .map(|i| lvals[i * lmax..i * lmax + label_len[i] as usize].to_vec())
            .collect();
        let m = &engine.load(artifact)?.manifest.model;
        Ok(AsrEvaluator {
            feats: feats_t.f32s(),
            feat_len,
            refs,
            batch: m.batch,
            seq_len,
            feat_dim,
            vocab: m.vocab,
            blank: m.ctc_blank as i32,
            harness,
        })
    }

    pub fn n_utts(&self) -> usize {
        self.refs.len()
    }

    /// Evaluate WER at one (tile, rate, quant) configuration.
    pub fn evaluate(
        &self,
        engine: &mut Engine,
        tile: usize,
        rate: f64,
        quant: Quant,
    ) -> Result<QosPoint> {
        let (params, plan) = self.harness.prepare_params(tile, rate, quant)?;
        let hyps = self.decode_all(engine, &params)?;
        let wer = token_error_rate(&self.refs, &hyps);
        Ok(QosPoint { tile, rate, quant, qos: wer, achieved_rate: plan.achieved_rate })
    }

    /// Run inference over the whole test set with given params.
    ///
    /// §Perf L3: the 55 weight/mask literals are converted once per
    /// configuration and reused across test-set chunks; only the two
    /// data arguments are rebuilt per chunk.
    pub fn decode_all(&self, engine: &mut Engine, params: &Bundle) -> Result<Vec<Vec<i32>>> {
        let n = self.n_utts();
        let (b, t, f) = (self.batch, self.seq_len, self.feat_dim);
        // Template literals (data args start as zeros, replaced below).
        let dummy = [
            ("feats", Tensor::zeros(&[b, t, f], crate::data::DType::F32)),
            ("pad_mask", Tensor::zeros(&[b, t], crate::data::DType::F32)),
        ];
        let args = self.harness.assemble_args(engine, params, &dummy)?;
        let mut literals: Vec<xla::Literal> = args
            .iter()
            .map(crate::runtime::tensor_to_literal)
            .collect::<Result<_>>()?;
        let manifest = engine.load(&self.harness.artifact)?.manifest.clone();
        let feats_idx = manifest.arg_index("feats").unwrap();
        let pad_idx = manifest.arg_index("pad_mask").unwrap();

        let mut hyps = Vec::with_capacity(n);
        let mut chunk = 0;
        while chunk * b < n {
            let lo = chunk * b;
            let hi = ((chunk + 1) * b).min(n);
            // Pad the final chunk by repeating the last utterance.
            let mut feats = vec![0.0f32; b * t * f];
            let mut pad = vec![0.0f32; b * t];
            for i in 0..b {
                let src = (lo + i).min(n - 1);
                feats[i * t * f..(i + 1) * t * f]
                    .copy_from_slice(&self.feats[src * t * f..(src + 1) * t * f]);
                for tt in 0..self.feat_len[src] as usize {
                    pad[i * t + tt] = 1.0;
                }
            }
            literals[feats_idx] = crate::runtime::tensor_to_literal(
                &Tensor::from_f32(&[b, t, f], &feats),
            )?;
            literals[pad_idx] = crate::runtime::tensor_to_literal(
                &Tensor::from_f32(&[b, t], &pad),
            )?;
            let out = engine.execute_literals(&self.harness.artifact, &literals)?;
            let lp = out.f32s();
            for i in 0..(hi - lo) {
                let src = lo + i;
                let frame0 = i * t * self.vocab;
                hyps.push(ctc_greedy(
                    &lp[frame0..frame0 + t * self.vocab],
                    self.feat_len[src] as usize,
                    self.vocab,
                    self.blank,
                ));
            }
            chunk += 1;
        }
        Ok(hyps)
    }

    /// The clean-weights baseline WER (rate 0, FP32).
    pub fn baseline(&self, engine: &mut Engine) -> Result<f64> {
        Ok(self.evaluate(engine, 8, 0.0, Quant::Fp32)?.qos)
    }
}

/// MT evaluator over `artifacts/testset_mt.bin` (BLEU, higher better).
pub struct MtEvaluator {
    harness: ModelHarness,
    src: Vec<i32>,
    refs: Vec<Vec<i32>>,
    batch: usize,
    seq_len: usize,
    vocab: usize,
}

impl MtEvaluator {
    pub fn new(engine: &mut Engine, dir: &str, artifact: &str) -> Result<Self> {
        let harness =
            ModelHarness::new(engine, artifact, &format!("{dir}/params_mt.bin"))?;
        let ts = load_bundle(format!("{dir}/testset_mt.bin"))?;
        let src_t = ts.require("src")?;
        let (n, seq_len) = (src_t.shape[0], src_t.shape[1]);
        let tgt = ts.require("tgt")?.i32s();
        let refs: Vec<Vec<i32>> = (0..n)
            .map(|i| tgt[i * seq_len..(i + 1) * seq_len].to_vec())
            .collect();
        let m = &engine.load(artifact)?.manifest.model;
        Ok(MtEvaluator {
            src: src_t.i32s(),
            refs,
            batch: m.batch,
            seq_len,
            vocab: m.vocab,
            harness,
        })
    }

    pub fn evaluate(
        &self,
        engine: &mut Engine,
        tile: usize,
        rate: f64,
        quant: Quant,
    ) -> Result<QosPoint> {
        let (params, plan) = self.harness.prepare_params(tile, rate, quant)?;
        let n = self.refs.len();
        let (b, t) = (self.batch, self.seq_len);
        let mut hyps = Vec::with_capacity(n);
        let mut chunk = 0;
        while chunk * b < n {
            let lo = chunk * b;
            let hi = ((chunk + 1) * b).min(n);
            let mut src = vec![0i32; b * t];
            for i in 0..b {
                let s = (lo + i).min(n - 1);
                src[i * t..(i + 1) * t]
                    .copy_from_slice(&self.src[s * t..(s + 1) * t]);
            }
            let data = [("src", Tensor::from_i32(&[b, t], &src))];
            let args = self.harness.assemble_args(engine, &params, &data)?;
            let out = engine.execute(&self.harness.artifact, &args)?;
            let logits = out.f32s();
            for i in 0..(hi - lo) {
                hyps.push(argmax_decode(
                    &logits[i * t * self.vocab..(i + 1) * t * self.vocab],
                    t,
                    self.vocab,
                ));
            }
            chunk += 1;
        }
        let score = bleu(&self.refs, &hyps, 4);
        Ok(QosPoint { tile, rate, quant, qos: score, achieved_rate: plan.achieved_rate })
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent evaluator tests live in rust/tests/integration.rs
    // (they require built artifacts). Shape-level checks only here.
    use crate::data::{DType, Tensor};

    #[test]
    fn dtype_marker_used() {
        // Silence unused-import lint meaningfully: the evaluators build
        // i32 mask tensors.
        let t = Tensor::from_i32(&[2], &[1, 1]);
        assert_eq!(t.dtype, DType::I32);
    }
}
