//! QoS evaluators: prune + quantize the trained weights, run the model
//! over the held-out test set, decode, and score.
//!
//! Execution is backend-pluggable through [`QosBackend`]: the PJRT path
//! ([`PjrtBackend`]) runs the AOT artifact exactly as before, and the
//! native engine ([`crate::infer::NativeBackend`]) runs the same weights
//! in pure rust — so QoS curves are measurable on a checkout with no
//! artifacts at all.
//!
//! Pruning at an arbitrary tile size is evaluated through the *dense*
//! weights by zeroing weight tiles — numerically identical to skipping
//! them (validated against the Pallas-mask artifact in the integration
//! tests, and against true tile-skipping in `infer::encoder` tests). The
//! INT8 configuration fake-quantizes weights (quantize → dequantize),
//! which is value-identical to dequantizing inside the kernel and
//! preserves pruned zeros exactly; the native backend additionally
//! re-packs them for its sign-magnitude INT8 kernel (idempotent).

use anyhow::{ensure, Context, Result};

use crate::data::{load_bundle, Bundle, Tensor};
use crate::pruning::{global_prune, tile_l1_norms, PrunePlan, TileNorms};
use crate::quant::{fake_quantize, fake_quantize_per_channel};
use crate::runtime::{tensor_to_literal, Engine, Manifest};
use crate::systolic::Quant;

use super::decode::{argmax_decode, ctc_greedy};
use super::metrics::{bleu, token_error_rate};

/// One evaluated configuration.
#[derive(Clone, Copy, Debug)]
pub struct QosPoint {
    pub tile: usize,
    pub rate: f64,
    pub quant: Quant,
    /// WER for ASR, BLEU for MT.
    pub qos: f64,
    pub achieved_rate: f64,
}

/// The execution surface the evaluators need. [`PjrtBackend`] runs the
/// compiled artifact; [`crate::infer::NativeBackend`] runs the native
/// engine; tests can stub it.
pub trait QosBackend {
    /// Bind one prepared configuration: `params` carries the pruned
    /// (tile-zeroed) and, for INT8, fake-quantized weights. `tile` and
    /// `quant` describe the configuration for backends that stage their
    /// own kernels (the PJRT backend ignores both — the zeroed weights
    /// already encode everything).
    fn configure(&mut self, params: &Bundle, tile: usize, quant: Quant) -> Result<()>;

    /// One padded ASR batch: `feats [batch*seq*feat]`, `pad [batch*seq]`
    /// → CTC log-probs `[batch*seq*vocab]`.
    fn run_asr(&mut self, feats: &[f32], pad: &[f32], batch: usize) -> Result<Vec<f32>>;

    /// One padded MT batch: `src [batch*seq]` tokens → logits
    /// `[batch*seq*vocab]`.
    fn run_mt(&mut self, src: &[i32], batch: usize) -> Result<Vec<f32>>;

    /// Autoregressive MT over one ragged batch: padded `src
    /// [batch*seq]` tokens plus per-utterance source lengths → greedy
    /// generated target sequences (BOS/EOS stripped). Backends without
    /// a decoder (PJRT encoder artifacts, stubs) keep the default
    /// error; [`crate::infer::NativeBackend`] overrides it.
    fn translate(
        &mut self,
        _src: &[i32],
        _src_len: &[usize],
        _batch: usize,
    ) -> Result<Vec<Vec<i32>>> {
        anyhow::bail!("backend has no autoregressive MT decoder")
    }
}

/// Engine-independent PJRT execution state for one artifact: the
/// manifest plus the converted argument literals of the current
/// configuration. The [`Engine`] is supplied per call, so this state
/// can live either behind a borrowed engine ([`PjrtBackend`]) or inside
/// an engine-owning wrapper ([`crate::coordinator::serve::Backend`]).
///
/// §Perf L3: `configure` converts the ~55 weight/mask literals once per
/// configuration; `run_*` rewrites only the data literals per test-set
/// chunk.
pub struct PjrtState {
    artifact: String,
    manifest: Option<Manifest>,
    literals: Vec<xla::Literal>,
}

impl PjrtState {
    pub fn new(artifact: &str) -> Self {
        PjrtState {
            artifact: artifact.to_string(),
            manifest: None,
            literals: Vec::new(),
        }
    }

    pub fn artifact(&self) -> &str {
        &self.artifact
    }

    pub fn configure(&mut self, engine: &mut Engine, params: &Bundle) -> Result<()> {
        let manifest = engine.load(&self.artifact)?.manifest.clone();
        // One shared contract: Manifest::assemble_args zeroes the data
        // inputs (replaced per chunk below), builds all-ones masks, and
        // pulls parameters from the bundle by name.
        let literals: Vec<xla::Literal> = manifest
            .assemble_args(params)?
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()?;
        self.manifest = Some(manifest);
        self.literals = literals;
        Ok(())
    }

    pub fn run_asr(
        &mut self,
        engine: &mut Engine,
        feats: &[f32],
        pad: &[f32],
        batch: usize,
    ) -> Result<Vec<f32>> {
        let (fi, fshape, pi, pshape) = {
            let man = self.manifest.as_ref().context("configure() not called")?;
            let fi = man.arg_index("feats").context("artifact has no 'feats'")?;
            let pi = man
                .arg_index("pad_mask")
                .context("artifact has no 'pad_mask'")?;
            (fi, man.args[fi].shape.clone(), pi, man.args[pi].shape.clone())
        };
        ensure!(
            fshape.first() == Some(&batch),
            "artifact batch {:?} != requested {batch}",
            fshape.first()
        );
        self.literals[fi] = tensor_to_literal(&Tensor::from_f32(&fshape, feats))?;
        self.literals[pi] = tensor_to_literal(&Tensor::from_f32(&pshape, pad))?;
        let out = engine.execute_literals(&self.artifact, &self.literals)?;
        Ok(out.f32s())
    }

    pub fn run_mt(&mut self, engine: &mut Engine, src: &[i32], batch: usize) -> Result<Vec<f32>> {
        let (si, sshape) = {
            let man = self.manifest.as_ref().context("configure() not called")?;
            let si = man.arg_index("src").context("artifact has no 'src'")?;
            (si, man.args[si].shape.clone())
        };
        ensure!(
            sshape.first() == Some(&batch),
            "artifact batch {:?} != requested {batch}",
            sshape.first()
        );
        self.literals[si] = tensor_to_literal(&Tensor::from_i32(&sshape, src))?;
        let out = engine.execute_literals(&self.artifact, &self.literals)?;
        Ok(out.f32s())
    }
}

/// PJRT execution of one artifact over a borrowed engine (the
/// historical QoS backend shape; [`PjrtState`] holds the actual logic).
pub struct PjrtBackend<'a> {
    engine: &'a mut Engine,
    state: PjrtState,
}

impl<'a> PjrtBackend<'a> {
    pub fn new(engine: &'a mut Engine, artifact: &str) -> Self {
        PjrtBackend { engine, state: PjrtState::new(artifact) }
    }
}

impl QosBackend for PjrtBackend<'_> {
    fn configure(&mut self, params: &Bundle, _tile: usize, _quant: Quant) -> Result<()> {
        self.state.configure(self.engine, params)
    }

    fn run_asr(&mut self, feats: &[f32], pad: &[f32], batch: usize) -> Result<Vec<f32>> {
        self.state.run_asr(self.engine, feats, pad, batch)
    }

    fn run_mt(&mut self, src: &[i32], batch: usize) -> Result<Vec<f32>> {
        self.state.run_mt(self.engine, src, batch)
    }
}

/// Shared plumbing for both evaluators: the clean parameter bundle plus
/// the feed-forward weight names SASP prunes.
struct ModelHarness {
    artifact: String,
    params: Bundle,
    ff_names: Vec<String>,
    /// Fake-quantize INT8 configurations with per-output-channel scales
    /// and stamp the bundle with the `quant.per_channel` marker, so any
    /// backend staging the bundle (native or PJRT) picks the same
    /// scheme from the artifact contract itself.
    per_channel: bool,
}

impl ModelHarness {
    fn build(artifact: &str, params: Bundle, n_blocks: usize) -> Result<Self> {
        let ff_names: Vec<String> = (0..n_blocks)
            .flat_map(|i| [format!("block{i}.ff.w1"), format!("block{i}.ff.w2")])
            .collect();
        Self::build_named(artifact, params, ff_names)
    }

    /// Build over an explicit prunable-GEMM name list — the MT path's
    /// constructor, where the decoder's `dec.block{i}.ff.*` weights join
    /// the encoder's in one global ranking.
    fn build_named(artifact: &str, params: Bundle, ff_names: Vec<String>) -> Result<Self> {
        for n in &ff_names {
            params.require(n)?;
        }
        Ok(ModelHarness {
            artifact: artifact.to_string(),
            params,
            ff_names,
            per_channel: false,
        })
    }

    /// Prune (at `tile`) + optionally fake-quantize a copy of the params.
    fn prepare_params(&self, tile: usize, rate: f64, quant: Quant) -> Result<(Bundle, PrunePlan)> {
        let mut params = self.params.clone();
        let norms: Vec<TileNorms> = self
            .ff_names
            .iter()
            .map(|n| tile_l1_norms(params.require(n).unwrap(), tile))
            .collect();
        let plan = global_prune(&norms, rate);
        for (name, mask) in self.ff_names.iter().zip(&plan.masks) {
            let w = params.get_mut(name).unwrap();
            crate::pruning::norms::apply_mask_to_weights(w, mask, tile);
        }
        if quant == Quant::Int8 {
            // PTQ applies to all stored weight matrices (attention,
            // feed-forward, projections) — not norms/biases.
            let names: Vec<String> = params
                .entries
                .iter()
                .filter(|(n, t)| {
                    t.shape.len() == 2
                        && (n.ends_with(".w")
                            || n.ends_with(".w1")
                            || n.ends_with(".w2")
                            || n.ends_with(".wq")
                            || n.ends_with(".wk")
                            || n.ends_with(".wv")
                            || n.ends_with(".wo"))
                })
                .map(|(n, _)| n.clone())
                .collect();
            for n in names {
                let w = params.get_mut(&n).unwrap();
                if self.per_channel {
                    fake_quantize_per_channel(w);
                } else {
                    fake_quantize(w);
                }
            }
            if self.per_channel {
                // The artifact contract's per-channel flag: staging
                // backends read this marker instead of needing an
                // out-of-band configuration bit.
                params.insert("quant.per_channel", Tensor::from_f32(&[1], &[1.0]));
            }
        }
        Ok((params, plan))
    }
}

/// Model metadata needed to construct an evaluator — named fields so
/// the several same-typed values cannot be swapped silently at call
/// sites.
#[derive(Clone, Copy, Debug)]
pub struct EvalMeta {
    pub n_blocks: usize,
    pub batch: usize,
    pub vocab: usize,
    pub blank: i32,
    /// The artifact-baked default tile (mask-recovering backends use it
    /// when no configuration tile is in play).
    pub tile_hint: usize,
}

/// ASR evaluator over a `testset_asr.bin`-layout bundle.
pub struct AsrEvaluator {
    harness: ModelHarness,
    feats: Vec<f32>,
    feat_len: Vec<i32>,
    refs: Vec<Vec<i32>>,
    batch: usize,
    seq_len: usize,
    feat_dim: usize,
    vocab: usize,
    blank: i32,
    /// Default tile passed to `configure` when none is in play (the
    /// artifact-baked tile; only mask-recovering backends look at it).
    tile_hint: usize,
}

impl AsrEvaluator {
    /// PJRT construction: artifact manifest + `artifacts/` bundles.
    pub fn new(engine: &mut Engine, dir: &str, artifact: &str) -> Result<Self> {
        let m = engine.load(artifact)?.manifest.clone();
        let params = load_bundle(format!("{dir}/params_asr.bin"))?;
        let ts = load_bundle(format!("{dir}/testset_asr.bin"))?;
        let meta = EvalMeta {
            n_blocks: m.model.n_blocks,
            batch: m.model.batch,
            vocab: m.model.vocab,
            blank: m.model.ctc_blank as i32,
            tile_hint: if m.model.tile > 0 { m.model.tile } else { 8 },
        };
        Self::from_parts(artifact, params, &ts, &meta)
    }

    /// Engine-free construction over in-memory bundles — the native
    /// (offline) path.
    pub fn from_parts(
        artifact: &str,
        params: Bundle,
        testset: &Bundle,
        meta: &EvalMeta,
    ) -> Result<Self> {
        ensure!(meta.batch > 0, "batch must be positive");
        let harness = ModelHarness::build(artifact, params, meta.n_blocks)?;
        let feats_t = testset.require("feats")?;
        ensure!(feats_t.shape.len() == 3, "feats must be [n, seq, feat]");
        let (n, seq_len, feat_dim) = (feats_t.shape[0], feats_t.shape[1], feats_t.shape[2]);
        let feat_len = testset.require("feat_len")?.i32s();
        let labels = testset.require("labels")?;
        let label_len = testset.require("label_len")?.i32s();
        let lmax = labels.shape[1];
        let lvals = labels.i32s();
        let refs: Vec<Vec<i32>> = (0..n)
            .map(|i| lvals[i * lmax..i * lmax + label_len[i] as usize].to_vec())
            .collect();
        Ok(AsrEvaluator {
            harness,
            feats: feats_t.f32s(),
            feat_len,
            refs,
            batch: meta.batch,
            seq_len,
            feat_dim,
            vocab: meta.vocab,
            blank: meta.blank,
            tile_hint: meta.tile_hint,
        })
    }

    pub fn n_utts(&self) -> usize {
        self.refs.len()
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Artifact name the PJRT wrappers execute.
    pub fn artifact(&self) -> &str {
        &self.harness.artifact
    }

    /// Emit INT8 configurations with per-output-channel scales: the
    /// prepared bundle is fake-quantized per channel and carries the
    /// `quant.per_channel` marker for the staging backend.
    pub fn set_per_channel(&mut self, on: bool) {
        self.harness.per_channel = on;
    }

    /// Evaluate WER at one (tile, rate, quant) configuration on any
    /// backend.
    pub fn evaluate_with<B: QosBackend>(
        &self,
        backend: &mut B,
        tile: usize,
        rate: f64,
        quant: Quant,
    ) -> Result<QosPoint> {
        let (params, plan) = self.harness.prepare_params(tile, rate, quant)?;
        backend.configure(&params, tile, quant)?;
        let hyps = self.decode_configured(backend)?;
        let wer = token_error_rate(&self.refs, &hyps);
        Ok(QosPoint { tile, rate, quant, qos: wer, achieved_rate: plan.achieved_rate })
    }

    /// PJRT convenience wrapper (the historical signature).
    pub fn evaluate(
        &self,
        engine: &mut Engine,
        tile: usize,
        rate: f64,
        quant: Quant,
    ) -> Result<QosPoint> {
        let mut backend = PjrtBackend::new(engine, &self.harness.artifact);
        self.evaluate_with(&mut backend, tile, rate, quant)
    }

    /// Decode the whole test set with explicitly supplied params.
    pub fn decode_all_with<B: QosBackend>(
        &self,
        backend: &mut B,
        params: &Bundle,
    ) -> Result<Vec<Vec<i32>>> {
        backend.configure(params, self.tile_hint, Quant::Fp32)?;
        self.decode_configured(backend)
    }

    /// PJRT convenience wrapper for [`Self::decode_all_with`].
    pub fn decode_all(&self, engine: &mut Engine, params: &Bundle) -> Result<Vec<Vec<i32>>> {
        let mut backend = PjrtBackend::new(engine, &self.harness.artifact);
        self.decode_all_with(&mut backend, params)
    }

    /// Run inference over the whole test set on a configured backend,
    /// chunking into padded batches (the final chunk repeats the last
    /// utterance; padding rows are discarded).
    fn decode_configured<B: QosBackend>(&self, backend: &mut B) -> Result<Vec<Vec<i32>>> {
        let n = self.n_utts();
        let (b, t, f) = (self.batch, self.seq_len, self.feat_dim);
        let mut hyps = Vec::with_capacity(n);
        let mut chunk = 0;
        while chunk * b < n {
            let lo = chunk * b;
            let hi = ((chunk + 1) * b).min(n);
            let mut feats = vec![0.0f32; b * t * f];
            let mut pad = vec![0.0f32; b * t];
            for i in 0..b {
                let src = (lo + i).min(n - 1);
                feats[i * t * f..(i + 1) * t * f]
                    .copy_from_slice(&self.feats[src * t * f..(src + 1) * t * f]);
                for tt in 0..self.feat_len[src] as usize {
                    pad[i * t + tt] = 1.0;
                }
            }
            let lp = backend.run_asr(&feats, &pad, b)?;
            ensure!(
                lp.len() == b * t * self.vocab,
                "backend returned {} log-probs, expected {}",
                lp.len(),
                b * t * self.vocab
            );
            for i in 0..(hi - lo) {
                let src = lo + i;
                let frame0 = i * t * self.vocab;
                hyps.push(ctc_greedy(
                    &lp[frame0..frame0 + t * self.vocab],
                    self.feat_len[src] as usize,
                    self.vocab,
                    self.blank,
                ));
            }
            chunk += 1;
        }
        Ok(hyps)
    }

    /// The clean-weights baseline WER (rate 0, FP32) through PJRT.
    pub fn baseline(&self, engine: &mut Engine) -> Result<f64> {
        Ok(self.evaluate(engine, 8, 0.0, Quant::Fp32)?.qos)
    }
}

/// MT evaluator (BLEU, higher better). Two decode modes:
///
/// - **per-position argmax** over the encoder logits — the historical
///   PJRT-artifact contract (`testset_mt.bin`, references are full
///   `seq_len` rows);
/// - **greedy autoregressive** through [`QosBackend::translate`] — the
///   native decoder path over a lengths-carrying test set
///   ([`crate::infer::synth::synth_mt_testset`] layout), references are
///   the dense FP32 model's own greedy decode (baseline BLEU 100).
pub struct MtEvaluator {
    harness: ModelHarness,
    src: Vec<i32>,
    src_len: Vec<usize>,
    refs: Vec<Vec<i32>>,
    batch: usize,
    seq_len: usize,
    vocab: usize,
    /// Greedy autoregressive decoding (vs per-position argmax).
    greedy: bool,
}

impl MtEvaluator {
    pub fn new(engine: &mut Engine, dir: &str, artifact: &str) -> Result<Self> {
        let m = engine.load(artifact)?.manifest.clone();
        let params = load_bundle(format!("{dir}/params_mt.bin"))?;
        let harness = ModelHarness::build(artifact, params, m.model.n_blocks)?;
        let ts = load_bundle(format!("{dir}/testset_mt.bin"))?;
        let src_t = ts.require("src")?;
        let (n, seq_len) = (src_t.shape[0], src_t.shape[1]);
        let tgt = ts.require("tgt")?.i32s();
        let refs: Vec<Vec<i32>> = (0..n)
            .map(|i| tgt[i * seq_len..(i + 1) * seq_len].to_vec())
            .collect();
        Ok(MtEvaluator {
            src: src_t.i32s(),
            src_len: vec![seq_len; n],
            refs,
            batch: m.model.batch,
            seq_len,
            vocab: m.model.vocab,
            harness,
            greedy: false,
        })
    }

    /// Engine-free construction over in-memory bundles — the native
    /// (offline) autoregressive path. `params` carries encoder plus
    /// `dec.*` decoder weights; `dec_blocks` decoder blocks join the
    /// prunable-GEMM list; `testset` is the `src`/`src_len`/`tgt`/
    /// `tgt_len` layout.
    pub fn from_parts(
        artifact: &str,
        params: Bundle,
        testset: &Bundle,
        meta: &EvalMeta,
        dec_blocks: usize,
    ) -> Result<Self> {
        ensure!(meta.batch > 0, "batch must be positive");
        let mut ff_names: Vec<String> = (0..meta.n_blocks)
            .flat_map(|i| [format!("block{i}.ff.w1"), format!("block{i}.ff.w2")])
            .collect();
        ff_names.extend(crate::infer::DecoderWeights::ff_names(dec_blocks));
        let harness = ModelHarness::build_named(artifact, params, ff_names)?;
        let src_t = testset.require("src")?;
        ensure!(src_t.shape.len() == 2, "src must be [n, seq]");
        let (n, seq_len) = (src_t.shape[0], src_t.shape[1]);
        let src_len: Vec<usize> = testset
            .require("src_len")?
            .i32s()
            .iter()
            .map(|l| *l as usize)
            .collect();
        ensure!(src_len.len() == n, "one src_len per sentence");
        for (i, l) in src_len.iter().enumerate() {
            ensure!(
                *l > 0 && *l <= seq_len,
                "sentence {i}: src_len {l} out of 1..={seq_len}"
            );
        }
        let tgt = testset.require("tgt")?;
        ensure!(
            tgt.shape.len() == 2 && tgt.shape[0] == n,
            "tgt must be [n, tmax]"
        );
        let tgt_len = testset.require("tgt_len")?.i32s();
        ensure!(tgt_len.len() == n, "one tgt_len per sentence");
        let tmax = tgt.shape[1];
        for (i, l) in tgt_len.iter().enumerate() {
            ensure!(
                (0..=tmax as i32).contains(l),
                "sentence {i}: tgt_len {l} out of 0..={tmax}"
            );
        }
        let tvals = tgt.i32s();
        let refs: Vec<Vec<i32>> = (0..n)
            .map(|i| tvals[i * tmax..i * tmax + tgt_len[i] as usize].to_vec())
            .collect();
        Ok(MtEvaluator {
            src: src_t.i32s(),
            src_len,
            refs,
            batch: meta.batch,
            seq_len,
            vocab: meta.vocab,
            harness,
            greedy: true,
        })
    }

    pub fn n_sents(&self) -> usize {
        self.refs.len()
    }

    /// Emit INT8 configurations with per-output-channel scales (see
    /// [`AsrEvaluator::set_per_channel`]).
    pub fn set_per_channel(&mut self, on: bool) {
        self.harness.per_channel = on;
    }

    pub fn evaluate_with<B: QosBackend>(
        &self,
        backend: &mut B,
        tile: usize,
        rate: f64,
        quant: Quant,
    ) -> Result<QosPoint> {
        let (params, plan) = self.harness.prepare_params(tile, rate, quant)?;
        backend.configure(&params, tile, quant)?;
        let hyps = if self.greedy {
            self.translate_configured(backend)?
        } else {
            self.argmax_configured(backend)?
        };
        let score = bleu(&self.refs, &hyps, 4);
        Ok(QosPoint { tile, rate, quant, qos: score, achieved_rate: plan.achieved_rate })
    }

    /// Per-position argmax decode over encoder logits (the PJRT
    /// contract), chunked into padded batches.
    fn argmax_configured<B: QosBackend>(&self, backend: &mut B) -> Result<Vec<Vec<i32>>> {
        let n = self.refs.len();
        let (b, t) = (self.batch, self.seq_len);
        let mut hyps = Vec::with_capacity(n);
        let mut chunk = 0;
        while chunk * b < n {
            let lo = chunk * b;
            let hi = ((chunk + 1) * b).min(n);
            let mut src = vec![0i32; b * t];
            for i in 0..b {
                let s = (lo + i).min(n - 1);
                src[i * t..(i + 1) * t].copy_from_slice(&self.src[s * t..(s + 1) * t]);
            }
            let logits = backend.run_mt(&src, b)?;
            ensure!(
                logits.len() == b * t * self.vocab,
                "backend returned {} logits, expected {}",
                logits.len(),
                b * t * self.vocab
            );
            for i in 0..(hi - lo) {
                hyps.push(argmax_decode(
                    &logits[i * t * self.vocab..(i + 1) * t * self.vocab],
                    t,
                    self.vocab,
                ));
            }
            chunk += 1;
        }
        Ok(hyps)
    }

    /// Greedy autoregressive decode through the backend's translate
    /// surface, chunked into batches. Unlike the fixed-batch PJRT
    /// argmax path, the decoder backends accept any batch, so the tail
    /// chunk is sent short instead of padded with discarded
    /// repeat-decodes.
    fn translate_configured<B: QosBackend>(&self, backend: &mut B) -> Result<Vec<Vec<i32>>> {
        let n = self.refs.len();
        let (b, t) = (self.batch, self.seq_len);
        let mut hyps = Vec::with_capacity(n);
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + b).min(n);
            let cb = hi - lo;
            let mut src = vec![0i32; cb * t];
            let mut lens = Vec::with_capacity(cb);
            for i in 0..cb {
                let s = lo + i;
                src[i * t..(i + 1) * t].copy_from_slice(&self.src[s * t..(s + 1) * t]);
                lens.push(self.src_len[s]);
            }
            let out = backend.translate(&src, &lens, cb)?;
            ensure!(
                out.len() == cb,
                "backend returned {} translations, expected {cb}",
                out.len()
            );
            hyps.extend(out);
            lo = hi;
        }
        Ok(hyps)
    }

    /// PJRT convenience wrapper (the historical signature).
    pub fn evaluate(
        &self,
        engine: &mut Engine,
        tile: usize,
        rate: f64,
        quant: Quant,
    ) -> Result<QosPoint> {
        let mut backend = PjrtBackend::new(engine, &self.harness.artifact);
        self.evaluate_with(&mut backend, tile, rate, quant)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A stub backend that answers every frame with a fixed class, so
    /// the evaluator's chunking/decode plumbing is testable without PJRT
    /// or the native engine.
    struct StubBackend {
        vocab: usize,
        seq: usize,
        hot: usize,
        configured: usize,
    }

    impl QosBackend for StubBackend {
        fn configure(&mut self, params: &Bundle, _tile: usize, _quant: Quant) -> Result<()> {
            // The harness hands over the pruned parameter bundle.
            params.require("block0.ff.w1")?;
            self.configured += 1;
            Ok(())
        }

        fn run_asr(&mut self, feats: &[f32], pad: &[f32], batch: usize) -> Result<Vec<f32>> {
            assert_eq!(pad.len(), batch * self.seq);
            assert_eq!(feats.len() % (batch * self.seq), 0);
            let mut lp = vec![-10.0f32; batch * self.seq * self.vocab];
            for row in 0..batch * self.seq {
                lp[row * self.vocab + self.hot] = 0.0;
            }
            Ok(lp)
        }

        fn run_mt(&mut self, _src: &[i32], _batch: usize) -> Result<Vec<f32>> {
            anyhow::bail!("not an MT stub")
        }
    }

    fn tiny_eval() -> AsrEvaluator {
        let t = 4usize;
        let f = 2usize;
        let n = 3usize;
        let mut params = Bundle::default();
        params.insert("block0.ff.w1", Tensor::from_f32(&[8, 8], &[0.5; 64]));
        params.insert("block0.ff.w2", Tensor::from_f32(&[8, 8], &[0.5; 64]));
        let mut ts = Bundle::default();
        ts.insert("feats", Tensor::zeros(&[n, t, f], crate::data::DType::F32));
        ts.insert("feat_len", Tensor::from_i32(&[n], &[4, 2, 3]));
        // References: utterance i expects `i+1` copies of token 1.
        ts.insert("labels", Tensor::from_i32(&[n, 3], &[1, 0, 0, 1, 1, 0, 1, 1, 1]));
        ts.insert("label_len", Tensor::from_i32(&[n], &[1, 2, 3]));
        let meta = EvalMeta { n_blocks: 1, batch: 2, vocab: 5, blank: 0, tile_hint: 8 };
        AsrEvaluator::from_parts("stub", params, &ts, &meta).unwrap()
    }

    #[test]
    fn evaluator_chunks_and_scores_via_backend() {
        let eval = tiny_eval();
        assert_eq!(eval.n_utts(), 3);
        assert_eq!(eval.batch(), 2);
        assert_eq!(eval.artifact(), "stub");
        // Hot class 1 with blank 0: every utterance decodes to a single
        // token [1] (repeats collapse), so utt 0 matches its reference
        // exactly and utts 1/2 have 1 and 2 errors -> WER = 3/6.
        let mut be = StubBackend { vocab: 5, seq: 4, hot: 1, configured: 0 };
        let p = eval.evaluate_with(&mut be, 8, 0.0, Quant::Fp32).unwrap();
        assert!((p.qos - 0.5).abs() < 1e-9, "wer {}", p.qos);
        assert_eq!(be.configured, 1, "one configure per configuration");
        assert_eq!(p.achieved_rate, 0.0);
    }

    #[test]
    fn decode_all_with_reports_per_utterance_hyps() {
        let eval = tiny_eval();
        let mut be = StubBackend { vocab: 5, seq: 4, hot: 2, configured: 0 };
        let params = eval.harness.params.clone();
        let hyps = eval.decode_all_with(&mut be, &params).unwrap();
        assert_eq!(hyps.len(), 3);
        for h in &hyps {
            assert_eq!(h, &vec![2]);
        }
    }

    #[test]
    fn native_mt_evaluator_baseline_bleu_100() {
        // The acceptance contract: the greedy-mode evaluator over the
        // synthetic teacher-labeled MT set scores exactly BLEU 100 for
        // the dense FP32 baseline (references are the model's own
        // decode), fully offline.
        use crate::infer::decoder::testutil::mini_dec_dims;
        use crate::infer::synth::{synth_decoder_weights, synth_mt_testset, synth_weights};
        use crate::infer::testutil::mini_dims;
        use crate::infer::{ModelDims, NativeBackend};
        let dims = ModelDims {
            token_input: true,
            ctc_blank: -1,
            ..mini_dims()
        };
        let dec_dims = mini_dec_dims();
        let enc = synth_weights(&dims, 61);
        let dec = synth_decoder_weights(&dec_dims, 61);
        let ts = synth_mt_testset(&enc, &dec, 6, 3).unwrap();
        let mut params = enc.to_bundle();
        dec.append_to_bundle(&mut params);
        let meta = EvalMeta {
            n_blocks: dims.n_blocks,
            batch: 2,
            vocab: dims.vocab,
            blank: -1,
            tile_hint: dims.tile,
        };
        let eval =
            MtEvaluator::from_parts("native_mt", params, &ts, &meta, dec_dims.n_blocks)
                .unwrap();
        assert_eq!(eval.n_sents(), 6);
        let mut be = NativeBackend::new_mt(enc, dec, 2).unwrap();
        let p = eval.evaluate_with(&mut be, 8, 0.0, Quant::Fp32).unwrap();
        assert!(
            (p.qos - 100.0).abs() < 1e-9,
            "dense FP32 must reproduce its own references: BLEU {}",
            p.qos
        );
        assert_eq!(p.achieved_rate, 0.0);
        // A pruned+quantized point still evaluates (degradation is
        // measurable, never NaN).
        let q = eval.evaluate_with(&mut be, 8, 0.5, Quant::Int8).unwrap();
        assert!((0.0..=100.0).contains(&q.qos), "BLEU {}", q.qos);
        assert!((q.achieved_rate - 0.5).abs() < 0.1);
    }

    #[test]
    fn prepare_params_prunes_and_quantizes() {
        let eval = tiny_eval();
        let (params, plan) = eval
            .harness
            .prepare_params(8, 0.5, Quant::Int8)
            .unwrap();
        assert!((plan.achieved_rate - 0.5).abs() < 1e-9);
        // One of the two 8x8 single-tile FF weights is fully zeroed.
        let zeroed = ["block0.ff.w1", "block0.ff.w2"]
            .iter()
            .filter(|n| params.get(n).unwrap().f32s().iter().all(|v| *v == 0.0))
            .count();
        assert_eq!(zeroed, 1);
    }

    #[test]
    fn per_channel_prepare_stamps_marker_and_backend_stages_it() {
        // Satellite: the per-channel flag travels inside the artifact
        // contract. `prepare_params` fake-quantizes per channel and
        // stamps `quant.per_channel`; a backend that was never told
        // out-of-band stages per-channel scales off the marker alone.
        use crate::infer::synth::{synth_testset, synth_weights};
        use crate::infer::testutil::mini_dims;
        use crate::infer::NativeBackend;

        let dims = mini_dims();
        let w = synth_weights(&dims, 71);
        let ts = synth_testset(&w, 4, 2).unwrap();
        let meta = EvalMeta {
            n_blocks: dims.n_blocks,
            batch: 2,
            vocab: dims.vocab,
            blank: dims.ctc_blank,
            tile_hint: dims.tile,
        };
        let mut eval = AsrEvaluator::from_parts("native", w.to_bundle(), &ts, &meta).unwrap();

        let (pt, _) = eval.harness.prepare_params(8, 0.2, Quant::Int8).unwrap();
        assert!(pt.get("quant.per_channel").is_none(), "per-tensor: no marker");
        eval.set_per_channel(true);
        let (pc, _) = eval.harness.prepare_params(8, 0.2, Quant::Int8).unwrap();
        assert!(pc.get("quant.per_channel").is_some(), "per-channel: marker");
        let (fp, _) = eval.harness.prepare_params(8, 0.2, Quant::Fp32).unwrap();
        assert!(fp.get("quant.per_channel").is_none(), "marker only on INT8 bundles");
        assert_ne!(
            pt.get("block0.attn.wq").unwrap().f32s(),
            pc.get("block0.attn.wq").unwrap().f32s(),
            "per-channel scales quantize onto a different grid"
        );

        let mut be = NativeBackend::new(w.clone(), 2).unwrap();
        assert!(!be.per_channel(), "backend never configured out-of-band");
        let a = eval.evaluate_with(&mut be, 8, 0.2, Quant::Int8).unwrap();
        assert!(be.model().per_channel, "marker flips the staged scheme");
        assert!(!be.per_channel(), "sticky flag untouched by the marker");
        // Kernel-equivalence identity over the marker-staged bundle:
        // per-channel INT8 kernels == FP32 kernels on the same
        // per-channel fake-quantized weights, at QoS scope. (A backend
        // that ignored the marker would re-quantize per tensor and
        // break the exact roundtrip.)
        struct ForceFp32<'a>(&'a mut NativeBackend);
        impl QosBackend for ForceFp32<'_> {
            fn configure(&mut self, p: &Bundle, tile: usize, _q: Quant) -> Result<()> {
                self.0.configure(p, tile, Quant::Fp32)
            }
            fn run_asr(&mut self, f: &[f32], p: &[f32], b: usize) -> Result<Vec<f32>> {
                self.0.run_asr(f, p, b)
            }
            fn run_mt(&mut self, s: &[i32], b: usize) -> Result<Vec<f32>> {
                self.0.run_mt(s, b)
            }
        }
        let b = eval
            .evaluate_with(&mut ForceFp32(&mut be), 8, 0.2, Quant::Int8)
            .unwrap();
        assert_eq!(a.qos, b.qos, "marker-staged INT8 == fake-quant FP32 WER");
    }
}
