//! `sasp report decode` — the continuous-batching decode frontier.
//!
//! Drives synthetic MT request streams through
//! [`crate::coordinator::serve::DecodeServer`] over the 25%-pruned INT8
//! native MT backend and sweeps the two knobs of iteration-level
//! scheduling:
//!
//! - **offered load** — the inter-arrival gap of the request stream
//!   (burst = everything queued at once vs a paced trickle);
//! - **panel width** — `max_slots`, the number of in-flight decodes
//!   advancing in lockstep per step. One slot *is* the sequential
//!   per-utterance baseline: the same scheduler degenerates to plain
//!   greedy decode, so every row of the table shares one code path and
//!   the frontier isolates the batching win.
//!
//! Each point reports served-request latency percentiles, request and
//! token throughput, the mean panel fill (live slots per step — the
//! occupancy evidence `sasp_decode_batch_occupancy` histograms under
//! telemetry), and the decode-scope PE utilization derived from the
//! recorded [`crate::systolic::TileTiming`] charges: batching k GEMV
//! rows onto one weight-stationary tile pass amortizes the fill/drain
//! bubble and the reprogramming stall, so MACs per array-cycle PE slot
//! rise with the fill. Every point serves the same request stream (same
//! seed, same gaps). The numbers are wall-clock on the current host —
//! a measurement harness, not a deterministic figure, which is why it
//! is not part of `sasp report all`.

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::{ensure, Result};

use crate::coordinator::serve::{DecodeReport, DecodeServer, MtRequest};
use crate::infer::{
    synth_decoder_weights, synth_weights, DecoderDims, ModelDims, NativeBackend,
};
use crate::systolic::Quant;
use crate::util::rng::Rng;

use super::Report;

/// Drive `n_requests` synthetic MT utterances (deterministic token
/// sources and inter-arrival `gap`) through a fresh 25%-pruned INT8
/// native MT backend with a `max_slots`-wide [`DecodeServer`].
/// Returns the serving report plus the run's decode-scope PE
/// utilization (MACs over array-busy PE slots, `tile x tile` PEs per
/// cycle, across self/cross-attention, feed-forward, head, and the
/// cross-K/V precompute).
pub fn measure_decode(
    dims: &ModelDims,
    dec_dims: &DecoderDims,
    max_slots: usize,
    n_requests: usize,
    gap: Duration,
) -> Result<(DecodeReport, f64)> {
    ensure!(dims.token_input, "decode frontier needs a token-input model");
    let mut backend = NativeBackend::new_mt(
        synth_weights(dims, 7),
        synth_decoder_weights(dec_dims, 7),
        max_slots.max(1),
    )?;
    backend.prepare(dims.tile, 0.25, Quant::Int8)?;
    backend.reset_stats();

    let (req_tx, req_rx) = mpsc::channel::<MtRequest>();
    let (resp_tx, resp_rx) = mpsc::channel();
    let (t, vocab) = (dims.seq_len, dims.vocab);
    let producer = thread::spawn(move || {
        let mut rng = Rng::new(11);
        for id in 0..n_requests as u64 {
            let len = t / 2 + rng.index(t - t / 2) + 1;
            let mut src = vec![0i32; t];
            for tok in src[..len.min(t)].iter_mut() {
                *tok = rng.index(vocab) as i32;
            }
            let _ = req_tx.send(MtRequest::new(id, src, len.min(t)));
            if !gap.is_zero() {
                thread::sleep(gap);
            }
        }
        // Dropping req_tx closes the queue and drains the server.
    });
    let mut server = DecodeServer::new(max_slots);
    let report = server.run(&mut backend, req_rx, resp_tx)?;
    producer.join().unwrap();
    let answered = resp_rx.try_iter().count();
    ensure!(
        answered == n_requests,
        "answered {answered} of {n_requests} requests"
    );

    let total = backend.decode_stats().total();
    let pes = (dims.tile * dims.tile) as f64;
    let util = total.timing.macs as f64 / (total.timing.array_cycles.max(1) as f64 * pes);
    Ok((report, util))
}

/// [`decode_report`] with explicit model/load parameters (the render
/// test uses the mini model and a short stream to stay fast). Sweeps
/// offered load x `max_slots`, with the 1-slot row as the sequential
/// per-utterance baseline of each load.
pub fn decode_report_sized(
    dims: &ModelDims,
    dec_dims: &DecoderDims,
    slot_counts: &[usize],
    n_requests: usize,
    gaps: &[(&str, Duration)],
) -> Result<Report> {
    let mut r = Report::new(
        "Decode — continuous iteration-level batching frontier (native MT, 25% SASP, INT8)",
    );
    r.line(format!(
        "{n_requests} requests per point, src seq {}, target max_len {}; \
         slots=1 is the sequential per-utterance baseline",
        dims.seq_len, dec_dims.max_len
    ));
    r.line(format!(
        "{:<24} {:>4} {:>10} {:>10} {:>10} {:>8} {:>8} {:>6} {:>6}",
        "load / scheduler", "ok", "p50", "p99", "req/s", "tok/s", "fill", "steps", "util%"
    ));
    for (gap_label, gap) in gaps {
        for &slots in slot_counts {
            let label = if slots == 1 {
                format!("{gap_label} sequential")
            } else {
                format!("{gap_label} continuous x{slots}")
            };
            let (rep, util) = measure_decode(dims, dec_dims, slots, n_requests, *gap)?;
            r.line(format!(
                "{:<24} {:>4} {:>10} {:>10} {:>10.1} {:>8.0} {:>8.2} {:>6} {:>6.1}",
                label,
                rep.n_requests,
                format!("{:.2?}", rep.p50),
                format!("{:.2?}", rep.p99),
                rep.throughput_rps,
                rep.tokens_per_sec,
                rep.mean_slot_fill,
                rep.n_steps,
                util * 100.0,
            ));
        }
    }
    Ok(r)
}

/// The `sasp report decode` entry point: tiny-MT native backend, 24
/// requests per point, a pre-queued burst against a paced trickle,
/// panel widths 1 (sequential baseline) / 2 / 4 / 8.
pub fn decode_report() -> Result<Report> {
    decode_report_sized(
        &ModelDims::tiny_mt(),
        &DecoderDims::tiny_mt(),
        &[1, 2, 4, 8],
        24,
        &[
            ("burst 0us", Duration::ZERO),
            ("paced 500us", Duration::from_micros(500)),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::decoder::testutil::mini_dec_dims;
    use crate::infer::testutil::mini_dims;

    fn mini_mt_dims() -> ModelDims {
        ModelDims {
            token_input: true,
            ctc_blank: -1,
            ..mini_dims()
        }
    }

    #[test]
    fn decode_report_renders_frontier() {
        let r = decode_report_sized(
            &mini_mt_dims(),
            &mini_dec_dims(),
            &[1, 2],
            4,
            &[("burst 0us", Duration::ZERO)],
        )
        .unwrap();
        let s = r.render();
        assert!(s.contains("burst 0us sequential"), "{s}");
        assert!(s.contains("burst 0us continuous x2"), "{s}");
        // Title block: load line + column header + 2 frontier points.
        assert_eq!(r.lines.len(), 2 + 2, "{s}");
    }

    #[test]
    fn measure_decode_answers_all_and_fills_panels() {
        let (rep, util) = measure_decode(
            &mini_mt_dims(),
            &mini_dec_dims(),
            3,
            5,
            Duration::ZERO,
        )
        .unwrap();
        assert_eq!(rep.n_requests, 5);
        assert_eq!(rep.shed + rep.expired + rep.invalid, 0);
        // All five requests were queued before the first step, so the
        // first panel is full and the mean fill beats sequential.
        assert_eq!(rep.schedule[0], 3);
        assert!(rep.mean_slot_fill > 1.0);
        assert!(util > 0.0 && util <= 1.0, "utilization {util} out of range");
    }

    #[test]
    fn continuous_fill_beats_sequential_on_a_burst() {
        // The panel-fill figure of merit: the same pre-queued burst at 4
        // slots runs strictly fuller panels (and strictly fewer steps)
        // than the 1-slot sequential degenerate case.
        let (seq, _) = measure_decode(
            &mini_mt_dims(),
            &mini_dec_dims(),
            1,
            4,
            Duration::ZERO,
        )
        .unwrap();
        let (cont, _) = measure_decode(
            &mini_mt_dims(),
            &mini_dec_dims(),
            4,
            4,
            Duration::ZERO,
        )
        .unwrap();
        assert!((seq.mean_slot_fill - 1.0).abs() < 1e-12);
        assert!(cont.mean_slot_fill > 1.0);
        assert!(cont.n_steps < seq.n_steps, "lockstep panels shorten the run");
        // Same total work: the step counts weighted by fill agree.
        assert_eq!(
            cont.schedule.iter().sum::<usize>(),
            seq.schedule.iter().sum::<usize>()
        );
    }
}
