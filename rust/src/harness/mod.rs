//! Experiment harness: one generator per table/figure of the paper's
//! evaluation section. Each produces a printable text report (the same
//! rows/series the paper plots) and is wired to both the CLI
//! (`sasp report <id>`) and the bench targets.

pub mod decode;
pub mod figures;
pub mod qos_cache;
pub mod serving;
pub mod trace;
pub mod util;

pub use decode::{decode_report, decode_report_sized, measure_decode};
pub use figures::*;
pub use qos_cache::QosCache;
pub use serving::{
    measure_overload, measure_serve, overload_report, overload_report_sized, serve_report,
    serve_report_sized,
};
pub use trace::{measure_trace, trace_report, trace_report_sized};
pub use util::{measure_util, util_frontier, util_report, util_report_sized};

/// A rendered report: title + lines (also JSON-emittable).
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub title: String,
    pub lines: Vec<String>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Self {
        Report { title: title.into(), lines: Vec::new() }
    }

    pub fn line(&mut self, s: impl Into<String>) {
        self.lines.push(s.into());
    }

    pub fn render(&self) -> String {
        let mut out = format!("== {} ==\n", self.title);
        for l in &self.lines {
            out.push_str(l);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders() {
        let mut r = Report::new("T");
        r.line("a");
        r.line("b");
        assert_eq!(r.render(), "== T ==\na\nb\n");
    }
}
