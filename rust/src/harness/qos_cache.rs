//! Memoized QoS evaluation over the (tile, rate, quant) grid — several
//! figures share the same points, and each point costs test-set
//! inference through PJRT.

use std::collections::HashMap;

use anyhow::Result;

use crate::qos::{AsrEvaluator, MtEvaluator};
use crate::runtime::Engine;
use crate::systolic::Quant;

/// Key with rate discretized to 1e-4 so f64 rates hash safely.
fn key(tile: usize, rate: f64, quant: Quant) -> (usize, u64, Quant) {
    (tile, (rate * 10_000.0).round() as u64, quant)
}

/// Cache over an ASR (WER) and optional MT (BLEU) evaluator.
pub struct QosCache {
    pub asr: AsrEvaluator,
    pub mt: Option<MtEvaluator>,
    wer: HashMap<(usize, u64, Quant), f64>,
    bleu: HashMap<(usize, u64, Quant), f64>,
}

impl QosCache {
    pub fn new(asr: AsrEvaluator, mt: Option<MtEvaluator>) -> Self {
        QosCache { asr, mt, wer: HashMap::new(), bleu: HashMap::new() }
    }

    /// WER of the tiny ASR model at a configuration (memoized).
    pub fn wer(
        &mut self,
        engine: &mut Engine,
        tile: usize,
        rate: f64,
        quant: Quant,
    ) -> Result<f64> {
        let k = key(tile, rate, quant);
        if let Some(v) = self.wer.get(&k) {
            return Ok(*v);
        }
        let v = self.asr.evaluate(engine, tile, rate, quant)?.qos;
        self.wer.insert(k, v);
        Ok(v)
    }

    /// BLEU of the tiny MT model at a configuration (memoized).
    pub fn bleu(
        &mut self,
        engine: &mut Engine,
        tile: usize,
        rate: f64,
        quant: Quant,
    ) -> Result<f64> {
        let k = key(tile, rate, quant);
        if let Some(v) = self.bleu.get(&k) {
            return Ok(*v);
        }
        let mt = self
            .mt
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no MT evaluator loaded"))?;
        let v = mt.evaluate(engine, tile, rate, quant)?.qos;
        self.bleu.insert(k, v);
        Ok(v)
    }

    pub fn cached_points(&self) -> usize {
        self.wer.len() + self.bleu.len()
    }
}
