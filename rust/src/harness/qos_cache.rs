//! Memoized QoS evaluation over the (tile, rate, quant) grid — several
//! figures share the same points, and each point costs test-set
//! inference.
//!
//! The cache owns the auto-selected execution backend
//! ([`crate::coordinator::serve::Backend`]): PJRT over compiled
//! artifacts when they exist, the batched native engine otherwise — so
//! `sasp report fig9/fig10/fig11/table3/headline` (and fig7's WER axis)
//! run fully offline instead of erroring on a fresh checkout.

use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::serve::Backend;
use crate::qos::{AsrEvaluator, MtEvaluator};
use crate::systolic::Quant;

/// Key with rate discretized to 1e-4 so f64 rates hash safely.
fn key(tile: usize, rate: f64, quant: Quant) -> (usize, u64, Quant) {
    (tile, (rate * 10_000.0).round() as u64, quant)
}

/// Number of synthetic utterances the offline (native) evaluator uses.
const NATIVE_TESTSET_UTTS: usize = 16;

/// Cache over an ASR (WER) and optional MT (BLEU) evaluator, executing
/// on the auto-selected backend.
pub struct QosCache {
    pub asr: AsrEvaluator,
    pub mt: Option<MtEvaluator>,
    backend: Backend,
    wer: HashMap<(usize, u64, Quant), f64>,
    bleu: HashMap<(usize, u64, Quant), f64>,
}

impl QosCache {
    pub fn new(backend: Backend, asr: AsrEvaluator, mt: Option<MtEvaluator>) -> Self {
        QosCache { asr, mt, backend, wer: HashMap::new(), bleu: HashMap::new() }
    }

    /// Build the whole QoS stack for `dir` on the auto-selected
    /// backend: PJRT evaluators over the artifact bundles when they
    /// exist, the native evaluator over the synthetic teacher-labeled
    /// test set otherwise (MT has no native path yet — see ROADMAP).
    pub fn auto(dir: &str) -> Result<Self> {
        let mut backend = Backend::auto(dir)?;
        let asr = backend.asr_evaluator(dir, NATIVE_TESTSET_UTTS)?;
        let mt = match backend.engine_mut() {
            Some(engine) => MtEvaluator::new(engine, dir, "mt_encoder_ref").ok(),
            None => None,
        };
        Ok(QosCache::new(backend, asr, mt))
    }

    /// Which execution backend the cache evaluates on.
    pub fn backend_label(&self) -> &'static str {
        self.backend.label()
    }

    /// WER of the ASR model at a configuration (memoized).
    pub fn wer(&mut self, tile: usize, rate: f64, quant: Quant) -> Result<f64> {
        let k = key(tile, rate, quant);
        if let Some(v) = self.wer.get(&k) {
            return Ok(*v);
        }
        let v = self.asr.evaluate_with(&mut self.backend, tile, rate, quant)?.qos;
        self.wer.insert(k, v);
        Ok(v)
    }

    /// BLEU of the MT model at a configuration (memoized; PJRT only —
    /// the native MT path is a ROADMAP item).
    pub fn bleu(&mut self, tile: usize, rate: f64, quant: Quant) -> Result<f64> {
        let k = key(tile, rate, quant);
        if let Some(v) = self.bleu.get(&k) {
            return Ok(*v);
        }
        let mt = self
            .mt
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no MT evaluator loaded"))?;
        let engine = self
            .backend
            .engine_mut()
            .ok_or_else(|| anyhow::anyhow!("MT QoS needs the PJRT backend"))?;
        let v = mt.evaluate(engine, tile, rate, quant)?.qos;
        self.bleu.insert(k, v);
        Ok(v)
    }

    pub fn cached_points(&self) -> usize {
        self.wer.len() + self.bleu.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::testutil::mini_dims;

    #[test]
    fn native_cache_memoizes_offline_wer() {
        let dims = mini_dims();
        let mut backend =
            Backend::auto_with("definitely/_no_artifacts_here", "asr_encoder_ref", dims, 5, 2)
                .unwrap();
        let asr = backend.asr_evaluator("unused", 3).unwrap();
        let mut qos = QosCache::new(backend, asr, None);
        assert_eq!(qos.backend_label(), "native");
        let a = qos.wer(dims.tile, 0.0, Quant::Fp32).unwrap();
        assert_eq!(a, 0.0, "teacher-labeled baseline");
        assert_eq!(qos.cached_points(), 1);
        let b = qos.wer(dims.tile, 0.0, Quant::Fp32).unwrap();
        assert_eq!(a, b);
        assert_eq!(qos.cached_points(), 1, "second read hits the cache");
        assert!(
            qos.bleu(dims.tile, 0.0, Quant::Fp32).is_err(),
            "no native MT path"
        );
    }
}
