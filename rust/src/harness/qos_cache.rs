//! Memoized QoS evaluation over the (tile, rate, quant) grid — several
//! figures share the same points, and each point costs test-set
//! inference.
//!
//! The cache owns the auto-selected execution backend
//! ([`crate::coordinator::serve::Backend`]): PJRT over compiled
//! artifacts when they exist, the batched native engine otherwise — so
//! `sasp report fig9/fig10/fig11/table3/headline` (and fig7's WER axis)
//! run fully offline instead of erroring on a fresh checkout. The MT
//! (BLEU) axis is offline too: without artifacts the cache builds a
//! synthetic MT model (token-input encoder + autoregressive decoder,
//! [`crate::infer::NativeBackend::new_mt`]) whose teacher-labeled test
//! set scores BLEU 100 at the dense FP32 baseline. The native MT stack
//! is built **lazily on the first [`QosCache::bleu`] call** — ASR-only
//! reports never pay for (or fail on) the MT teacher decode.

use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::serve::Backend;
use crate::infer::{
    synth_decoder_weights, synth_mt_testset, synth_weights, DecoderDims, ModelDims,
    NativeBackend,
};
use crate::qos::{AsrEvaluator, EvalMeta, MtEvaluator};
use crate::systolic::Quant;

/// Key with rate discretized to 1e-4 so f64 rates hash safely.
fn key(tile: usize, rate: f64, quant: Quant) -> (usize, u64, Quant) {
    (tile, (rate * 10_000.0).round() as u64, quant)
}

/// Number of synthetic utterances the offline (native) evaluator uses.
const NATIVE_TESTSET_UTTS: usize = 16;

/// Number of synthetic sentences in the offline MT test set.
const NATIVE_MT_SENTS: usize = 12;

/// Serving batch of the offline MT backend.
const NATIVE_MT_BATCH: usize = 4;

/// The MT evaluation stack, in whichever mode auto-selection produced.
enum MtStack {
    /// PJRT artifact evaluator (executes on the cache's [`Backend`]).
    Pjrt(MtEvaluator),
    /// Greedy-mode evaluator over its own native encoder+decoder
    /// backend.
    Native {
        eval: MtEvaluator,
        backend: Box<NativeBackend>,
    },
}

/// Cache over an ASR (WER) and optional MT (BLEU) evaluator, executing
/// on the auto-selected backend.
pub struct QosCache {
    pub asr: AsrEvaluator,
    mt: Option<MtStack>,
    /// Build the native MT stack on first [`Self::bleu`] (the offline
    /// mode — deferred so ASR-only surfaces never pay for it).
    lazy_native_mt: bool,
    backend: Backend,
    wer: HashMap<(usize, u64, Quant), f64>,
    bleu: HashMap<(usize, u64, Quant), f64>,
}

/// Build the fully offline MT stack: deterministic synthetic
/// (encoder, decoder) weights, their teacher-labeled test set, the
/// greedy-mode evaluator, and the `new_mt` backend.
pub fn native_mt_stack(n_sents: usize) -> Result<(MtEvaluator, NativeBackend)> {
    let dims = ModelDims::tiny_mt();
    let dec_dims = DecoderDims::tiny_mt();
    let enc = synth_weights(&dims, 13);
    let dec = synth_decoder_weights(&dec_dims, 13);
    let testset = synth_mt_testset(&enc, &dec, n_sents, 17)?;
    let mut params = enc.to_bundle();
    dec.append_to_bundle(&mut params);
    let meta = EvalMeta {
        n_blocks: dims.n_blocks,
        batch: NATIVE_MT_BATCH,
        vocab: dims.vocab,
        blank: dims.ctc_blank,
        tile_hint: dims.tile,
    };
    let eval = MtEvaluator::from_parts("native_mt", params, &testset, &meta, dec_dims.n_blocks)?;
    let backend = NativeBackend::new_mt(enc, dec, NATIVE_MT_BATCH)?;
    Ok((eval, backend))
}

impl QosCache {
    /// Build over an already-selected backend and (for PJRT) evaluator.
    pub fn new(backend: Backend, asr: AsrEvaluator, mt: Option<MtEvaluator>) -> Self {
        QosCache {
            asr,
            mt: mt.map(MtStack::Pjrt),
            lazy_native_mt: false,
            backend,
            wer: HashMap::new(),
            bleu: HashMap::new(),
        }
    }

    /// Attach a native (greedy autoregressive) MT stack explicitly —
    /// what [`Self::auto`] defers until the first BLEU query.
    pub fn set_native_mt(&mut self, eval: MtEvaluator, backend: NativeBackend) {
        self.mt = Some(MtStack::Native {
            eval,
            backend: Box::new(backend),
        });
    }

    /// Build the whole QoS stack for `dir` on the auto-selected
    /// backend: PJRT evaluators over the artifact bundles when they
    /// exist, native evaluators over synthetic teacher-labeled test
    /// sets (ASR **and**, lazily, autoregressive MT) otherwise.
    pub fn auto(dir: &str) -> Result<Self> {
        let mut backend = Backend::auto(dir)?;
        let asr = backend.asr_evaluator(dir, NATIVE_TESTSET_UTTS)?;
        if backend.is_native() {
            let mut cache = QosCache::new(backend, asr, None);
            cache.lazy_native_mt = true;
            Ok(cache)
        } else {
            let mt = match backend.engine_mut() {
                Some(engine) => MtEvaluator::new(engine, dir, "mt_encoder_ref").ok(),
                None => None,
            };
            Ok(QosCache::new(backend, asr, mt))
        }
    }

    /// Which execution backend the cache evaluates on.
    pub fn backend_label(&self) -> &'static str {
        self.backend.label()
    }

    /// Whether a BLEU surface exists (loaded, or native-lazy and built
    /// on first use).
    pub fn has_mt(&self) -> bool {
        self.mt.is_some() || self.lazy_native_mt
    }

    /// WER of the ASR model at a configuration (memoized).
    pub fn wer(&mut self, tile: usize, rate: f64, quant: Quant) -> Result<f64> {
        let k = key(tile, rate, quant);
        if let Some(v) = self.wer.get(&k) {
            return Ok(*v);
        }
        let v = self.asr.evaluate_with(&mut self.backend, tile, rate, quant)?.qos;
        self.wer.insert(k, v);
        Ok(v)
    }

    /// BLEU of the MT model at a configuration (memoized): the PJRT
    /// artifact when one is loaded, the native autoregressive decoder
    /// otherwise (constructed on first call).
    pub fn bleu(&mut self, tile: usize, rate: f64, quant: Quant) -> Result<f64> {
        let k = key(tile, rate, quant);
        if let Some(v) = self.bleu.get(&k) {
            return Ok(*v);
        }
        if self.mt.is_none() && self.lazy_native_mt {
            let (eval, nb) = native_mt_stack(NATIVE_MT_SENTS)?;
            self.set_native_mt(eval, nb);
        }
        let v = match self.mt.as_mut() {
            None => anyhow::bail!("no MT evaluator loaded"),
            Some(MtStack::Native { eval, backend }) => {
                eval.evaluate_with(&mut **backend, tile, rate, quant)?.qos
            }
            Some(MtStack::Pjrt(eval)) => {
                let engine = self
                    .backend
                    .engine_mut()
                    .ok_or_else(|| anyhow::anyhow!("MT QoS needs the PJRT backend"))?;
                eval.evaluate(engine, tile, rate, quant)?.qos
            }
        };
        self.bleu.insert(k, v);
        Ok(v)
    }

    pub fn cached_points(&self) -> usize {
        self.wer.len() + self.bleu.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::testutil::mini_dims;

    #[test]
    fn native_cache_memoizes_offline_wer() {
        let dims = mini_dims();
        let mut backend =
            Backend::auto_with("definitely/_no_artifacts_here", "asr_encoder_ref", dims, 5, 2, 1)
                .unwrap();
        let asr = backend.asr_evaluator("unused", 3).unwrap();
        let mut qos = QosCache::new(backend, asr, None);
        assert_eq!(qos.backend_label(), "native");
        assert!(!qos.has_mt());
        let a = qos.wer(dims.tile, 0.0, Quant::Fp32).unwrap();
        assert_eq!(a, 0.0, "teacher-labeled baseline");
        assert_eq!(qos.cached_points(), 1);
        let b = qos.wer(dims.tile, 0.0, Quant::Fp32).unwrap();
        assert_eq!(a, b);
        assert_eq!(qos.cached_points(), 1, "second read hits the cache");
        assert!(
            qos.bleu(dims.tile, 0.0, Quant::Fp32).is_err(),
            "no MT evaluator attached"
        );
    }

    #[test]
    fn native_mt_stack_scores_bleu_100_baseline() {
        // The offline BLEU acceptance point at the harness level: the
        // auto-style native MT stack reports exactly 100 for the dense
        // FP32 baseline and memoizes it.
        let dims = mini_dims();
        let mut backend =
            Backend::auto_with("definitely/_no_artifacts_here", "asr_encoder_ref", dims, 5, 2, 1)
                .unwrap();
        let asr = backend.asr_evaluator("unused", 3).unwrap();
        let (mt, mt_backend) = native_mt_stack(4).unwrap();
        let mut qos = QosCache::new(backend, asr, None);
        qos.set_native_mt(mt, mt_backend);
        assert!(qos.has_mt());
        let base = qos.bleu(8, 0.0, Quant::Fp32).unwrap();
        assert!((base - 100.0).abs() < 1e-9, "baseline BLEU {base}");
        assert_eq!(qos.cached_points(), 1);
        let again = qos.bleu(8, 0.0, Quant::Fp32).unwrap();
        assert_eq!(base, again);
        assert_eq!(qos.cached_points(), 1, "memoized");
        // A pruned INT8 point degrades but stays in range.
        let pruned = qos.bleu(8, 0.5, Quant::Int8).unwrap();
        assert!((0.0..=100.0).contains(&pruned), "BLEU {pruned}");
    }

    #[test]
    fn lazy_native_mt_defers_construction_until_bleu() {
        let dims = mini_dims();
        let mut backend =
            Backend::auto_with("definitely/_no_artifacts_here", "asr_encoder_ref", dims, 5, 2, 1)
                .unwrap();
        let asr = backend.asr_evaluator("unused", 3).unwrap();
        let mut qos = QosCache::new(backend, asr, None);
        qos.lazy_native_mt = true;
        assert!(qos.has_mt(), "lazy stack counts as available");
        assert!(qos.mt.is_none(), "but nothing is built yet");
        // ASR-only use never touches the MT stack.
        qos.wer(dims.tile, 0.0, Quant::Fp32).unwrap();
        assert!(qos.mt.is_none());
        // First BLEU call materializes it (tiny_mt stack — the dense
        // baseline is the BLEU-100 teacher identity).
        let base = qos.bleu(8, 0.0, Quant::Fp32).unwrap();
        assert!((base - 100.0).abs() < 1e-9, "baseline BLEU {base}");
        assert!(qos.mt.is_some(), "stack built on demand");
    }
}
