//! `sasp report trace` — replay a serving run under a recording
//! telemetry session and export the request-lifecycle Chrome trace
//! plus a metrics snapshot.
//!
//! The run pre-queues a deterministic synthetic utterance stream (same
//! seed and feature generator as [`super::serving::measure_serve`]) and
//! serves it with the dynamic flush policy over the 25%-pruned INT8
//! native backend, so the exported trace shows the full lifecycle —
//! `serve.run` → `serve.batch_window` → `request.queue` → `serve.flush`
//! → `serve.execute` → `shard.forward` → per-GEMM kernel spans →
//! `request.decode` → `request.respond` — with every GEMM span carrying
//! its live/skipped-tile and array-cycle accounting. Load the JSON in
//! Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;

use anyhow::{ensure, Context, Result};

use crate::coordinator::serve::{Request, ServeConfig, ServeReport, Server};
use crate::data::Bundle;
use crate::infer::{synth_weights, ModelDims, NativeBackend};
use crate::systolic::Quant;
use crate::telemetry::{write_chrome_trace, EventKind, Telemetry, Trace};
use crate::util::rng::Rng;

use super::Report;

/// Serve `n_requests` pre-queued synthetic utterances (deterministic
/// features, no inter-arrival gap — the trace is about structure, not
/// wall-clock load) through a fresh 25%-pruned INT8 native backend
/// under a recording telemetry session; return the serving report and
/// everything the session captured.
pub fn measure_trace(
    dims: &ModelDims,
    cfg: ServeConfig,
    n_requests: usize,
) -> Result<(ServeReport, Trace)> {
    let mut backend = NativeBackend::new(synth_weights(dims, 7), cfg.max_batch)?;
    backend.prepare(dims.tile, 0.25, Quant::Int8)?;
    let manifest = backend.manifest().clone();
    let mut server =
        Server::with_manifest(&manifest, &manifest.name, Bundle::default(), cfg)?;

    let (req_tx, req_rx) = mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = mpsc::channel();
    let (t, f) = (dims.seq_len, dims.input_dim);
    let mut rng = Rng::new(11);
    for id in 0..n_requests as u64 {
        let feat_len = t / 2 + rng.index(t - t / 2) + 1;
        let feats: Vec<f32> = (0..t * f).map(|_| rng.normal() as f32 * 0.5).collect();
        req_tx
            .send(Request::new(id, feats, feat_len.min(t)))
            .expect("receiver is live");
    }
    drop(req_tx);

    let session = Telemetry::start();
    let run = server.run(&mut backend, req_rx, resp_tx);
    let trace = session.finish();
    let report = run?;
    let answered = resp_rx.try_iter().count();
    ensure!(
        answered == n_requests,
        "every request gets exactly one response: {answered} of {n_requests}"
    );
    Ok((report, trace))
}

/// [`trace_report`] with explicit model/load parameters (the render
/// test uses the mini model and a short stream to stay fast). When
/// `trace_out`/`metrics_out` are given, the Chrome trace JSON and the
/// Prometheus-style metrics text are written there.
pub fn trace_report_sized(
    dims: &ModelDims,
    n_requests: usize,
    trace_out: Option<&Path>,
    metrics_out: Option<&Path>,
) -> Result<Report> {
    let cfg = ServeConfig::dynamic(4, 2);
    let (rep, trace) = measure_trace(dims, cfg, n_requests)?;

    let mut r = Report::new("Trace — request-lifecycle telemetry (native, 25% SASP, INT8)");
    r.line(format!(
        "{n_requests} requests pre-queued, dynamic flush b<=4, 2 worker threads, \
         seq {} x feat {}; {} ok at p50 {:.2?} / p99.9 {:.2?}",
        dims.seq_len, dims.input_dim, rep.n_requests, rep.p50, rep.p999
    ));
    let spans = trace
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Span)
        .count();
    let counters = trace
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Counter)
        .count();
    r.line(format!(
        "{} events recorded ({} spans, {} instants, {} counter samples)",
        trace.events.len(),
        spans,
        trace.events.len() - spans - counters,
        counters
    ));
    let mut by_name: BTreeMap<&'static str, usize> = BTreeMap::new();
    for e in &trace.events {
        *by_name.entry(e.name).or_default() += 1;
    }
    r.line(format!("{:<24} {:>6}", "event", "count"));
    for (name, count) in &by_name {
        r.line(format!("{name:<24} {count:>6}"));
    }
    let m = &trace.metrics;
    r.line(format!(
        "metrics: admitted={} ok={} flushes={} ok_latency_count={}",
        m.counters.get("serve_admitted_total").copied().unwrap_or(0),
        m.counters.get("serve_ok_total").copied().unwrap_or(0),
        m.counters.get("serve_flushes_total").copied().unwrap_or(0),
        m.histograms
            .get("serve_ok_latency_us")
            .map_or(0, |h| h.count),
    ));

    if let Some(path) = trace_out {
        let file = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        write_chrome_trace(&trace.events, std::io::BufWriter::new(file))
            .with_context(|| format!("write {}", path.display()))?;
        r.line(format!(
            "chrome trace -> {} (load in Perfetto / chrome://tracing)",
            path.display()
        ));
    }
    if let Some(path) = metrics_out {
        std::fs::write(path, m.render_prometheus())
            .with_context(|| format!("write {}", path.display()))?;
        r.line(format!("metrics -> {}", path.display()));
    }
    Ok(r)
}

/// The `sasp report trace` entry point: tiny-ASR native backend, 16
/// pre-queued requests, dynamic flushes of up to 4 across 2 worker
/// threads.
pub fn trace_report(trace_out: Option<&Path>, metrics_out: Option<&Path>) -> Result<Report> {
    trace_report_sized(&ModelDims::tiny_asr(), 16, trace_out, metrics_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::testutil::mini_dims;
    use crate::util::json::Json;

    #[test]
    fn trace_report_emits_parseable_chrome_trace_with_lifecycle_spans() {
        let n = 5usize;
        let (rep, trace) =
            measure_trace(&mini_dims(), ServeConfig::dynamic(4, 2), n).unwrap();
        assert_eq!(rep.n_requests, n);

        // Every lifecycle stage appears; the per-request stages appear
        // once per served request.
        for stage in ["request.queue", "request.decode", "request.respond"] {
            assert_eq!(trace.named(stage).count(), n, "{stage}");
        }
        for stage in ["serve.run", "serve.batch_window", "serve.flush", "serve.execute"] {
            assert!(trace.named(stage).count() >= 1, "{stage}");
        }
        assert!(trace.named("shard.forward").count() >= 1);
        // The INT8-prepared backend emits int8 kernel spans carrying
        // tile accounting.
        let gemms: Vec<_> = trace.named("gemm.batched_int8").collect();
        assert!(!gemms.is_empty());
        assert!(gemms
            .iter()
            .all(|e| e.attrs.iter().any(|(k, _)| *k == "tiles_live")));
        // Kernel spans parent under a shard.forward span.
        let shard_ids: Vec<u64> = trace.named("shard.forward").map(|e| e.id).collect();
        assert!(gemms.iter().all(|e| shard_ids.contains(&e.parent)));

        // The metrics snapshot agrees with the serving report.
        assert_eq!(trace.metrics.counters["serve_admitted_total"], n as u64);
        assert_eq!(trace.metrics.counters["serve_ok_total"], n as u64);
        assert_eq!(trace.metrics.histograms["serve_ok_latency_us"].count, n as u64);

        // The Chrome export round-trips through the crate's own JSON
        // parser and carries every recorded event.
        let bytes = write_chrome_trace(&trace.events, Vec::new()).unwrap();
        let text = String::from_utf8(bytes).unwrap();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").as_arr().unwrap();
        assert_eq!(events.len(), trace.events.len());
        let queue_spans = events
            .iter()
            .filter(|e| e.get("name").as_str() == Some("request.queue"))
            .count();
        assert_eq!(queue_spans, n);
        assert!(events.iter().all(|e| {
            let ph = e.get("ph").as_str().unwrap();
            ph == "X" || ph == "i" || ph == "C"
        }));
        // The serve run samples the array-utilization counter track
        // (one sample per instrumented GEMM).
        assert!(
            trace.named("array_utilization").count() >= gemms.len(),
            "utilization counter track sampled per GEMM"
        );
    }

    #[test]
    fn trace_report_renders_and_writes_files() {
        let dir = std::env::temp_dir().join(format!(
            "sasp_trace_report_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let trace_path = dir.join("trace.json");
        let metrics_path = dir.join("metrics.prom");
        let r = trace_report_sized(&mini_dims(), 4, Some(&trace_path), Some(&metrics_path))
            .unwrap();
        let s = r.render();
        assert!(s.contains("events recorded"), "{s}");
        assert!(s.contains("request.decode"), "{s}");
        assert!(s.contains("chrome trace ->"), "{s}");

        let text = std::fs::read_to_string(&trace_path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let prom = std::fs::read_to_string(&metrics_path).unwrap();
        assert!(prom.contains("serve_ok_total 4"), "{prom}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
