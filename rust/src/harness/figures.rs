//! Generators for every table and figure of the evaluation section.
//!
//! Timing-only reports (Table 1/2, Fig. 6, and the timing axes of the
//! rest) need no model execution at all; QoS-bearing reports take a
//! [`QosCache`], which owns the auto-selected execution backend — PJRT
//! over the trained stand-in models when artifacts exist, the batched
//! native engine (synthetic teacher-labeled test set) otherwise — so
//! every report regenerates on a fresh checkout.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::{Explorer, RateSearch, SweepPoint};
use crate::hwmodel::{self, area_energy_product};
use crate::model::zoo;
use crate::systolic::{ArrayConfig, Quant};

use super::{QosCache, Report};

/// Table 1: deployed model parameters (+ the trained stand-ins).
pub fn table1() -> Report {
    let mut r = Report::new("Table 1 — deployed models");
    r.line(format!(
        "{:<28} {:>7} {:>8} {:>7} {:>8} {:>8}",
        "model", "blocks", "d_model", "heads", "d_ff", "seq"
    ));
    for s in [
        zoo::espnet_asr(),
        zoo::espnet2_asr(),
        zoo::mustc_asr_encoder(),
        zoo::mustc_mt_encoder(),
        zoo::tiny_asr(),
        zoo::tiny_mt(),
    ] {
        r.line(format!(
            "{:<28} {:>7} {:>8} {:>7} {:>8} {:>8}",
            s.name, s.n_blocks, s.d_model, s.n_heads, s.d_ff, s.seq_len
        ));
    }
    r
}

/// Table 2: simulated system configuration.
pub fn table2() -> Report {
    let mut r = Report::new("Table 2 — simulated system");
    for (k, v) in [
        ("Processors", "1x in-order ARMv8-like core @ 1.0 GHz"),
        ("L1-I Cache", "32 kB, 2-way, 2-cycle"),
        ("L1-D Cache", "32 kB, 2-way, 2-cycle"),
        ("L2 Cache", "1 MB, 2-way, 20-cycle"),
        ("Memory", "DDR4-class, 60-cycle line fill"),
        ("Systolic array", "tightly coupled, custom instructions"),
    ] {
        r.line(format!("{k:<16} {v}"));
    }
    r
}

/// Fig. 6: synthesis area & power across sizes and quantization.
pub fn fig6() -> Report {
    let mut r = Report::new("Fig. 6 — synthesis results (area mm² / power mW)");
    r.line(format!(
        "{:>6} {:>12} {:>12} {:>12} {:>12}",
        "size", "FP32 area", "INT8 area", "FP32 power", "INT8 power"
    ));
    for n in [4usize, 8, 16, 32] {
        let f = ArrayConfig::square(n, Quant::Fp32);
        let i = ArrayConfig::square(n, Quant::Int8);
        r.line(format!(
            "{:>6} {:>12.3} {:>12.3} {:>12.1} {:>12.1}",
            n,
            hwmodel::area_mm2(&f),
            hwmodel::area_mm2(&i),
            hwmodel::power_mw(&f),
            hwmodel::power_mw(&i)
        ));
    }
    let b = hwmodel::components::area_breakdown(&ArrayConfig::square(8, Quant::Fp32));
    r.line(format!(
        "8x8 FP32 multiplier share: {:.1}% area (paper: 55.6%)",
        100.0 * b.multipliers / b.total()
    ));
    r
}

/// Fig. 7: SASP speedup & energy improvement under the QoS target,
/// vs non-pruned quantized execution, per workload and array size.
pub fn fig7(qos: &mut QosCache, cfg: &ExperimentConfig) -> Result<Report> {
    let mut r = Report::new(
        "Fig. 7 — SASP gains under QoS target (vs non-pruned INT8)",
    );
    let base_wer = qos.wer(8, 0.0, Quant::Int8)?;
    let wer_target = base_wer * cfg.wer_target_ratio;
    let base_bleu = if qos.has_mt() {
        qos.bleu(8, 0.0, Quant::Int8)?
    } else {
        0.0
    };
    let bleu_floor = base_bleu * cfg.bleu_floor_ratio;
    r.line(format!(
        "QoS targets: WER <= {:.4} (baseline {:.4}), BLEU >= {:.2} (baseline {:.2})",
        wer_target, base_wer, bleu_floor, base_bleu
    ));
    r.line(format!(
        "{:<26} {:>5} {:>8} {:>10} {:>10}",
        "workload", "size", "rate*", "speedup%", "energy%"
    ));
    let search = RateSearch { grid: cfg.rates.clone() };
    for spec in zoo::fig7_workloads() {
        let ex = Explorer::new(spec.clone());
        // Pass 1 (serial — one QoS backend): rate* per size from the QoS curve.
        let mut points = Vec::with_capacity(cfg.sizes.len());
        for &n in &cfg.sizes {
            let is_mt = spec.name.contains("mustc") && qos.has_mt();
            let found = if is_mt {
                search.max_rate(
                    |rate| qos.bleu(n, rate, Quant::Int8),
                    |b| b >= bleu_floor,
                )?
            } else {
                search.max_rate(
                    |rate| qos.wer(n, rate, Quant::Int8),
                    |w| w <= wer_target,
                )?
            };
            let (rate, _q) = found.unwrap_or((0.0, 0.0));
            points.push(SweepPoint { tile: n, quant: Quant::Int8, rate });
        }
        // Pass 2 (parallel): timing/energy for the selected rates.
        for p in ex.sweep(&points) {
            let speedup_pct = (p.speedup_vs_dense - 1.0) * 100.0;
            let energy_pct = (1.0 - p.energy_j / p.dense_energy_j) * 100.0;
            r.line(format!(
                "{:<26} {:>5} {:>8.2} {:>9.1}% {:>9.1}%",
                spec.name, p.tile, p.rate, speedup_pct, energy_pct
            ));
        }
    }
    Ok(r)
}

/// Fig. 8: per-layer normalized encoder runtime, 8x8 INT8 array, at two
/// global sparsity targets.
pub fn fig8() -> Report {
    let mut r = Report::new(
        "Fig. 8 — per-layer normalized runtime (8x8 FP32_INT8)",
    );
    let ex = Explorer::new(zoo::espnet_asr());
    let low = ex.per_layer_normalized(8, Quant::Int8, 0.25);
    let high = ex.per_layer_normalized(8, Quant::Int8, 0.375);
    r.line(format!("{:>6} {:>12} {:>12}", "layer", "25% sparse", "37.5% sparse"));
    for (i, (a, b)) in low.iter().zip(&high).enumerate() {
        r.line(format!("{:>6} {:>12.3} {:>12.3}", i, a, b));
    }
    r
}

/// Fig. 9: WER vs SASP rate, per array size and quantization.
pub fn fig9(qos: &mut QosCache, cfg: &ExperimentConfig) -> Result<Report> {
    let mut r = Report::new("Fig. 9 — WER vs structured pruning rate");
    let mut header = format!("{:>6} {:>10}", "size", "rate");
    for q in &cfg.quants {
        header.push_str(&format!(" {:>12}", q.label()));
    }
    r.line(header);
    for &n in &cfg.sizes {
        for &rate in &cfg.rates {
            let mut line = format!("{:>6} {:>10.2}", n, rate);
            for &q in &cfg.quants {
                let wer = qos.wer(n, rate, q)?;
                line.push_str(&format!(" {:>12.4}", wer));
            }
            r.line(line);
        }
    }
    Ok(r)
}

/// §MT: offline BLEU sweep — BLEU vs SASP rate per array size and
/// quantization, the MT mirror of [`fig9`]'s WER sweep. On the native
/// backend the points come from the autoregressive KV-cache decoder
/// over the synthetic teacher-labeled set (dense FP32 baseline = BLEU
/// 100); with PJRT artifacts they come from the compiled MT encoder.
pub fn mt_report(qos: &mut QosCache, cfg: &ExperimentConfig) -> Result<Report> {
    let mut r = Report::new("MT — BLEU vs structured pruning rate");
    if !qos.has_mt() {
        r.line("no MT evaluator available (PJRT MT artifact missing)");
        return Ok(r);
    }
    let base = qos.bleu(8, 0.0, Quant::Fp32)?;
    let floor = base * cfg.bleu_floor_ratio;
    r.line(format!(
        "baseline BLEU {base:.2} (dense FP32), Table 1 floor {floor:.2}"
    ));
    let mut header = format!("{:>6} {:>10}", "size", "rate");
    for q in &cfg.quants {
        header.push_str(&format!(" {:>12}", q.label()));
    }
    r.line(header);
    for &n in &cfg.sizes {
        for &rate in &cfg.rates {
            let mut line = format!("{:>6} {:>10.2}", n, rate);
            for &q in &cfg.quants {
                let b = qos.bleu(n, rate, q)?;
                line.push_str(&format!(" {:>12.2}", b));
            }
            r.line(line);
        }
    }
    Ok(r)
}

/// Fig. 10: WER / speedup / area-energy trade-off scatter.
pub fn fig10(qos: &mut QosCache, cfg: &ExperimentConfig) -> Result<Report> {
    let mut r = Report::new("Fig. 10 — WER vs speedup vs area-energy");
    r.line(format!(
        "{:>6} {:>10} {:>8} {:>10} {:>10} {:>12}",
        "size", "quant", "rate", "wer", "speedup", "area*energy"
    ));
    let ex = Explorer::new(zoo::espnet_asr());
    // Timing for the whole grid in one parallel sweep; QoS stays serial
    // (one execution backend).
    let grid = SweepPoint::grid(&cfg.sizes, &cfg.quants, &cfg.rates);
    let timing = ex.sweep(&grid);
    for (sp, p) in grid.iter().zip(&timing) {
        let wer = qos.wer(sp.tile, sp.rate, sp.quant)?;
        let aep = area_energy_product(
            &ArrayConfig::square(sp.tile, sp.quant),
            p.energy_j,
        );
        r.line(format!(
            "{:>6} {:>10} {:>8.2} {:>10.4} {:>10.2} {:>12.4}",
            sp.tile,
            sp.quant.label(),
            sp.rate,
            wer,
            p.speedup_vs_cpu,
            aep
        ));
    }
    Ok(r)
}

/// Fig. 11: speedup vs array size at fixed WER levels.
pub fn fig11(qos: &mut QosCache, cfg: &ExperimentConfig) -> Result<Report> {
    let mut r = Report::new("Fig. 11 — speedup vs size at fixed WER");
    let base = qos.wer(8, 0.0, Quant::Fp32)?;
    // Three WER levels: near-baseline, the 5%-equivalent target, relaxed.
    let levels = [base * 1.1, base * cfg.wer_target_ratio, base * 2.0];
    r.line(format!(
        "{:>6} {:>10} {:>14} {:>14} {:>14}",
        "size", "quant", "wer<=1.1x", "wer<=target", "wer<=2.0x"
    ));
    let ex = Explorer::new(zoo::espnet_asr());
    let search = RateSearch { grid: cfg.rates.clone() };
    // Pass 1 (serial — one QoS backend): the rate per (quant, size,
    // WER level); pass 2 (parallel): one sweep over all of them.
    let mut points = Vec::new();
    for &q in &cfg.quants {
        for &n in &cfg.sizes {
            for target in levels {
                let found = search.max_rate(
                    |rate| qos.wer(n, rate, q),
                    |w| w <= target,
                )?;
                let rate = found.map_or(0.0, |f| f.0);
                points.push(SweepPoint { tile: n, quant: q, rate });
            }
        }
    }
    let speedups = ex.sweep(&points);
    for (row, chunk) in speedups.chunks(levels.len()).enumerate() {
        let cells: Vec<String> = chunk
            .iter()
            .map(|p| format!("{:>14.2}", p.speedup_vs_cpu))
            .collect();
        let q = cfg.quants[row / cfg.sizes.len()];
        let n = cfg.sizes[row % cfg.sizes.len()];
        r.line(format!(
            "{:>6} {:>10} {} {} {}",
            n,
            q.label(),
            cells[0],
            cells[1],
            cells[2]
        ));
    }
    Ok(r)
}

/// Table 3: area / speedup / energy, no-SASP vs SASP at the 5% WER
/// inflection point.
pub fn table3(qos: &mut QosCache, cfg: &ExperimentConfig) -> Result<Report> {
    let mut r = Report::new("Table 3 — SASP at the WER inflection point");
    let base = qos.wer(8, 0.0, Quant::Fp32)?;
    let target = base * cfg.wer_target_ratio;
    r.line(format!("WER inflection target: {target:.4} (baseline {base:.4})"));
    r.line(format!(
        "{:>10} {:>6} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "quant", "size", "area mm²", "speedup", "energy J", "prune%", "speedup+", "energy J+"
    ));
    let ex = Explorer::new(zoo::espnet_asr());
    let search = RateSearch { grid: cfg.rates.clone() };
    // Pass 1 (serial — one QoS backend): rate per (quant, size); pass 2
    // (parallel): dense + SASP timing points in one sweep.
    let mut points = Vec::new();
    for &q in &cfg.quants {
        for &n in &cfg.sizes {
            let found = search.max_rate(
                |rate| qos.wer(n, rate, q),
                |w| w <= target,
            )?;
            let rate = found.map_or(0.0, |f| f.0);
            points.push(SweepPoint { tile: n, quant: q, rate: 0.0 });
            points.push(SweepPoint { tile: n, quant: q, rate });
        }
    }
    for pair in ex.sweep(&points).chunks(2) {
        let (dense, sasp) = (&pair[0], &pair[1]);
        r.line(format!(
            "{:>10} {:>6} {:>10.3} {:>10.2} {:>10.4} {:>9.0}% {:>10.2} {:>10.4}",
            dense.quant.label(),
            dense.tile,
            dense.area_mm2,
            dense.speedup_vs_cpu,
            dense.energy_j,
            sasp.rate * 100.0,
            sasp.speedup_vs_cpu,
            sasp.energy_j
        ));
    }
    Ok(r)
}

/// The headline claim: 32x32 INT8 + 20% SASP vs non-pruned non-quantized.
pub fn headline(qos: &mut QosCache) -> Result<Report> {
    let mut r = Report::new("Headline — SASP+quant at 32x32, 20% rate");
    let ex = Explorer::new(zoo::espnet_asr());
    let dense_fp32 = ex.timing_point(32, Quant::Fp32, 0.0);
    let sasp_int8 = ex.timing_point(32, Quant::Int8, 0.20);
    let speedup =
        (dense_fp32.energy_j / dense_fp32.energy_j).max(0.0); // placeholder guard
    let _ = speedup;
    let runtime_gain = 1.0
        - (1.0 / sasp_int8.speedup_vs_cpu) / (1.0 / dense_fp32.speedup_vs_cpu);
    let energy_gain = 1.0 - sasp_int8.energy_j / dense_fp32.energy_j;
    let wer0 = qos.wer(32, 0.0, Quant::Fp32)?;
    let wer1 = qos.wer(32, 0.20, Quant::Int8)?;
    r.line(format!(
        "system speedup {:.1}% (paper: up to 44%), energy saving {:.1}% (paper: 42%)",
        runtime_gain * 100.0,
        energy_gain * 100.0
    ));
    r.line(format!(
        "WER {:.4} -> {:.4} (degradation {:+.4}; paper: +1.4% absolute)",
        wer0,
        wer1,
        wer1 - wer0
    ));
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_only_reports_render() {
        assert!(table1().render().contains("espnet_asr"));
        assert!(table2().render().contains("L2 Cache"));
        let f6 = fig6().render();
        assert!(f6.contains("55.6%"));
        let f8 = fig8().render();
        assert!(f8.lines().count() > 18);
    }
}
