//! `sasp report util` — accelerator-level utilization and roofline
//! report, fully offline.
//!
//! Runs a batched encode workload on the 25%-pruned INT8 native backend
//! under a recording telemetry session, then reads back the per-layer
//! attribution counters ([`crate::infer::layers`]) and renders:
//!
//! - the **per-layer utilization table** — MACs, bus words, array
//!   cycles, the PE-occupancy split (active / fill-drain bubble /
//!   reprogramming stall / pruning-skipped), utilization, and the
//!   [`crate::hwmodel::EnergyModel`] energy charge per layer;
//! - the **roofline classification** — arithmetic intensity (MACs per
//!   bus word) against the array ridge point (`tile²` MACs/word: the
//!   array peaks at `n_pes` MACs/cycle on a one-word-per-cycle bus),
//!   labelling each layer compute- or bandwidth-bound;
//! - the **utilization x pruning-rate x array-shape frontier** — an
//!   analytic sweep ([`crate::sysim::engine::gemm_on_array_batched`])
//!   over tile sizes and pruning rates of the same model.
//!
//! The recorded counters are cross-checked **exactly** against the
//! analytic engine for the feed-forward GEMMs (the instrumented kernels
//! and the system simulator charge identical [`crate::systolic::TileTiming`]
//! schedules), and the per-layer totals must sum to the backend's own
//! [`crate::infer::ForwardStats`] — functional == analytic, enforced at
//! report time.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::infer::layers::{self, Layer};
use crate::infer::{synth_weights, ForwardStats, ModelDims, NativeBackend};
use crate::model::{GemmKind, GemmShape};
use crate::pruning::global_prune;
use crate::sysim::engine::{gemm_on_array_batched, GemmCost};
use crate::sysim::SimParams;
use crate::systolic::{ArrayConfig, Occupancy, Quant};
use crate::telemetry::{Telemetry, Trace};
use crate::util::rng::Rng;

use super::Report;

/// One layer's recorded attribution, read back from the metrics
/// snapshot a session scraped.
#[derive(Clone, Copy, Debug)]
pub struct LayerUtil {
    pub layer: Layer,
    pub macs: u64,
    pub bus_words: u64,
    pub array_cycles: u64,
    pub energy_pj: u64,
    pub occ: Occupancy,
}

impl LayerUtil {
    /// Arithmetic intensity in MACs per bus word (programming +
    /// streaming traffic).
    pub fn intensity(&self) -> f64 {
        self.macs as f64 / (self.bus_words.max(1)) as f64
    }

    /// Compute-bound iff the layer's intensity reaches the array ridge
    /// point (`n_pes` MACs per word).
    pub fn compute_bound(&self, n_pes: usize) -> bool {
        self.intensity() >= n_pes as f64
    }
}

impl LayerUtil {
    /// Zeroed accumulator for a concrete layer.
    fn empty(layer: Layer) -> Self {
        LayerUtil {
            layer,
            macs: 0,
            bus_words: 0,
            array_cycles: 0,
            energy_pj: 0,
            occ: Occupancy::default(),
        }
    }
}

/// Read one layer's attribution counters out of a scraped snapshot.
fn read_layer(trace: &Trace, layer: Layer) -> LayerUtil {
    let c = |family: &str| {
        trace
            .metrics
            .counters
            .get(&layer.metric(family))
            .copied()
            .unwrap_or(0)
    };
    LayerUtil {
        layer,
        macs: c("sasp_layer_macs_total"),
        bus_words: c("sasp_layer_bus_words_total"),
        array_cycles: c("sasp_layer_array_cycles_total"),
        energy_pj: c("sasp_layer_energy_pj_total"),
        occ: Occupancy {
            active_pe_cycles: c("sasp_layer_active_pe_cycles_total") as usize,
            bubble_pe_cycles: c("sasp_layer_bubble_pe_cycles_total") as usize,
            stall_pe_cycles: c("sasp_layer_stall_pe_cycles_total") as usize,
            skipped_pe_cycles: c("sasp_layer_skipped_pe_cycles_total") as usize,
        },
    }
}

/// Run `n_batches` deterministic full-length batches through a fresh
/// `rate`-pruned INT8 native backend under a recording session; return
/// the backend's cumulative statistics, the achieved pruning masks'
/// plan, and everything the session captured.
pub fn measure_util(
    dims: &ModelDims,
    rate: f64,
    batch: usize,
    n_batches: usize,
) -> Result<(ForwardStats, crate::pruning::PrunePlan, Trace)> {
    let mut backend = NativeBackend::new(synth_weights(dims, 7), batch)?;
    let plan = backend.prepare(dims.tile, rate, Quant::Int8)?;
    backend.reset_stats();

    let (t, f) = (dims.seq_len, dims.input_dim);
    let mut rng = Rng::new(13);
    let pad = vec![1.0f32; batch * t];
    let session = Telemetry::start();
    for _ in 0..n_batches {
        let feats: Vec<f32> =
            (0..batch * t * f).map(|_| rng.normal() as f32 * 0.5).collect();
        let _ = backend.forward_batch(&feats, &pad, batch);
    }
    let trace = session.finish();
    Ok((*backend.stats(), plan, trace))
}

/// The analytic batched cost of the encoder's feed-forward GEMMs under
/// `masks`, summed over blocks — what the instrumented kernels must have
/// charged for one flush of `batch` utterances.
fn analytic_ff(dims: &ModelDims, masks: &[crate::sysim::TileMask], batch: usize) -> GemmCost {
    let cfg = ArrayConfig::square(dims.tile, Quant::Int8);
    let p = SimParams::default();
    let (t, d, f) = (dims.seq_len, dims.d_model, dims.d_ff);
    let mut total = GemmCost::default();
    for i in 0..dims.n_blocks {
        let g1 = GemmShape { m: t, k: d, n: f, kind: GemmKind::FeedForward };
        let g2 = GemmShape { m: t, k: f, n: d, kind: GemmKind::FeedForward };
        total.add(&gemm_on_array_batched(&g1, &cfg, &p, Some(&masks[2 * i]), batch));
        total.add(&gemm_on_array_batched(&g2, &cfg, &p, Some(&masks[2 * i + 1]), batch));
    }
    total
}

/// One point of the utilization frontier: an analytic whole-encoder
/// sweep (per-block QKV/O projections dense + both feed-forward GEMMs
/// under the global plan at this tile/rate).
#[derive(Clone, Copy, Debug)]
pub struct FrontierPoint {
    pub tile: usize,
    pub rate: f64,
    pub achieved_rate: f64,
    pub cycles: f64,
    pub occ: Occupancy,
}

impl FrontierPoint {
    /// Share of the work the pruning masks skipped outright.
    pub fn skipped_share(&self) -> f64 {
        let o = &self.occ;
        let total =
            o.active_pe_cycles + o.bubble_pe_cycles + o.stall_pe_cycles + o.skipped_pe_cycles;
        if total == 0 {
            return 0.0;
        }
        o.skipped_pe_cycles as f64 / total as f64
    }
}

/// Analytic utilization x pruning-rate x array-shape sweep over the
/// encoder's weight GEMMs (the frontier the co-design trades along:
/// bigger arrays lower the bubble share but raise the skipped-work
/// granularity).
pub fn util_frontier(
    dims: &ModelDims,
    tiles: &[usize],
    rates: &[f64],
    batch: usize,
) -> Result<Vec<FrontierPoint>> {
    let w = synth_weights(dims, 7);
    let p = SimParams::default();
    let (t, d, f) = (dims.seq_len, dims.d_model, dims.d_ff);
    let mut out = Vec::with_capacity(tiles.len() * rates.len());
    for &tile in tiles {
        let norms = crate::infer::backend::ff_norms(&w, tile)?;
        let cfg = ArrayConfig::square(tile, Quant::Int8);
        for &rate in rates {
            let plan = global_prune(&norms, rate);
            let mut total = GemmCost::default();
            for i in 0..dims.n_blocks {
                let proj = GemmShape { m: t, k: d, n: d, kind: GemmKind::AttnProj };
                for _ in 0..4 {
                    total.add(&gemm_on_array_batched(&proj, &cfg, &p, None, batch));
                }
                let g1 = GemmShape { m: t, k: d, n: f, kind: GemmKind::FeedForward };
                let g2 = GemmShape { m: t, k: f, n: d, kind: GemmKind::FeedForward };
                total.add(&gemm_on_array_batched(
                    &g1, &cfg, &p, Some(&plan.masks[2 * i]), batch,
                ));
                total.add(&gemm_on_array_batched(
                    &g2, &cfg, &p, Some(&plan.masks[2 * i + 1]), batch,
                ));
            }
            out.push(FrontierPoint {
                tile,
                rate,
                achieved_rate: plan.achieved_rate,
                cycles: total.cycles,
                occ: total.occ,
            });
        }
    }
    Ok(out)
}

/// [`util_report`] with explicit model/load/sweep parameters (the
/// render test uses the mini model to stay fast). When `metrics_out` is
/// given, the session's Prometheus-style snapshot is written there.
pub fn util_report_sized(
    dims: &ModelDims,
    rate: f64,
    batch: usize,
    n_batches: usize,
    tiles: &[usize],
    rates: &[f64],
    metrics_out: Option<&Path>,
) -> Result<Report> {
    let (stats, plan, trace) = measure_util(dims, rate, batch, n_batches)?;
    let per_layer: Vec<LayerUtil> = layers::ALL
        .iter()
        .map(|&l| read_layer(&trace, l))
        .filter(|u| u.macs > 0 || u.occ.skipped_pe_cycles > 0)
        .collect();

    // -- functional == analytic cross-checks --------------------------------
    // The feed-forward layers' recorded counters must equal the analytic
    // engine's batched charges for the same masks, exactly.
    let want = {
        let per_flush = analytic_ff(dims, &plan.masks, batch);
        let mut total = GemmCost::default();
        for _ in 0..n_batches {
            total.add(&per_flush);
        }
        total
    };
    let got = per_layer
        .iter()
        .filter(|u| matches!(u.layer, Layer::Ff1 | Layer::Ff2))
        .fold(LayerUtil::empty(Layer::Ff1), |mut a, u| {
            a.macs += u.macs;
            a.bus_words += u.bus_words;
            a.array_cycles += u.array_cycles;
            a.occ.add(&u.occ);
            a
        });
    ensure!(
        got.macs == want.counts.macs
            && got.bus_words == want.counts.bus_words
            && got.array_cycles == want.counts.array_busy_cycles
            && got.occ == want.occ,
        "recorded ff attribution must equal the analytic batched charges: \
         got {got:?}, want macs={} bus={} cycles={} occ={:?}",
        want.counts.macs,
        want.counts.bus_words,
        want.counts.array_busy_cycles,
        want.occ
    );
    // And the per-layer totals must account for every MAC the backend
    // itself charged — nothing double-counted, nothing missed.
    let recorded: u64 = per_layer.iter().map(|u| u.macs).sum();
    let charged =
        (stats.ff.timing.macs + stats.attn.timing.macs + stats.other.timing.macs) as u64;
    ensure!(
        recorded == charged,
        "per-layer MACs must sum to the backend's ForwardStats: {recorded} != {charged}"
    );

    // -- render -------------------------------------------------------------
    let n_pes = dims.tile * dims.tile;
    let mut r = Report::new("Util — PE utilization, attribution and roofline (native, INT8)");
    r.line(format!(
        "{}x{} array, {:.0}% SASP (achieved {:.1}%), {} flushes x batch {}, seq {}",
        dims.tile,
        dims.tile,
        rate * 100.0,
        plan.achieved_rate * 100.0,
        n_batches,
        batch,
        dims.seq_len
    ));
    r.line(format!(
        "ridge point: {n_pes} MACs/word (array peak {n_pes} MACs/cycle, 1 word/cycle bus)"
    ));
    r.line(format!(
        "{:<9} {:>12} {:>10} {:>10} {:>6} {:>7} {:>7} {:>12} {:>7} {}",
        "layer", "macs", "bus_words", "cycles", "util%", "stall%", "skip%", "energy_pJ", "AI", "bound"
    ));
    for u in &per_layer {
        let busy = u.occ.busy_pe_cycles();
        let full = busy + u.occ.stall_pe_cycles + u.occ.skipped_pe_cycles;
        r.line(format!(
            "{:<9} {:>12} {:>10} {:>10} {:>6.1} {:>7.1} {:>7.1} {:>12} {:>7.1} {}",
            u.layer.label(),
            u.macs,
            u.bus_words,
            u.array_cycles,
            u.occ.utilization() * 100.0,
            u.occ.stall_pe_cycles as f64 / full.max(1) as f64 * 100.0,
            u.occ.skipped_pe_cycles as f64 / full.max(1) as f64 * 100.0,
            u.energy_pj,
            u.intensity(),
            if u.compute_bound(n_pes) { "compute" } else { "bandwidth" }
        ));
    }
    r.line("cross-check: recorded ff attribution == analytic batched charges (exact)".to_string());

    r.line(String::new());
    r.line("frontier — utilization x pruning rate x array shape (analytic encoder sweep)".to_string());
    r.line(format!(
        "{:<5} {:>6} {:>10} {:>6} {:>6} {:>8}",
        "tile", "rate%", "cycles", "util%", "skip%", "speedup"
    ));
    let frontier = util_frontier(dims, tiles, rates, batch)?;
    for pt in &frontier {
        let dense = frontier
            .iter()
            .find(|d| d.tile == pt.tile && d.rate == 0.0)
            .map_or(pt.cycles, |d| d.cycles);
        r.line(format!(
            "{:<5} {:>6.0} {:>10.0} {:>6.1} {:>6.1} {:>8.2}",
            pt.tile,
            pt.rate * 100.0,
            pt.cycles,
            pt.occ.utilization() * 100.0,
            pt.skipped_share() * 100.0,
            dense / pt.cycles
        ));
    }

    if let Some(path) = metrics_out {
        std::fs::write(path, trace.metrics.render_prometheus())
            .with_context(|| format!("write {}", path.display()))?;
        r.line(format!("metrics -> {}", path.display()));
    }
    Ok(r)
}

/// The `sasp report util` entry point: tiny-ASR model, 25% pruning,
/// three flushes of batch 4, frontier over 4/8/16-wide arrays at
/// 0/25/50/75% rates.
pub fn util_report(metrics_out: Option<&Path>) -> Result<Report> {
    util_report_sized(
        &ModelDims::tiny_asr(),
        0.25,
        4,
        3,
        &[4, 8, 16],
        &[0.0, 0.25, 0.5, 0.75],
        metrics_out,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::testutil::mini_dims;

    #[test]
    fn util_report_cross_checks_and_renders() {
        let dir = std::env::temp_dir()
            .join(format!("sasp_util_report_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let metrics_path = dir.join("util.prom");
        // util_report_sized ensure!()s functional == analytic internally;
        // unwrap is the cross-check.
        let r = util_report_sized(
            &mini_dims(),
            0.5,
            3,
            2,
            &[4, 8],
            &[0.0, 0.5],
            Some(&metrics_path),
        )
        .unwrap();
        let s = r.render();
        assert!(s.contains("ff1"), "{s}");
        assert!(s.contains("ff2"), "{s}");
        assert!(s.contains("qkv"), "{s}");
        assert!(s.contains("ridge point"), "{s}");
        assert!(s.contains("frontier"), "{s}");
        assert!(s.contains("bandwidth") || s.contains("compute"), "{s}");

        let prom = std::fs::read_to_string(&metrics_path).unwrap();
        assert!(
            prom.contains("sasp_layer_macs_total{layer=\"ff1\"}"),
            "{prom}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn frontier_pruning_always_helps_at_fixed_tile() {
        let pts = util_frontier(&mini_dims(), &[8], &[0.0, 0.25, 0.5], 2).unwrap();
        assert_eq!(pts.len(), 3);
        for w in pts.windows(2) {
            assert!(
                w[1].cycles <= w[0].cycles,
                "more pruning must not cost more cycles: {w:?}"
            );
            assert!(w[1].skipped_share() >= w[0].skipped_share(), "{w:?}");
        }
        // Dense execution skips nothing.
        assert_eq!(pts[0].occ.skipped_pe_cycles, 0);
    }
}
