//! `sasp report serve` — the offline latency/throughput frontier of the
//! serving runtime.
//!
//! Drives synthetic utterance streams through
//! [`crate::coordinator::serve::Server`] over the 25%-pruned INT8 native
//! backend and measures the two scaling levers ISSUE 5 opened:
//!
//! - **flush policy** — fixed-batch (wait for a full artifact batch,
//!   pad tails) vs dynamic (flush whatever is queued, exact rows);
//! - **worker threads** — the native backend sharding each flush's
//!   utterances across a `std::thread::scope` pool.
//!
//! Every point serves the same request stream (same seed, same
//! inter-arrival gaps), so the frontier isolates the runtime knobs. The
//! numbers are wall-clock on the current host — the report is a
//! measurement harness, not a deterministic figure, which is why it is
//! not part of `sasp report all`.

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::{ensure, Result};

use crate::coordinator::resilience::{
    LadderConfig, OperatingPoint, ResilienceConfig, ShedPolicy,
};
use crate::coordinator::serve::{Request, ServeConfig, ServeReport, Server};
use crate::data::Bundle;
use crate::infer::{synth_weights, ModelDims, NativeBackend};
use crate::systolic::Quant;
use crate::util::rng::Rng;

use super::Report;

/// Drive `n_requests` synthetic utterances (deterministic features and
/// inter-arrival `gap`) through a fresh 25%-pruned INT8 native backend
/// for `dims` under `cfg`, returning the serving report.
pub fn measure_serve(
    dims: &ModelDims,
    cfg: ServeConfig,
    n_requests: usize,
    gap: Duration,
) -> Result<ServeReport> {
    let mut backend = NativeBackend::new(synth_weights(dims, 7), cfg.max_batch)?;
    backend.prepare(dims.tile, 0.25, Quant::Int8)?;
    let manifest = backend.manifest().clone();
    let mut server =
        Server::with_manifest(&manifest, &manifest.name, Bundle::default(), cfg)?;

    let (req_tx, req_rx) = mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = mpsc::channel();
    let (t, f) = (dims.seq_len, dims.input_dim);
    let producer = thread::spawn(move || {
        let mut rng = Rng::new(11);
        for id in 0..n_requests as u64 {
            let feat_len = t / 2 + rng.index(t - t / 2) + 1;
            let feats: Vec<f32> =
                (0..t * f).map(|_| rng.normal() as f32 * 0.5).collect();
            let _ = req_tx.send(Request::new(id, feats, feat_len.min(t)));
            if !gap.is_zero() {
                thread::sleep(gap);
            }
        }
        // Dropping req_tx closes the queue and drains the server.
    });
    let report = server.run(&mut backend, req_rx, resp_tx)?;
    producer.join().unwrap();
    let served = resp_rx.try_iter().count();
    ensure!(served == n_requests, "served {served} of {n_requests} requests");
    Ok(report)
}

/// The frontier points every serve report measures: the single-threaded
/// fixed-batch baseline against the dynamic flush at 1/2/4 worker
/// threads.
fn frontier_points(fixed_batch: usize, max_batch: usize) -> Vec<(String, ServeConfig)> {
    let mut points = vec![(
        format!("fixed   b={fixed_batch} threads=1"),
        ServeConfig::fixed(fixed_batch, Duration::from_millis(2)),
    )];
    for threads in [1usize, 2, 4] {
        points.push((
            format!("dynamic b<={max_batch} threads={threads}"),
            ServeConfig::dynamic(max_batch, threads),
        ));
    }
    points
}

/// [`serve_report`] with explicit model/load parameters (the render
/// test uses the mini model and a short stream to stay fast).
pub fn serve_report_sized(
    dims: &ModelDims,
    fixed_batch: usize,
    max_batch: usize,
    n_requests: usize,
    gap: Duration,
) -> Result<Report> {
    let mut r = Report::new(
        "Serve — latency/throughput frontier (native, 25% SASP, INT8)",
    );
    r.line(format!(
        "{n_requests} requests, ~{gap:?} inter-arrival, fixed-policy \
         window 2ms (dynamic rows have none), seq {} x feat {}",
        dims.seq_len, dims.input_dim
    ));
    r.line(format!(
        "{:<26} {:>10} {:>10} {:>10} {:>10} {:>8} {:>7}",
        "policy", "p50", "p95", "p99.9", "req/s", "fill", "slack"
    ));
    for (label, cfg) in frontier_points(fixed_batch, max_batch) {
        let rep = measure_serve(dims, cfg, n_requests, gap)?;
        r.line(format!(
            "{:<26} {:>10} {:>10} {:>10} {:>10.1} {:>8.2} {:>7}",
            label,
            format!("{:.2?}", rep.p50),
            format!("{:.2?}", rep.p95),
            format!("{:.2?}", rep.p999),
            rep.throughput_rps,
            rep.mean_batch_fill,
            rep.slack_rows
        ));
    }
    Ok(r)
}

/// The `sasp report serve` entry point: tiny-ASR native backend, 64
/// requests at a ~300µs inter-arrival gap, fixed batch 4 vs dynamic
/// flushes of up to 16.
pub fn serve_report() -> Result<Report> {
    serve_report_sized(
        &ModelDims::tiny_asr(),
        4,
        16,
        64,
        Duration::from_micros(300),
    )
}

/// Drive `n_requests` deadline-stamped utterances through the bounded
/// admission queue at inter-arrival `gap`, optionally with the
/// graceful-degradation ladder armed, and return the overload report.
/// Same stream seed and feature generator as [`measure_serve`], so the
/// overload sweep isolates the resilience knobs.
pub fn measure_overload(
    dims: &ModelDims,
    n_requests: usize,
    gap: Duration,
    ttl: Duration,
    capacity: usize,
    policy: ShedPolicy,
    ladder: bool,
) -> Result<ServeReport> {
    let max_batch = 4usize;
    let mut backend = NativeBackend::new(synth_weights(dims, 7), max_batch)?;
    backend.prepare(dims.tile, 0.25, Quant::Int8)?;
    let manifest = backend.manifest().clone();
    let cfg = ServeConfig::dynamic(max_batch, 1);
    let mut server =
        Server::with_manifest(&manifest, &manifest.name, Bundle::default(), cfg)?;
    let mut res = ResilienceConfig::bounded(capacity, policy);
    if ladder {
        // Nominal point first; the pressure ladder climbs the pruning
        // rate along the frontier the QoS harness measures.
        res = res.with_ladder(LadderConfig::new(vec![
            OperatingPoint::new(0.25, Quant::Int8),
            OperatingPoint::new(0.5, Quant::Int8),
            OperatingPoint::new(0.75, Quant::Int8),
        ]));
    }
    server.set_resilience(res);

    let (req_tx, req_rx) = mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = mpsc::channel();
    let (t, f) = (dims.seq_len, dims.input_dim);
    let producer = thread::spawn(move || {
        let mut rng = Rng::new(11);
        for id in 0..n_requests as u64 {
            let feat_len = t / 2 + rng.index(t - t / 2) + 1;
            let feats: Vec<f32> =
                (0..t * f).map(|_| rng.normal() as f32 * 0.5).collect();
            let _ = req_tx.send(Request::with_deadline(id, feats, feat_len.min(t), ttl));
            if !gap.is_zero() {
                thread::sleep(gap);
            }
        }
    });
    let report = server.run(&mut backend, req_rx, resp_tx)?;
    producer.join().unwrap();
    let answered = resp_rx.try_iter().count();
    ensure!(
        answered == n_requests,
        "every request gets exactly one response: {answered} of {n_requests}"
    );
    Ok(report)
}

/// [`overload_report`] with explicit load parameters (the render test
/// uses the mini model and a short stream to stay fast). Sweeps arrival
/// rate x shed policy x ladder on/off over a bounded queue.
pub fn overload_report_sized(
    dims: &ModelDims,
    n_requests: usize,
    gaps: &[(&str, Duration)],
    ttl: Duration,
    capacity: usize,
) -> Result<Report> {
    let mut r = Report::new(
        "Overload — goodput under bounded admission (native, 25% SASP, INT8)",
    );
    r.line(format!(
        "{n_requests} requests per point, queue capacity {capacity}, deadline \
         {ttl:?}, dynamic flush b<=4, ladder 0.25 -> 0.50 -> 0.75 INT8",
    ));
    r.line(format!(
        "{:<34} {:>4} {:>5} {:>5} {:>5} {:>8} {:>10} {:>10} {:>10} {:>5}",
        "scenario", "ok", "shed", "exp", "fail", "good/s", "p50", "p99", "p99.9", "degr"
    ));
    let policies = [
        ("reject-new", ShedPolicy::RejectNew),
        ("drop-oldest", ShedPolicy::DropOldest),
        ("deadline-aware", ShedPolicy::DeadlineAware),
    ];
    for (gap_label, gap) in gaps {
        for (pol_label, policy) in policies {
            for ladder in [false, true] {
                let rep =
                    measure_overload(dims, n_requests, *gap, ttl, capacity, policy, ladder)?;
                let ok_lat = rep
                    .outcomes
                    .iter()
                    .find(|o| o.outcome == crate::coordinator::serve::Outcome::Ok);
                let (p50, p99, p999) = ok_lat.map_or(
                    (Duration::ZERO, Duration::ZERO, Duration::ZERO),
                    |o| (o.p50, o.p99, o.p999),
                );
                r.line(format!(
                    "{:<34} {:>4} {:>5} {:>5} {:>5} {:>8.1} {:>10} {:>10} {:>10} {:>5}",
                    format!(
                        "{gap_label} {pol_label}{}",
                        if ladder { " +ladder" } else { "" }
                    ),
                    rep.n_requests,
                    rep.shed,
                    rep.expired,
                    rep.failed,
                    rep.goodput_rps,
                    format!("{p50:.2?}"),
                    format!("{p99:.2?}"),
                    format!("{p999:.2?}"),
                    rep.degrade_steps,
                ));
            }
        }
    }
    Ok(r)
}

/// The `sasp report overload` entry point: tiny-ASR native backend, 96
/// deadline-stamped requests per point, a 2x-overload arrival rate
/// against a moderate one, queue capacity 8.
pub fn overload_report() -> Result<Report> {
    overload_report_sized(
        &ModelDims::tiny_asr(),
        96,
        &[
            ("overload 100us", Duration::from_micros(100)),
            ("moderate 400us", Duration::from_micros(400)),
        ],
        Duration::from_millis(10),
        8,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::testutil::mini_dims;

    #[test]
    fn serve_report_renders_frontier() {
        let r = serve_report_sized(
            &mini_dims(),
            2,
            8,
            6,
            Duration::from_micros(100),
        )
        .unwrap();
        let s = r.render();
        assert!(s.contains("fixed   b=2 threads=1"), "{s}");
        assert!(s.contains("dynamic b<=8 threads=4"), "{s}");
        // Header + load line + 4 frontier points.
        assert_eq!(r.lines.len(), 2 + 4, "{s}");
    }

    #[test]
    fn overload_report_renders_sweep() {
        let r = overload_report_sized(
            &mini_dims(),
            6,
            &[("burst 50us", Duration::from_micros(50))],
            Duration::from_millis(50),
            4,
        )
        .unwrap();
        let s = r.render();
        assert!(s.contains("burst 50us reject-new"), "{s}");
        assert!(s.contains("burst 50us deadline-aware +ladder"), "{s}");
        // Header + load line + 3 policies x ladder off/on.
        assert_eq!(r.lines.len(), 2 + 6, "{s}");
    }

    #[test]
    fn measure_overload_answers_every_request() {
        // Generous deadline + capacity: nothing sheds, and the ladder
        // path still accounts for all requests.
        let rep = measure_overload(
            &mini_dims(),
            5,
            Duration::from_micros(50),
            Duration::from_secs(60),
            16,
            ShedPolicy::DeadlineAware,
            true,
        )
        .unwrap();
        assert_eq!(
            rep.n_requests + rep.shed + rep.expired + rep.invalid + rep.failed,
            5,
            "every request lands in exactly one outcome bucket"
        );
        assert!(rep.goodput_rps >= 0.0);
    }

    #[test]
    fn measure_serve_dynamic_has_no_slack() {
        let rep = measure_serve(
            &mini_dims(),
            ServeConfig::dynamic(8, 2),
            5,
            Duration::from_micros(50),
        )
        .unwrap();
        assert_eq!(rep.n_requests, 5);
        assert_eq!(rep.slack_rows, 0, "any-batch path executes no slack rows");
        assert!(rep.p95 >= rep.p50);
    }
}
