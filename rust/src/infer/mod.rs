//! Native pruned+quantized inference engine — the transformer encoder
//! forward pass executed entirely in rust, no PJRT required.
//!
//! The PJRT path ([`crate::runtime`]) runs the AOT-compiled artifacts,
//! but needs `make artifacts` and a linked `xla_extension`; the tier-1
//! build stubs `xla` out, so on a fresh checkout the repository could not
//! execute the model whose QoS numbers it reports. This module closes
//! that gap with a functional engine over the same weight format
//! ([`crate::data::tensorfile`] bundles, python `param_names` layout):
//!
//! - [`gemm`] — the tiled masked GEMM kernels. The tile grid, the
//!   j-outer/k-inner schedule, and the dead-tile skip are exactly those
//!   of [`crate::systolic::scheduler::TileScheduler`] (cross-validated in
//!   tests, per-tile costs accounted with the same
//!   [`crate::systolic::TileTiming`]), so the functional engine and the
//!   analytic system simulator charge identical schedules for identical
//!   [`crate::sysim::TileMask`]s. The INT8 kernel stores weights as
//!   sign-magnitude bytes ([`crate::arith::SignMag8`]) with the
//!   [`crate::quant`] per-tensor scale; the FP32 kernel over
//!   fake-quantized weights is its value-exact oracle.
//! - [`batch`] — the batched weight-stationary serving runtime:
//!   flattened `[batch*seq, d]` GEMMs that load each pruned tile once
//!   per batch ([`crate::systolic::TileTiming::batched`] accounting) and
//!   a batched encoder forward ([`BatchForward`]) that is bitwise
//!   identical to the per-utterance reference — what
//!   [`NativeBackend`] serves batches on.
//! - [`ops`] — the non-GEMM operators (LayerNorm, masked softmax, ReLU,
//!   GELU, residual adds, sinusoidal positions, log-softmax CTC head),
//!   mirroring `python/compile/model.py`.
//! - [`encoder`] — model dimensions, weight containers, and the
//!   buffer-reusing forward pass over [`crate::model::zoo`]-shaped
//!   encoders (pre-LN MHSA + SASP feed-forward).
//! - [`decoder`] — the autoregressive transformer decoder: pre-LN
//!   causal self-attention + encoder-decoder cross-attention + pruned
//!   feed-forward blocks on the same tile kernels, an incremental KV
//!   cache (bitwise identical to full-prefix recompute), and greedy
//!   BOS→EOS generation — the decode-side twin of the encoder engine.
//! - [`backend`] — [`NativeBackend`]: prunes/quantizes its weights and
//!   serves as both a [`crate::coordinator::serve::ServeBackend`] and a
//!   [`crate::qos::QosBackend`], making `qos/eval`, `coordinator/serve`,
//!   and the `asr_pipeline`/`serve` examples fully offline.
//! - [`layers`] — per-layer GEMM attribution: every call site in
//!   [`encoder`]/[`batch`]/[`decoder`] is labeled ([`Layer`]) and its
//!   MACs, array cycles, bus words, energy, and PE-occupancy breakdown
//!   accumulate into the [`crate::telemetry::metrics`] registry, with
//!   an `array_utilization` Chrome counter track sampled per GEMM.
//! - [`synth`] — deterministic synthetic weights + a self-labeled test
//!   set (references = the dense FP32 model's own greedy decode), so QoS
//!   degradation curves are measurable without trained artifacts.

pub mod backend;
pub mod batch;
pub mod decoder;
pub mod encoder;
pub mod gemm;
pub mod layers;
pub mod ops;
pub mod synth;

pub use backend::NativeBackend;
pub use batch::BatchForward;
pub use decoder::{
    ContinuousDecoder, DecodeStats, DecoderDims, DecoderForward, DecoderWeights, Finished,
    PreparedDecoder,
};
pub use encoder::{EncoderWeights, Forward, ForwardStats, ModelDims, PreparedModel};
pub use gemm::{Linear, QuantizedLinear, TileStats};
pub use layers::Layer;
pub use synth::{synth_decoder_weights, synth_mt_testset, synth_testset, synth_weights};

/// Shared fixtures for this module's test suites.
#[cfg(test)]
pub(crate) mod testutil {
    use crate::data::Tensor;
    use crate::pruning::norms::apply_mask_to_weights;
    use crate::sysim::TileMask;

    use super::encoder::{EncoderWeights, ModelDims};

    /// A small model that keeps debug-mode tests fast.
    pub fn mini_dims() -> ModelDims {
        ModelDims {
            input_dim: 8,
            vocab: 12,
            d_model: 32,
            n_heads: 4,
            d_ff: 64,
            n_blocks: 2,
            seq_len: 24,
            tile: 8,
            ctc_blank: 11,
            token_input: false,
        }
    }

    /// Zero the feed-forward tiles the masks mark dead, in place — the
    /// prune-by-zeroing reference the skipping paths are checked
    /// against.
    pub fn zero_ff_tiles(w: &mut EncoderWeights, masks: &[TileMask], tile: usize) {
        let (d, f) = (w.dims.d_model, w.dims.d_ff);
        for (i, blk) in w.blocks.iter_mut().enumerate() {
            let mut t1 = Tensor::from_f32(&[d, f], &blk.w1);
            apply_mask_to_weights(&mut t1, &masks[2 * i], tile);
            blk.w1 = t1.f32s();
            let mut t2 = Tensor::from_f32(&[f, d], &blk.w2);
            apply_mask_to_weights(&mut t2, &masks[2 * i + 1], tile);
            blk.w2 = t2.f32s();
        }
    }
}
