//! Deterministic synthetic models + self-labeled test sets — what makes
//! the QoS surfaces runnable on a checkout with no trained artifacts.
//!
//! Weights follow python `init_params` (scaled-normal dense layers, unit
//! LayerNorm gains, zero biases) from the crate's own xoshiro RNG, so
//! every run regenerates the identical model. The test set is labeled by
//! the model itself: references are the **dense FP32** engine's greedy
//! CTC decode, so the unpruned baseline scores WER 0 by construction and
//! every pruned/quantized configuration measures pure degradation — the
//! same role the trained tiny model plays for the PJRT path.

use anyhow::Result;

use crate::data::{Bundle, Tensor};
use crate::qos::ctc_greedy;
use crate::systolic::Quant;
use crate::util::rng::Rng;

use super::encoder::{BlockWeights, EncoderWeights, Forward, ModelDims, PreparedModel};

fn dense(rng: &mut Rng, m: usize, n: usize) -> Vec<f32> {
    let std = (2.0 / (m + n) as f64).sqrt();
    (0..m * n).map(|_| (rng.normal() * std) as f32).collect()
}

/// Scaled-normal encoder weights for `dims` (python `init_params`).
pub fn synth_weights(dims: &ModelDims, seed: u64) -> EncoderWeights {
    let mut rng = Rng::new(seed ^ 0x1A7E_57EE);
    let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
    let in_rows = if dims.token_input { v } else { dims.input_dim };
    let in_w = dense(&mut rng, in_rows, d);
    let blocks = (0..dims.n_blocks)
        .map(|_| BlockWeights {
            ln1_g: vec![1.0; d],
            ln1_b: vec![0.0; d],
            wq: dense(&mut rng, d, d),
            wk: dense(&mut rng, d, d),
            wv: dense(&mut rng, d, d),
            wo: dense(&mut rng, d, d),
            ln2_g: vec![1.0; d],
            ln2_b: vec![0.0; d],
            w1: dense(&mut rng, d, f),
            b1: vec![0.0; f],
            w2: dense(&mut rng, f, d),
            b2: vec![0.0; d],
        })
        .collect();
    EncoderWeights {
        dims: *dims,
        in_w,
        in_b: vec![0.0; d],
        blocks,
        lnf_g: vec![1.0; d],
        lnf_b: vec![0.0; d],
        head_w: dense(&mut rng, d, v),
        head_b: vec![0.0; v],
    }
}

/// A synthetic ASR test set over `w`, in the `testset_asr.bin` bundle
/// layout (`feats`, `feat_len`, `labels`, `label_len`): random feature
/// matrices with varying valid lengths, labeled by the dense FP32
/// model's own greedy decode.
pub fn synth_testset(w: &EncoderWeights, n_utts: usize, seed: u64) -> Result<Bundle> {
    let dims = w.dims;
    assert!(!dims.token_input, "ASR test sets need a feature-input model");
    assert!(n_utts > 0);
    let (t, f, v) = (dims.seq_len, dims.input_dim, dims.vocab);
    let mut rng = Rng::new(seed ^ 0x7E57_5E7);

    let teacher = PreparedModel::new(w, dims.tile, Quant::Fp32, None)?;
    let mut fwd = Forward::new();
    let mut lp = Vec::new();

    let mut feats = Vec::with_capacity(n_utts * t * f);
    let mut feat_len = Vec::with_capacity(n_utts);
    let mut refs: Vec<Vec<i32>> = Vec::with_capacity(n_utts);
    for _ in 0..n_utts {
        let len = t / 2 + rng.index(t / 2 + 1);
        let utt: Vec<f32> = (0..t * f)
            .map(|i| {
                if i / f < len {
                    rng.normal() as f32 * 0.5
                } else {
                    0.0
                }
            })
            .collect();
        let mut pad = vec![0.0f32; t];
        for p in pad.iter_mut().take(len) {
            *p = 1.0;
        }
        fwd.run_feats(&teacher, &utt, &pad, &mut lp);
        refs.push(ctc_greedy(&lp, len, v, dims.ctc_blank));
        feats.extend_from_slice(&utt);
        feat_len.push(len as i32);
    }

    let lmax = refs.iter().map(Vec::len).max().unwrap_or(0).max(1);
    let mut labels = vec![0i32; n_utts * lmax];
    let mut label_len = Vec::with_capacity(n_utts);
    for (i, r) in refs.iter().enumerate() {
        labels[i * lmax..i * lmax + r.len()].copy_from_slice(r);
        label_len.push(r.len() as i32);
    }

    let mut b = Bundle::default();
    b.insert("feats", Tensor::from_f32(&[n_utts, t, f], &feats));
    b.insert("feat_len", Tensor::from_i32(&[n_utts], &feat_len));
    b.insert("labels", Tensor::from_i32(&[n_utts, lmax], &labels));
    b.insert("label_len", Tensor::from_i32(&[n_utts], &label_len));
    Ok(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tensorfile::{emit_bundle, parse_bundle};
    use crate::data::DType;
    use crate::infer::testutil::mini_dims;

    #[test]
    fn weights_deterministic_and_shaped() {
        let dims = mini_dims();
        let a = synth_weights(&dims, 3);
        let b = synth_weights(&dims, 3);
        let c = synth_weights(&dims, 4);
        assert_eq!(a.in_w, b.in_w);
        assert_eq!(a.blocks[1].w1, b.blocks[1].w1);
        assert_ne!(a.in_w, c.in_w, "different seeds differ");
        assert_eq!(a.in_w.len(), dims.input_dim * dims.d_model);
        assert_eq!(a.blocks.len(), dims.n_blocks);
        assert!(a.blocks[0].ln1_g.iter().all(|g| *g == 1.0));
        // Scaled init: weights are small but not degenerate.
        let amax = a.in_w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(amax > 0.0 && amax < 2.0, "amax {amax}");
    }

    #[test]
    fn testset_layout_and_tensorfile_roundtrip() {
        let dims = mini_dims();
        let w = synth_weights(&dims, 3);
        let ts = synth_testset(&w, 5, 1).unwrap();
        let feats = ts.get("feats").unwrap();
        assert_eq!(feats.shape, vec![5, dims.seq_len, dims.input_dim]);
        assert_eq!(feats.dtype, DType::F32);
        let fl = ts.get("feat_len").unwrap().i32s();
        assert_eq!(fl.len(), 5);
        assert!(fl.iter().all(|l| *l as usize >= dims.seq_len / 2));
        let labels = ts.get("labels").unwrap();
        let ll = ts.get("label_len").unwrap().i32s();
        assert_eq!(labels.shape[0], 5);
        for (i, l) in ll.iter().enumerate() {
            assert!(*l as usize <= labels.shape[1], "utt {i}");
        }
        // The bundle survives the tensorfile wire format.
        let rt = parse_bundle(&emit_bundle(&ts)).unwrap();
        assert_eq!(rt.get("feats"), ts.get("feats"));
        assert_eq!(rt.get("labels"), ts.get("labels"));
    }

    #[test]
    fn teacher_labels_reproduce_under_dense_decode() {
        // Decoding the dense model again must reproduce the references
        // exactly — the WER-0 baseline property the examples rely on.
        let dims = mini_dims();
        let w = synth_weights(&dims, 5);
        let ts = synth_testset(&w, 3, 2).unwrap();
        let model = PreparedModel::new(&w, dims.tile, Quant::Fp32, None).unwrap();
        let mut fwd = Forward::new();
        let feats = ts.get("feats").unwrap().f32s();
        let fl = ts.get("feat_len").unwrap().i32s();
        let labels = ts.get("labels").unwrap();
        let lmax = labels.shape[1];
        let lvals = labels.i32s();
        let ll = ts.get("label_len").unwrap().i32s();
        let (t, f) = (dims.seq_len, dims.input_dim);
        let mut lp = Vec::new();
        for i in 0..3usize {
            let len = fl[i] as usize;
            let mut pad = vec![0.0f32; t];
            for p in pad.iter_mut().take(len) {
                *p = 1.0;
            }
            fwd.run_feats(&model, &feats[i * t * f..(i + 1) * t * f], &pad, &mut lp);
            let hyp = ctc_greedy(&lp, len, dims.vocab, dims.ctc_blank);
            let want = lvals[i * lmax..i * lmax + ll[i] as usize].to_vec();
            assert_eq!(hyp, want, "utt {i}");
        }
    }
}
