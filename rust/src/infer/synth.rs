//! Deterministic synthetic models + self-labeled test sets — what makes
//! the QoS surfaces runnable on a checkout with no trained artifacts.
//!
//! Weights follow python `init_params` (scaled-normal dense layers, unit
//! LayerNorm gains, zero biases) from the crate's own xoshiro RNG, so
//! every run regenerates the identical model. The test set is labeled by
//! the model itself: references are the **dense FP32** engine's greedy
//! CTC decode, so the unpruned baseline scores WER 0 by construction and
//! every pruned/quantized configuration measures pure degradation — the
//! same role the trained tiny model plays for the PJRT path.

use anyhow::Result;

use crate::data::{Bundle, Tensor};
use crate::qos::ctc_greedy;
use crate::systolic::Quant;
use crate::util::rng::Rng;

use super::decoder::{
    DecoderBlockWeights, DecoderDims, DecoderForward, DecoderWeights, PreparedDecoder,
};
use super::encoder::{BlockWeights, EncoderWeights, Forward, ModelDims, PreparedModel};

fn dense(rng: &mut Rng, m: usize, n: usize) -> Vec<f32> {
    let std = (2.0 / (m + n) as f64).sqrt();
    (0..m * n).map(|_| (rng.normal() * std) as f32).collect()
}

/// Scaled-normal encoder weights for `dims` (python `init_params`).
pub fn synth_weights(dims: &ModelDims, seed: u64) -> EncoderWeights {
    let mut rng = Rng::new(seed ^ 0x1A7E_57EE);
    let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
    let in_rows = if dims.token_input { v } else { dims.input_dim };
    let in_w = dense(&mut rng, in_rows, d);
    let blocks = (0..dims.n_blocks)
        .map(|_| BlockWeights {
            ln1_g: vec![1.0; d],
            ln1_b: vec![0.0; d],
            wq: dense(&mut rng, d, d),
            wk: dense(&mut rng, d, d),
            wv: dense(&mut rng, d, d),
            wo: dense(&mut rng, d, d),
            ln2_g: vec![1.0; d],
            ln2_b: vec![0.0; d],
            w1: dense(&mut rng, d, f),
            b1: vec![0.0; f],
            w2: dense(&mut rng, f, d),
            b2: vec![0.0; d],
        })
        .collect();
    EncoderWeights {
        dims: *dims,
        in_w,
        in_b: vec![0.0; d],
        blocks,
        lnf_g: vec![1.0; d],
        lnf_b: vec![0.0; d],
        head_w: dense(&mut rng, d, v),
        head_b: vec![0.0; v],
    }
}

/// Scaled-normal decoder weights for `dims` (same init family as
/// [`synth_weights`]; distinct seed mix so encoder and decoder never
/// alias).
pub fn synth_decoder_weights(dims: &DecoderDims, seed: u64) -> DecoderWeights {
    let mut rng = Rng::new(seed ^ 0xDEC0_DE55);
    let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
    let blocks = (0..dims.n_blocks)
        .map(|_| DecoderBlockWeights {
            ln1_g: vec![1.0; d],
            ln1_b: vec![0.0; d],
            sq: dense(&mut rng, d, d),
            sk: dense(&mut rng, d, d),
            sv: dense(&mut rng, d, d),
            so: dense(&mut rng, d, d),
            lnx_g: vec![1.0; d],
            lnx_b: vec![0.0; d],
            xq: dense(&mut rng, d, d),
            xk: dense(&mut rng, d, d),
            xv: dense(&mut rng, d, d),
            xo: dense(&mut rng, d, d),
            ln2_g: vec![1.0; d],
            ln2_b: vec![0.0; d],
            w1: dense(&mut rng, d, f),
            b1: vec![0.0; f],
            w2: dense(&mut rng, f, d),
            b2: vec![0.0; d],
        })
        .collect();
    DecoderWeights {
        dims: *dims,
        emb: dense(&mut rng, v, d),
        blocks,
        lnf_g: vec![1.0; d],
        lnf_b: vec![0.0; d],
        head_w: dense(&mut rng, d, v),
        head_b: vec![0.0; v],
    }
}

/// A synthetic MT test set over the (encoder, decoder) pair, in the
/// `testset_mt.bin`-plus-lengths layout (`src`, `src_len`, `tgt`,
/// `tgt_len`): random ragged source sentences whose references are the
/// **dense FP32** model's own greedy autoregressive decode — so the
/// unpruned baseline scores corpus BLEU 100 by construction and every
/// pruned/quantized configuration measures pure degradation.
pub fn synth_mt_testset(
    enc: &EncoderWeights,
    dec: &DecoderWeights,
    n_sents: usize,
    seed: u64,
) -> Result<Bundle> {
    let dims = enc.dims;
    assert!(dims.token_input, "MT test sets need a token-input encoder");
    assert_eq!(dims.d_model, dec.dims.d_model, "encoder/decoder width mismatch");
    assert!(n_sents > 0);
    let (t, d) = (dims.seq_len, dims.d_model);
    let mut rng = Rng::new(seed ^ 0x7E57_D0DE);

    let teacher_enc = PreparedModel::new(enc, dims.tile, Quant::Fp32, None)?;
    let teacher_dec = PreparedDecoder::new(dec, dec.dims.tile, Quant::Fp32, None)?;
    let mut fwd = Forward::new();
    let mut dfwd = DecoderForward::new();
    let mut memory = Vec::new();

    let mut src = Vec::with_capacity(n_sents * t);
    let mut src_len = Vec::with_capacity(n_sents);
    let mut refs: Vec<Vec<i32>> = Vec::with_capacity(n_sents);
    for _ in 0..n_sents {
        // Redraw sources whose teacher decode is empty (EOS-first) so
        // the reference corpus always carries scoreable content; the
        // kept reference is still exactly the model's own decode.
        let mut tgt = Vec::new();
        let mut sent = vec![0i32; t];
        let mut len = 1usize;
        for attempt in 0..8 {
            len = (t / 2 + rng.index(t / 2) + 1).min(t);
            sent.fill(0);
            for tok in sent.iter_mut().take(len) {
                *tok = rng.index(dims.vocab) as i32;
            }
            fwd.memory_tokens(&teacher_enc, &sent, len, &mut memory);
            dfwd.generate(&teacher_dec, &memory[..len * d], len, &mut tgt);
            if !tgt.is_empty() || attempt == 7 {
                break;
            }
        }
        refs.push(tgt);
        src.extend_from_slice(&sent);
        src_len.push(len as i32);
    }

    let tmax = refs.iter().map(Vec::len).max().unwrap_or(0).max(1);
    let mut tgt = vec![0i32; n_sents * tmax];
    let mut tgt_len = Vec::with_capacity(n_sents);
    for (i, r) in refs.iter().enumerate() {
        tgt[i * tmax..i * tmax + r.len()].copy_from_slice(r);
        tgt_len.push(r.len() as i32);
    }

    let mut b = Bundle::default();
    b.insert("src", Tensor::from_i32(&[n_sents, t], &src));
    b.insert("src_len", Tensor::from_i32(&[n_sents], &src_len));
    b.insert("tgt", Tensor::from_i32(&[n_sents, tmax], &tgt));
    b.insert("tgt_len", Tensor::from_i32(&[n_sents], &tgt_len));
    Ok(b)
}

/// A synthetic ASR test set over `w`, in the `testset_asr.bin` bundle
/// layout (`feats`, `feat_len`, `labels`, `label_len`): random feature
/// matrices with varying valid lengths, labeled by the dense FP32
/// model's own greedy decode.
pub fn synth_testset(w: &EncoderWeights, n_utts: usize, seed: u64) -> Result<Bundle> {
    let dims = w.dims;
    assert!(!dims.token_input, "ASR test sets need a feature-input model");
    assert!(n_utts > 0);
    let (t, f, v) = (dims.seq_len, dims.input_dim, dims.vocab);
    let mut rng = Rng::new(seed ^ 0x7E57_5E7);

    let teacher = PreparedModel::new(w, dims.tile, Quant::Fp32, None)?;
    let mut fwd = Forward::new();
    let mut lp = Vec::new();

    let mut feats = Vec::with_capacity(n_utts * t * f);
    let mut feat_len = Vec::with_capacity(n_utts);
    let mut refs: Vec<Vec<i32>> = Vec::with_capacity(n_utts);
    for _ in 0..n_utts {
        let len = t / 2 + rng.index(t / 2 + 1);
        let utt: Vec<f32> = (0..t * f)
            .map(|i| {
                if i / f < len {
                    rng.normal() as f32 * 0.5
                } else {
                    0.0
                }
            })
            .collect();
        let mut pad = vec![0.0f32; t];
        for p in pad.iter_mut().take(len) {
            *p = 1.0;
        }
        fwd.run_feats(&teacher, &utt, &pad, &mut lp);
        refs.push(ctc_greedy(&lp, len, v, dims.ctc_blank));
        feats.extend_from_slice(&utt);
        feat_len.push(len as i32);
    }

    let lmax = refs.iter().map(Vec::len).max().unwrap_or(0).max(1);
    let mut labels = vec![0i32; n_utts * lmax];
    let mut label_len = Vec::with_capacity(n_utts);
    for (i, r) in refs.iter().enumerate() {
        labels[i * lmax..i * lmax + r.len()].copy_from_slice(r);
        label_len.push(r.len() as i32);
    }

    let mut b = Bundle::default();
    b.insert("feats", Tensor::from_f32(&[n_utts, t, f], &feats));
    b.insert("feat_len", Tensor::from_i32(&[n_utts], &feat_len));
    b.insert("labels", Tensor::from_i32(&[n_utts, lmax], &labels));
    b.insert("label_len", Tensor::from_i32(&[n_utts], &label_len));
    Ok(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tensorfile::{emit_bundle, parse_bundle};
    use crate::data::DType;
    use crate::infer::testutil::mini_dims;

    #[test]
    fn weights_deterministic_and_shaped() {
        let dims = mini_dims();
        let a = synth_weights(&dims, 3);
        let b = synth_weights(&dims, 3);
        let c = synth_weights(&dims, 4);
        assert_eq!(a.in_w, b.in_w);
        assert_eq!(a.blocks[1].w1, b.blocks[1].w1);
        assert_ne!(a.in_w, c.in_w, "different seeds differ");
        assert_eq!(a.in_w.len(), dims.input_dim * dims.d_model);
        assert_eq!(a.blocks.len(), dims.n_blocks);
        assert!(a.blocks[0].ln1_g.iter().all(|g| *g == 1.0));
        // Scaled init: weights are small but not degenerate.
        let amax = a.in_w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(amax > 0.0 && amax < 2.0, "amax {amax}");
    }

    #[test]
    fn testset_layout_and_tensorfile_roundtrip() {
        let dims = mini_dims();
        let w = synth_weights(&dims, 3);
        let ts = synth_testset(&w, 5, 1).unwrap();
        let feats = ts.get("feats").unwrap();
        assert_eq!(feats.shape, vec![5, dims.seq_len, dims.input_dim]);
        assert_eq!(feats.dtype, DType::F32);
        let fl = ts.get("feat_len").unwrap().i32s();
        assert_eq!(fl.len(), 5);
        assert!(fl.iter().all(|l| *l as usize >= dims.seq_len / 2));
        let labels = ts.get("labels").unwrap();
        let ll = ts.get("label_len").unwrap().i32s();
        assert_eq!(labels.shape[0], 5);
        for (i, l) in ll.iter().enumerate() {
            assert!(*l as usize <= labels.shape[1], "utt {i}");
        }
        // The bundle survives the tensorfile wire format.
        let rt = parse_bundle(&emit_bundle(&ts)).unwrap();
        assert_eq!(rt.get("feats"), ts.get("feats"));
        assert_eq!(rt.get("labels"), ts.get("labels"));
    }

    #[test]
    fn mt_testset_layout_and_teacher_reproduction() {
        use crate::infer::decoder::testutil::mini_dec_dims;
        let dims = ModelDims {
            token_input: true,
            ctc_blank: -1,
            ..mini_dims()
        };
        let dec_dims = mini_dec_dims();
        let enc = synth_weights(&dims, 3);
        let dec = synth_decoder_weights(&dec_dims, 3);
        let ts = synth_mt_testset(&enc, &dec, 4, 2).unwrap();
        let src = ts.get("src").unwrap();
        assert_eq!(src.shape, vec![4, dims.seq_len]);
        let sl = ts.get("src_len").unwrap().i32s();
        assert!(sl.iter().all(|l| *l as usize >= dims.seq_len / 2));
        let tgt = ts.get("tgt").unwrap();
        let tl = ts.get("tgt_len").unwrap().i32s();
        assert_eq!(tgt.shape[0], 4);
        for (i, l) in tl.iter().enumerate() {
            assert!(*l as usize <= tgt.shape[1], "sent {i}");
            assert!(*l as usize <= dec_dims.max_len, "sent {i}");
        }
        // Regenerating with the dense FP32 teacher reproduces the
        // references exactly — the BLEU-100 baseline property.
        let teacher_enc = PreparedModel::new(&enc, dims.tile, Quant::Fp32, None).unwrap();
        let teacher_dec =
            PreparedDecoder::new(&dec, dec_dims.tile, Quant::Fp32, None).unwrap();
        let mut fwd = Forward::new();
        let mut dfwd = DecoderForward::new();
        let mut memory = Vec::new();
        let mut hyp = Vec::new();
        let svals = src.i32s();
        let tvals = tgt.i32s();
        let (t, d, tmax) = (dims.seq_len, dims.d_model, tgt.shape[1]);
        for i in 0..4usize {
            let len = sl[i] as usize;
            fwd.memory_tokens(&teacher_enc, &svals[i * t..(i + 1) * t], len, &mut memory);
            dfwd.generate(&teacher_dec, &memory[..len * d], len, &mut hyp);
            let want = tvals[i * tmax..i * tmax + tl[i] as usize].to_vec();
            assert_eq!(hyp, want, "sent {i}");
        }
    }

    #[test]
    fn teacher_labels_reproduce_under_dense_decode() {
        // Decoding the dense model again must reproduce the references
        // exactly — the WER-0 baseline property the examples rely on.
        let dims = mini_dims();
        let w = synth_weights(&dims, 5);
        let ts = synth_testset(&w, 3, 2).unwrap();
        let model = PreparedModel::new(&w, dims.tile, Quant::Fp32, None).unwrap();
        let mut fwd = Forward::new();
        let feats = ts.get("feats").unwrap().f32s();
        let fl = ts.get("feat_len").unwrap().i32s();
        let labels = ts.get("labels").unwrap();
        let lmax = labels.shape[1];
        let lvals = labels.i32s();
        let ll = ts.get("label_len").unwrap().i32s();
        let (t, f) = (dims.seq_len, dims.input_dim);
        let mut lp = Vec::new();
        for i in 0..3usize {
            let len = fl[i] as usize;
            let mut pad = vec![0.0f32; t];
            for p in pad.iter_mut().take(len) {
                *p = 1.0;
            }
            fwd.run_feats(&model, &feats[i * t * f..(i + 1) * t * f], &pad, &mut lp);
            let hyp = ctc_greedy(&lp, len, dims.vocab, dims.ctc_blank);
            let want = lvals[i * lmax..i * lmax + ll[i] as usize].to_vec();
            assert_eq!(hyp, want, "utt {i}");
        }
    }
}
