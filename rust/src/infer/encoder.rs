//! Encoder weights + the native forward pass.
//!
//! The architecture is exactly `python/compile/model.py`: input
//! projection (or token embedding) + sinusoidal positions, then pre-LN
//! blocks of MHSA and a SASP feed-forward (w1 → ReLU → w2), a final
//! LayerNorm and the vocabulary head (log-softmax for the CTC models).
//! Parameter names and shapes follow `param_names` there, so the same
//! `tensorfile` bundles drive the PJRT artifact and this engine.
//!
//! Attention projections and the feed-forward pair run through the
//! [`super::gemm`] tile kernels (the array-executed GEMMs); the dynamic
//! score/context GEMMs, LayerNorms, softmax and the head run as plain
//! software ops (the core-executed remainder), matching the paper's
//! execution split.

use anyhow::{ensure, Result};

use crate::data::{Bundle, Tensor};
use crate::quant::{fake_quantize, fake_quantize_per_channel};
use crate::sysim::TileMask;
use crate::systolic::Quant;

use super::gemm::{gemm_f32, Linear, TileStats};
use super::layers::{self, Layer};
use super::ops;

/// Shape hyper-parameters of one encoder model — the rust mirror of
/// python's `ModelConfig` plus the serving-relevant sequence length.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    /// Acoustic feature dimension (ASR); unused when `token_input`.
    pub input_dim: usize,
    /// Output vocabulary (including the CTC blank).
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_blocks: usize,
    /// Fixed sequence length of one utterance / sentence.
    pub seq_len: usize,
    /// Default SASP tile (the size baked into the AOT artifact).
    pub tile: usize,
    /// CTC blank index (ASR); ignored for MT.
    pub ctc_blank: i32,
    /// MT: embed int tokens instead of projecting features.
    pub token_input: bool,
}

impl ModelDims {
    /// The trained tiny ASR stand-in (`ASR_TINY` in python).
    pub fn tiny_asr() -> Self {
        ModelDims {
            input_dim: 40,
            vocab: 28,
            d_model: 64,
            n_heads: 4,
            d_ff: 256,
            n_blocks: 4,
            seq_len: 96,
            tile: 8,
            ctc_blank: 27,
            token_input: false,
        }
    }

    /// The trained tiny MT stand-in (`MT_TINY` in python).
    pub fn tiny_mt() -> Self {
        ModelDims {
            input_dim: 32,
            vocab: 32,
            d_model: 64,
            n_heads: 4,
            d_ff: 256,
            n_blocks: 2,
            seq_len: 32,
            tile: 8,
            ctc_blank: -1,
            token_input: true,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Whether `tile` is a legal SASP tile for these dimensions.
    pub fn tile_ok(&self, tile: usize) -> bool {
        tile > 0 && self.d_model % tile == 0 && self.d_ff % tile == 0
    }
}

/// One encoder block's FP32 weights (python naming in comments).
#[derive(Clone, Debug)]
pub struct BlockWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

/// The full FP32 weight set of one encoder.
#[derive(Clone, Debug)]
pub struct EncoderWeights {
    pub dims: ModelDims,
    pub in_w: Vec<f32>,
    pub in_b: Vec<f32>,
    pub blocks: Vec<BlockWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
}

fn take(b: &Bundle, name: &str, shape: &[usize]) -> Result<Vec<f32>> {
    let t = b.require(name)?;
    ensure!(
        t.shape == shape,
        "{name}: shape {:?} != expected {:?}",
        t.shape,
        shape
    );
    Ok(t.f32s())
}

impl EncoderWeights {
    /// Rows of the input projection / embedding matrix.
    fn in_rows(dims: &ModelDims) -> usize {
        if dims.token_input { dims.vocab } else { dims.input_dim }
    }

    /// Load from a `tensorfile` bundle laid out like python
    /// `param_names` (the `params_asr.bin` / `params_mt.bin` format).
    pub fn from_bundle(dims: ModelDims, b: &Bundle) -> Result<Self> {
        let (d, f, v) = (dims.d_model, dims.d_ff, dims.vocab);
        let in_rows = Self::in_rows(&dims);
        let mut blocks = Vec::with_capacity(dims.n_blocks);
        for i in 0..dims.n_blocks {
            let p = format!("block{i}.");
            blocks.push(BlockWeights {
                ln1_g: take(b, &format!("{p}ln1.g"), &[d])?,
                ln1_b: take(b, &format!("{p}ln1.b"), &[d])?,
                wq: take(b, &format!("{p}attn.wq"), &[d, d])?,
                wk: take(b, &format!("{p}attn.wk"), &[d, d])?,
                wv: take(b, &format!("{p}attn.wv"), &[d, d])?,
                wo: take(b, &format!("{p}attn.wo"), &[d, d])?,
                ln2_g: take(b, &format!("{p}ln2.g"), &[d])?,
                ln2_b: take(b, &format!("{p}ln2.b"), &[d])?,
                w1: take(b, &format!("{p}ff.w1"), &[d, f])?,
                b1: take(b, &format!("{p}ff.b1"), &[f])?,
                w2: take(b, &format!("{p}ff.w2"), &[f, d])?,
                b2: take(b, &format!("{p}ff.b2"), &[d])?,
            });
        }
        Ok(EncoderWeights {
            in_w: take(b, "in_proj.w", &[in_rows, d])?,
            in_b: take(b, "in_proj.b", &[d])?,
            blocks,
            lnf_g: take(b, "ln_f.g", &[d])?,
            lnf_b: take(b, "ln_f.b", &[d])?,
            head_w: take(b, "head.w", &[d, v])?,
            head_b: take(b, "head.b", &[v])?,
            dims,
        })
    }

    /// Serialize back to the python `param_names` bundle layout.
    pub fn to_bundle(&self) -> Bundle {
        let (d, f, v) = (self.dims.d_model, self.dims.d_ff, self.dims.vocab);
        let in_rows = Self::in_rows(&self.dims);
        let mut b = Bundle::default();
        b.insert("in_proj.w", Tensor::from_f32(&[in_rows, d], &self.in_w));
        b.insert("in_proj.b", Tensor::from_f32(&[d], &self.in_b));
        for (i, blk) in self.blocks.iter().enumerate() {
            let p = format!("block{i}.");
            b.insert(&format!("{p}ln1.g"), Tensor::from_f32(&[d], &blk.ln1_g));
            b.insert(&format!("{p}ln1.b"), Tensor::from_f32(&[d], &blk.ln1_b));
            b.insert(&format!("{p}attn.wq"), Tensor::from_f32(&[d, d], &blk.wq));
            b.insert(&format!("{p}attn.wk"), Tensor::from_f32(&[d, d], &blk.wk));
            b.insert(&format!("{p}attn.wv"), Tensor::from_f32(&[d, d], &blk.wv));
            b.insert(&format!("{p}attn.wo"), Tensor::from_f32(&[d, d], &blk.wo));
            b.insert(&format!("{p}ln2.g"), Tensor::from_f32(&[d], &blk.ln2_g));
            b.insert(&format!("{p}ln2.b"), Tensor::from_f32(&[d], &blk.ln2_b));
            b.insert(&format!("{p}ff.w1"), Tensor::from_f32(&[d, f], &blk.w1));
            b.insert(&format!("{p}ff.b1"), Tensor::from_f32(&[f], &blk.b1));
            b.insert(&format!("{p}ff.w2"), Tensor::from_f32(&[f, d], &blk.w2));
            b.insert(&format!("{p}ff.b2"), Tensor::from_f32(&[d], &blk.b2));
        }
        b.insert("ln_f.g", Tensor::from_f32(&[d], &self.lnf_g));
        b.insert("ln_f.b", Tensor::from_f32(&[d], &self.lnf_b));
        b.insert("head.w", Tensor::from_f32(&[d, v], &self.head_w));
        b.insert("head.b", Tensor::from_f32(&[v], &self.head_b));
        b
    }
}

/// One block, staged for execution: kernel-format weight GEMMs plus the
/// tile masks the feed-forward pair skips by.
#[derive(Clone, Debug)]
pub struct PreparedBlock {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Linear,
    pub b1: Vec<f32>,
    pub w2: Linear,
    pub b2: Vec<f32>,
    pub mask1: TileMask,
    pub mask2: TileMask,
}

/// A model staged for inference at one (tile, quant, masks)
/// configuration.
#[derive(Clone, Debug)]
pub struct PreparedModel {
    pub dims: ModelDims,
    pub tile: usize,
    pub quant: Quant,
    /// Input projection / embedding (always executed in FP32 precision;
    /// fake-quantized in INT8 mode, matching the PTQ set of `qos::eval`).
    pub in_w: Vec<f32>,
    pub in_b: Vec<f32>,
    pub blocks: Vec<PreparedBlock>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
    /// Precomputed `seq_len x d_model` position table.
    pub pe: Vec<f32>,
    /// Whether INT8 weights were staged with per-output-channel scales.
    pub per_channel: bool,
}

/// Fake-quantize a copy of a software-executed matrix in INT8 mode.
pub(crate) fn soft_weight(
    w: &[f32],
    rows: usize,
    cols: usize,
    quant: Quant,
    per_channel: bool,
) -> Vec<f32> {
    match quant {
        Quant::Fp32 => w.to_vec(),
        Quant::Int8 => {
            let mut t = Tensor::from_f32(&[rows, cols], w);
            if per_channel {
                fake_quantize_per_channel(&mut t);
            } else {
                fake_quantize(&mut t);
            }
            t.f32s()
        }
    }
}

/// Stage an array-executed weight GEMM in the configured format.
pub(crate) fn kernel_weight(
    w: &[f32],
    k: usize,
    n: usize,
    quant: Quant,
    per_channel: bool,
) -> Linear {
    match (quant, per_channel) {
        (Quant::Fp32, _) => Linear::from_f32(w.to_vec(), k, n),
        (Quant::Int8, false) => Linear::quantized(w, k, n),
        (Quant::Int8, true) => Linear::quantized_per_channel(w, k, n),
    }
}

/// Stage a *masked* weight GEMM: dead tiles are zeroed **before**
/// quantization, matching the paper's prune-then-PTQ order (and the QoS
/// harness's `prepare_params`), so the INT8 per-tensor scale ranges over
/// live weights only. Execution never reads the dead tiles either way —
/// this fixes the scale, not the schedule.
pub(crate) fn masked_kernel_weight(
    w: &[f32],
    k: usize,
    n: usize,
    tile: usize,
    mask: &TileMask,
    quant: Quant,
    per_channel: bool,
) -> Linear {
    if mask.live_count() == mask.n_tiles() {
        return kernel_weight(w, k, n, quant, per_channel);
    }
    let mut wz = w.to_vec();
    for (idx, v) in wz.iter_mut().enumerate() {
        let (kk, nn) = (idx / n, idx % n);
        if !mask.is_live(kk / tile, nn / tile) {
            *v = 0.0;
        }
    }
    kernel_weight(&wz, k, n, quant, per_channel)
}

impl PreparedModel {
    /// Stage `w` for execution. `masks` supplies one [`TileMask`] per
    /// feed-forward GEMM in execution order (`[w1_0, w2_0, w1_1, ...]`,
    /// grid `ceil(K/tile) x ceil(N/tile)`); `None` runs dense.
    pub fn new(
        w: &EncoderWeights,
        tile: usize,
        quant: Quant,
        masks: Option<&[TileMask]>,
    ) -> Result<Self> {
        Self::new_with(w, tile, quant, masks, false)
    }

    /// [`Self::new`] with the per-output-channel INT8 scale flag: when
    /// set (and `quant` is INT8), every quantized weight gets one scale
    /// per output channel ([`crate::quant::quantize_per_channel`])
    /// instead of the per-tensor scale — tighter PTQ at high pruning
    /// rates. Ignored in FP32 mode.
    pub fn new_with(
        w: &EncoderWeights,
        tile: usize,
        quant: Quant,
        masks: Option<&[TileMask]>,
        per_channel: bool,
    ) -> Result<Self> {
        let dims = w.dims;
        let (d, f) = (dims.d_model, dims.d_ff);
        ensure!(dims.tile_ok(tile), "tile {tile} does not divide {d}x{f}");
        if let Some(ms) = masks {
            ensure!(
                ms.len() == 2 * dims.n_blocks,
                "expected {} ff masks, got {}",
                2 * dims.n_blocks,
                ms.len()
            );
        }
        let (kt1, nt1) = (d / tile, f / tile);
        let mut blocks = Vec::with_capacity(dims.n_blocks);
        for (i, blk) in w.blocks.iter().enumerate() {
            let mask1 = match masks {
                Some(ms) => ms[2 * i].clone(),
                None => TileMask::full(kt1, nt1),
            };
            let mask2 = match masks {
                Some(ms) => ms[2 * i + 1].clone(),
                None => TileMask::full(nt1, kt1),
            };
            ensure!(
                (mask1.kt, mask1.nt) == (kt1, nt1)
                    && (mask2.kt, mask2.nt) == (nt1, kt1),
                "block {i}: ff mask grid does not match tile {tile}"
            );
            blocks.push(PreparedBlock {
                ln1_g: blk.ln1_g.clone(),
                ln1_b: blk.ln1_b.clone(),
                wq: kernel_weight(&blk.wq, d, d, quant, per_channel),
                wk: kernel_weight(&blk.wk, d, d, quant, per_channel),
                wv: kernel_weight(&blk.wv, d, d, quant, per_channel),
                wo: kernel_weight(&blk.wo, d, d, quant, per_channel),
                ln2_g: blk.ln2_g.clone(),
                ln2_b: blk.ln2_b.clone(),
                w1: masked_kernel_weight(&blk.w1, d, f, tile, &mask1, quant, per_channel),
                b1: blk.b1.clone(),
                w2: masked_kernel_weight(&blk.w2, f, d, tile, &mask2, quant, per_channel),
                b2: blk.b2.clone(),
                mask1,
                mask2,
            });
        }
        let in_rows = EncoderWeights::in_rows(&dims);
        Ok(PreparedModel {
            dims,
            tile,
            quant,
            in_w: soft_weight(&w.in_w, in_rows, d, quant, per_channel),
            in_b: w.in_b.clone(),
            blocks,
            lnf_g: w.lnf_g.clone(),
            lnf_b: w.lnf_b.clone(),
            head_w: soft_weight(&w.head_w, d, dims.vocab, quant, per_channel),
            head_b: w.head_b.clone(),
            pe: ops::sinusoidal_pe(dims.seq_len, d),
            per_channel,
        })
    }

    /// Mean feed-forward tile sparsity of the staged masks.
    pub fn ff_sparsity(&self) -> f64 {
        let mut dead = 0usize;
        let mut total = 0usize;
        for blk in &self.blocks {
            dead += blk.mask1.n_tiles() - blk.mask1.live_count();
            dead += blk.mask2.n_tiles() - blk.mask2.live_count();
            total += blk.mask1.n_tiles() + blk.mask2.n_tiles();
        }
        dead as f64 / total.max(1) as f64
    }
}

/// Per-run schedule statistics, split by GEMM role.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ForwardStats {
    /// Feed-forward GEMMs (the SASP-pruned, array-executed pair).
    pub ff: TileStats,
    /// Attention projections (array-executed, never pruned).
    pub attn: TileStats,
    /// Input projection + vocabulary head (software-executed).
    pub other: TileStats,
    /// Utterances processed since the last reset.
    pub utterances: usize,
}

impl ForwardStats {
    /// Sum of all GEMM-scope counters (ff + attn + other) — the
    /// aggregate telemetry spans attach to one instrumented forward.
    pub fn total(&self) -> TileStats {
        let mut t = self.ff;
        t.add(&self.attn);
        t.add(&self.other);
        t
    }

    /// Accumulate another run's counters — the shard-merge of the
    /// thread-parallel serving path (each worker's [`TileStats`] are
    /// summed after the scope joins, so the merged accounting is
    /// deterministic regardless of thread completion order).
    pub fn add(&mut self, o: &ForwardStats) {
        self.ff.add(&o.ff);
        self.attn.add(&o.attn);
        self.other.add(&o.other);
        self.utterances += o.utterances;
    }
}

/// The forward-pass runtime: owns every intermediate buffer, so steady
/// state (one utterance after another) performs no allocation.
pub struct Forward {
    h: Vec<f32>,
    hn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f32>,
    ctx: Vec<f32>,
    tmp: Vec<f32>,
    mid: Vec<f32>,
    /// Pad-mask buffer for the token (MT) path, rebuilt per call from
    /// the utterance's real source length (all-ones only for full
    /// sentences), reused across calls.
    pad_buf: Vec<f32>,
    pub stats: ForwardStats,
}

impl Default for Forward {
    fn default() -> Self {
        Forward::new()
    }
}

impl Forward {
    pub fn new() -> Self {
        Forward {
            h: Vec::new(),
            hn: Vec::new(),
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            scores: Vec::new(),
            ctx: Vec::new(),
            tmp: Vec::new(),
            mid: Vec::new(),
            pad_buf: Vec::new(),
            stats: ForwardStats::default(),
        }
    }

    /// ASR: one utterance of `seq_len x input_dim` features with a
    /// `seq_len` validity mask → CTC log-probs `seq_len x vocab` in
    /// `out`.
    pub fn run_feats(
        &mut self,
        m: &PreparedModel,
        feats: &[f32],
        pad: &[f32],
        out: &mut Vec<f32>,
    ) {
        let dims = &m.dims;
        assert!(!dims.token_input, "feature input on a token-input model");
        let t = dims.seq_len;
        assert_eq!(feats.len(), t * dims.input_dim, "feats must be seq x input");
        assert_eq!(pad.len(), t, "pad mask must be seq");
        let st = gemm_f32(
            feats,
            &m.in_w,
            t,
            dims.input_dim,
            dims.d_model,
            None,
            m.tile,
            &mut self.h,
        );
        self.stats.other.add(&st);
        // The projection runs in FP32 regardless of the kernel format.
        layers::record(Layer::InProj, &st, m.tile, Quant::Fp32);
        self.encode(m, pad);
        self.head(m, out, true);
        self.stats.utterances += 1;
    }

    /// MT: one full-length `seq_len` token sentence → per-position
    /// logits `seq_len x vocab` in `out` (no log-softmax — the MT head).
    pub fn run_tokens(&mut self, m: &PreparedModel, tokens: &[i32], out: &mut Vec<f32>) {
        self.run_tokens_padded(m, tokens, m.dims.seq_len, out);
    }

    /// MT with a ragged source: only the first `src_len` of the
    /// `seq_len` token slots are real; the pad tail is masked out of
    /// attention (so logits on the valid prefix are bitwise independent
    /// of the pad content — tested below).
    pub fn run_tokens_padded(
        &mut self,
        m: &PreparedModel,
        tokens: &[i32],
        src_len: usize,
        out: &mut Vec<f32>,
    ) {
        self.embed_encode_tokens(m, tokens, src_len);
        self.head(m, out, false);
        self.stats.utterances += 1;
    }

    /// MT encoder memory for the decoder's cross-attention: embed +
    /// encode a (possibly padded) source sentence and write the
    /// **post-final-LayerNorm** hidden states `seq_len x d_model` into
    /// `memory` (rows `>= src_len` are pad rows — callers slice the
    /// valid prefix).
    pub fn memory_tokens(
        &mut self,
        m: &PreparedModel,
        tokens: &[i32],
        src_len: usize,
        memory: &mut Vec<f32>,
    ) {
        self.embed_encode_tokens(m, tokens, src_len);
        memory.clear();
        memory.extend_from_slice(&self.h);
        ops::layer_norm(memory, m.dims.d_model, &m.lnf_g, &m.lnf_b);
        self.stats.utterances += 1;
    }

    /// Shared token path: embed the sentence, build the real pad mask
    /// from `src_len`, and run the encoder stack.
    fn embed_encode_tokens(&mut self, m: &PreparedModel, tokens: &[i32], src_len: usize) {
        let dims = &m.dims;
        assert!(dims.token_input, "token input on a feature-input model");
        let t = dims.seq_len;
        assert_eq!(tokens.len(), t, "tokens must be seq");
        assert!(src_len > 0 && src_len <= t, "src_len {src_len} out of 1..={t}");
        let d = dims.d_model;
        self.h.clear();
        self.h.resize(t * d, 0.0);
        for (row, tok) in tokens.iter().enumerate() {
            let ti = *tok as usize;
            assert!(ti < dims.vocab, "token {ti} out of vocab {}", dims.vocab);
            self.h[row * d..(row + 1) * d].copy_from_slice(&m.in_w[ti * d..(ti + 1) * d]);
        }
        // Take/restore the reusable pad buffer so `encode` can borrow
        // it alongside `&mut self` (same pattern as the systolic array's
        // register planes).
        let mut pad = std::mem::take(&mut self.pad_buf);
        pad.clear();
        pad.resize(t, 0.0);
        for p in pad.iter_mut().take(src_len) {
            *p = 1.0;
        }
        self.encode(m, &pad);
        self.pad_buf = pad;
    }

    /// Shared encoder stack over `self.h` (which holds the projected /
    /// embedded input before bias + positions).
    fn encode(&mut self, m: &PreparedModel, pad: &[f32]) {
        let dims = &m.dims;
        let (t, d) = (dims.seq_len, dims.d_model);
        let (h_heads, hd) = (dims.n_heads, dims.head_dim());
        let inv_sqrt_hd = 1.0 / (hd as f32).sqrt();
        ops::add_bias(&mut self.h, &m.in_b);
        ops::residual_add(&mut self.h, &m.pe);
        self.hn.clear();
        self.scores.clear();
        self.scores.resize(t * t, 0.0);
        self.ctx.clear();
        self.ctx.resize(t * d, 0.0);

        for blk in &m.blocks {
            // --- pre-LN multi-head self-attention ------------------------
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.ln1_g, &blk.ln1_b);
            let sq = blk.wq.gemm(&self.hn, t, None, m.tile, &mut self.q);
            let sk = blk.wk.gemm(&self.hn, t, None, m.tile, &mut self.k);
            let sv = blk.wv.gemm(&self.hn, t, None, m.tile, &mut self.v);
            self.stats.attn.add(&sq);
            self.stats.attn.add(&sk);
            self.stats.attn.add(&sv);
            layers::record(Layer::Qkv, &sq, m.tile, m.quant);
            layers::record(Layer::Qkv, &sk, m.tile, m.quant);
            layers::record(Layer::Qkv, &sv, m.tile, m.quant);
            for head in 0..h_heads {
                let c0 = head * hd;
                // Dynamic score GEMM (activation x activation — software
                // FP32, like the artifact's einsum; never pruned).
                for a in 0..t {
                    for b in 0..t {
                        let mut acc = 0.0f32;
                        for j in 0..hd {
                            acc += self.q[a * d + c0 + j] * self.k[b * d + c0 + j];
                        }
                        self.scores[a * t + b] =
                            acc * inv_sqrt_hd + (1.0 - pad[b]) * -1e9;
                    }
                }
                ops::softmax_rows(&mut self.scores, t);
                // Dynamic context GEMM.
                for a in 0..t {
                    for j in 0..hd {
                        let mut acc = 0.0f32;
                        for b in 0..t {
                            acc += self.scores[a * t + b] * self.v[b * d + c0 + j];
                        }
                        self.ctx[a * d + c0 + j] = acc;
                    }
                }
            }
            let so = blk.wo.gemm(&self.ctx, t, None, m.tile, &mut self.tmp);
            self.stats.attn.add(&so);
            layers::record(Layer::AttnOut, &so, m.tile, m.quant);
            ops::residual_add(&mut self.h, &self.tmp);

            // --- pre-LN SASP feed-forward --------------------------------
            self.hn.clear();
            self.hn.extend_from_slice(&self.h);
            ops::layer_norm(&mut self.hn, d, &blk.ln2_g, &blk.ln2_b);
            let s1 = blk.w1.gemm(&self.hn, t, Some(&blk.mask1), m.tile, &mut self.mid);
            self.stats.ff.add(&s1);
            layers::record(Layer::Ff1, &s1, m.tile, m.quant);
            ops::add_bias(&mut self.mid, &blk.b1);
            ops::relu(&mut self.mid);
            let s2 = blk.w2.gemm(&self.mid, t, Some(&blk.mask2), m.tile, &mut self.tmp);
            self.stats.ff.add(&s2);
            layers::record(Layer::Ff2, &s2, m.tile, m.quant);
            ops::add_bias(&mut self.tmp, &blk.b2);
            ops::residual_add(&mut self.h, &self.tmp);
        }
    }

    /// Final LayerNorm + vocabulary head (+ log-softmax for CTC).
    fn head(&mut self, m: &PreparedModel, out: &mut Vec<f32>, log_probs: bool) {
        let dims = &m.dims;
        let (t, d, v) = (dims.seq_len, dims.d_model, dims.vocab);
        self.hn.clear();
        self.hn.extend_from_slice(&self.h);
        ops::layer_norm(&mut self.hn, d, &m.lnf_g, &m.lnf_b);
        let st = gemm_f32(&self.hn, &m.head_w, t, d, v, None, m.tile, out);
        self.stats.other.add(&st);
        layers::record(Layer::Head, &st, m.tile, Quant::Fp32);
        ops::add_bias(out, &m.head_b);
        if log_probs {
            ops::log_softmax_rows(out, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::testutil::{mini_dims, zero_ff_tiles};
    use crate::qos::ctc_greedy;
    use crate::util::rng::Rng;

    fn random_masks(dims: &ModelDims, tile: usize, p_dead: f64, seed: u64) -> Vec<TileMask> {
        let mut rng = Rng::new(seed);
        let (kt, nt) = (dims.d_model / tile, dims.d_ff / tile);
        let mut out = Vec::new();
        for _ in 0..dims.n_blocks {
            out.push(TileMask {
                kt,
                nt,
                live: (0..kt * nt).map(|_| !rng.chance(p_dead)).collect(),
            });
            out.push(TileMask {
                kt: nt,
                nt: kt,
                live: (0..kt * nt).map(|_| !rng.chance(p_dead)).collect(),
            });
        }
        out
    }

    fn random_input(dims: &ModelDims, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let feats: Vec<f32> = (0..dims.seq_len * dims.input_dim)
            .map(|_| rng.normal() as f32 * 0.5)
            .collect();
        let pad = vec![1.0f32; dims.seq_len];
        (feats, pad)
    }

    #[test]
    fn bundle_roundtrip_preserves_weights() {
        let dims = mini_dims();
        let w = crate::infer::synth::synth_weights(&dims, 5);
        let b = w.to_bundle();
        let back = EncoderWeights::from_bundle(dims, &b).unwrap();
        assert_eq!(w.in_w, back.in_w);
        assert_eq!(w.blocks[1].w2, back.blocks[1].w2);
        assert_eq!(w.head_b, back.head_b);
    }

    #[test]
    fn from_bundle_rejects_wrong_shapes() {
        let dims = mini_dims();
        let w = crate::infer::synth::synth_weights(&dims, 5);
        let mut b = w.to_bundle();
        b.insert("head.w", Tensor::from_f32(&[2, 2], &[0.0; 4]));
        assert!(EncoderWeights::from_bundle(dims, &b).is_err());
    }

    #[test]
    fn dense_none_equals_full_masks() {
        let dims = mini_dims();
        let w = crate::infer::synth::synth_weights(&dims, 5);
        let (feats, pad) = random_input(&dims, 1);
        let dense = PreparedModel::new(&w, dims.tile, Quant::Fp32, None).unwrap();
        let full_masks = random_masks(&dims, dims.tile, 0.0, 1);
        let full = PreparedModel::new(&w, dims.tile, Quant::Fp32, Some(&full_masks)).unwrap();
        let mut fwd = Forward::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        fwd.run_feats(&dense, &feats, &pad, &mut a);
        fwd.run_feats(&full, &feats, &pad, &mut b);
        assert_eq!(a, b);
        assert_eq!(dense.ff_sparsity(), 0.0);
    }

    #[test]
    fn tile_skipping_equals_zeroed_weights_end_to_end() {
        // The SASP identity through the whole encoder: skipping ff tiles
        // == running dense over weights with those tiles zeroed.
        let dims = mini_dims();
        let w = crate::infer::synth::synth_weights(&dims, 7);
        let tile = dims.tile;
        let masks = random_masks(&dims, tile, 0.4, 3);
        let (feats, pad) = random_input(&dims, 2);

        let masked = PreparedModel::new(&w, tile, Quant::Fp32, Some(&masks)).unwrap();
        let mut wz = w.clone();
        zero_ff_tiles(&mut wz, &masks, tile);
        let zeroed = PreparedModel::new(&wz, tile, Quant::Fp32, None).unwrap();

        let mut fwd = Forward::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        fwd.run_feats(&masked, &feats, &pad, &mut a);
        let skipped = fwd.stats.ff.tiles_skipped;
        fwd.run_feats(&zeroed, &feats, &pad, &mut b);
        assert!(skipped > 0, "mask must actually skip tiles");
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn int8_forward_matches_fake_quantized_fp32_forward() {
        // Kernel INT8 == FP32 over prune-then-fake-quantized weights,
        // end to end (the gemm-level identity composed through the
        // network; the reference applies the same prune→PTQ order the
        // staging path uses, so the per-tensor scales agree).
        let dims = mini_dims();
        let w = crate::infer::synth::synth_weights(&dims, 9);
        let masks = random_masks(&dims, dims.tile, 0.3, 5);
        let (feats, pad) = random_input(&dims, 4);

        let int8 = PreparedModel::new(&w, dims.tile, Quant::Int8, Some(&masks)).unwrap();
        let mut wfq = w.clone();
        zero_ff_tiles(&mut wfq, &masks, dims.tile);
        let fq2 = |vals: &mut Vec<f32>, r: usize, c: usize| {
            let mut t = Tensor::from_f32(&[r, c], vals);
            fake_quantize(&mut t);
            *vals = t.f32s();
        };
        let (d, f) = (dims.d_model, dims.d_ff);
        fq2(&mut wfq.in_w, dims.input_dim, d);
        fq2(&mut wfq.head_w, d, dims.vocab);
        for blk in wfq.blocks.iter_mut() {
            fq2(&mut blk.wq, d, d);
            fq2(&mut blk.wk, d, d);
            fq2(&mut blk.wv, d, d);
            fq2(&mut blk.wo, d, d);
            fq2(&mut blk.w1, d, f);
            fq2(&mut blk.w2, f, d);
        }
        let fp32 = PreparedModel::new(&wfq, dims.tile, Quant::Fp32, Some(&masks)).unwrap();

        let mut fwd = Forward::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        fwd.run_feats(&int8, &feats, &pad, &mut a);
        fwd.run_feats(&fp32, &feats, &pad, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn per_channel_int8_forward_matches_fake_quantized_fp32_forward() {
        // The per-channel oracle identity at encoder scope: kernel INT8
        // with per-column scales == FP32 over prune-then-per-channel
        // fake-quantized weights.
        use crate::quant::fake_quantize_per_channel;
        let dims = mini_dims();
        let w = crate::infer::synth::synth_weights(&dims, 29);
        let masks = random_masks(&dims, dims.tile, 0.3, 15);
        let (feats, pad) = random_input(&dims, 14);

        let int8 =
            PreparedModel::new_with(&w, dims.tile, Quant::Int8, Some(&masks), true).unwrap();
        assert!(int8.per_channel);
        let mut wfq = w.clone();
        zero_ff_tiles(&mut wfq, &masks, dims.tile);
        let fq2 = |vals: &mut Vec<f32>, r: usize, c: usize| {
            let mut t = Tensor::from_f32(&[r, c], vals);
            fake_quantize_per_channel(&mut t);
            *vals = t.f32s();
        };
        let (d, f) = (dims.d_model, dims.d_ff);
        fq2(&mut wfq.in_w, dims.input_dim, d);
        fq2(&mut wfq.head_w, d, dims.vocab);
        for blk in wfq.blocks.iter_mut() {
            fq2(&mut blk.wq, d, d);
            fq2(&mut blk.wk, d, d);
            fq2(&mut blk.wv, d, d);
            fq2(&mut blk.wo, d, d);
            fq2(&mut blk.w1, d, f);
            fq2(&mut blk.w2, f, d);
        }
        let fp32 = PreparedModel::new(&wfq, dims.tile, Quant::Fp32, Some(&masks)).unwrap();

        let mut fwd = Forward::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        fwd.run_feats(&int8, &feats, &pad, &mut a);
        fwd.run_feats(&fp32, &feats, &pad, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn functional_stats_match_analytic_accounting() {
        // The analytic x functional cross-check at encoder scope: the ff
        // schedule the forward pass executed must cost exactly what the
        // analytic engine charges for the same GEMMs and masks.
        use crate::model::{GemmKind, GemmShape};
        use crate::sysim::engine::gemm_on_array;
        use crate::sysim::SimParams;
        use crate::systolic::ArrayConfig;

        let dims = mini_dims();
        let tile = dims.tile;
        let w = crate::infer::synth::synth_weights(&dims, 11);
        let masks = random_masks(&dims, tile, 0.5, 7);
        let model = PreparedModel::new(&w, tile, Quant::Int8, Some(&masks)).unwrap();
        let (feats, pad) = random_input(&dims, 6);
        let mut fwd = Forward::new();
        let mut out = Vec::new();
        fwd.run_feats(&model, &feats, &pad, &mut out);

        let cfg = ArrayConfig::square(tile, Quant::Int8);
        let p = SimParams::default();
        let (t, d, f) = (dims.seq_len, dims.d_model, dims.d_ff);
        let mut macs = 0u64;
        let mut bus_words = 0u64;
        for i in 0..dims.n_blocks {
            let g1 = GemmShape { m: t, k: d, n: f, kind: GemmKind::FeedForward };
            let g2 = GemmShape { m: t, k: f, n: d, kind: GemmKind::FeedForward };
            let c1 = gemm_on_array(&g1, &cfg, &p, Some(&masks[2 * i]));
            let c2 = gemm_on_array(&g2, &cfg, &p, Some(&masks[2 * i + 1]));
            macs += c1.counts.macs + c2.counts.macs;
            bus_words += c1.counts.bus_words + c2.counts.bus_words;
        }
        assert_eq!(fwd.stats.ff.timing.macs as u64, macs);
        assert_eq!(fwd.stats.ff.timing.total_words() as u64, bus_words);
        let live: usize = masks.iter().map(TileMask::live_count).sum();
        let dead: usize = masks.iter().map(|m| m.n_tiles() - m.live_count()).sum();
        assert_eq!(fwd.stats.ff.tiles_live, live);
        assert_eq!(fwd.stats.ff.tiles_skipped, dead);
    }

    #[test]
    fn pruning_changes_but_does_not_destroy_output() {
        // Moderate ff pruning perturbs log-probs without NaNs; decode
        // still yields a valid token sequence.
        let dims = mini_dims();
        let w = crate::infer::synth::synth_weights(&dims, 13);
        let masks = random_masks(&dims, dims.tile, 0.25, 9);
        let dense = PreparedModel::new(&w, dims.tile, Quant::Fp32, None).unwrap();
        let pruned = PreparedModel::new(&w, dims.tile, Quant::Fp32, Some(&masks)).unwrap();
        let (feats, pad) = random_input(&dims, 8);
        let mut fwd = Forward::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        fwd.run_feats(&dense, &feats, &pad, &mut a);
        fwd.run_feats(&pruned, &feats, &pad, &mut b);
        assert!(a.iter().all(|v| v.is_finite()));
        assert!(b.iter().all(|v| v.is_finite()));
        assert!(a != b, "pruning must perturb the outputs");
        let hyp = ctc_greedy(&b, dims.seq_len, dims.vocab, dims.ctc_blank);
        assert!(hyp.iter().all(|s| *s >= 0 && (*s as usize) < dims.vocab));
    }

    #[test]
    fn token_input_forward_runs() {
        let dims = ModelDims {
            token_input: true,
            ctc_blank: -1,
            ..mini_dims()
        };
        let w = crate::infer::synth::synth_weights(&dims, 17);
        let model = PreparedModel::new(&w, dims.tile, Quant::Fp32, None).unwrap();
        let mut rng = Rng::new(2);
        let tokens: Vec<i32> = (0..dims.seq_len)
            .map(|_| rng.index(dims.vocab) as i32)
            .collect();
        let mut fwd = Forward::new();
        let mut out = Vec::new();
        fwd.run_tokens(&model, &tokens, &mut out);
        assert_eq!(out.len(), dims.seq_len * dims.vocab);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn padded_and_unpadded_sources_agree_on_prefix() {
        // The satellite contract: a ragged source run at the full
        // seq_len with a real pad mask must produce the same logits on
        // the valid prefix as the same sentence run unpadded at
        // seq_len == src_len. The additive -1e9 mask underflows pad
        // scores to exactly 0 after softmax, so the agreement is
        // bitwise, not approximate.
        let dims = ModelDims {
            token_input: true,
            ctc_blank: -1,
            ..mini_dims()
        };
        let w = crate::infer::synth::synth_weights(&dims, 53);
        let src_len = dims.seq_len / 2 + 3;
        let short_dims = ModelDims { seq_len: src_len, ..dims };
        // Weights do not depend on seq_len — rewrap them at the short
        // length for the unpadded reference model.
        let w_short = EncoderWeights { dims: short_dims, ..w.clone() };

        let mut rng = Rng::new(21);
        let mut tokens: Vec<i32> = (0..dims.seq_len)
            .map(|_| rng.index(dims.vocab) as i32)
            .collect();
        let model = PreparedModel::new(&w, dims.tile, Quant::Fp32, None).unwrap();
        let model_short =
            PreparedModel::new(&w_short, dims.tile, Quant::Fp32, None).unwrap();

        let mut fwd = Forward::new();
        let mut padded = Vec::new();
        fwd.run_tokens_padded(&model, &tokens, src_len, &mut padded);
        let mut unpadded = Vec::new();
        fwd.run_tokens(&model_short, &tokens[..src_len], &mut unpadded);
        let v = dims.vocab;
        assert_eq!(
            &padded[..src_len * v],
            unpadded.as_slice(),
            "valid prefix must be bitwise independent of padding"
        );
        // And independent of the pad *content* too.
        for tok in tokens.iter_mut().skip(src_len) {
            *tok = (*tok + 1) % dims.vocab as i32;
        }
        let mut padded2 = Vec::new();
        fwd.run_tokens_padded(&model, &tokens, src_len, &mut padded2);
        assert_eq!(&padded[..src_len * v], &padded2[..src_len * v]);
        // The memory surface applies the final LayerNorm.
        let mut mem = Vec::new();
        fwd.memory_tokens(&model, &tokens, src_len, &mut mem);
        assert_eq!(mem.len(), dims.seq_len * dims.d_model);
        assert!(mem.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prepared_model_rejects_bad_tile_and_masks() {
        let dims = mini_dims();
        let w = crate::infer::synth::synth_weights(&dims, 19);
        assert!(PreparedModel::new(&w, 5, Quant::Fp32, None).is_err());
        let bad = vec![TileMask::full(1, 1); 2 * dims.n_blocks];
        assert!(PreparedModel::new(&w, dims.tile, Quant::Fp32, Some(&bad)).is_err());
        let short = vec![TileMask::full(4, 8)];
        assert!(PreparedModel::new(&w, dims.tile, Quant::Fp32, Some(&short)).is_err());
    }
}
