//! Per-layer GEMM attribution — labels every array-executed (and
//! software-executed) GEMM call site of the native engine and
//! accumulates its cost into the process-global metrics registry.
//!
//! Each [`record`] call charges one GEMM's [`TileStats`] to the
//! `layer`-labeled counter family:
//!
//! - `sasp_layer_macs_total{layer="..."}` — MAC operations executed.
//! - `sasp_layer_array_cycles_total{...}` — array-busy cycles.
//! - `sasp_layer_bus_words_total{...}` — 32-bit bus words moved
//!   (weights + activations, [`TileTiming::total_words`]).
//! - `sasp_layer_energy_pj_total{...}` — picojoules charged at the
//!   [`EnergyModel::default`] rates (MACs at the array's per-MAC energy
//!   for this tile/quant configuration, bus words at the per-word bus
//!   energy) — the same model `sysim` uses, so per-layer energy sums
//!   reconcile with the system simulator's totals.
//! - `sasp_layer_{active,bubble,stall,skipped}_pe_cycles_total{...}` —
//!   the [`Occupancy`] breakdown: steady-state work, fill/drain
//!   bubbles, reprogramming stalls, and pruning-skipped savings.
//!
//! Every call also samples the `array_utilization` Chrome counter track
//! ([`crate::telemetry::counter`]), so a Perfetto-loaded serve trace
//! shows the array's occupancy mix evolving GEMM by GEMM over the run.
//!
//! Like every instrumentation site in [`crate::telemetry`], the whole
//! record is behind the one relaxed-atomic [`telemetry::active`] branch:
//! with no recording session the serving hot path pays a single load.
//!
//! [`TileTiming::total_words`]: crate::systolic::TileTiming::total_words
//! [`Occupancy`]: crate::systolic::Occupancy
//! [`EnergyModel::default`]: crate::hwmodel::EnergyModel

use crate::hwmodel::EnergyModel;
use crate::systolic::{ArrayConfig, Quant};
use crate::telemetry::{self, LazyCounter};

use super::gemm::TileStats;

/// The GEMM roles of the native engine's forward passes, encoder and
/// decoder side. Attention projections carry one label per projection
/// group; the SASP feed-forward pair is split so pruning savings are
/// attributable per GEMM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layer {
    /// Input projection / token embedding (software FP32).
    InProj,
    /// Encoder q/k/v projections.
    Qkv,
    /// Encoder attention output projection.
    AttnOut,
    /// Encoder feed-forward expand (`w1`, SASP-pruned).
    Ff1,
    /// Encoder feed-forward contract (`w2`, SASP-pruned).
    Ff2,
    /// Decoder cross-attention K/V precompute (per-utterance reuse).
    CrossKv,
    /// Decoder self/cross attention projections (`m = 1` GEMVs).
    DecAttn,
    /// Decoder feed-forward pair (SASP-pruned GEMVs).
    DecFf,
    /// Vocabulary head (software FP32).
    Head,
}

/// Every layer, in [`Layer`] discriminant order (report iteration).
pub const ALL: [Layer; 9] = [
    Layer::InProj,
    Layer::Qkv,
    Layer::AttnOut,
    Layer::Ff1,
    Layer::Ff2,
    Layer::CrossKv,
    Layer::DecAttn,
    Layer::DecFf,
    Layer::Head,
];

impl Layer {
    /// The `layer` label value used in the metric names.
    pub fn label(self) -> &'static str {
        match self {
            Layer::InProj => "in_proj",
            Layer::Qkv => "qkv",
            Layer::AttnOut => "attn_out",
            Layer::Ff1 => "ff1",
            Layer::Ff2 => "ff2",
            Layer::CrossKv => "cross_kv",
            Layer::DecAttn => "dec_attn",
            Layer::DecFf => "dec_ff",
            Layer::Head => "head",
        }
    }

    /// The full metric name for `family` (one of the
    /// `sasp_layer_*_total` families) at this layer's label — what the
    /// series is keyed by in a [`crate::telemetry::MetricsSnapshot`].
    pub fn metric(self, family: &str) -> String {
        format!("{family}{{layer=\"{}\"}}", self.label())
    }
}

/// One layer's counter handles (resolved lazily, lock-free after).
struct LayerCounters {
    macs: LazyCounter,
    array_cycles: LazyCounter,
    bus_words: LazyCounter,
    energy_pj: LazyCounter,
    active: LazyCounter,
    bubble: LazyCounter,
    stall: LazyCounter,
    skipped: LazyCounter,
}

macro_rules! layer_counters {
    ($label:literal) => {
        LayerCounters {
            macs: LazyCounter::new(concat!(
                "sasp_layer_macs_total{layer=\"", $label, "\"}"
            )),
            array_cycles: LazyCounter::new(concat!(
                "sasp_layer_array_cycles_total{layer=\"", $label, "\"}"
            )),
            bus_words: LazyCounter::new(concat!(
                "sasp_layer_bus_words_total{layer=\"", $label, "\"}"
            )),
            energy_pj: LazyCounter::new(concat!(
                "sasp_layer_energy_pj_total{layer=\"", $label, "\"}"
            )),
            active: LazyCounter::new(concat!(
                "sasp_layer_active_pe_cycles_total{layer=\"", $label, "\"}"
            )),
            bubble: LazyCounter::new(concat!(
                "sasp_layer_bubble_pe_cycles_total{layer=\"", $label, "\"}"
            )),
            stall: LazyCounter::new(concat!(
                "sasp_layer_stall_pe_cycles_total{layer=\"", $label, "\"}"
            )),
            skipped: LazyCounter::new(concat!(
                "sasp_layer_skipped_pe_cycles_total{layer=\"", $label, "\"}"
            )),
        }
    };
}

/// Indexed like [`ALL`] / the [`Layer`] discriminants.
static COUNTERS: [LayerCounters; 9] = [
    layer_counters!("in_proj"),
    layer_counters!("qkv"),
    layer_counters!("attn_out"),
    layer_counters!("ff1"),
    layer_counters!("ff2"),
    layer_counters!("cross_kv"),
    layer_counters!("dec_attn"),
    layer_counters!("dec_ff"),
    layer_counters!("head"),
];

/// Energy one GEMM's schedule costs at the default [`EnergyModel`], in
/// picojoules: MACs at the array's per-MAC energy for this (tile,
/// quant) configuration plus bus words at the per-word bus energy.
pub fn energy_pj(stats: &TileStats, tile: usize, quant: Quant) -> f64 {
    let em = EnergyModel::default();
    let cfg = ArrayConfig::square(tile, quant);
    stats.timing.macs as f64 * em.mac_energy_j(&cfg) * 1e12
        + stats.timing.total_words() as f64 * em.bus_word_j * 1e12
}

/// Attribute one executed GEMM to `layer`: charge its MACs, array
/// cycles, bus words, energy, and occupancy breakdown to the labeled
/// counters, and sample the `array_utilization` counter track. `tile`
/// and `quant` are the configuration the GEMM ran at (they set the
/// per-MAC energy). A single branch when no session is recording.
#[inline]
pub fn record(layer: Layer, stats: &TileStats, tile: usize, quant: Quant) {
    if !telemetry::active() {
        return;
    }
    let c = &COUNTERS[layer as usize];
    let t = &stats.timing;
    c.macs.get().add(t.macs as u64);
    c.array_cycles.get().add(t.array_cycles as u64);
    c.bus_words.get().add(t.total_words() as u64);
    c.energy_pj.get().add(energy_pj(stats, tile, quant).round() as u64);
    c.active.get().add(t.occ.active_pe_cycles as u64);
    c.bubble.get().add(t.occ.bubble_pe_cycles as u64);
    c.stall.get().add(t.occ.stall_pe_cycles as u64);
    c.skipped.get().add(t.occ.skipped_pe_cycles as u64);
    telemetry::counter(
        "array_utilization",
        vec![
            ("active", t.occ.active_pe_cycles.into()),
            ("bubble", t.occ.bubble_pe_cycles.into()),
            ("stall", t.occ.stall_pe_cycles.into()),
            ("skipped", t.occ.skipped_pe_cycles.into()),
            ("layer", layer.label().into()),
        ],
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::TileTiming;
    use crate::telemetry::Telemetry;

    fn stats_of(tile: usize, quant: Quant, m: usize) -> TileStats {
        let cfg = ArrayConfig::square(tile, quant);
        let mut s = TileStats::default();
        s.tiles_live = 1;
        s.timing.add(&TileTiming::live(&cfg, m));
        s.tiles_skipped = 1;
        s.timing.add(&TileTiming::skipped_pass(&cfg, m, 1));
        s
    }

    #[test]
    fn record_accumulates_labeled_counters_and_samples_track() {
        let (tile, quant, m) = (8usize, Quant::Int8, 24usize);
        let s = stats_of(tile, quant, m);
        let session = Telemetry::start();
        record(Layer::Ff1, &s, tile, quant);
        record(Layer::Ff1, &s, tile, quant);
        record(Layer::Qkv, &s, tile, quant);
        let trace = session.finish();

        let c = &trace.metrics.counters;
        let t = &s.timing;
        assert_eq!(c[&Layer::Ff1.metric("sasp_layer_macs_total")], 2 * t.macs as u64);
        assert_eq!(
            c[&Layer::Ff1.metric("sasp_layer_bus_words_total")],
            2 * t.total_words() as u64
        );
        assert_eq!(
            c[&Layer::Ff1.metric("sasp_layer_active_pe_cycles_total")],
            2 * t.occ.active_pe_cycles as u64
        );
        assert_eq!(
            c[&Layer::Ff1.metric("sasp_layer_skipped_pe_cycles_total")],
            2 * t.occ.skipped_pe_cycles as u64
        );
        assert_eq!(c[&Layer::Qkv.metric("sasp_layer_macs_total")], t.macs as u64);
        let pj = c[&Layer::Qkv.metric("sasp_layer_energy_pj_total")];
        assert_eq!(pj, energy_pj(&s, tile, quant).round() as u64);
        assert!(pj > 0, "a live pass costs energy");
        // One counter-track sample per record call.
        assert_eq!(trace.named("array_utilization").count(), 3);
    }

    #[test]
    fn record_is_inert_without_a_session() {
        let s = stats_of(8, Quant::Fp32, 8);
        record(Layer::Head, &s, 8, Quant::Fp32);
        // A later session starts from zero — the gated call charged
        // nothing.
        let session = Telemetry::start();
        let trace = session.finish();
        assert_eq!(
            trace
                .metrics
                .counters
                .get(&Layer::Head.metric("sasp_layer_macs_total"))
                .copied()
                .unwrap_or(0),
            0
        );
        assert_eq!(trace.named("array_utilization").count(), 0);
    }

    #[test]
    fn labels_are_unique_and_stable() {
        let mut seen = std::collections::BTreeSet::new();
        for l in ALL {
            assert!(seen.insert(l.label()), "duplicate label {:?}", l.label());
        }
        assert_eq!(
            Layer::Ff1.metric("sasp_layer_macs_total"),
            "sasp_layer_macs_total{layer=\"ff1\"}"
        );
    }
}
