//! Non-GEMM operators of the native engine — the software-executed
//! remainder of an encoder block (the paper's §4.1: GEMMs dominate, the
//! rest runs on the core). Semantics mirror `python/compile/model.py`
//! so the native engine computes the same function as the AOT artifact.

/// In-place LayerNorm over each length-`d` row of `x`: population
/// variance, `eps = 1e-5`, learned gain/shift — `_layer_norm` in the
/// python model.
pub fn layer_norm(x: &mut [f32], d: usize, gamma: &[f32], beta: &[f32]) {
    assert!(d > 0 && x.len() % d == 0, "rows must be length {d}");
    assert_eq!(gamma.len(), d);
    assert_eq!(beta.len(), d);
    for row in x.chunks_exact_mut(d) {
        // lint:allow(bitwise-contract-drift) -- canonical shared mean reduction; single implementation all engines call
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row
            .iter()
            .map(|v| {
                let c = v - mean;
                c * c
            })
            // lint:allow(bitwise-contract-drift) -- canonical shared variance reduction; single implementation all engines call
            .sum::<f32>()
            / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (v, (g, b)) in row.iter_mut().zip(gamma.iter().zip(beta)) {
            *v = (*v - mean) * inv * g + b;
        }
    }
}

/// Numerically stable in-place softmax over each length-`n` row.
pub fn softmax_rows(x: &mut [f32], n: usize) {
    assert!(n > 0 && x.len() % n == 0);
    for row in x.chunks_exact_mut(n) {
        // lint:allow(bitwise-contract-drift) -- max-fold is order-independent
        let max = row.iter().fold(f32::NEG_INFINITY, |a, v| a.max(*v));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// In-place log-softmax over each length-`n` row (the CTC head's
/// `jax.nn.log_softmax`).
pub fn log_softmax_rows(x: &mut [f32], n: usize) {
    assert!(n > 0 && x.len() % n == 0);
    for row in x.chunks_exact_mut(n) {
        // lint:allow(bitwise-contract-drift) -- max-fold is order-independent
        let max = row.iter().fold(f32::NEG_INFINITY, |a, v| a.max(*v));
        // lint:allow(bitwise-contract-drift) -- canonical shared exp-sum; single implementation all engines call
        let sum: f32 = row.iter().map(|v| (*v - max).exp()).sum();
        let lse = max + sum.ln();
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
}

/// In-place ReLU (the tiny trained models' feed-forward activation).
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place GELU (tanh approximation) — the activation of the full-size
/// Table 1 encoders; the tiny artifacts use [`relu`].
pub fn gelu(x: &mut [f32]) {
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    for v in x.iter_mut() {
        let u = *v;
        let inner = SQRT_2_OVER_PI * (u + 0.044_715 * u * u * u);
        *v = 0.5 * u * (1.0 + inner.tanh());
    }
}

/// `x[row] += bias` for each length-`bias.len()` row.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    assert!(n > 0 && x.len() % n == 0);
    for row in x.chunks_exact_mut(n) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// `acc += x` elementwise (residual connections, position table add).
pub fn residual_add(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    for (a, v) in acc.iter_mut().zip(x) {
        *a += v;
    }
}

/// Fixed sinusoidal position table, row-major `t x d` — the same
/// `sin/cos(pos / 10000^(2*(i/2)/d))` layout as `sinusoidal_pe` in the
/// python model.
pub fn sinusoidal_pe(t: usize, d: usize) -> Vec<f32> {
    let mut pe = vec![0.0f32; t * d];
    for pos in 0..t {
        for dim in 0..d {
            let exponent = (2 * (dim / 2)) as f64 / d as f64;
            let angle = pos as f64 / 10000f64.powf(exponent);
            pe[pos * d + dim] = if dim % 2 == 0 {
                angle.sin() as f32
            } else {
                angle.cos() as f32
            };
        }
    }
    pe
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0, /* row 2 */ -5.0, 0.0, 5.0, 10.0];
        let g = vec![1.0f32; 4];
        let b = vec![0.0f32; 4];
        layer_norm(&mut x, 4, &g, &b);
        for row in x.chunks_exact(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
    }

    #[test]
    fn layer_norm_gain_shift() {
        let mut x = vec![-1.0f32, 1.0];
        layer_norm(&mut x, 2, &[2.0, 2.0], &[1.0, 1.0]);
        // Normalized row is [-1, 1] (up to eps), scaled by 2 shifted by 1.
        assert!((x[0] + 1.0).abs() < 1e-3, "{x:?}");
        assert!((x[1] - 3.0).abs() < 1e-3, "{x:?}");
    }

    #[test]
    fn softmax_rows_normalized_and_ordered() {
        let mut x = vec![0.0f32, 1.0, 2.0, /* large magnitudes */ 1000.0, 1001.0, 999.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!(x[2] > x[1] && x[1] > x[0]);
        assert!(x[4] > x[3] && x[3] > x[5], "stable under large inputs");
    }

    #[test]
    fn log_softmax_matches_softmax_log() {
        let mut a = vec![0.3f32, -1.2, 2.5, 0.0];
        let mut b = a.clone();
        log_softmax_rows(&mut a, 4);
        softmax_rows(&mut b, 4);
        for (la, sb) in a.iter().zip(&b) {
            assert!((la - sb.ln()).abs() < 1e-5, "{la} vs ln {sb}");
        }
    }

    #[test]
    fn relu_and_gelu_basics() {
        let mut x = vec![-2.0f32, -0.5, 0.0, 0.5, 2.0];
        let mut y = x.clone();
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 0.0, 0.5, 2.0]);
        gelu(&mut y);
        // GELU(0) = 0; GELU(2) ~ 1.954; GELU(-2) ~ -0.045.
        assert_eq!(y[2], 0.0);
        assert!((y[4] - 1.954).abs() < 5e-3, "{}", y[4]);
        assert!((y[0] + 0.045).abs() < 5e-3, "{}", y[0]);
    }

    #[test]
    fn bias_and_residual() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        add_bias(&mut x, &[10.0, 20.0]);
        assert_eq!(x, vec![11.0, 22.0, 13.0, 24.0]);
        residual_add(&mut x, &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(x, vec![12.0, 23.0, 14.0, 25.0]);
    }

    #[test]
    fn sinusoidal_pe_layout() {
        let pe = sinusoidal_pe(4, 6);
        // Position 0: sin(0) = 0 on even dims, cos(0) = 1 on odd dims.
        assert_eq!(&pe[0..6], &[0.0, 1.0, 0.0, 1.0, 0.0, 1.0]);
        // Position 1, dim 0: sin(1).
        assert!((pe[6] - 1f64.sin() as f32).abs() < 1e-6);
        // Position 1, dim 1: cos(1 / 10000^0) = cos(1) (dim//2 == 0).
        assert!((pe[7] - 1f64.cos() as f32).abs() < 1e-6);
        // Position 2, dim 2: sin(2 / 10000^(2/6)).
        let want = (2.0 / 10000f64.powf(2.0 / 6.0)).sin() as f32;
        assert!((pe[2 * 6 + 2] - want).abs() < 1e-6);
    }
}
