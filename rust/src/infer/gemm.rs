//! Tiled masked GEMM kernels — the compute core of the native engine.
//!
//! Both kernels execute the *same schedule* as the per-cycle
//! [`crate::systolic::scheduler::TileScheduler`]: the weight matrix is a
//! `ceil(K/t) x ceil(N/t)` grid of tiles, iterated j-outer (output
//! columns hot) / k-inner (accumulation sweep), and a tile whose
//! [`TileMask`] bit is dead is skipped outright — no weight touch, no
//! multiply. Per-live-tile costs are accounted with the same closed-form
//! [`TileTiming`] the analytic system simulator charges, which is what
//! makes the functional and analytic layers cross-checkable on identical
//! masks (asserted in the tests below).
//!
//! Within a tile the K index ascends and partial products accumulate
//! straight into the output row, so every output element sees its
//! products in plain k-ascending order — the FP32 kernel is
//! value-identical to a naive masked matmul, and the INT8 kernel (which
//! dequantizes each sign-magnitude byte through a 256-entry table of
//! exactly the fake-quantized values) is value-identical to the FP32
//! kernel over fake-quantized weights. That makes the FP32 path the
//! oracle for the INT8 path at full precision, not just to a tolerance.

use crate::arith::SignMag8;
use crate::data::Tensor;
use crate::quant::{quantize, QuantizedTensor};
use crate::sysim::TileMask;
use crate::systolic::{ArrayConfig, Quant, TileTiming};

/// Tile-schedule statistics of one or more masked GEMMs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TileStats {
    /// Weight tiles executed.
    pub tiles_live: usize,
    /// Weight tiles skipped via the mask (the SASP saving).
    pub tiles_skipped: usize,
    /// Closed-form cost of the executed schedule (same accounting as the
    /// analytic engine and the per-cycle scheduler).
    pub timing: TileTiming,
}

impl TileStats {
    pub fn add(&mut self, o: &TileStats) {
        self.tiles_live += o.tiles_live;
        self.tiles_skipped += o.tiles_skipped;
        self.timing.add(&o.timing);
    }

    /// Fraction of tiles skipped.
    pub fn sparsity(&self) -> f64 {
        let n = self.tiles_live + self.tiles_skipped;
        self.tiles_skipped as f64 / n.max(1) as f64
    }

    /// Difference `self - earlier`: the accounting attributable to one
    /// instrumented region when stats accumulate across calls (the
    /// telemetry spans' per-step/per-shard deltas).
    pub fn minus(&self, earlier: &TileStats) -> TileStats {
        TileStats {
            tiles_live: self.tiles_live - earlier.tiles_live,
            tiles_skipped: self.tiles_skipped - earlier.tiles_skipped,
            timing: TileTiming {
                prog_words: self.timing.prog_words - earlier.timing.prog_words,
                in_words: self.timing.in_words - earlier.timing.in_words,
                out_words: self.timing.out_words - earlier.timing.out_words,
                stream_insts: self.timing.stream_insts - earlier.timing.stream_insts,
                array_cycles: self.timing.array_cycles - earlier.timing.array_cycles,
                macs: self.timing.macs - earlier.timing.macs,
                occ: crate::systolic::Occupancy {
                    active_pe_cycles: self.timing.occ.active_pe_cycles
                        - earlier.timing.occ.active_pe_cycles,
                    bubble_pe_cycles: self.timing.occ.bubble_pe_cycles
                        - earlier.timing.occ.bubble_pe_cycles,
                    stall_pe_cycles: self.timing.occ.stall_pe_cycles
                        - earlier.timing.occ.stall_pe_cycles,
                    skipped_pe_cycles: self.timing.occ.skipped_pe_cycles
                        - earlier.timing.occ.skipped_pe_cycles,
                },
            },
        }
    }

    /// Attach the tile counts, [`TileTiming`] cost, and occupancy split
    /// to a telemetry span (no-op on an inert span).
    pub fn annotate(&self, span: &mut crate::telemetry::Span) {
        if !span.is_live() {
            return;
        }
        span.attr("tiles_live", self.tiles_live);
        span.attr("tiles_skipped", self.tiles_skipped);
        span.attr("macs", self.timing.macs);
        span.attr("array_cycles", self.timing.array_cycles);
        span.attr("active_pe_cycles", self.timing.occ.active_pe_cycles);
        span.attr("bubble_pe_cycles", self.timing.occ.bubble_pe_cycles);
        span.attr("stall_pe_cycles", self.timing.occ.stall_pe_cycles);
        span.attr("skipped_pe_cycles", self.timing.occ.skipped_pe_cycles);
    }
}

pub(crate) fn check_grid(
    k: usize,
    n: usize,
    tile: usize,
    mask: Option<&TileMask>,
) -> (usize, usize) {
    assert!(tile > 0, "tile must be positive");
    let kt = k.div_ceil(tile);
    let nt = n.div_ceil(tile);
    if let Some(ms) = mask {
        assert_eq!((ms.kt, ms.nt), (kt, nt), "mask/gemm tile grid mismatch");
    }
    (kt, nt)
}

/// The single tiled schedule both kernels share: j-outer / k-inner over
/// the `kt x nt` grid, dead tiles skipped, per-live-tile
/// [`TileTiming::live`] charged. `w_at(kk, c)` supplies the (dequantized)
/// weight element — monomorphized per kernel, so the FP operation
/// sequence is *identical* across weight formats (the basis of the
/// INT8-vs-FP32 oracle identity).
fn gemm_tiled(
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    mask: Option<&TileMask>,
    tile: usize,
    quant: Quant,
    y: &mut Vec<f32>,
    w_at: impl Fn(usize, usize) -> f32,
) -> TileStats {
    assert_eq!(x.len(), m * k, "x must be m x k");
    let (kt, nt) = check_grid(k, n, tile, mask);
    y.clear();
    y.resize(m * n, 0.0);
    let mut stats = TileStats::default();
    if m == 0 {
        return stats;
    }
    let cfg = ArrayConfig::square(tile, quant);
    let per_tile = TileTiming::live(&cfg, m);
    let per_skip = TileTiming::skipped_pass(&cfg, m, 1);
    for j in 0..nt {
        let n0 = j * tile;
        let n_hi = (n0 + tile).min(n);
        for i in 0..kt {
            if let Some(ms) = mask {
                if !ms.is_live(i, j) {
                    stats.tiles_skipped += 1;
                    stats.timing.add(&per_skip);
                    continue;
                }
            }
            let k0 = i * tile;
            let k_hi = (k0 + tile).min(k);
            for r in 0..m {
                let xrow = &x[r * k..r * k + k];
                let yrow = &mut y[r * n + n0..r * n + n_hi];
                for kk in k0..k_hi {
                    let xv = xrow[kk];
                    for (cc, yv) in yrow.iter_mut().enumerate() {
                        *yv += xv * w_at(kk, n0 + cc);
                    }
                }
            }
            stats.tiles_live += 1;
            stats.timing.add(&per_tile);
        }
    }
    stats
}

/// `y = x[m,k] * w[k,n]` (row-major), skipping dead tiles. `y` is
/// cleared and resized to `m*n`.
pub fn gemm_f32(
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    mask: Option<&TileMask>,
    tile: usize,
    y: &mut Vec<f32>,
) -> TileStats {
    assert_eq!(w.len(), k * n, "w must be k x n");
    gemm_tiled(x, m, k, n, mask, tile, Quant::Fp32, y, |kk, c| w[kk * n + c])
}

/// A weight matrix quantized to sign-magnitude INT8 — what `SA_PROG`
/// ships over the bus (§3.2/§3.3), one byte per weight instead of four.
/// Scales are per-tensor by default, or per **output channel** (one per
/// column, the ROADMAP's QoS-tightening follow-on) when constructed via
/// [`QuantizedLinear::from_f32_per_channel`].
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub k: usize,
    pub n: usize,
    /// Row-major `k x n` sign-magnitude encodings
    /// ([`SignMag8::to_bits`]).
    pub bits: Vec<u8>,
    /// Per-tensor dequantization scale (`w ≈ mag * scale`); in
    /// per-channel mode, the coarsest (maximum) column scale.
    pub scale: f32,
    /// Per-output-channel scales (`Some` = per-channel mode).
    pub col_scales: Option<Vec<f32>>,
    /// Dequantization table(s): `lut[bits] = to_i8(bits) * scale` — 256
    /// entries per-tensor, or one 256-entry table **per column**
    /// (`lut[c*256 + bits]`) in per-channel mode. Either way the entries
    /// are exactly the fake-quantized weight values, so the INT8 kernel
    /// is value-identical to the FP32 kernel over the matching
    /// fake-quantized weights.
    lut: Vec<f32>,
}

impl QuantizedLinear {
    /// Quantize a row-major `k x n` FP32 matrix ([`crate::quant`] PTQ).
    pub fn from_f32(w: &[f32], k: usize, n: usize) -> Self {
        assert_eq!(w.len(), k * n);
        let t = Tensor::from_f32(&[k, n], w);
        Self::from_quantized(&quantize(&t))
    }

    /// Wrap an already-quantized tensor (must be 2-D).
    pub fn from_quantized(q: &QuantizedTensor) -> Self {
        assert_eq!(q.shape.len(), 2, "quantized weights must be 2-D");
        let (k, n) = (q.shape[0], q.shape[1]);
        let bits: Vec<u8> = q.sign_mag().iter().map(|sm| sm.to_bits()).collect();
        let mut lut = vec![0.0f32; 256];
        for (b, slot) in lut.iter_mut().enumerate() {
            *slot = SignMag8::from_bits(b as u8).to_i8() as f32 * q.scale;
        }
        QuantizedLinear { k, n, bits, scale: q.scale, col_scales: None, lut }
    }

    /// Quantize with one scale per output channel
    /// ([`crate::quant::quantize_per_channel`]): a 256-entry table per
    /// column, value-identical to `fake_quantize_per_channel`d FP32.
    pub fn from_f32_per_channel(w: &[f32], k: usize, n: usize) -> Self {
        assert_eq!(w.len(), k * n);
        let t = Tensor::from_f32(&[k, n], w);
        let q = crate::quant::quantize_per_channel(&t);
        let bits: Vec<u8> = q
            .values
            .iter()
            .map(|v| SignMag8::from_i8(*v).to_bits())
            .collect();
        let mut lut = vec![0.0f32; 256 * n];
        for (c, s) in q.scales.iter().enumerate() {
            for b in 0..256usize {
                lut[c * 256 + b] = SignMag8::from_bits(b as u8).to_i8() as f32 * s;
            }
        }
        // lint:allow(bitwise-contract-drift) -- max over column scales is order-independent
        let scale = q.scales.iter().fold(0.0f32, |a, s| a.max(*s));
        QuantizedLinear { k, n, bits, scale, col_scales: Some(q.scales), lut }
    }

    /// Dequantized value of one stored weight byte (per-tensor mode).
    pub fn dequant(&self, bits: u8) -> f32 {
        assert!(
            self.col_scales.is_none(),
            "per-channel weights dequantize by column: use dequant_at"
        );
        self.lut[bits as usize]
    }

    /// Dequantized value of the stored weight at `(kk, c)` in either
    /// scale mode.
    #[inline]
    pub fn dequant_at(&self, kk: usize, c: usize) -> f32 {
        let b = self.bits[kk * self.n + c] as usize;
        if self.col_scales.is_some() {
            self.lut[c * 256 + b]
        } else {
            self.lut[b]
        }
    }

    /// Dequantize the `[k0, k0+tk) x [n0, n0+tn)` weight tile into `dst`
    /// (row-major `tk x tn`) — one table pass per tile, which is what
    /// lets the batched weight-stationary kernel dequantize each tile
    /// **once per batch** instead of once per MAC.
    pub fn dequant_tile(&self, dst: &mut [f32], k0: usize, tk: usize, n0: usize, tn: usize) {
        debug_assert_eq!(dst.len(), tk * tn);
        match &self.col_scales {
            None => {
                for kk in 0..tk {
                    let row = (k0 + kk) * self.n + n0;
                    let src = &self.bits[row..row + tn];
                    let out = &mut dst[kk * tn..kk * tn + tn];
                    for (o, &b) in out.iter_mut().zip(src) {
                        *o = self.lut[b as usize];
                    }
                }
            }
            Some(_) => {
                for kk in 0..tk {
                    let row = (k0 + kk) * self.n + n0;
                    let src = &self.bits[row..row + tn];
                    let out = &mut dst[kk * tn..kk * tn + tn];
                    for (cc, (o, &b)) in out.iter_mut().zip(src).enumerate() {
                        *o = self.lut[(n0 + cc) * 256 + b as usize];
                    }
                }
            }
        }
    }
}

/// INT8 variant of [`gemm_f32`]: the identical schedule, weights read
/// as sign-magnitude bytes and dequantized through the table(s).
pub fn gemm_int8(
    x: &[f32],
    w: &QuantizedLinear,
    m: usize,
    mask: Option<&TileMask>,
    tile: usize,
    y: &mut Vec<f32>,
) -> TileStats {
    let (k, n) = (w.k, w.n);
    let (bits, lut) = (&w.bits, &w.lut);
    match &w.col_scales {
        None => gemm_tiled(x, m, k, n, mask, tile, Quant::Int8, y, |kk, c| {
            lut[bits[kk * n + c] as usize]
        }),
        Some(_) => gemm_tiled(x, m, k, n, mask, tile, Quant::Int8, y, |kk, c| {
            lut[c * 256 + bits[kk * n + c] as usize]
        }),
    }
}

/// One weight GEMM of the prepared model: FP32 or kernel-INT8.
#[derive(Clone, Debug)]
pub enum Linear {
    F32 { k: usize, n: usize, w: Vec<f32> },
    Int8(QuantizedLinear),
}

impl Linear {
    pub fn from_f32(w: Vec<f32>, k: usize, n: usize) -> Self {
        assert_eq!(w.len(), k * n);
        Linear::F32 { k, n, w }
    }

    pub fn quantized(w: &[f32], k: usize, n: usize) -> Self {
        Linear::Int8(QuantizedLinear::from_f32(w, k, n))
    }

    pub fn quantized_per_channel(w: &[f32], k: usize, n: usize) -> Self {
        Linear::Int8(QuantizedLinear::from_f32_per_channel(w, k, n))
    }

    pub fn k(&self) -> usize {
        match self {
            Linear::F32 { k, .. } => *k,
            Linear::Int8(q) => q.k,
        }
    }

    pub fn n(&self) -> usize {
        match self {
            Linear::F32 { n, .. } => *n,
            Linear::Int8(q) => q.n,
        }
    }

    /// Run the masked GEMM for `m` input rows.
    pub fn gemm(
        &self,
        x: &[f32],
        m: usize,
        mask: Option<&TileMask>,
        tile: usize,
        y: &mut Vec<f32>,
    ) -> TileStats {
        match self {
            Linear::F32 { k, n, w } => gemm_f32(x, w, m, *k, *n, mask, tile, y),
            Linear::Int8(q) => gemm_int8(x, q, m, mask, tile, y),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GemmKind, GemmShape};
    use crate::quant::fake_quantize;
    use crate::sysim::engine::gemm_on_array;
    use crate::sysim::SimParams;
    use crate::systolic::TileScheduler;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Reference: naive matmul with dead tiles treated as zero weights.
    fn masked_matmul(
        x: &[f32],
        w: &[f32],
        m: usize,
        k: usize,
        n: usize,
        mask: Option<&TileMask>,
        t: usize,
    ) -> Vec<f32> {
        let nt = n.div_ceil(t);
        let mut y = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    let live = mask.map_or(true, |ms| ms.live[(kk / t) * nt + j / t]);
                    if live {
                        acc += x[i * k + kk] * w[kk * n + j];
                    }
                }
                y[i * n + j] = acc;
            }
        }
        y
    }

    fn random_mask(rng: &mut Rng, kt: usize, nt: usize, p_dead: f64) -> TileMask {
        TileMask {
            kt,
            nt,
            live: (0..kt * nt).map(|_| !rng.chance(p_dead)).collect(),
        }
    }

    #[test]
    fn f32_gemm_matches_reference_matmul() {
        check("infer gemm_f32 == masked matmul", 24, |rng: &mut Rng| {
            let t = [2usize, 4, 8][rng.index(3)];
            let m = rng.index(10) + 1;
            let k = rng.index(3 * t) + 1;
            let n = rng.index(3 * t) + 1;
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let mask = random_mask(rng, k.div_ceil(t), n.div_ceil(t), 0.3);
            let mut y = Vec::new();
            let stats = gemm_f32(&x, &w, m, k, n, Some(&mask), t, &mut y);
            let want = masked_matmul(&x, &w, m, k, n, Some(&mask), t);
            let close = y
                .iter()
                .zip(&want)
                .all(|(g, r)| (g - r).abs() <= 1e-5 * r.abs().max(1.0));
            let counts_ok = stats.tiles_live == mask.live_count()
                && stats.tiles_skipped == mask.n_tiles() - mask.live_count();
            (close && counts_ok, format!("t={t} m={m} k={k} n={n}"))
        });
    }

    #[test]
    fn prop_int8_gemm_matches_fake_quantized_f32_oracle() {
        // Satellite property: the INT8 tiled GEMM agrees with the FP32
        // tiled GEMM over fake-quantized weights within 1 ULP of the
        // dequant scale — including on masked (pruned) tiles. By kernel
        // construction they run the identical FP op sequence, so the
        // difference is exactly zero; the ULP bound is the contract.
        check("int8 gemm == fake-quant f32 gemm", 32, |rng: &mut Rng| {
            let t = [2usize, 4, 8][rng.index(3)];
            let m = rng.index(8) + 1;
            let k = rng.index(3 * t) + 1;
            let n = rng.index(3 * t) + 1;
            let scale_pow = rng.index(5) as i32 - 2;
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n)
                .map(|_| (rng.normal() as f32) * 10f32.powi(scale_pow))
                .collect();
            let mask = random_mask(rng, k.div_ceil(t), n.div_ceil(t), 0.4);
            let q = QuantizedLinear::from_f32(&w, k, n);
            let mut got = Vec::new();
            gemm_int8(&x, &q, m, Some(&mask), t, &mut got);
            let mut wfq = Tensor::from_f32(&[k, n], &w);
            let fq_scale = fake_quantize(&mut wfq);
            let mut want = Vec::new();
            gemm_f32(&x, &wfq.f32s(), m, k, n, Some(&mask), t, &mut want);
            let tol = fq_scale.abs() * f32::EPSILON;
            for (g, r) in got.iter().zip(&want) {
                if (g - r).abs() > tol {
                    return (false, format!("t={t} m={m} k={k} n={n}: {g} vs {r}"));
                }
            }
            (q.scale == fq_scale, format!("scale {} vs {}", q.scale, fq_scale))
        });
    }

    #[test]
    fn prop_per_channel_int8_matches_fake_quantized_f32_oracle() {
        // The per-channel INT8 kernel agrees with the FP32 kernel over
        // per-channel fake-quantized weights exactly — both read weight
        // values computed as `to_i8(bits) * scales[c]`, so the FP op
        // sequences are identical.
        use crate::quant::fake_quantize_per_channel;
        check("per-channel int8 gemm == fq f32 gemm", 24, |rng: &mut Rng| {
            let t = [2usize, 4, 8][rng.index(3)];
            let m = rng.index(8) + 1;
            let k = rng.index(3 * t) + 1;
            let n = rng.index(3 * t) + 1;
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let mask = random_mask(rng, k.div_ceil(t), n.div_ceil(t), 0.4);
            let q = QuantizedLinear::from_f32_per_channel(&w, k, n);
            let mut got = Vec::new();
            gemm_int8(&x, &q, m, Some(&mask), t, &mut got);
            let mut wfq = Tensor::from_f32(&[k, n], &w);
            let scales = fake_quantize_per_channel(&mut wfq);
            let mut want = Vec::new();
            gemm_f32(&x, &wfq.f32s(), m, k, n, Some(&mask), t, &mut want);
            if got != want {
                return (false, format!("t={t} m={m} k={k} n={n}"));
            }
            let sc = q.col_scales.as_ref().unwrap();
            (sc == &scales, "col scales diverge from fake-quant".into())
        });
    }

    #[test]
    fn per_channel_dequant_at_and_tile() {
        // Column 1 carries a 10x outlier, so its scale is 10x coarser
        // while column 0 keeps fine resolution.
        let w = vec![1.27f32, 12.7, -0.635, -12.7];
        let q = QuantizedLinear::from_f32_per_channel(&w, 2, 2);
        let sc = q.col_scales.as_ref().unwrap();
        assert!((sc[0] - 0.01).abs() < 1e-6);
        assert!((sc[1] - 0.1).abs() < 1e-6);
        assert!((q.scale - 0.1).abs() < 1e-6, "tensor scale = coarsest column");
        assert!((q.dequant_at(0, 0) - 1.27).abs() < 1e-6);
        assert!((q.dequant_at(1, 1) + 12.7).abs() < 1e-6);
        // dequant_tile reproduces dequant_at over the full grid.
        let mut tile = vec![0.0f32; 4];
        q.dequant_tile(&mut tile, 0, 2, 0, 2);
        for kk in 0..2 {
            for cc in 0..2 {
                assert_eq!(tile[kk * 2 + cc], q.dequant_at(kk, cc));
            }
        }
    }

    #[test]
    fn per_tensor_dequant_tile_matches_dequant_at() {
        let mut rng = Rng::new(17);
        let (k, n) = (6usize, 10usize);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let q = QuantizedLinear::from_f32(&w, k, n);
        let (tk, tn) = (3usize, 4usize);
        let mut tile = vec![0.0f32; tk * tn];
        q.dequant_tile(&mut tile, 2, tk, 5, tn);
        for kk in 0..tk {
            for cc in 0..tn {
                assert_eq!(tile[kk * tn + cc], q.dequant_at(2 + kk, 5 + cc));
            }
        }
    }

    #[test]
    fn dequant_table_matches_sign_magnitude() {
        let w = vec![1.27f32, -1.27, 0.0, 0.635];
        let q = QuantizedLinear::from_f32(&w, 2, 2);
        assert!((q.scale - 0.01).abs() < 1e-6);
        assert!((q.dequant(SignMag8 { sign: false, mag: 127 }.to_bits()) - 1.27).abs() < 1e-6);
        assert!((q.dequant(SignMag8 { sign: true, mag: 127 }.to_bits()) + 1.27).abs() < 1e-6);
        // Negative zero dequantizes to exactly 0.
        assert_eq!(q.dequant(SignMag8 { sign: true, mag: 0 }.to_bits()), 0.0);
    }

    #[test]
    fn stats_match_per_cycle_scheduler_on_identical_masks() {
        // Functional x functional cross-check: same x/w/mask through the
        // native kernel and through the per-cycle TileScheduler must give
        // the same outputs (tolerance: FTZ arithmetic vs plain f32) and
        // the *same* closed-form schedule accounting, exactly.
        let mut rng = Rng::new(41);
        let (t, m, k, n) = (4usize, 6, 16, 12);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mask = random_mask(&mut rng, 4, 3, 0.4);
        let mut y = Vec::new();
        let stats = gemm_f32(&x, &w, m, k, n, Some(&mask), t, &mut y);
        let mut sched = TileScheduler::new(ArrayConfig::square(t, Quant::Fp32));
        let (want, sstats) = sched.gemm(&x, &w, m, k, n, Some(&mask), 1.0);
        for (g, r) in y.iter().zip(&want) {
            assert!((g - r).abs() <= 1e-4 * r.abs().max(1.0), "{g} vs {r}");
        }
        assert_eq!(stats.tiles_live, sstats.tiles_live);
        assert_eq!(stats.tiles_skipped, sstats.tiles_skipped);
        assert_eq!(stats.timing, sstats.timing);
    }

    #[test]
    fn stats_match_analytic_engine_on_identical_masks() {
        // Functional x analytic cross-check: the schedule the native
        // kernel actually executed must equal what the analytic system
        // simulator charges for the same GEMM + mask.
        let mut rng = Rng::new(43);
        let (t, m, k, n) = (8usize, 16, 32, 24);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mask = random_mask(&mut rng, 4, 3, 0.5);
        let g = GemmShape { m, k, n, kind: GemmKind::FeedForward };
        for quant in [Quant::Fp32, Quant::Int8] {
            let cfg = ArrayConfig::square(t, quant);
            let cost = gemm_on_array(&g, &cfg, &SimParams::default(), Some(&mask));
            let mut y = Vec::new();
            let stats = match quant {
                Quant::Fp32 => gemm_f32(&x, &w, m, k, n, Some(&mask), t, &mut y),
                Quant::Int8 => {
                    let q = QuantizedLinear::from_f32(&w, k, n);
                    gemm_int8(&x, &q, m, Some(&mask), t, &mut y)
                }
            };
            assert_eq!(cost.counts.macs, stats.timing.macs as u64, "{quant:?}");
            assert_eq!(
                cost.counts.bus_words,
                stats.timing.total_words() as u64,
                "{quant:?}"
            );
            assert_eq!(
                cost.counts.array_busy_cycles,
                stats.timing.array_cycles as u64,
                "{quant:?}"
            );
        }
    }

    #[test]
    fn dense_equals_full_mask_and_none() {
        let mut rng = Rng::new(3);
        let (t, m, k, n) = (4usize, 5, 8, 8);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut a = Vec::new();
        let mut b = Vec::new();
        let sa = gemm_f32(&x, &w, m, k, n, None, t, &mut a);
        let full = TileMask::full(2, 2);
        let sb = gemm_f32(&x, &w, m, k, n, Some(&full), t, &mut b);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        assert_eq!(sa.tiles_live, 4);
        assert_eq!(sa.sparsity(), 0.0);
    }

    #[test]
    fn fully_pruned_column_is_zero() {
        let mut rng = Rng::new(23);
        let (t, m, k, n) = (4usize, 3, 8, 8);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mask = TileMask { kt: 2, nt: 2, live: vec![false, true, false, true] };
        let mut y = Vec::new();
        let stats = gemm_f32(&x, &w, m, k, n, Some(&mask), t, &mut y);
        for mm in 0..m {
            for cc in 0..t {
                assert_eq!(y[mm * n + cc], 0.0);
            }
        }
        assert!(y.iter().any(|v| *v != 0.0));
        assert_eq!(stats.tiles_skipped, 2);
        assert_eq!(stats.sparsity(), 0.5);
    }

    #[test]
    fn linear_dispatch_consistent() {
        let mut rng = Rng::new(9);
        let (t, m, k, n) = (4usize, 3, 8, 8);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let lin_f = Linear::from_f32(w.clone(), k, n);
        let lin_q = Linear::quantized(&w, k, n);
        assert_eq!((lin_f.k(), lin_f.n()), (k, n));
        assert_eq!((lin_q.k(), lin_q.n()), (k, n));
        let mut a = Vec::new();
        let mut b = Vec::new();
        lin_f.gemm(&x, m, None, t, &mut a);
        lin_q.gemm(&x, m, None, t, &mut b);
        // INT8 roundtrip error bounded by scale/2 per weight, k per output.
        for (g, r) in a.iter().zip(&b) {
            assert!((g - r).abs() < 0.5, "{g} vs {r}");
        }
    }

    #[test]
    fn empty_m_returns_empty() {
        let w = vec![1.0f32; 16];
        let mut y = vec![9.0f32; 3];
        let stats = gemm_f32(&[], &w, 0, 4, 4, None, 4, &mut y);
        assert!(y.is_empty());
        assert_eq!(stats, TileStats::default());
    }
}
